//! hgemms as a service: a leader thread scheduling a stream of GEMM
//! requests over the shared testbed.
//!
//! ```bash
//! cargo run --release --example gemm_service
//! ```
//!
//! The paper frames POAS as infrastructure ("real matrix multiplication
//! workloads arrive" against the stored profile, §4.1.2). This example
//! builds that service shape: a leader thread owns the machine, clients
//! submit heterogeneous GEMM requests over a channel, the leader plans
//! each request with the profiled model (re-using the installation-time
//! profile — no re-profiling per request) and executes them in arrival
//! order, reporting per-request latency and aggregate throughput.

use poas::baselines;
use poas::config::presets;
use poas::coordinator::Pipeline;
use poas::report::Table;
use poas::rng::Rng;
use poas::schedule::suitability::recommend;
use poas::workload::GemmSize;
use std::sync::mpsc;

/// A client request.
struct Request {
    id: usize,
    size: GemmSize,
    reps: u32,
    respond: mpsc::Sender<Response>,
}

/// The leader's answer.
struct Response {
    id: usize,
    makespan: f64,
    virtual_latency: f64,
    shares: Vec<f64>,
    mode: &'static str,
}

fn main() {
    let cfg = presets::mach2();
    let (tx, rx) = mpsc::channel::<Request>();

    // Leader: owns the simulated machine and the profiled model.
    let leader_cfg = cfg.clone();
    let leader = std::thread::spawn(move || {
        let mut pipeline = Pipeline::for_simulated_machine(&leader_cfg, 0);
        let mut virtual_now = 0.0f64; // service-level virtual clock
        while let Ok(req) = rx.recv() {
            // Suitability gate (§6): small requests skip co-execution.
            let rec = recommend(&pipeline.model, req.size, 1.05, 20e-6);
            let (makespan, shares, mode) = if rec.co_execute() {
                let r = pipeline.run_sim(req.size, req.reps);
                (r.makespan, r.plan.shares(), "co-exec")
            } else {
                let dev = match &rec {
                    poas::schedule::Recommendation::Standalone { device, .. } => *device,
                    _ => unreachable!(),
                };
                let o = baselines::standalone(&mut pipeline.sim, dev, req.size, req.reps);
                let mut sh = vec![0.0; 3];
                sh[dev] = 1.0;
                (o.makespan, sh, "standalone")
            };
            virtual_now += makespan;
            let _ = req.respond.send(Response {
                id: req.id,
                makespan,
                virtual_latency: virtual_now,
                shares,
                mode,
            });
        }
    });

    // Clients: submit a mixed workload stream.
    let mut rng = Rng::new(99);
    let (rtx, rrx) = mpsc::channel::<Response>();
    let n_requests = 12;
    for id in 0..n_requests {
        let size = match id % 4 {
            3 => GemmSize::square(256 + rng.below(512)), // too small to co-execute
            0 => GemmSize::square(8_000 + rng.below(8_000)),
            1 => GemmSize::new(
                16_000 + rng.below(16_000),
                4_000 + rng.below(8_000),
                8_000 + rng.below(8_000),
            ),
            _ => GemmSize::new(
                2_000 + rng.below(2_000),
                30_000 + rng.below(10_000),
                8_000 + rng.below(4_000),
            ),
        };
        tx.send(Request {
            id,
            size,
            reps: 10,
            respond: rtx.clone(),
        })
        .unwrap();
    }
    drop(tx);
    drop(rtx);

    let mut responses: Vec<Response> = rrx.iter().collect();
    leader.join().unwrap();
    responses.sort_by_key(|r| r.id);

    let mut t = Table::new(
        "gemm service on mach2 (12 queued requests, 10 reps each)",
        &["req", "mode", "exec", "completion", "cpu/gpu/xpu"],
    );
    let mut total = 0.0f64;
    for r in &responses {
        total = total.max(r.virtual_latency);
        t.row(&[
            format!("#{:02}", r.id),
            r.mode.to_string(),
            format!("{:.2}s", r.makespan),
            format!("{:.2}s", r.virtual_latency),
            format!(
                "{:.1}%/{:.1}%/{:.1}%",
                r.shares[0] * 100.0,
                r.shares[1] * 100.0,
                r.shares[2] * 100.0
            ),
        ]);
    }
    t.print();
    println!(
        "served {n_requests} requests in {total:.2}s of machine time \
         ({:.2}s mean completion)",
        total / n_requests as f64
    );
    assert_eq!(responses.len(), n_requests);
}
