//! hgemms as a service: the first-class `service` subsystem driving a
//! stream of GEMM requests over the shared testbed.
//!
//! ```bash
//! cargo run --release --example gemm_service
//! ```
//!
//! The paper frames POAS as infrastructure ("real matrix multiplication
//! workloads arrive" against the stored profile, §4.1.2). This example
//! is that deployment: client threads submit heterogeneous GEMM
//! requests over a channel; the server owns the machine and the
//! installation-time profile, gates every request through the §6
//! suitability detector, plans through the `PlanCache` (repeated shapes
//! skip the MILP solve), serves in arrival order, and co-schedules
//! small standalone-bound requests on the device its plans leave idle
//! (the bypass — which pairs at dispatch time and therefore shines
//! exactly here, where small jobs queue behind heavy ones; under SPJF
//! the small jobs would simply dispatch first instead). Per-request
//! latency and aggregate throughput come out of the session report.
//!
//! Part 2 then scales the same deployment out: a 2-shard `Cluster`
//! (two machines, each with its own installation-time profile and plan
//! cache) serving an online Poisson arrival trace at ~2x one machine's
//! capacity — earliest-predicted-finish routing, work stealing, and
//! queueing-delay / tail-sojourn metrics under real offered load.
//!
//! Part 3 turns on the QoS tiers: the same 2-shard cluster is
//! *overloaded* by a heavy `Batch` stream while a light, SLO-bound
//! `Interactive` stream rides on top (a per-class Poisson mix). The
//! weighted drain keeps the interactive tail low while batch queues,
//! and deadline-aware admission turns away (or, with
//! `DeadlinePolicy::Downclass`, demotes) requests whose SLO cannot be
//! met — the per-class table shows p50/p99 sojourn per tier and the
//! deadline-hit rate of everything admission let through.
//!
//! Part 4 goes heterogeneous: `Cluster::builder()` assembles a cluster
//! from three *different* machines (GPU-heavy, CPU-only, single-XPU),
//! each profiled independently with its own admission gate, and a
//! bursty Markov-modulated on/off stream arrives. Routing consults each
//! shard's own model, so shapes sort themselves onto the hardware that
//! predicts them fastest; the closing shard table prints per-shard
//! model fingerprints and placement quality (realized vs predicted
//! service time) — the figure CI gates against a committed floor.
//!
//! Part 5 turns on admission-time batching: the same heterogeneous
//! cluster under a small-GEMM flood, once with `BatchPolicy::Off`
//! (every small request bypasses alone onto a single device) and once
//! with `BatchPolicy::Windowed` (compatible smalls wait briefly in a
//! batch window and fuse into one row-stacked co-execution the gate
//! re-scores as a batch). The comparison prints the fusion rate,
//! members per batch, and the throughput delta — the batching band
//! CI's `ci/check_bench.py` gates on.
//!
//! Part 6 loads a declarative scenario: the committed
//! `scenarios/crash_mid_burst.toml` describes a cluster, an arrival
//! mix and a crash/restart schedule in one TOML file; `Scenario::run`
//! executes it on the same event loop and the stable JSON digest it
//! prints is exactly what `scenario_runner` emits for CI's corpus
//! gate (see `docs/scenarios.md`).

use poas::config::presets;
use poas::report::secs;
use poas::rng::Rng;
use poas::service::{
    BatchPolicy, BatchWindow, ClassLoad, Cluster, ClusterOptions, GemmRequest, MixedArrivals,
    OnOffArrivals, PoissonArrivals, QosClass, QueuePolicy, Server, ServerOptions,
};
use poas::workload::GemmSize;
use std::sync::mpsc;

fn main() {
    let cfg = presets::mach2();
    let (tx, rx) = mpsc::channel::<GemmRequest>();

    // Clients: three tenants submit mixed streams concurrently.
    let mut clients = Vec::new();
    for tenant in 0..3u64 {
        let tx = tx.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(99 + tenant);
            for i in 0..4u64 {
                let id = tenant * 100 + i;
                let size = match i % 4 {
                    // Too small to co-execute: the gate sends these
                    // standalone, and the bypass overlaps them with a
                    // neighbour's co-execution.
                    3 => GemmSize::square(256 + rng.below(512)),
                    0 => GemmSize::square(8_000 + rng.below(8_000)),
                    1 => GemmSize::new(
                        16_000 + rng.below(16_000),
                        4_000 + rng.below(8_000),
                        8_000 + rng.below(8_000),
                    ),
                    _ => GemmSize::new(
                        2_000 + rng.below(2_000),
                        30_000 + rng.below(10_000),
                        8_000 + rng.below(4_000),
                    ),
                };
                tx.send(GemmRequest::new(id, size, 10)).unwrap();
            }
        }));
    }
    drop(tx);

    // Leader: one server owns the simulated machine, the profiled
    // model, the plan cache and the queue.
    let mut server = Server::new(
        &cfg,
        0,
        ServerOptions {
            policy: QueuePolicy::Fifo,
            standalone_bypass: true,
            ..Default::default()
        },
    );

    // Admit everything the tenants send, then drain the queue. (A
    // production loop would interleave admission and dispatch; in
    // virtual time the batch drain is equivalent for a fixed admitted
    // set.)
    let mut admitted = 0usize;
    while let Ok(req) = rx.recv() {
        server.submit_request(req);
        admitted += 1;
    }
    for c in clients {
        c.join().unwrap();
    }

    let report = server.run_to_completion();
    report
        .table(&format!(
            "gemm service on {} ({} requests, 10 reps each, FIFO + bypass)",
            cfg.name, admitted
        ))
        .print();
    println!("{}", report.summary());
    println!(
        "bypassed requests: {}   plan-cache hit rate: {:.0}%",
        report.bypassed(),
        100.0 * report.cache_hit_rate()
    );
    assert_eq!(report.served.len(), admitted);

    // ---- Part 2: the same service sharded across two machines, fed by
    // an online Poisson arrival trace instead of a batch drain. Offered
    // load is ~2x what one machine sustained above, so a single shard
    // would queue indefinitely — the second shard absorbs it, and the
    // report finally has real queueing delay to show.
    let offered_rps = 2.0 * report.throughput_rps();
    let menu = vec![
        (GemmSize::square(16_000), 10),
        (GemmSize::square(24_000), 10),
        (GemmSize::square(512), 10),
    ];
    let trace = PoissonArrivals::new(offered_rps, menu, 7).trace(12);
    let mut cluster = Cluster::builder()
        .replicas(&cfg, 2)
        .options(ClusterOptions {
            shard: ServerOptions {
                standalone_bypass: true,
                ..Default::default()
            },
            work_stealing: true,
            ..Default::default()
        })
        .build();
    let ids = cluster.submit_trace(&trace);
    let creport = cluster.run_to_completion();
    println!();
    creport
        .table(&format!(
            "2-shard cluster on {}, Poisson arrivals at {:.2} req/s ({} requests)",
            cfg.name,
            offered_rps,
            ids.len()
        ))
        .print();
    println!("{}", creport.summary());
    println!(
        "mean queue wait: {}   sojourn p50/p99: {} / {}",
        secs(creport.mean_queue_wait()),
        secs(creport.latency_percentile(50.0)),
        secs(creport.latency_percentile(99.0)),
    );
    for (i, s) in creport.shards.iter().enumerate() {
        println!(
            "shard {i}: {} dispatches, busy {}, stole {} request(s)",
            s.dispatches,
            secs(s.busy_s),
            s.stolen
        );
    }
    assert_eq!(creport.served.len(), ids.len());

    // ---- Part 3: QoS tiers under overload. A heavy Batch stream
    // overruns the same 2-shard cluster (~2.5x its capacity) while a
    // light Interactive stream with a sojourn SLO rides on top. The
    // per-class queues drain 4:2:1, so interactive requests keep
    // moving; deadline-aware admission predicts each SLO request's
    // sojourn (shard backlog + service prediction, the router's own
    // numbers) and turns away the ones that would miss.
    let unit = 1.0 / report.throughput_rps(); // ~seconds per heavy request
    let slo = 6.0 * unit;
    let mix = MixedArrivals::new(
        vec![
            ClassLoad {
                class: QosClass::Interactive,
                rate_rps: 0.6 / unit,
                menu: vec![(GemmSize::square(16_000), 10), (GemmSize::square(20_000), 10)],
                deadline_s: Some(slo),
            },
            ClassLoad {
                class: QosClass::Batch,
                rate_rps: 5.0 / unit,
                menu: vec![(GemmSize::square(16_000), 10), (GemmSize::square(24_000), 10)],
                deadline_s: None,
            },
        ],
        21,
    );
    let mut qos_cluster = Cluster::builder().replicas(&cfg, 2).build();
    let qos_ids = qos_cluster.submit_trace(&mix.trace(12));
    let qreport = qos_cluster.run_to_completion();
    println!();
    qreport
        .class_table(&format!(
            "QoS tiers on an overloaded 2-shard cluster ({} requests, interactive SLO {})",
            qos_ids.len(),
            secs(slo)
        ))
        .print();
    println!(
        "interactive p99: {}   batch p99: {}   deadline-hit rate (accepted): {:.0}%   denied: {}",
        secs(qreport.class_latency_percentile(QosClass::Interactive, 99.0)),
        secs(qreport.class_latency_percentile(QosClass::Batch, 99.0)),
        100.0 * qreport.deadline_hit_rate(),
        qreport.denied,
    );
    assert_eq!(qreport.served.len(), qos_ids.len());

    // ---- Part 4: a heterogeneous cluster. Three genuinely different
    // machines — a GPU-heavy node, a CPU-only node and a single-XPU
    // node — each profiled independently at install time, each with its
    // own admission gate. A bursty on/off (Markov-modulated) stream of
    // mixed shapes arrives; routing scores every shard with *that
    // shard's* predictions, so large GEMMs land on the accelerator
    // nodes while tiny ones run on the CPU node's stronger host. The
    // shard table shows the per-shard model fingerprints and placement
    // quality (realized / predicted service time): near 1.0 means the
    // machines honour the predictions that routed the work.
    let mut hetero = Cluster::builder()
        .machine(&presets::gpu_node())
        .machine(&presets::cpu_node())
        .machine(&presets::xpu_node())
        .seed(31)
        .build();
    let bursty = OnOffArrivals::new(
        3.0 / unit, // burst: ~3 heavy requests per service time
        0.3 / unit, // quiet tail
        4.0 * unit,
        8.0 * unit,
        vec![
            (GemmSize::square(20_000), 2),
            (GemmSize::square(16_000), 2),
            (GemmSize::square(448), 2),
        ],
        31,
    );
    let hids = hetero.submit_trace(&bursty.trace(12));
    let hreport = hetero.run_to_completion();
    println!();
    hreport
        .table(&format!(
            "heterogeneous cluster (gpu/cpu/xpu nodes), bursty on/off arrivals ({} requests, {:.1}x burst ratio)",
            hids.len(),
            bursty.rate_ratio()
        ))
        .print();
    hreport
        .shard_table("per-shard models and placement quality")
        .print();
    println!(
        "cluster placement quality: {:.3}   (1.0 = predictions honoured exactly)",
        hreport.placement_quality()
    );
    assert_eq!(hreport.served.len(), hids.len());

    // ---- Part 5: admission-time batching. The suitability gate is
    // *right* to send small GEMMs standalone one at a time — but under
    // a flood of them, that leaves every other accelerator dark. The
    // batch former fuses compatible smalls (same (n, k) shape class,
    // same reps, adjacent QoS classes) into one row-stacked GEMM that
    // is gated, routed and executed as a single unit, copying the
    // shared B operand once instead of once per member. Same trace,
    // batching off versus windowed.
    let small_unit = {
        let mut probe = Server::new(&presets::gpu_node(), 0, ServerOptions::default());
        probe.submit(GemmSize::new(2000, 2000, 2000), 2);
        probe.run_to_completion().makespan
    };
    let flood = PoissonArrivals::new(
        6.0 / small_unit,
        vec![(GemmSize::new(2000, 2000, 2000), 2)],
        41,
    )
    .trace(48);
    let run_batching = |batching: BatchPolicy| {
        let mut c = Cluster::builder()
            .machines(&presets::hetero_mix())
            .seed(41)
            .options(ClusterOptions {
                batching,
                work_stealing: false,
                ..Default::default()
            })
            .build();
        c.submit_trace(&flood);
        c.run_to_completion()
    };
    let b_off = run_batching(BatchPolicy::Off);
    let b_on = run_batching(BatchPolicy::Windowed(BatchWindow {
        window_s: 8.0 * small_unit,
        max_members: 8,
        ..Default::default()
    }));
    println!(
        "\nadmission-time batching, {} small GEMMs on the hetero mix:",
        flood.len()
    );
    println!(
        "  off      : throughput {}   makespan {}",
        poas::report::rate(b_off.throughput_rps()),
        secs(b_off.makespan),
    );
    println!(
        "  windowed : throughput {}   makespan {}   fusion rate {:.0}%   {:.1} members/batch \
         over {} batches",
        poas::report::rate(b_on.throughput_rps()),
        secs(b_on.makespan),
        100.0 * b_on.fusion_rate(),
        b_on.mean_batch_members(),
        b_on.num_batches(),
    );
    println!(
        "  speedup  : {:.2}x throughput from fusing what would have bypassed one at a time",
        b_on.throughput_rps() / b_off.throughput_rps()
    );
    assert_eq!(b_off.served.len(), flood.len());
    assert_eq!(b_on.served.len(), flood.len());
    assert!(b_on.fused() > 0, "the flood must actually fuse");
    assert!(
        b_on.throughput_rps() > b_off.throughput_rps(),
        "batching must not lose throughput on a small-GEMM flood"
    );

    // ---- Part 6: a declarative fault scenario. The whole session —
    // cluster, arrival mix, crash-and-restart schedule — lives in one
    // committed TOML file; running it here and printing the digest
    // shows exactly what the CI corpus gate diffs.
    use poas::service::scenario::{digest, Scenario};
    // The corpus sits at the workspace root; fall back one level so
    // `cargo run --example gemm_service` works from `rust/` too.
    let path = ["scenarios/crash_mid_burst.toml", "../scenarios/crash_mid_burst.toml"]
        .iter()
        .map(std::path::Path::new)
        .find(|p| p.exists())
        .expect("scenarios/crash_mid_burst.toml not found");
    let sc = Scenario::from_file(path).expect("scenario parses");
    let scenario_report = sc.run();
    println!(
        "\nscenario `{}`: {} served, {} requeued by the crash, makespan {}",
        sc.name,
        scenario_report.served.len(),
        scenario_report.requeued,
        secs(scenario_report.makespan),
    );
    println!("  digest: {}", digest(&scenario_report));
    assert_eq!(
        scenario_report.served.len(),
        sc.trace().len(),
        "every scenario arrival must complete exactly once"
    );
    assert!(
        scenario_report.requeued > 0,
        "the mid-burst crash must displace work"
    );
    assert_eq!(
        digest(&scenario_report),
        digest(&sc.run()),
        "scenario replay must be digest-identical"
    );
}
