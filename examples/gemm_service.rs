//! hgemms as a service: the first-class `service` subsystem driving a
//! stream of GEMM requests over the shared testbed.
//!
//! ```bash
//! cargo run --release --example gemm_service
//! ```
//!
//! The paper frames POAS as infrastructure ("real matrix multiplication
//! workloads arrive" against the stored profile, §4.1.2). This example
//! is that deployment: client threads submit heterogeneous GEMM
//! requests over a channel; the server owns the machine and the
//! installation-time profile, gates every request through the §6
//! suitability detector, plans through the `PlanCache` (repeated shapes
//! skip the MILP solve), serves in arrival order, and co-schedules
//! small standalone-bound requests on the device its plans leave idle
//! (the bypass — which pairs at dispatch time and therefore shines
//! exactly here, where small jobs queue behind heavy ones; under SPJF
//! the small jobs would simply dispatch first instead). Per-request
//! latency and aggregate throughput come out of the session report.

use poas::config::presets;
use poas::rng::Rng;
use poas::service::{GemmRequest, QueuePolicy, Server, ServerOptions};
use poas::workload::GemmSize;
use std::sync::mpsc;

fn main() {
    let cfg = presets::mach2();
    let (tx, rx) = mpsc::channel::<GemmRequest>();

    // Clients: three tenants submit mixed streams concurrently.
    let mut clients = Vec::new();
    for tenant in 0..3u64 {
        let tx = tx.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Rng::new(99 + tenant);
            for i in 0..4u64 {
                let id = tenant * 100 + i;
                let size = match i % 4 {
                    // Too small to co-execute: the gate sends these
                    // standalone, and the bypass overlaps them with a
                    // neighbour's co-execution.
                    3 => GemmSize::square(256 + rng.below(512)),
                    0 => GemmSize::square(8_000 + rng.below(8_000)),
                    1 => GemmSize::new(
                        16_000 + rng.below(16_000),
                        4_000 + rng.below(8_000),
                        8_000 + rng.below(8_000),
                    ),
                    _ => GemmSize::new(
                        2_000 + rng.below(2_000),
                        30_000 + rng.below(10_000),
                        8_000 + rng.below(4_000),
                    ),
                };
                tx.send(GemmRequest { id, size, reps: 10 }).unwrap();
            }
        }));
    }
    drop(tx);

    // Leader: one server owns the simulated machine, the profiled
    // model, the plan cache and the queue.
    let mut server = Server::new(
        &cfg,
        0,
        ServerOptions {
            policy: QueuePolicy::Fifo,
            standalone_bypass: true,
            ..Default::default()
        },
    );

    // Admit everything the tenants send, then drain the queue. (A
    // production loop would interleave admission and dispatch; in
    // virtual time the batch drain is equivalent for a fixed admitted
    // set.)
    let mut admitted = 0usize;
    while let Ok(req) = rx.recv() {
        server.submit_request(req);
        admitted += 1;
    }
    for c in clients {
        c.join().unwrap();
    }

    let report = server.run_to_completion();
    report
        .table(&format!(
            "gemm service on {} ({} requests, 10 reps each, FIFO + bypass)",
            cfg.name, admitted
        ))
        .print();
    println!("{}", report.summary());
    println!(
        "bypassed requests: {}   plan-cache hit rate: {:.0}%",
        report.bypassed(),
        100.0 * report.cache_hit_rate()
    );
    assert_eq!(report.served.len(), admitted);
}
