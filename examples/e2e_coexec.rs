//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_coexec
//! ```
//!
//! This is the proof that all layers compose (recorded in
//! EXPERIMENTS.md §End-to-end):
//!
//! 1. **L1/L2 (build time)** — Pallas tiled GEMM kernels were lowered by
//!    `python/compile/aot.py` into the shape-specialized HLO artifacts;
//! 2. **Predict** — the PJRT executables are profiled with wall-clock
//!    microbenchmarks (the same profiler code that measures the
//!    simulator);
//! 3. **Optimize/Adapt/Schedule** — the identical POAS pipeline splits
//!    each workload across the three "devices" (cpu/gpu → f32 artifact
//!    family, xpu → bf16);
//! 4. **L3 execution** — one worker thread per device runs its row band
//!    through its own PJRT client, tiles are padded/accumulated through
//!    the artifact menu, C is assembled and verified against a host
//!    triple-loop reference.
//!
//! Workloads: the paper's Table 3 inputs scaled by 1/100 (so i1 becomes
//! 296x296x296 after 8-alignment — real compute on this host).

use poas::coordinator::PjrtCoordinator;
use poas::metrics::Stopwatch;
use poas::report::Table;
use poas::rng::Rng;
use poas::runtime::ArtifactManifest;
use poas::workload::{scaled_inputs, Matrix};

fn main() {
    let dir = ArtifactManifest::default_dir();
    println!("artifacts: {}", dir.display());

    let sw = Stopwatch::start();
    let coord = match PjrtCoordinator::new(&dir, None) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("cannot start PJRT coordinator: {e}\n(run `make artifacts` first)");
            std::process::exit(1);
        }
    };
    println!("profiled PJRT executables in {:.2}s:", sw.elapsed_s());
    for d in &coord.model.devices {
        println!(
            "  {:>9}: {:8.4} Gops/s (fitted)   prio {}",
            d.name,
            d.rate_tops() * 1e3,
            d.priority
        );
    }

    let mut rng = Rng::new(7);
    let mut table = Table::new(
        "end-to-end co-execution (Table 3 inputs, scaled 1/100)",
        &[
            "input", "m", "n", "k", "split cpu/gpu/xpu", "makespan", "Gops/s", "rel err",
        ],
    );
    let mut total_err: f64 = 0.0;
    for inp in scaled_inputs(100) {
        let (m, n, k) = (
            inp.size.m as usize,
            inp.size.n as usize,
            inp.size.k as usize,
        );
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);
        let run = coord.run(&a, &b, true).expect("co-execution failed");
        let shares = run.plan.shares();
        let err = run.verify_rel_err.unwrap();
        total_err = total_err.max(err);
        table.row(&[
            inp.id.to_string(),
            m.to_string(),
            n.to_string(),
            k.to_string(),
            format!(
                "{:.0}%/{:.0}%/{:.0}%",
                shares[0] * 100.0,
                shares[1] * 100.0,
                shares[2] * 100.0
            ),
            format!("{:.3}s", run.makespan_s),
            format!("{:.3}", inp.size.ops() / run.makespan_s / 1e9),
            format!("{err:.2e}"),
        ]);
    }
    table.print();
    println!(
        "\nall inputs verified against the host reference (worst rel err {total_err:.2e})"
    );
    println!("layers proven: Pallas kernel -> HLO artifact -> PJRT load -> POAS plan -> threaded co-execution -> assembly -> verification");
    assert!(total_err < 2e-2, "verification failed");
}
