//! Dynamic scheduling under thermal drift (paper §3.4.2).
//!
//! ```bash
//! cargo run --release --example dynamic_drift
//! ```
//!
//! mach1's accelerators throttle ~11% under sustained load — the very
//! effect the paper blames for its Table 4 outliers. A static plan built
//! from cold-profile rates keeps over-assigning the XPU once the machine
//! is hot; the dynamic scheduler measures real executions, refreshes the
//! model (EWMA over observed rates) and re-plans.

use poas::config::presets;
use poas::coordinator::Pipeline;
use poas::report::Table;
use poas::workload::GemmSize;

fn main() {
    let cfg = presets::mach1();
    let size = GemmSize::square(30_000);
    let reps = 50;
    let rounds = 6;

    // Static: one plan, reused for every round.
    let mut stat = Pipeline::for_simulated_machine(&cfg, 0);
    let static_plan = stat.plan(size).unwrap();
    let static_times: Vec<f64> = (0..rounds)
        .map(|_| stat.sim.execute(&static_plan.to_work_order(reps)).makespan)
        .collect();

    // Dynamic: observe + re-plan.
    let mut dynp = Pipeline::for_simulated_machine(&cfg, 0);
    let (dynamic_results, sched) = dynp.run_sim_dynamic(size, reps, rounds);

    let mut t = Table::new(
        &format!("static vs dynamic over {rounds} rounds of {size} x{reps} (mach1)"),
        &["round", "static", "dynamic", "xpu share (dyn)"],
    );
    let mut s_total = 0.0;
    let mut d_total = 0.0;
    for i in 0..rounds {
        s_total += static_times[i];
        d_total += dynamic_results[i].makespan;
        t.row(&[
            format!("{}", i + 1),
            format!("{:.2}s", static_times[i]),
            format!("{:.2}s", dynamic_results[i].makespan),
            format!("{:.1}%", dynamic_results[i].plan.shares()[2] * 100.0),
        ]);
    }
    t.print();
    println!("totals: static {s_total:.2}s  dynamic {d_total:.2}s  ({} re-plans)", sched.replans);
    println!(
        "model drift captured: XPU slope moved {:.1}% from the cold profile",
        100.0 * (sched.model.devices[2].a / dynp.model.devices[2].a - 1.0)
    );
    if d_total <= s_total {
        println!("dynamic scheduling recovered {:.2}s ({:.1}%)",
            s_total - d_total, 100.0 * (s_total - d_total) / s_total);
    } else {
        println!("note: drift too small this run for dynamic to pay off");
    }
}
