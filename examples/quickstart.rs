//! Quickstart: schedule and co-execute one GEMM on a simulated testbed.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! This is the 60-second tour: build the simulated `mach2` testbed
//! (AMD EPYC 7413 + RTX 3090 + RTX 2080 Ti from the paper's Table 1),
//! run the Predict phase (profiling microbenchmarks), POAS-plan the
//! paper's i1 input (30K×30K×30K), execute it co-scheduled, and compare
//! with running the same workload on the XPU alone.

use poas::baselines;
use poas::config::presets;
use poas::coordinator::Pipeline;
use poas::report::pct;
use poas::workload::GemmSize;

fn main() {
    // 1. A simulated testbed (the paper's mach2). Seed = "independent
    //    run" identity; the paper averages 3 of these.
    let machine = presets::mach2();
    println!("testbed: {}", machine.name);

    // 2. Predict: profile the machine (square-GEMM sweep + memory
    //    microbenchmark, §4.1.2) and fit the linear performance model.
    let mut pipeline = Pipeline::for_simulated_machine(&machine, 42);
    for d in &pipeline.model.devices {
        println!(
            "  profiled {:>10}: {:6.2} Tops, bw {:5.1} GB/s",
            d.name,
            d.rate_tops(),
            d.bw / 1e9
        );
    }

    // 3. Optimize + Adapt + Schedule: the paper's i1 input, 50 reps.
    let size = GemmSize::new(30_000, 30_000, 30_000);
    let reps = 50;
    let result = pipeline.run_sim(size, reps);

    println!("\nPOAS split for {size}:");
    for (i, share) in result.plan.shares().iter().enumerate() {
        println!(
            "  {:>10}: {} ({} rows)",
            pipeline.model.devices[i].name,
            pct(*share),
            result.plan.assignments[i].rows
        );
    }
    println!(
        "\nco-executed makespan: {:.2}s ({} reps)",
        result.makespan, reps
    );

    // 4. Compare against the fastest single device (Table 7's headline).
    let xpu_alone = baselines::standalone(&mut pipeline.sim, 2, size, reps).makespan;
    println!("XPU standalone:       {xpu_alone:.2}s");
    println!(
        "speedup from ALP co-execution: {:.2}x",
        xpu_alone / result.makespan
    );
}
