//! Energy-objective POAS (paper §3: "minimizing the energy consumption").
//!
//! ```bash
//! cargo run --release --example energy_mode
//! ```
//!
//! The same Predict/Adapt/Schedule machinery with the Optimize phase
//! swapped to the energy LP: minimize joules subject to the same
//! finish-time constraints plus an optional deadline. Sweeping the
//! deadline from "time-optimal" to "unconstrained" traces the
//! energy/time Pareto front of the testbed.

use poas::config::presets;
use poas::optimize::energy::{DevicePower, EnergyProblem};
use poas::optimize::problem::{BusModel, SplitProblem};
use poas::predict::{profile, ProfileOptions};
use poas::report::Table;
use poas::sim::SimMachine;
use poas::workload::GemmSize;

fn main() {
    let cfg = presets::mach1();
    let mut sim = SimMachine::new(&cfg, 0);
    let model = profile(&mut sim, &ProfileOptions::default()).unwrap();
    let size = GemmSize::square(30_000);

    let power: Vec<DevicePower> = cfg
        .devices
        .iter()
        .map(|d| DevicePower {
            active_w: d.active_w,
            idle_w: d.idle_w,
        })
        .collect();

    // Time-optimal makespan = the left end of the Pareto front.
    let t_opt = SplitProblem {
        devices: model.model_inputs(),
        size,
        bus: BusModel::SharedPriority,
        row_integral: false,
    }
    .solve()
    .unwrap()
    .t_pred;

    let mut t = Table::new(
        &format!("energy/time trade-off for {size} on mach1 (per repetition)"),
        &["deadline", "makespan", "energy", "cpu/gpu/xpu split"],
    );
    let mut sweep: Vec<Option<f64>> = (0..=6)
        .map(|i| Some(t_opt * (1.0 + 0.25 * i as f64)))
        .collect();
    sweep.push(None); // unconstrained
    let mut last_energy = f64::INFINITY;
    for deadline in sweep {
        let (sol, energy) = EnergyProblem {
            devices: model.model_inputs(),
            power: power.clone(),
            size,
            bus: BusModel::SharedPriority,
            deadline_s: deadline,
        }
        .solve()
        .unwrap();
        let shares = sol.shares();
        t.row(&[
            deadline
                .map(|d| format!("{d:.2}s"))
                .unwrap_or_else(|| "none".into()),
            format!("{:.2}s", sol.t_pred),
            format!("{energy:.0} J"),
            format!(
                "{:.1}%/{:.1}%/{:.1}%",
                shares[0] * 100.0,
                shares[1] * 100.0,
                shares[2] * 100.0
            ),
        ]);
        assert!(
            energy <= last_energy + 1e-6,
            "energy must fall as the deadline loosens"
        );
        last_energy = energy;
    }
    t.print();
    println!("tight deadlines force co-execution (joule-hungry GPU helps meet T);");
    println!("loose deadlines drain work onto the most efficient device (XPU).");
}
