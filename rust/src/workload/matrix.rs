//! Host-side dense f32 matrices for the real (PJRT) execution path.
//!
//! Row-major storage, with the slicing/assembly operations the
//! coordinator needs: row-band extraction (hgemms splits m), column-band
//! extraction of B/C tiles, padded tile extraction (the artifact menu is
//! square power-of-two tiles), and write-back of computed tiles. A naive
//! triple-loop `matmul` serves as the end-to-end verification oracle.

use crate::rng::Rng;

/// Dense row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Matrix from an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Matrix { rows, cols, data }
    }

    /// Deterministic random matrix with entries in [-1, 1).
    pub fn random(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let data = (0..rows * cols)
            .map(|_| rng.range(-1.0, 1.0) as f32)
            .collect();
        Matrix { rows, cols, data }
    }

    /// Identity (rows == cols).
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow the row-major buffer.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Element accessor (debug-checked).
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Mutable element accessor (debug-checked).
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Copy of rows `[r0, r0+h)` — the hgemms m-split of A or C.
    pub fn row_band(&self, r0: usize, h: usize) -> Matrix {
        assert!(r0 + h <= self.rows, "row band out of range");
        let start = r0 * self.cols;
        Matrix {
            rows: h,
            cols: self.cols,
            data: self.data[start..start + h * self.cols].to_vec(),
        }
    }

    /// Copy of the rectangular block at (`r0`, `c0`) of size `h x w`,
    /// zero-padded to `ph x pw` (artifact tiles are fixed square sizes,
    /// edge tiles are padded — padding with zeros is exact for GEMM).
    pub fn padded_block(&self, r0: usize, c0: usize, h: usize, w: usize, ph: usize, pw: usize) -> Matrix {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "block out of range");
        assert!(ph >= h && pw >= w, "padded size smaller than block");
        let mut out = Matrix::zeros(ph, pw);
        for r in 0..h {
            let src = (r0 + r) * self.cols + c0;
            let dst = r * pw;
            out.data[dst..dst + w].copy_from_slice(&self.data[src..src + w]);
        }
        out
    }

    /// Add the top-left `h x w` corner of `tile` into the block at
    /// (`r0`, `c0`) — tile write-back with padding discarded.
    pub fn add_block(&mut self, r0: usize, c0: usize, h: usize, w: usize, tile: &Matrix) {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "block out of range");
        assert!(tile.rows >= h && tile.cols >= w, "tile smaller than block");
        for r in 0..h {
            let src = r * tile.cols;
            let dst = (r0 + r) * self.cols + c0;
            for c in 0..w {
                self.data[dst + c] += tile.data[src + c];
            }
        }
    }

    /// Overwrite the block at (`r0`, `c0`) with the `h x w` corner of `tile`.
    pub fn set_block(&mut self, r0: usize, c0: usize, h: usize, w: usize, tile: &Matrix) {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "block out of range");
        assert!(tile.rows >= h && tile.cols >= w, "tile smaller than block");
        for r in 0..h {
            let src = r * tile.cols;
            let dst = (r0 + r) * self.cols + c0;
            self.data[dst..dst + w].copy_from_slice(&tile.data[src..src + w]);
        }
    }

    /// Naive triple-loop reference matmul (ikj order for cache behaviour).
    /// Verification oracle only — never on a hot path.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "contraction mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * n..(p + 1) * n];
                let crow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// Max absolute difference to another matrix of the same shape.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative Frobenius-norm difference `||A-B||_F / ||B||_F`.
    pub fn rel_frob_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            num += ((a - b) as f64).powi(2);
            den += (*b as f64).powi(2);
        }
        if den == 0.0 {
            return if num == 0.0 { 0.0 } else { f64::INFINITY };
        }
        (num / den).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(1234)
    }

    #[test]
    fn identity_matmul() {
        let mut r = rng();
        let a = Matrix::random(7, 7, &mut r);
        let i = Matrix::identity(7);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn row_band_roundtrip() {
        let mut r = rng();
        let a = Matrix::random(10, 4, &mut r);
        let band = a.row_band(3, 4);
        assert_eq!(band.rows(), 4);
        for rr in 0..4 {
            for cc in 0..4 {
                assert_eq!(band.get(rr, cc), a.get(rr + 3, cc));
            }
        }
    }

    #[test]
    fn padded_block_zero_fills() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let p = a.padded_block(0, 0, 2, 2, 4, 4);
        assert_eq!(p.get(0, 0), 1.0);
        assert_eq!(p.get(1, 1), 4.0);
        assert_eq!(p.get(2, 2), 0.0);
        assert_eq!(p.get(3, 0), 0.0);
    }

    #[test]
    fn padding_is_exact_for_gemm() {
        // (A|0) @ (B;0) == A @ B — padded tiles give exact products.
        let mut r = rng();
        let a = Matrix::random(3, 5, &mut r);
        let b = Matrix::random(5, 2, &mut r);
        let ap = a.padded_block(0, 0, 3, 5, 8, 8);
        let bp = b.padded_block(0, 0, 5, 2, 8, 8);
        let full = ap.matmul(&bp);
        let want = a.matmul(&b);
        let mut got = Matrix::zeros(3, 2);
        got.set_block(0, 0, 3, 2, &full);
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn add_block_accumulates() {
        let mut c = Matrix::zeros(4, 4);
        let t = Matrix::from_vec(2, 2, vec![1.0; 4]);
        c.add_block(1, 1, 2, 2, &t);
        c.add_block(1, 1, 2, 2, &t);
        assert_eq!(c.get(1, 1), 2.0);
        assert_eq!(c.get(0, 0), 0.0);
    }

    #[test]
    fn split_k_matmul_composes() {
        // A@B == A[:, :k1]@B[:k1, :] + A[:, k1:]@B[k1:, :] — the k-split
        // contract the coordinator relies on.
        let mut r = rng();
        let a = Matrix::random(6, 10, &mut r);
        let b = Matrix::random(10, 5, &mut r);
        let a1 = a.padded_block(0, 0, 6, 4, 6, 4);
        let a2 = a.padded_block(0, 4, 6, 6, 6, 6);
        let b1 = b.padded_block(0, 0, 4, 5, 4, 5);
        let b2 = b.padded_block(4, 0, 6, 5, 6, 5);
        let mut c = Matrix::zeros(6, 5);
        c.add_block(0, 0, 6, 5, &a1.matmul(&b1));
        c.add_block(0, 0, 6, 5, &a2.matmul(&b2));
        assert!(c.max_abs_diff(&a.matmul(&b)) < 1e-4);
    }

    #[test]
    fn rel_frob_diff_zero_for_identical() {
        let mut r = rng();
        let a = Matrix::random(5, 5, &mut r);
        assert_eq!(a.rel_frob_diff(&a), 0.0);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
