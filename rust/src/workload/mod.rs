//! Workloads: GEMM problem sizes (Table 3) and host-side matrices.
//!
//! [`GemmSize`] is the unit the whole framework schedules: a single
//! `C[m,n] = A[m,k] @ B[k,n]` product, with the paper's op count
//! convention `ops = m * n * k` (one op = one multiply-add). [`Matrix`] is
//! the host representation used on the real (PJRT) execution path.

pub mod inputs;
pub mod matrix;

pub use inputs::{paper_inputs, scaled_inputs, PaperInput};
pub use matrix::Matrix;

/// Dimensions of one GEMM: `C[m,n] = A[m,k] @ B[k,n]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmSize {
    pub m: u64,
    pub n: u64,
    pub k: u64,
}

impl GemmSize {
    /// Construct a size; all dimensions must be >= 1.
    pub fn new(m: u64, n: u64, k: u64) -> Self {
        assert!(m >= 1 && n >= 1 && k >= 1, "GEMM dims must be >= 1");
        GemmSize { m, n, k }
    }

    /// Square size `s x s x s`.
    pub fn square(s: u64) -> Self {
        GemmSize::new(s, s, s)
    }

    /// The paper's operation count: `ops = m*n*k` (multiply-adds).
    pub fn ops(&self) -> f64 {
        self.m as f64 * self.n as f64 * self.k as f64
    }

    /// Tera-ops, the unit of Table 3.
    pub fn tops(&self) -> f64 {
        self.ops() / 1e12
    }

    /// FLOPs (2 per multiply-add) — used for roofline arithmetic.
    pub fn flops(&self) -> f64 {
        2.0 * self.ops()
    }

    /// Bytes of A for element size `dt`.
    pub fn a_bytes(&self, dt: u64) -> f64 {
        (self.m * self.k * dt) as f64
    }

    /// Bytes of B for element size `dt`.
    pub fn b_bytes(&self, dt: u64) -> f64 {
        (self.k * self.n * dt) as f64
    }

    /// Bytes of C for element size `dt`.
    pub fn c_bytes(&self, dt: u64) -> f64 {
        (self.m * self.n * dt) as f64
    }

    /// Total working set (A + B + C) in bytes.
    pub fn working_set_bytes(&self, dt: u64) -> f64 {
        self.a_bytes(dt) + self.b_bytes(dt) + self.c_bytes(dt)
    }

    /// A row-slice of this GEMM: the sub-product computing `rows` rows of
    /// C (the paper's hgemms splits only the m dimension, §4.3.1).
    pub fn row_slice(&self, rows: u64) -> GemmSize {
        assert!(rows >= 1 && rows <= self.m, "row slice out of range");
        GemmSize::new(rows, self.n, self.k)
    }

    /// "Squareness" of this (sub-)matrix product per the paper's Eq. 5
    /// numerator term: `min(m,k)/max(m,k)` (n is excluded — it is kept at
    /// its original value by ops_to_mnk).
    pub fn squareness(&self) -> f64 {
        let (lo, hi) = if self.m < self.k {
            (self.m, self.k)
        } else {
            (self.k, self.m)
        };
        lo as f64 / hi as f64
    }
}

impl std::fmt::Display for GemmSize {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.m, self.n, self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_matches_paper_convention() {
        // i1 of Table 3: 30K^3 = 27.0 TOps.
        let s = GemmSize::new(30_000, 30_000, 30_000);
        assert!((s.tops() - 27.0).abs() < 1e-9);
    }

    #[test]
    fn nonsquare_tops() {
        // i2: 60K x 20K x 35K = 42.0 TOps.
        let s = GemmSize::new(60_000, 20_000, 35_000);
        assert!((s.tops() - 42.0).abs() < 1e-9);
    }

    #[test]
    fn byte_accounting() {
        let s = GemmSize::new(4, 6, 8);
        assert_eq!(s.a_bytes(4), (4 * 8 * 4) as f64);
        assert_eq!(s.b_bytes(4), (8 * 6 * 4) as f64);
        assert_eq!(s.c_bytes(2), (4 * 6 * 2) as f64);
        assert_eq!(
            s.working_set_bytes(4),
            s.a_bytes(4) + s.b_bytes(4) + s.c_bytes(4)
        );
    }

    #[test]
    fn row_slice_keeps_n_k() {
        let s = GemmSize::new(100, 50, 25);
        let r = s.row_slice(10);
        assert_eq!(r, GemmSize::new(10, 50, 25));
    }

    #[test]
    #[should_panic]
    fn row_slice_rejects_oversize() {
        GemmSize::new(10, 10, 10).row_slice(11);
    }

    #[test]
    fn squareness_bounds() {
        assert_eq!(GemmSize::square(64).squareness(), 1.0);
        let skinny = GemmSize::new(1000, 10, 10);
        assert!((skinny.squareness() - 0.01).abs() < 1e-12);
    }
}
