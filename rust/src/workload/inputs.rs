//! The paper's evaluation inputs (Table 3) and scaled-down variants.
//!
//! Table 3 defines six matrix sizes, each run 50 times per experiment and
//! averaged over 3 independent runs (§5.1.2). `scaled_inputs` divides all
//! dimensions by a factor so the same *shapes* can be executed for real
//! through the PJRT runtime on this host.

use super::GemmSize;

/// One row of Table 3.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PaperInput {
    /// Paper id: "i1" .. "i6".
    pub id: &'static str,
    /// Matrix dimensions.
    pub size: GemmSize,
    /// Why this shape is in the evaluation (§5.1.2).
    pub rationale: &'static str,
}

/// Number of repetitions per input in the paper's evaluation (§5.1.2).
pub const PAPER_REPS: u32 = 50;

/// Number of independent runs averaged in the paper (§5.1.2).
pub const PAPER_RUNS: u32 = 3;

/// Table 3: the six evaluation inputs, in paper order.
pub fn paper_inputs() -> Vec<PaperInput> {
    vec![
        PaperInput {
            id: "i1",
            size: GemmSize::new(30_000, 30_000, 30_000),
            rationale: "relatively small squared matrix",
        },
        PaperInput {
            id: "i2",
            size: GemmSize::new(60_000, 20_000, 35_000),
            rationale: "larger non-square matrix",
        },
        PaperInput {
            id: "i3",
            size: GemmSize::new(130_000, 20_000, 20_000),
            rationale: "very skinny: m much larger than n, k",
        },
        PaperInput {
            id: "i4",
            size: GemmSize::new(40_000, 80_000, 20_000),
            rationale: "n-dominant shape",
        },
        PaperInput {
            id: "i5",
            size: GemmSize::new(40_000, 30_000, 60_000),
            rationale: "k-dominant shape",
        },
        PaperInput {
            id: "i6",
            size: GemmSize::new(56_000, 40_000, 40_000),
            rationale: "largest product in the list",
        },
    ]
}

/// The Table 3 shapes divided by `factor` (rounded to multiples of 8 so
/// the XPU alignment path stays exercised). Used by the real-execution
/// examples and integration tests.
pub fn scaled_inputs(factor: u64) -> Vec<PaperInput> {
    assert!(factor >= 1);
    paper_inputs()
        .into_iter()
        .map(|p| {
            let scale = |d: u64| ((d / factor).max(8) / 8) * 8;
            PaperInput {
                size: GemmSize::new(scale(p.size.m), scale(p.size.n), scale(p.size.k)),
                ..p
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_tops_column() {
        // The TOps column of Table 3: 27.0, 42.0, 52.0, 64.0, 72.0, 89.6.
        let want = [27.0, 42.0, 52.0, 64.0, 72.0, 89.6];
        for (p, w) in paper_inputs().iter().zip(want) {
            assert!(
                (p.size.tops() - w).abs() < 1e-9,
                "{}: {} != {w}",
                p.id,
                p.size.tops()
            );
        }
    }

    #[test]
    fn table3_is_sorted_by_tops() {
        let inputs = paper_inputs();
        for w in inputs.windows(2) {
            assert!(w[0].size.tops() <= w[1].size.tops());
        }
    }

    #[test]
    fn ids_are_i1_to_i6() {
        let ids: Vec<_> = paper_inputs().iter().map(|p| p.id).collect();
        assert_eq!(ids, ["i1", "i2", "i3", "i4", "i5", "i6"]);
    }

    #[test]
    fn scaled_inputs_are_aligned_and_positive() {
        for f in [1, 100, 1000, 100_000] {
            for p in scaled_inputs(f) {
                assert!(p.size.m >= 8 && p.size.n >= 8 && p.size.k >= 8);
                assert_eq!(p.size.m % 8, 0);
                assert_eq!(p.size.n % 8, 0);
                assert_eq!(p.size.k % 8, 0);
            }
        }
    }

    #[test]
    fn scaled_preserves_relative_shape() {
        let full = paper_inputs();
        let small = scaled_inputs(100);
        // i3 stays the m-dominant input after scaling.
        assert!(small[2].size.m > small[2].size.n * 5);
        assert_eq!(full[2].id, small[2].id);
    }
}
