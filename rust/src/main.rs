//! `poas` — CLI for the POAS/hgemms reproduction.
//!
//! Subcommands:
//!
//! * `info` — testbed presets and artifact menu;
//! * `profile` — run the Predict phase on a simulated machine and print
//!   (or save) the fitted performance model;
//! * `plan` — profile + optimize + adapt a workload and print the split;
//! * `run` — full simulated co-execution, with standalone baselines;
//! * `pjrt` — real co-execution of a small GEMM through the AOT
//!   artifacts, with verification;
//! * `bus` — the Fig. 2 predicted bus timeline.
//!
//! Argument parsing is hand-rolled (the offline build has no clap); see
//! `Args` below.

use poas::baselines;
use poas::config::{presets, MachineConfig};
use poas::coordinator::{Pipeline, PjrtCoordinator};
use poas::report::{pct, secs, times, Table};
use poas::runtime::ArtifactManifest;
use poas::schedule::comm::{predicted_timeline, render_ascii};
use poas::workload::{GemmSize, Matrix};

/// Tiny argument cursor: positional subcommand + `--key value` flags.
struct Args {
    flags: Vec<(String, String)>,
    positional: Vec<String>,
}

impl Args {
    fn parse() -> Self {
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut it = std::env::args().skip(1).peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = if it.peek().map(|v| !v.starts_with("--")).unwrap_or(false) {
                    it.next().unwrap()
                } else {
                    "true".to_string()
                };
                flags.push((key.to_string(), val));
            } else {
                positional.push(a);
            }
        }
        Args { flags, positional }
    }

    fn flag(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn machine(&self) -> MachineConfig {
        match self.flag("machine").unwrap_or("mach1") {
            "mach1" => presets::mach1(),
            "mach2" => presets::mach2(),
            path => MachineConfig::from_file(std::path::Path::new(path))
                .unwrap_or_else(|e| die(&format!("cannot load machine config `{path}`: {e}"))),
        }
    }

    fn size(&self) -> GemmSize {
        let parse = |k: &str, d: u64| {
            self.flag(k)
                .map(|v| v.parse().unwrap_or_else(|_| die(&format!("bad --{k}"))))
                .unwrap_or(d)
        };
        GemmSize::new(parse("m", 30_000), parse("n", 30_000), parse("k", 30_000))
    }

    fn reps(&self) -> u32 {
        self.flag("reps")
            .map(|v| v.parse().unwrap_or_else(|_| die("bad --reps")))
            .unwrap_or(50)
    }

    fn seed(&self) -> u64 {
        self.flag("seed")
            .map(|v| v.parse().unwrap_or_else(|_| die("bad --seed")))
            .unwrap_or(0)
    }
}

fn die(msg: &str) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(2);
}

const USAGE: &str = "\
poas — POAS (Predict, Optimize, Adapt, Schedule) reproduction

USAGE: poas <command> [--machine mach1|mach2|<config.toml>] [flags]

COMMANDS:
  info                       testbed presets + artifact menu
  profile [--save FILE]      run the Predict phase, print the fitted model
  plan    [--m --n --k]      print the optimized work split for a GEMM
  run     [--m --n --k --reps --seed]
                             simulated co-execution + standalone baselines
  pjrt    [--m --n --k]      real co-execution through the AOT artifacts
  bus     [--m --n --k]      predicted Fig.2 bus timeline (ASCII)
  suit    [--m --n --k --min-gain]
                             co-execution suitability + crossover size
";

fn main() {
    let args = Args::parse();
    match args.positional.first().map(String::as_str) {
        Some("info") => cmd_info(&args),
        Some("profile") => cmd_profile(&args),
        Some("plan") => cmd_plan(&args),
        Some("run") => cmd_run(&args),
        Some("pjrt") => cmd_pjrt(&args),
        Some("bus") => cmd_bus(&args),
        Some("suit") => cmd_suit(&args),
        _ => print!("{USAGE}"),
    }
}

fn cmd_info(args: &Args) {
    let cfg = args.machine();
    let mut t = Table::new(
        &format!("machine `{}` (Table 1/2 analogue)", cfg.name),
        &["device", "kind", "model", "eff TOps", "bus GB/s", "mem GiB"],
    );
    for d in &cfg.devices {
        t.row(&[
            d.name.clone(),
            d.kind.as_str().to_string(),
            d.model.clone(),
            format!("{:.3}", d.eff_rate_tops),
            format!("{:.2}", d.bus_bw_gbs),
            format!("{:.0}", d.mem_gib),
        ]);
    }
    t.print();
    match ArtifactManifest::load(&ArtifactManifest::default_dir()) {
        Ok(m) => {
            println!(
                "\nartifacts: {} entries in {}",
                m.entries.len(),
                m.dir.display()
            );
            for kind in ["f32", "bf16", "acc_f32", "acc_bf16"] {
                println!("  {kind}: tiles {:?}", m.tile_menu(kind));
            }
        }
        Err(e) => println!("\nartifacts: unavailable ({e})"),
    }
}

fn cmd_profile(args: &Args) {
    let cfg = args.machine();
    let p = Pipeline::for_simulated_machine(&cfg, args.seed());
    print!("{}", p.model.to_text());
    if let Some(path) = args.flag("save") {
        p.model
            .save(std::path::Path::new(path))
            .unwrap_or_else(|e| die(&e.to_string()));
        eprintln!("saved to {path}");
    }
}

fn cmd_plan(args: &Args) {
    let cfg = args.machine();
    let p = Pipeline::for_simulated_machine(&cfg, args.seed());
    let size = args.size();
    let plan = p.plan(size).unwrap_or_else(|e| die(&e.to_string()));
    let mut t = Table::new(
        &format!("plan for {size} on {}", cfg.name),
        &["device", "share", "rows", "tiles", "pred compute", "pred copy"],
    );
    for (i, a) in plan.assignments.iter().enumerate() {
        t.row(&[
            p.model.devices[i].name.clone(),
            pct(plan.shares()[i]),
            a.rows.to_string(),
            a.subproducts.len().to_string(),
            secs(plan.predicted.compute_pred[i]),
            secs(plan.predicted.copy_pred[i]),
        ]);
    }
    t.print();
    println!("predicted makespan/rep: {}", secs(plan.predicted_makespan()));
}

fn cmd_run(args: &Args) {
    let cfg = args.machine();
    let mut p = Pipeline::for_simulated_machine(&cfg, args.seed());
    let size = args.size();
    let reps = args.reps();
    let r = p.run_sim(size, reps);
    let mut t = Table::new(
        &format!("co-execution of {size} x{reps} on {}", cfg.name),
        &["device", "share", "compute", "copy", "bus wait", "finish"],
    );
    for (i, tl) in r.exec.timelines.iter().enumerate() {
        t.row(&[
            p.model.devices[i].name.clone(),
            pct(r.plan.shares()[i]),
            secs(tl.compute_s),
            secs(tl.copy_s()),
            secs(tl.bus_wait_s),
            secs(tl.finish),
        ]);
    }
    t.print();
    println!(
        "makespan {}   energy {:.1} kJ   avg power {:.0} W",
        secs(r.makespan),
        r.exec.energy.total_j / 1e3,
        r.exec.energy.avg_power_w()
    );
    // Standalone baselines (Table 7 comparison).
    let mut t = Table::new("speedup vs standalone", &["device", "standalone", "speedup"]);
    for dev in 0..cfg.devices.len() {
        let alone = baselines::standalone(&mut p.sim, dev, size, reps).makespan;
        t.row(&[
            p.model.devices[dev].name.clone(),
            secs(alone),
            times(alone / r.makespan),
        ]);
    }
    t.print();
}

fn cmd_pjrt(args: &Args) {
    let dir = ArtifactManifest::default_dir();
    let coord = PjrtCoordinator::new(&dir, None).unwrap_or_else(|e| die(&e.to_string()));
    let m = args.flag("m").map(|v| v.parse().unwrap()).unwrap_or(256usize);
    let n = args.flag("n").map(|v| v.parse().unwrap()).unwrap_or(192usize);
    let k = args.flag("k").map(|v| v.parse().unwrap()).unwrap_or(224usize);
    let mut rng = poas::rng::Rng::new(args.seed());
    let a = Matrix::random(m, k, &mut rng);
    let b = Matrix::random(k, n, &mut rng);
    println!("co-executing {m}x{n}x{k} through PJRT artifacts...");
    let run = coord.run(&a, &b, true).unwrap_or_else(|e| die(&e.to_string()));
    let mut t = Table::new("real co-execution", &["device", "rows", "tiles", "compute"]);
    for d in &run.devices {
        t.row(&[
            d.name.clone(),
            d.rows.to_string(),
            d.tiles.to_string(),
            secs(d.compute_s),
        ]);
    }
    t.print();
    println!(
        "makespan {}   verification rel err {:.2e}",
        secs(run.makespan_s),
        run.verify_rel_err.unwrap()
    );
}

fn cmd_suit(args: &Args) {
    use poas::schedule::suitability::{coexec_crossover, recommend, Recommendation};
    let cfg = args.machine();
    let p = Pipeline::for_simulated_machine(&cfg, args.seed());
    let size = args.size();
    let min_gain: f64 = args
        .flag("min-gain")
        .map(|v| v.parse().unwrap_or_else(|_| die("bad --min-gain")))
        .unwrap_or(1.05);
    match recommend(&p.model, size, min_gain, 20e-6) {
        Recommendation::CoExecute {
            t_coexec,
            t_best_single,
            best_device,
            gain,
        } => println!(
            "{size} on {}: CO-EXECUTE — predicted {} vs best single ({}) {}, gain {}",
            cfg.name,
            secs(t_coexec),
            p.model.devices[best_device].name,
            secs(t_best_single),
            times(gain)
        ),
        Recommendation::Standalone {
            device,
            t_single,
            t_coexec,
        } => println!(
            "{size} on {}: STANDALONE on {} — {} beats co-execution ({})",
            cfg.name,
            p.model.devices[device].name,
            secs(t_single),
            secs(t_coexec)
        ),
    }
    let cross = coexec_crossover(&p.model, min_gain, 20e-6);
    println!(
        "co-execution crossover (square GEMM, gain >= {times_g}): ~{cross}^3",
        times_g = times(min_gain)
    );
}

fn cmd_bus(args: &Args) {
    let cfg = args.machine();
    let p = Pipeline::for_simulated_machine(&cfg, args.seed());
    let size = args.size();
    let plan = p.plan(size).unwrap_or_else(|e| die(&e.to_string()));
    let tl = predicted_timeline(&plan, &p.model);
    let names: Vec<String> = p.model.devices.iter().map(|d| d.name.clone()).collect();
    println!("predicted Fig.2 timeline for {size} on {} (one repetition):\n", cfg.name);
    print!("{}", render_ascii(&tl, &names, 72));
}
