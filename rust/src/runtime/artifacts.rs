//! AOT artifact discovery: the manifest written by `python/compile/aot.py`.
//!
//! Artifacts are shape-specialized HLO-text files, one per (device-class
//! kernel, square tile size). The manifest row format is
//! `name kind m n k n_inputs file` — see `aot.py`.

use crate::error::{Error, Result};
use std::path::{Path, PathBuf};

/// One artifact entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactEntry {
    /// Unique name, e.g. `gemm_f32_128`.
    pub name: String,
    /// Kernel family: `f32`, `bf16`, `acc_f32`, `acc_bf16`.
    pub kind: String,
    /// Tile dimensions (square menu: m == n == k).
    pub m: u64,
    pub n: u64,
    pub k: u64,
    /// Number of HLO entry parameters (2 or 3).
    pub n_inputs: u32,
    /// HLO text file path (absolute).
    pub path: PathBuf,
}

/// The parsed artifact menu.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    pub entries: Vec<ArtifactEntry>,
    pub dir: PathBuf,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let mpath = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&mpath).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                mpath.display()
            ))
        })?;
        let mut entries = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 7 {
                return Err(Error::Runtime(format!(
                    "manifest line {}: expected 7 fields, got {}",
                    ln + 1,
                    f.len()
                )));
            }
            let parse_u64 = |s: &str, what: &str| -> Result<u64> {
                s.parse()
                    .map_err(|_| Error::Runtime(format!("manifest line {}: bad {what} `{s}`", ln + 1)))
            };
            entries.push(ArtifactEntry {
                name: f[0].to_string(),
                kind: f[1].to_string(),
                m: parse_u64(f[2], "m")?,
                n: parse_u64(f[3], "n")?,
                k: parse_u64(f[4], "k")?,
                n_inputs: parse_u64(f[5], "n_inputs")? as u32,
                path: dir.join(f[6]),
            });
        }
        if entries.is_empty() {
            return Err(Error::Runtime("manifest has no artifacts".into()));
        }
        Ok(ArtifactManifest {
            entries,
            dir: dir.to_path_buf(),
        })
    }

    /// Default artifact directory: `$POAS_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("POAS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Entry lookup by kernel family and tile size.
    pub fn find(&self, kind: &str, tile: u64) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.m == tile)
    }

    /// Sorted tile sizes available for a kernel family.
    pub fn tile_menu(&self, kind: &str) -> Vec<u64> {
        let mut v: Vec<u64> = self
            .entries
            .iter()
            .filter(|e| e.kind == kind)
            .map(|e| e.m)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Pick the menu tile that minimizes padded work for a sub-product
    /// of shape (m, n, k): cost = #tiles * tile³. Ties prefer the larger
    /// tile (fewer kernel launches).
    pub fn best_tile(&self, kind: &str, m: u64, n: u64, k: u64) -> Option<u64> {
        let menu = self.tile_menu(kind);
        menu.into_iter().min_by(|&a, &b| {
            let cost = |t: u64| {
                let tiles = m.div_ceil(t) * n.div_ceil(t) * k.div_ceil(t);
                (tiles * t * t * t) as f64
            };
            cost(a)
                .total_cmp(&cost(b))
                .then(b.cmp(&a)) // tie: larger tile first
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn temp_manifest(rows: &str) -> ArtifactManifest {
        let dir = std::env::temp_dir().join(format!(
            "poas-test-manifest-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.txt")).unwrap();
        writeln!(f, "# name kind m n k n_inputs file").unwrap();
        write!(f, "{rows}").unwrap();
        ArtifactManifest::load(&dir).unwrap()
    }

    fn sample() -> ArtifactManifest {
        temp_manifest(
            "gemm_f32_64 f32 64 64 64 2 gemm_f32_64.hlo.txt\n\
             gemm_f32_128 f32 128 128 128 2 gemm_f32_128.hlo.txt\n\
             gemm_f32_256 f32 256 256 256 2 gemm_f32_256.hlo.txt\n\
             gemm_bf16_128 bf16 128 128 128 2 gemm_bf16_128.hlo.txt\n\
             gemm_acc_f32_128 acc_f32 128 128 128 3 gemm_acc_f32_128.hlo.txt\n",
        )
    }

    #[test]
    fn load_and_lookup() {
        let m = sample();
        assert_eq!(m.entries.len(), 5);
        let e = m.find("f32", 128).unwrap();
        assert_eq!(e.name, "gemm_f32_128");
        assert_eq!(e.n_inputs, 2);
        assert!(m.find("f32", 512).is_none());
        assert!(m.find("int8", 128).is_none());
    }

    #[test]
    fn tile_menu_sorted() {
        let m = sample();
        assert_eq!(m.tile_menu("f32"), vec![64, 128, 256]);
        assert_eq!(m.tile_menu("bf16"), vec![128]);
        assert!(m.tile_menu("nope").is_empty());
    }

    #[test]
    fn best_tile_minimizes_padding() {
        let m = sample();
        // 64-cube: tile 64 exactly (cost 64^3) beats 128 (128^3).
        assert_eq!(m.best_tile("f32", 64, 64, 64), Some(64));
        // 128-cube: 128 exact; 64 also exact (8 tiles) -> tie on cost,
        // larger preferred.
        assert_eq!(m.best_tile("f32", 128, 128, 128), Some(128));
        // 65^3: 64-tiles cost 8*64^3=2^21*... vs 128: 128^3. 8*262144 =
        // 2,097,152 = 128^3 exactly -> tie -> 128.
        assert_eq!(m.best_tile("f32", 65, 65, 65), Some(128));
        // 192: 64 divides -> 27*64^3 < padding alternatives.
        assert_eq!(m.best_tile("f32", 192, 192, 192), Some(64));
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("poas-no-such-dir-xyz");
        assert!(ArtifactManifest::load(&dir).is_err());
    }

    #[test]
    fn malformed_rows_error() {
        let dir = std::env::temp_dir().join(format!("poas-bad-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "gemm f32 64\n").unwrap();
        assert!(ArtifactManifest::load(&dir).is_err());
    }
}
