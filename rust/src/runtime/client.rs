//! PJRT execution of the AOT artifacts.
//!
//! The request-path compute engine: loads HLO text produced by
//! `python/compile/aot.py`, compiles it once on the PJRT CPU client, and
//! executes tiles from the L3 hot path. Python is never involved here.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`, with the 1-tuple unwrap required by the
//! `return_tuple=True` lowering.

use super::artifacts::ArtifactManifest;
use crate::error::{Error, Result};
use crate::workload::Matrix;
use std::collections::HashMap;
use std::path::Path;

// The offline build carries no external crates: without the `pjrt`
// feature, the `xla` name resolves to the in-tree stub, which
// type-checks identically and fails at `PjRtClient::cpu()`. With the
// feature (and the `xla` dependency added to Cargo.toml), the real
// bindings take over and the stub is compiled out.
#[cfg(not(feature = "pjrt"))]
use super::pjrt_stub as xla;

/// A PJRT client with a compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Compiles performed (diagnostics: cache effectiveness).
    pub compiles: usize,
    /// Tile executions performed.
    pub executions: usize,
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("artifacts", &self.manifest.dir)
            .field("cached_exes", &self.exes.len())
            .field("compiles", &self.compiles)
            .field("executions", &self.executions)
            .finish()
    }
}

impl Runtime {
    /// Create a CPU PJRT client over the artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = ArtifactManifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu failed: {e:?}")))?;
        Ok(Runtime {
            client,
            manifest,
            exes: HashMap::new(),
            compiles: 0,
            executions: 0,
        })
    }

    /// The artifact menu.
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling and caching on first use) the executable for a
    /// kernel family + tile size.
    fn executable(&mut self, kind: &str, tile: u64) -> Result<&xla::PjRtLoadedExecutable> {
        let entry = self
            .manifest
            .find(kind, tile)
            .ok_or_else(|| Error::Runtime(format!("no artifact for kind={kind} tile={tile}")))?
            .clone();
        if !self.exes.contains_key(&entry.name) {
            let proto = xla::HloModuleProto::from_text_file(
                entry.path.to_str().ok_or_else(|| {
                    Error::Runtime(format!("non-utf8 path {}", entry.path.display()))
                })?,
            )
            .map_err(|e| {
                Error::Runtime(format!("parse {} failed: {e:?}", entry.path.display()))
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {} failed: {e:?}", entry.name)))?;
            self.compiles += 1;
            self.exes.insert(entry.name.clone(), exe);
        }
        Ok(&self.exes[&entry.name])
    }

    /// Pre-compile every artifact of a kernel family (warm-up, so the
    /// first scheduled tile does not pay the compile).
    pub fn warmup(&mut self, kind: &str) -> Result<usize> {
        let tiles = self.manifest.tile_menu(kind);
        for t in &tiles {
            self.executable(kind, *t)?;
        }
        Ok(tiles.len())
    }

    fn literal_from_matrix(m: &Matrix) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(m.as_slice());
        lit.reshape(&[m.rows() as i64, m.cols() as i64])
            .map_err(|e| Error::Runtime(format!("literal reshape failed: {e:?}")))
    }

    fn matrix_from_result(result: xla::Literal, rows: usize, cols: usize) -> Result<Matrix> {
        let tuple = result
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("tuple unwrap failed: {e:?}")))?;
        let data = tuple
            .to_vec::<f32>()
            .map_err(|e| Error::Runtime(format!("literal to_vec failed: {e:?}")))?;
        if data.len() != rows * cols {
            return Err(Error::Runtime(format!(
                "result size {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Matrix::from_vec(rows, cols, data))
    }

    /// Execute one square tile product `C = A @ B` (both `tile x tile`)
    /// through the `kind` kernel family.
    pub fn run_tile(&mut self, kind: &str, tile: u64, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        let t = tile as usize;
        if a.rows() != t || a.cols() != t || b.rows() != t || b.cols() != t {
            return Err(Error::Runtime(format!(
                "run_tile expects {t}x{t} operands, got {}x{} and {}x{}",
                a.rows(),
                a.cols(),
                b.rows(),
                b.cols()
            )));
        }
        let la = Self::literal_from_matrix(a)?;
        let lb = Self::literal_from_matrix(b)?;
        let exe = self.executable(kind, tile)?;
        let result = exe
            .execute::<xla::Literal>(&[la, lb])
            .map_err(|e| Error::Runtime(format!("execute failed: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("to_literal_sync failed: {e:?}")))?;
        self.executions += 1;
        Self::matrix_from_result(result, t, t)
    }

    /// Execute one accumulating tile `C = A @ B + C_in` through the
    /// `acc_<kind>` family.
    pub fn run_tile_acc(
        &mut self,
        kind: &str,
        tile: u64,
        a: &Matrix,
        b: &Matrix,
        c_in: &Matrix,
    ) -> Result<Matrix> {
        let t = tile as usize;
        let acc_kind = format!("acc_{kind}");
        let la = Self::literal_from_matrix(a)?;
        let lb = Self::literal_from_matrix(b)?;
        let lc = Self::literal_from_matrix(c_in)?;
        let exe = self.executable(&acc_kind, tile)?;
        let result = exe
            .execute::<xla::Literal>(&[la, lb, lc])
            .map_err(|e| Error::Runtime(format!("execute failed: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("to_literal_sync failed: {e:?}")))?;
        self.executions += 1;
        Self::matrix_from_result(result, t, t)
    }

    /// Compute a general `C[m,n] = A[m,k] @ B[k,n]` by tiling over the
    /// artifact menu: pick the best tile size, pad edge blocks with
    /// zeros (exact for GEMM), and chain k-chunks through the
    /// accumulating kernel so partial sums stay in the XLA graph.
    pub fn run_gemm(&mut self, kind: &str, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        let (m, k) = (a.rows(), a.cols());
        let (k2, n) = (b.rows(), b.cols());
        if k != k2 {
            return Err(Error::Runtime(format!(
                "contraction mismatch: {m}x{k} @ {k2}x{n}"
            )));
        }
        let tile = self
            .manifest
            .best_tile(kind, m as u64, n as u64, k as u64)
            .ok_or_else(|| Error::Runtime(format!("no tiles for kind={kind}")))?
            as usize;

        let mut c = Matrix::zeros(m, n);
        for i0 in (0..m).step_by(tile) {
            let h = tile.min(m - i0);
            for j0 in (0..n).step_by(tile) {
                let w = tile.min(n - j0);
                let mut acc: Option<Matrix> = None;
                for p0 in (0..k).step_by(tile) {
                    let d = tile.min(k - p0);
                    let at = a.padded_block(i0, p0, h, d, tile, tile);
                    let bt = b.padded_block(p0, j0, d, w, tile, tile);
                    acc = Some(match acc {
                        None => self.run_tile(kind, tile as u64, &at, &bt)?,
                        Some(prev) => {
                            self.run_tile_acc(kind, tile as u64, &at, &bt, &prev)?
                        }
                    });
                }
                c.set_block(i0, j0, h, w, &acc.expect("k >= 1 guarantees one chunk"));
            }
        }
        Ok(c)
    }
}
