//! The PJRT runtime: real execution of the AOT-compiled GEMM artifacts.
//!
//! This is the Rust end of the three-layer stack:
//!
//! 1. Pallas kernels (`python/compile/kernels/`) are the compute;
//! 2. the JAX tile functions (`python/compile/model.py`) wrap them and
//!    are lowered to HLO text once at build time (`make artifacts`);
//! 3. this module loads those artifacts through the PJRT C API (`xla`
//!    crate), compiles them once per process, and executes square tiles
//!    from the scheduler's hot path — Python never runs here.
//!
//! * [`artifacts`] — manifest parsing + tile-menu selection;
//! * [`client`] — the PJRT client, executable cache, and the padded/
//!   accumulating tiled-GEMM driver.

pub mod artifacts;
pub mod client;
#[cfg(not(feature = "pjrt"))]
pub mod pjrt_stub;

pub use artifacts::{ArtifactEntry, ArtifactManifest};
pub use client::Runtime;
