//! Offline stand-in for the external `xla` crate (PJRT bindings).
//!
//! The repository builds with zero external dependencies; the real PJRT
//! backend needs the `xla` crate, which is not vendored. This module
//! mirrors exactly the slice of its API that [`super::client`] uses, so
//! the whole runtime path type-checks everywhere — and fails cleanly at
//! *client construction* ([`PjRtClient::cpu`] returns an error) instead
//! of at compile time. Artifact-dependent tests and CLI commands already
//! handle that failure (they skip or report "artifacts unavailable").
//!
//! To run against real PJRT: add the `xla` dependency to `Cargo.toml`,
//! enable the `pjrt` feature, and `super::client` switches to the real
//! crate — this file is then compiled out.

/// Error value for every stub operation.
#[derive(Debug)]
pub struct PjrtUnavailable;

impl std::fmt::Display for PjrtUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PJRT backend not compiled in (offline build; enable the `pjrt` \
             feature with the `xla` dependency)"
        )
    }
}

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    /// Always fails: there is no PJRT runtime in this build.
    pub fn cpu() -> Result<PjRtClient, PjrtUnavailable> {
        Err(PjrtUnavailable)
    }

    /// Platform id of the stub.
    pub fn platform_name(&self) -> String {
        "pjrt-stub".to_string()
    }

    /// Unreachable in practice (`cpu()` never yields a client).
    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, PjrtUnavailable> {
        Err(PjrtUnavailable)
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, PjrtUnavailable> {
        Err(PjrtUnavailable)
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    /// Trivial conversion (never executed against real hardware).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of `xla::Literal`.
pub struct Literal;

impl Literal {
    /// Host buffer wrapper (inert in the stub).
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Always fails in the stub.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, PjrtUnavailable> {
        Err(PjrtUnavailable)
    }

    /// Always fails in the stub.
    pub fn to_tuple1(self) -> Result<Literal, PjrtUnavailable> {
        Err(PjrtUnavailable)
    }

    /// Always fails in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, PjrtUnavailable> {
        Err(PjrtUnavailable)
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Always fails in the stub.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, PjrtUnavailable> {
        Err(PjrtUnavailable)
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Always fails in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal, PjrtUnavailable> {
        Err(PjrtUnavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_fails_closed() {
        let err = PjRtClient::cpu().err().expect("stub must not build a client");
        let msg = format!("{err} / {err:?}");
        assert!(msg.contains("PJRT"));
    }

    #[test]
    fn stub_surface_matches_usage() {
        // The inert pieces used before the first failing call.
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[1, 2]).is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(PjRtLoadedExecutable.execute::<Literal>(&[]).is_err());
        assert!(Literal.to_vec::<f32>().is_err());
        assert!(Literal.to_tuple1().is_err());
    }
}
