//! Run the committed scenario corpus and emit per-scenario digests.
//!
//! ```text
//! scenario_runner [--out FILE] [--jobs N] [PATH ...]
//! ```
//!
//! Each `PATH` is a scenario file or a directory (expanded to its
//! `*.toml` entries, sorted by file name); with no paths the runner
//! looks for `scenarios/`, falling back to `../scenarios/` so
//! `cargo run --bin scenario_runner` works from `rust/` too. Scenarios
//! execute on a scoped thread pool of `--jobs` workers (default: the
//! machine's available parallelism) — each scenario is deterministic
//! in isolation and the output is assembled in sorted order from a
//! per-scenario slot, so the JSON is **byte-identical to a serial
//! run** regardless of the job count. The output is one JSON object
//! mapping scenario name to its digest (see
//! [`poas::service::scenario::digest`]), keys sorted, one digest per
//! line — CI diffs it against the blessed `ci/scenario_digests.json`
//! (see `docs/scenarios.md` for the blessing workflow). Any parse or
//! I/O error, duplicate scenario name or empty corpus exits non-zero.

use poas::service::scenario::{digest, Scenario};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("scenario_runner: {e}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<(), String> {
    let mut out: Option<PathBuf> = None;
    let mut jobs: Option<usize> = None;
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--out" => {
                let f = it.next().ok_or("--out needs a file argument")?;
                out = Some(PathBuf::from(f));
            }
            "--jobs" => {
                let n = it.next().ok_or("--jobs needs a count argument")?;
                let n = n
                    .parse::<usize>()
                    .map_err(|_| format!("--jobs: bad count `{n}`"))?;
                if n == 0 {
                    return Err("--jobs must be >= 1".into());
                }
                jobs = Some(n);
            }
            "--help" | "-h" => {
                println!("usage: scenario_runner [--out FILE] [--jobs N] [PATH ...]");
                return Ok(());
            }
            other if other.starts_with('-') => {
                return Err(format!("unknown flag `{other}`"));
            }
            other => paths.push(PathBuf::from(other)),
        }
    }
    if paths.is_empty() {
        let default = PathBuf::from("scenarios");
        paths.push(if default.is_dir() {
            default
        } else {
            PathBuf::from("../scenarios")
        });
    }

    let mut files: Vec<PathBuf> = Vec::new();
    for p in &paths {
        if p.is_dir() {
            let mut entries: Vec<PathBuf> = std::fs::read_dir(p)
                .map_err(|e| format!("{}: {e}", p.display()))?
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|e| e.extension().is_some_and(|x| x == "toml"))
                .collect();
            entries.sort();
            files.extend(entries);
        } else {
            files.push(p.clone());
        }
    }
    if files.is_empty() {
        return Err(format!(
            "no scenario files under {}",
            paths
                .iter()
                .map(|p| p.display().to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ));
    }

    // Parse everything up front, serially: the duplicate-name check
    // stays deterministic in file order, and only the (expensive,
    // independent) runs go to the pool.
    let mut scenarios: Vec<Scenario> = Vec::new();
    for file in &files {
        let sc = Scenario::from_file(file).map_err(|e| e.to_string())?;
        if scenarios.iter().any(|s| s.name == sc.name) {
            return Err(format!(
                "duplicate scenario name `{}` (second copy in {})",
                sc.name,
                file.display()
            ));
        }
        scenarios.push(sc);
    }

    let jobs = jobs
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(usize::from)
                .unwrap_or(1)
        })
        .min(scenarios.len());
    // One result slot per scenario: workers pull the next unclaimed
    // index and write into their own slot, so the assembled output is
    // independent of scheduling order.
    let slots: Vec<Mutex<Option<String>>> = scenarios.iter().map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let Some(sc) = scenarios.get(i) else { break };
                eprintln!("running {} ({})", sc.name, files[i].display());
                let report = sc.run();
                *slots[i].lock().expect("result slot") = Some(digest(&report));
            });
        }
    });

    let mut entries: Vec<(String, String)> = scenarios
        .iter()
        .zip(&slots)
        .map(|(sc, slot)| {
            let d = slot
                .lock()
                .expect("result slot")
                .take()
                .expect("every scenario ran");
            (sc.name.clone(), d)
        })
        .collect();
    entries.sort_by(|a, b| a.0.cmp(&b.0));

    let mut json = String::from("{\n");
    for (i, (name, d)) in entries.iter().enumerate() {
        json.push_str(&format!("  \"{name}\": {d}"));
        json.push_str(if i + 1 < entries.len() { ",\n" } else { "\n" });
    }
    json.push_str("}\n");

    match out {
        Some(path) => {
            std::fs::write(&path, &json).map_err(|e| format!("{}: {e}", path.display()))?
        }
        None => print!("{json}"),
    }
    Ok(())
}
