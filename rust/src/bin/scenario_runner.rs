//! Run the committed scenario corpus and emit per-scenario digests.
//!
//! ```text
//! scenario_runner [--out FILE] [PATH ...]
//! ```
//!
//! Each `PATH` is a scenario file or a directory (expanded to its
//! `*.toml` entries, sorted by file name); with no paths the runner
//! looks for `scenarios/`, falling back to `../scenarios/` so
//! `cargo run --bin scenario_runner` works from `rust/` too. The
//! output is one JSON object mapping scenario name to its digest (see
//! [`poas::service::scenario::digest`]), keys sorted, one digest per
//! line — CI diffs it against the blessed `ci/scenario_digests.json`
//! (see `docs/scenarios.md` for the blessing workflow). Any parse or
//! I/O error, duplicate scenario name or empty corpus exits non-zero.

use poas::service::scenario::{digest, Scenario};
use std::path::PathBuf;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("scenario_runner: {e}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<(), String> {
    let mut out: Option<PathBuf> = None;
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--out" => {
                let f = it.next().ok_or("--out needs a file argument")?;
                out = Some(PathBuf::from(f));
            }
            "--help" | "-h" => {
                println!("usage: scenario_runner [--out FILE] [PATH ...]");
                return Ok(());
            }
            other if other.starts_with('-') => {
                return Err(format!("unknown flag `{other}`"));
            }
            other => paths.push(PathBuf::from(other)),
        }
    }
    if paths.is_empty() {
        let default = PathBuf::from("scenarios");
        paths.push(if default.is_dir() {
            default
        } else {
            PathBuf::from("../scenarios")
        });
    }

    let mut files: Vec<PathBuf> = Vec::new();
    for p in &paths {
        if p.is_dir() {
            let mut entries: Vec<PathBuf> = std::fs::read_dir(p)
                .map_err(|e| format!("{}: {e}", p.display()))?
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|e| e.extension().is_some_and(|x| x == "toml"))
                .collect();
            entries.sort();
            files.extend(entries);
        } else {
            files.push(p.clone());
        }
    }
    if files.is_empty() {
        return Err(format!(
            "no scenario files under {}",
            paths
                .iter()
                .map(|p| p.display().to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ));
    }

    let mut entries: Vec<(String, String)> = Vec::new();
    for file in &files {
        let sc = Scenario::from_file(file).map_err(|e| e.to_string())?;
        if entries.iter().any(|(name, _)| *name == sc.name) {
            return Err(format!(
                "duplicate scenario name `{}` (second copy in {})",
                sc.name,
                file.display()
            ));
        }
        eprintln!("running {} ({})", sc.name, file.display());
        let report = sc.run();
        entries.push((sc.name, digest(&report)));
    }
    entries.sort_by(|a, b| a.0.cmp(&b.0));

    let mut json = String::from("{\n");
    for (i, (name, d)) in entries.iter().enumerate() {
        json.push_str(&format!("  \"{name}\": {d}"));
        json.push_str(if i + 1 < entries.len() { ",\n" } else { "\n" });
    }
    json.push_str("}\n");

    match out {
        Some(path) => {
            std::fs::write(&path, &json).map_err(|e| format!("{}: {e}", path.display()))?
        }
        None => print!("{json}"),
    }
    Ok(())
}
