//! # poas — POAS (Predict, Optimize, Adapt, Schedule) for Accelerator Level Parallelism
//!
//! Reproduction of *"POAS: A high-performance scheduling framework for
//! exploiting Accelerator Level Parallelism"* (Martínez, Bernabé, García —
//! PACT 2022), including the paper's **hgemms** case study: co-executing a
//! single large GEMM across a CPU, a GPU (FP32) and an XPU (tensor-core /
//! low-precision) sharing one PCIe bus.
//!
//! The library is organised around the paper's four phases:
//!
//! 1. [`predict`] — hardware profiling (compute power + memory bandwidth
//!    microbenchmarks) and a linear-regression performance model that maps
//!    an operation count to execution time (paper §3.1, §4.1).
//! 2. [`optimize`] — a from-scratch simplex / branch-and-bound MILP solver
//!    (substituting the paper's CPLEX 12.10) and the minimax work-split
//!    formulation of Eq. 1–4, including serialized shared-bus copy terms
//!    (paper §3.2, §4.2).
//! 3. [`adapt`] — the `ops_to_mnk` algorithm: ops → (m, n, k) mapping, the
//!    square-submatrix decomposition driven by the squareness heuristic of
//!    Eq. 5, and the hardware alignment rules (tensor-core `m % 8 == 0`,
//!    CPU cache-fit) (paper §3.3, §4.3).
//! 4. [`schedule`] — static and dynamic schedulers plus the priority-ordered
//!    shared-bus communication scheme of Fig. 2 (paper §3.4, §4.4).
//!
//! Everything the paper's evaluation depends on is built here as well:
//!
//! * [`sim`] — a virtual-time heterogeneous testbed simulator (device
//!   performance curves with noise + thermal throttling, a shared PCIe bus
//!   with pluggable arbitration, an energy model). The paper ran on two HPC
//!   servers (`mach1`, `mach2`, Tables 1–2); we do not own that hardware, so
//!   the simulator plays its role and the POAS pipeline *profiles it* exactly
//!   as the paper profiled cuBLAS/MKL (see `DESIGN.md` §Hardware-Adaptation).
//! * [`runtime`] — the real compute path: AOT-compiled HLO artifacts
//!   (JAX/Pallas tiled GEMM kernels, lowered at build time) loaded and
//!   executed through the PJRT CPU client from Rust. Python never runs on
//!   the request path.
//! * [`coordinator`] — the end-to-end pipeline gluing the four phases to an
//!   executor (simulated or PJRT) and assembling the output matrix.
//! * [`baselines`] — standalone single-device execution and the co-execution
//!   baselines POAS is compared against (equal split, ratio split,
//!   queue-based work stealing à la HPMaX).
//! * [`service`] — the serving layer: a multi-machine
//!   [`service::Cluster`] that admits a stream of heterogeneous GEMM
//!   requests through the §6 suitability gate ([`service::Admission`],
//!   memoized in a bounded LRU), routes each one to the
//!   [`service::ExecutorShard`] with the earliest predicted finish via
//!   an event-driven virtual-time loop, steals queued work onto idle
//!   shards, and replays online arrival traces
//!   ([`service::PoissonArrivals`]) so reports measure queueing delay
//!   and tail sojourn time under offered load. Each shard dispatches
//!   under pluggable queue policies (FIFO /
//!   shortest-predicted-job-first, with a standalone bypass that
//!   co-schedules small jobs on an idle device) and memoizes
//!   Optimize-phase output in a [`service::PlanCache`] keyed by
//!   `(shape, model epoch)` so repeated shapes skip the MILP solve.
//!   Tenants submit under QoS tiers ([`service::QosClass`]) drained by
//!   a weighted fair pick, and SLO-bound requests face deadline-aware
//!   admission (reject or down-class, [`service::DeadlinePolicy`])
//!   backed by the deadline-constrained LP. The single-machine
//!   [`service::Server`] is a 1-shard cluster.
//! * [`workload`], [`config`], [`metrics`], [`report`] — Table 3 inputs,
//!   machine descriptions, statistics and table/figure rendering.
//!
//! ## Quick start
//!
//! ```no_run
//! use poas::config::presets;
//! use poas::coordinator::Pipeline;
//! use poas::workload::GemmSize;
//!
//! // Simulated mach2 (AMD EPYC 7413 + RTX 3090 + RTX 2080 Ti) testbed.
//! let machine = presets::mach2();
//! let mut pipeline = Pipeline::for_simulated_machine(&machine, 42);
//! let outcome = pipeline.run_sim(GemmSize::new(30_000, 30_000, 30_000), 50);
//! println!("simulated co-executed GEMM finished in {:.3}s", outcome.makespan);
//! ```
//!
//! Serving a request stream instead of running one GEMM:
//!
//! ```no_run
//! use poas::config::presets;
//! use poas::service::{QueuePolicy, Server, ServerOptions};
//! use poas::workload::GemmSize;
//!
//! let mut server = Server::new(
//!     &presets::mach2(),
//!     42,
//!     ServerOptions {
//!         policy: QueuePolicy::Spjf,
//!         standalone_bypass: true,
//!         ..Default::default()
//!     },
//! );
//! server.submit(GemmSize::square(30_000), 10); // co-executed
//! server.submit(GemmSize::square(400), 10); // standalone (gate, §6)
//! let report = server.run_to_completion();
//! println!("{}", report.summary());
//! ```
//!
//! See `examples/` for runnable end-to-end drivers (including real PJRT
//! co-execution with numerics checks and the `gemm_service` request
//! server) and `rust/benches/` for the regenerators of every table and
//! figure in the paper's evaluation.

pub mod adapt;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod metrics;
pub mod optimize;
pub mod predict;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod schedule;
pub mod service;
pub mod sim;
pub mod workload;

pub use error::{Error, Result};
