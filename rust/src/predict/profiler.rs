//! The profiling harness — the Predict phase's installation-time run.
//!
//! Paper §4.1.2 / §5.1.3: the profiler runs a set of square GEMMs on
//! every device (30 sizes, CPU in [1000, 2000], GPU/XPU in [3000, 6000],
//! 5 repetitions each, averaged) plus a memory microbenchmark per
//! accelerator, then fits the linear models.
//!
//! The harness is generic over a [`ProfileTarget`] so the identical code
//! profiles the virtual testbed (`SimMachine`) and the real PJRT
//! executables — exactly the property POAS claims: the pipeline only
//! ever sees measurements.

use super::model::{DevicePerf, PerfModel};
use super::regression::{fit_linear, mean};
use crate::config::DeviceKind;
use crate::error::{Error, Result};
use crate::sim::SimMachine;
use crate::workload::GemmSize;

/// Anything the profiler can measure.
pub trait ProfileTarget {
    /// Human-readable machine name.
    fn machine_name(&self) -> String;
    /// Number of devices.
    fn num_devices(&self) -> usize;
    /// Device name/kind and square profiling range [lo, hi].
    fn device_meta(&self, dev: usize) -> (String, DeviceKind, u64, u64);
    /// Alignment the device needs for full-rate operation (paper: the
    /// profiler must measure "in the optimal conditions of the hardware",
    /// §3.1 — tensor-core benchmarks must use aligned sizes).
    fn device_align(&self, _dev: usize) -> u64 {
        1
    }
    /// Measure one square `s x s x s` GEMM; returns seconds.
    fn bench_compute(&mut self, dev: usize, s: u64) -> f64;
    /// Measure one host<->device transfer of `bytes`; returns seconds.
    /// Unsupported (CPU) -> None.
    fn bench_transfer(&mut self, dev: usize, bytes: f64) -> Option<f64>;
}

impl ProfileTarget for SimMachine {
    fn machine_name(&self) -> String {
        self.config().name.clone()
    }

    fn num_devices(&self) -> usize {
        self.config().devices.len()
    }

    fn device_meta(&self, dev: usize) -> (String, DeviceKind, u64, u64) {
        let d = &self.config().devices[dev];
        (d.name.clone(), d.kind, d.profile_lo, d.profile_hi)
    }

    fn bench_compute(&mut self, dev: usize, s: u64) -> f64 {
        self.profile_compute_once(dev, s)
    }

    fn bench_transfer(&mut self, dev: usize, bytes: f64) -> Option<f64> {
        if self.config().devices[dev].kind == DeviceKind::Cpu {
            None
        } else {
            let bw = self.profile_bandwidth_once(dev, bytes);
            Some(bytes / bw)
        }
    }

    fn device_align(&self, dev: usize) -> u64 {
        self.config().devices[dev].align
    }
}

/// Profiling options (defaults = the paper's settings).
#[derive(Debug, Clone)]
pub struct ProfileOptions {
    /// Number of square sizes per device (paper: 30).
    pub num_sizes: usize,
    /// Repetitions per size, averaged (paper: 5).
    pub reps: u32,
    /// Transfer sizes for the memory microbenchmark, bytes.
    pub transfer_bytes: Vec<f64>,
    /// Repetitions per transfer size.
    pub transfer_reps: u32,
}

impl Default for ProfileOptions {
    fn default() -> Self {
        ProfileOptions {
            num_sizes: 30,
            reps: 5,
            transfer_bytes: vec![16e6, 64e6, 256e6, 1e9, 4e9],
            transfer_reps: 5,
        }
    }
}

/// Run the full profiling pass and fit the performance model.
pub fn profile<T: ProfileTarget>(target: &mut T, opts: &ProfileOptions) -> Result<PerfModel> {
    let nd = target.num_devices();
    if nd == 0 {
        return Err(Error::Predict("no devices to profile".into()));
    }
    let mut devices = Vec::with_capacity(nd);
    for dev in 0..nd {
        let (name, kind, lo, hi) = target.device_meta(dev);
        let align = target.device_align(dev).max(1);

        // ---- Compute-power profiling: square GEMMs across [lo, hi].
        // Sizes are rounded to the device's alignment: profiling must run
        // under the hardware's optimal conditions (§3.1) or the fitted
        // rate would mix full-rate and fallback-path measurements.
        let mut xs = Vec::with_capacity(opts.num_sizes); // ops
        let mut ys = Vec::with_capacity(opts.num_sizes); // seconds
        for i in 0..opts.num_sizes {
            let frac = if opts.num_sizes > 1 {
                i as f64 / (opts.num_sizes - 1) as f64
            } else {
                0.0
            };
            let raw = (lo as f64 + frac * (hi - lo) as f64).round() as u64;
            let s = ((raw / align).max(1)) * align;
            let times: Vec<f64> = (0..opts.reps)
                .map(|_| target.bench_compute(dev, s))
                .collect();
            xs.push(GemmSize::square(s).ops());
            ys.push(mean(&times));
        }
        let fit = fit_linear(&xs, &ys).ok_or_else(|| {
            Error::Predict(format!("device {name}: degenerate compute profile"))
        })?;
        if fit.slope <= 0.0 {
            return Err(Error::Predict(format!(
                "device {name}: non-positive fitted rate"
            )));
        }

        // ---- Memory-bandwidth profiling (accelerators only).
        let (bw, lat) = if kind == DeviceKind::Cpu {
            (0.0, 0.0)
        } else {
            let mut txs = Vec::new();
            let mut tys = Vec::new();
            for &bytes in &opts.transfer_bytes {
                let times: Vec<f64> = (0..opts.transfer_reps)
                    .filter_map(|_| target.bench_transfer(dev, bytes))
                    .collect();
                if times.is_empty() {
                    continue;
                }
                txs.push(bytes);
                tys.push(mean(&times));
            }
            let tfit = fit_linear(&txs, &tys).ok_or_else(|| {
                Error::Predict(format!("device {name}: degenerate transfer profile"))
            })?;
            if tfit.slope <= 0.0 {
                return Err(Error::Predict(format!(
                    "device {name}: non-positive fitted bandwidth"
                )));
            }
            (1.0 / tfit.slope, tfit.intercept.max(0.0))
        };

        devices.push(DevicePerf {
            name,
            kind,
            a: fit.slope,
            // Launch overhead can be below the fit's noise floor; clamp.
            b: fit.intercept.max(0.0),
            r2: fit.r2,
            bw,
            lat,
            priority: 0,
        });
    }

    let mut model = PerfModel {
        machine: target.machine_name(),
        devices,
    };
    model.assign_priorities();
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn profile_mach1(seed: u64) -> PerfModel {
        let mut m = SimMachine::new(&presets::mach1(), seed);
        profile(&mut m, &ProfileOptions::default()).unwrap()
    }

    #[test]
    fn fitted_rates_near_ground_truth() {
        let cfg = presets::mach1();
        let model = profile_mach1(0);
        for (spec, fitted) in cfg.devices.iter().zip(&model.devices) {
            let rel = (fitted.rate_tops() - spec.eff_rate_tops).abs() / spec.eff_rate_tops;
            // Profiling sees noise + mild heating; 5% is the paper's own
            // prediction-accuracy ballpark.
            assert!(
                rel < 0.05,
                "{}: fitted {} vs truth {}",
                spec.name,
                fitted.rate_tops(),
                spec.eff_rate_tops
            );
        }
    }

    #[test]
    fn fitted_bandwidth_near_link_speed() {
        let cfg = presets::mach1();
        let model = profile_mach1(1);
        for (spec, fitted) in cfg.devices.iter().zip(&model.devices).skip(1) {
            let rel = (fitted.bw - spec.bus_bw_gbs * 1e9).abs() / (spec.bus_bw_gbs * 1e9);
            assert!(rel < 0.05, "{}: bw {} ", spec.name, fitted.bw);
        }
    }

    #[test]
    fn regression_quality_is_high() {
        let model = profile_mach1(2);
        for d in &model.devices {
            assert!(d.r2 > 0.98, "{}: r2={}", d.name, d.r2);
        }
    }

    #[test]
    fn priorities_fastest_first() {
        let model = profile_mach1(3);
        // mach1: xpu (devices[2]) fastest accelerator.
        assert_eq!(model.devices[2].priority, 2);
        assert_eq!(model.devices[1].priority, 1);
        assert_eq!(model.devices[0].priority, 0);
    }

    #[test]
    fn profile_is_reasonably_stable_across_seeds() {
        let a = profile_mach1(10);
        let b = profile_mach1(11);
        for (x, y) in a.devices.iter().zip(&b.devices) {
            let rel = (x.rate_tops() - y.rate_tops()).abs() / x.rate_tops();
            assert!(rel < 0.05, "{}: unstable profile", x.name);
        }
    }

    #[test]
    fn small_options_still_fit() {
        let mut m = SimMachine::new(&presets::mach2(), 5);
        let opts = ProfileOptions {
            num_sizes: 5,
            reps: 2,
            transfer_bytes: vec![1e8, 1e9],
            transfer_reps: 2,
        };
        let model = profile(&mut m, &opts).unwrap();
        assert_eq!(model.devices.len(), 3);
        assert_eq!(model.machine, "mach2");
    }
}
