//! Ordinary least squares for the performance predictor.
//!
//! The paper's Predict phase models compute time as a *linear* function
//! of the op count (`ops = m*n*k`) so that linear programming stays
//! applicable (§3.2, §4.1.1), and copy time as linear in bytes. Both fits
//! reduce to simple 1-D OLS.

/// Result of a 1-D least-squares fit `y ≈ slope * x + intercept`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearFit {
    pub slope: f64,
    pub intercept: f64,
    /// Coefficient of determination in [0, 1] (1 = perfect).
    pub r2: f64,
    /// Root mean square residual, in y-units.
    pub rmse: f64,
}

/// Fit `y = slope*x + intercept` by OLS. Needs >= 2 distinct x values.
pub fn fit_linear(xs: &[f64], ys: &[f64]) -> Option<LinearFit> {
    if xs.len() != ys.len() || xs.len() < 2 {
        return None;
    }
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    if sxx <= 0.0 {
        return None; // all x identical: slope undefined
    }
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let mut ss_res = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        let e = y - (slope * x + intercept);
        ss_res += e * e;
    }
    let r2 = if syy > 0.0 { 1.0 - ss_res / syy } else { 1.0 };
    let rmse = (ss_res / n).sqrt();
    Some(LinearFit {
        slope,
        intercept,
        r2,
        rmse,
    })
}

/// Fit through the origin: `y = slope * x` (used when the intercept is
/// known to be zero, e.g. pure-bandwidth models).
pub fn fit_proportional(xs: &[f64], ys: &[f64]) -> Option<f64> {
    if xs.len() != ys.len() || xs.is_empty() {
        return None;
    }
    let num: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let den: f64 = xs.iter().map(|x| x * x).sum();
    if den <= 0.0 {
        None
    } else {
        Some(num / den)
    }
}

/// Mean of a slice (0 for empty — callers guard).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_line_recovered() {
        let xs: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 2.0).collect();
        let f = fit_linear(&xs, &ys).unwrap();
        assert!((f.slope - 3.0).abs() < 1e-12);
        assert!((f.intercept - 2.0).abs() < 1e-12);
        assert!((f.r2 - 1.0).abs() < 1e-12);
        assert!(f.rmse < 1e-12);
    }

    #[test]
    fn noisy_line_close() {
        let mut rng = crate::rng::Rng::new(11);
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 0.5 * x + 10.0 + rng.normal_with(0.0, 0.5))
            .collect();
        let f = fit_linear(&xs, &ys).unwrap();
        assert!((f.slope - 0.5).abs() < 0.01, "slope={}", f.slope);
        assert!((f.intercept - 10.0).abs() < 1.0);
        assert!(f.r2 > 0.99);
    }

    #[test]
    fn degenerate_inputs_rejected() {
        assert!(fit_linear(&[1.0], &[2.0]).is_none());
        assert!(fit_linear(&[1.0, 1.0], &[2.0, 3.0]).is_none());
        assert!(fit_linear(&[1.0, 2.0], &[2.0]).is_none());
    }

    #[test]
    fn proportional_fit() {
        let xs = [1.0, 2.0, 4.0];
        let ys = [2.1, 3.9, 8.05];
        let s = fit_proportional(&xs, &ys).unwrap();
        assert!((s - 2.0).abs() < 0.05);
        assert!(fit_proportional(&[], &[]).is_none());
    }

    #[test]
    fn constant_y_gives_r2_one_zero_slope() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [5.0, 5.0, 5.0];
        let f = fit_linear(&xs, &ys).unwrap();
        assert!(f.slope.abs() < 1e-12);
        assert_eq!(f.r2, 1.0);
    }
}
