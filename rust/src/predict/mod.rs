//! The Predict phase: profiling + linear-regression performance model.
//!
//! POAS requires "a mathematical function that, given the input size,
//! predicts the execution time of the application for a variety of
//! hardware devices" (§3.1). For GEMM the paper linearizes the cubic
//! growth by regressing on the op count `ops = m*n*k` instead of the
//! matrix dimension (§4.1.1), and separately fits the host↔device link
//! as `t = latency + bytes/bandwidth` (§4.1.2).
//!
//! * [`regression`] — OLS fits;
//! * [`profiler`] — the installation-time microbenchmark harness,
//!   generic over simulated and real (PJRT) targets;
//! * [`model`] — the fitted [`PerfModel`], its text-file persistence and
//!   the conversion into optimizer inputs.

pub mod model;
pub mod profiler;
pub mod regression;

pub use model::{DevicePerf, PerfModel};
pub use profiler::{profile, ProfileOptions, ProfileTarget};
pub use regression::{fit_linear, fit_proportional, LinearFit};
