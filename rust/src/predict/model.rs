//! The fitted performance model — the Predict phase's output.
//!
//! One [`DevicePerf`] per device: the compute-time line `t = a*ops + b`
//! (paper §4.1.1) and the copy-time line `t = lat + bytes/bw` from the
//! memory microbenchmark (§4.1.2). The model persists to the plain text
//! file the paper describes ("results are stored in a text file that is
//! read when real matrix multiplication workloads arrive").

use crate::config::DeviceKind;
use crate::error::{Error, Result};
use crate::optimize::problem::DeviceModelInput;
use crate::workload::GemmSize;

/// Fitted performance description of one device.
#[derive(Debug, Clone, PartialEq)]
pub struct DevicePerf {
    pub name: String,
    pub kind: DeviceKind,
    /// Compute seconds per op.
    pub a: f64,
    /// Compute intercept seconds.
    pub b: f64,
    /// Fit quality of the compute regression.
    pub r2: f64,
    /// Link bandwidth bytes/s (0 for CPU).
    pub bw: f64,
    /// Link latency seconds (0 for CPU).
    pub lat: f64,
    /// Bus priority (assigned from fitted speed: fastest = highest).
    pub priority: u32,
}

impl DevicePerf {
    /// Fitted effective rate in Tera-ops/s.
    pub fn rate_tops(&self) -> f64 {
        1.0 / self.a / 1e12
    }

    /// Predicted compute seconds for a sub-product.
    pub fn predict_compute(&self, size: GemmSize) -> f64 {
        self.a * size.ops() + self.b
    }

    /// Predicted one-way copy seconds for `bytes`.
    pub fn predict_copy(&self, bytes: f64) -> f64 {
        if self.kind == DeviceKind::Cpu {
            0.0
        } else {
            self.lat + bytes / self.bw
        }
    }

    /// Convert into the optimizer's input row.
    pub fn to_model_input(&self) -> DeviceModelInput {
        DeviceModelInput {
            name: self.name.clone(),
            is_cpu: self.kind == DeviceKind::Cpu,
            a: self.a,
            b: self.b,
            dtype_bytes: self.kind.dtype_bytes() as f64,
            bw: if self.bw > 0.0 { self.bw } else { 1.0 },
            lat: self.lat,
            priority: self.priority,
        }
    }
}

/// The complete fitted model for one machine.
#[derive(Debug, Clone, PartialEq)]
pub struct PerfModel {
    pub machine: String,
    pub devices: Vec<DevicePerf>,
}

impl PerfModel {
    /// Assign bus priorities by fitted speed: the fastest device gets the
    /// highest priority (paper §4.4: "the faster the device, the higher
    /// priority"). CPUs keep priority 0 (they do not use the bus).
    pub fn assign_priorities(&mut self) {
        let mut order: Vec<usize> = (0..self.devices.len())
            .filter(|&i| self.devices[i].kind != DeviceKind::Cpu)
            .collect();
        order.sort_by(|&x, &y| self.devices[x].a.total_cmp(&self.devices[y].a));
        // order[0] = fastest accelerator.
        let n = order.len() as u32;
        for (rank, &i) in order.iter().enumerate() {
            self.devices[i].priority = n - rank as u32;
        }
        for d in &mut self.devices {
            if d.kind == DeviceKind::Cpu {
                d.priority = 0;
            }
        }
    }

    /// Optimizer inputs, machine order.
    pub fn model_inputs(&self) -> Vec<DeviceModelInput> {
        self.devices.iter().map(|d| d.to_model_input()).collect()
    }

    /// Deterministic 64-bit fingerprint of the fitted parameters (FNV-1a
    /// over every device's regression lines, link figures and priority).
    /// Two shards profiled on different machines — or re-profiled after
    /// drift — disagree here, which is how service reports show *which*
    /// model each shard's predictions came from.
    pub fn fingerprint(&self) -> u64 {
        fn eat(mut h: u64, x: u64) -> u64 {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0100_0000_01b3);
            }
            h
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        h = eat(h, self.devices.len() as u64);
        for d in &self.devices {
            h = eat(h, d.a.to_bits());
            h = eat(h, d.b.to_bits());
            h = eat(h, d.bw.to_bits());
            h = eat(h, d.lat.to_bits());
            h = eat(h, u64::from(d.priority));
        }
        h
    }

    // ------------------------------------------------------------------
    // Text persistence (paper: profile results live in a text file).
    // ------------------------------------------------------------------

    /// Serialize to the profile text format.
    pub fn to_text(&self) -> String {
        let mut s = String::from("# poas perf profile v1\n");
        s.push_str(&format!("machine {}\n", self.machine));
        for d in &self.devices {
            s.push_str(&format!(
                "device {} {} a={:e} b={:e} r2={} bw={} lat={:e} prio={}\n",
                d.name,
                d.kind.as_str(),
                d.a,
                d.b,
                d.r2,
                d.bw,
                d.lat,
                d.priority
            ));
        }
        s
    }

    /// Parse the profile text format.
    pub fn from_text(text: &str) -> Result<PerfModel> {
        let mut machine = None;
        let mut devices = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("machine") => {
                    machine = Some(
                        parts
                            .next()
                            .ok_or_else(|| Error::Predict(format!("line {}: machine needs a name", ln + 1)))?
                            .to_string(),
                    );
                }
                Some("device") => {
                    let name = parts
                        .next()
                        .ok_or_else(|| Error::Predict(format!("line {}: device needs a name", ln + 1)))?
                        .to_string();
                    let kind = DeviceKind::parse(
                        parts
                            .next()
                            .ok_or_else(|| Error::Predict(format!("line {}: device needs a kind", ln + 1)))?,
                    )?;
                    let mut d = DevicePerf {
                        name,
                        kind,
                        a: 0.0,
                        b: 0.0,
                        r2: 0.0,
                        bw: 0.0,
                        lat: 0.0,
                        priority: 0,
                    };
                    for kv in parts {
                        let (k, v) = kv.split_once('=').ok_or_else(|| {
                            Error::Predict(format!("line {}: bad key=value `{kv}`", ln + 1))
                        })?;
                        let fv: f64 = v.parse().map_err(|_| {
                            Error::Predict(format!("line {}: bad number `{v}`", ln + 1))
                        })?;
                        match k {
                            "a" => d.a = fv,
                            "b" => d.b = fv,
                            "r2" => d.r2 = fv,
                            "bw" => d.bw = fv,
                            "lat" => d.lat = fv,
                            "prio" => d.priority = fv as u32,
                            other => {
                                return Err(Error::Predict(format!(
                                    "line {}: unknown key `{other}`",
                                    ln + 1
                                )))
                            }
                        }
                    }
                    if d.a <= 0.0 {
                        return Err(Error::Predict(format!(
                            "device {}: slope a must be > 0",
                            d.name
                        )));
                    }
                    devices.push(d);
                }
                Some(other) => {
                    return Err(Error::Predict(format!(
                        "line {}: unknown directive `{other}`",
                        ln + 1
                    )))
                }
                None => unreachable!(),
            }
        }
        Ok(PerfModel {
            machine: machine.ok_or_else(|| Error::Predict("missing `machine` line".into()))?,
            devices,
        })
    }

    /// Write to a file.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_text())?;
        Ok(())
    }

    /// Read from a file.
    pub fn load(path: &std::path::Path) -> Result<PerfModel> {
        Self::from_text(&std::fs::read_to_string(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> PerfModel {
        PerfModel {
            machine: "mach1".into(),
            devices: vec![
                DevicePerf {
                    name: "xeon".into(),
                    kind: DeviceKind::Cpu,
                    a: 1.0 / 0.109e12,
                    b: 2e-5,
                    r2: 0.999,
                    bw: 0.0,
                    lat: 0.0,
                    priority: 0,
                },
                DevicePerf {
                    name: "gpu".into(),
                    kind: DeviceKind::Gpu,
                    a: 1.0 / 5.6e12,
                    b: 6e-5,
                    r2: 0.998,
                    bw: 15.6e9,
                    lat: 1.1e-5,
                    priority: 0,
                },
                DevicePerf {
                    name: "xpu".into(),
                    kind: DeviceKind::Xpu,
                    a: 1.0 / 21.5e12,
                    b: 6e-5,
                    r2: 0.997,
                    bw: 15.7e9,
                    lat: 1.2e-5,
                    priority: 0,
                },
            ],
        }
    }

    #[test]
    fn priorities_by_speed() {
        let mut m = sample();
        m.assign_priorities();
        assert_eq!(m.devices[0].priority, 0); // cpu
        assert_eq!(m.devices[2].priority, 2); // xpu fastest
        assert_eq!(m.devices[1].priority, 1);
    }

    #[test]
    fn text_roundtrip() {
        let mut m = sample();
        m.assign_priorities();
        let parsed = PerfModel::from_text(&m.to_text()).unwrap();
        assert_eq!(parsed.machine, m.machine);
        assert_eq!(parsed.devices.len(), m.devices.len());
        for (a, b) in parsed.devices.iter().zip(&m.devices) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kind, b.kind);
            assert!((a.a - b.a).abs() / b.a < 1e-12);
            assert!((a.bw - b.bw).abs() <= b.bw * 1e-12);
            assert_eq!(a.priority, b.priority);
        }
    }

    #[test]
    fn rate_tops_inverse_of_slope() {
        let m = sample();
        assert!((m.devices[1].rate_tops() - 5.6).abs() < 1e-9);
    }

    #[test]
    fn predictions_linear() {
        let m = sample();
        let d = &m.devices[1];
        let s1 = GemmSize::square(1000);
        let s2 = GemmSize::new(2000, 1000, 1000);
        let t1 = d.predict_compute(s1) - d.b;
        let t2 = d.predict_compute(s2) - d.b;
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn cpu_copy_is_free() {
        let m = sample();
        assert_eq!(m.devices[0].predict_copy(1e9), 0.0);
        assert!(m.devices[1].predict_copy(1e9) > 0.0);
    }

    #[test]
    fn parse_errors() {
        assert!(PerfModel::from_text("device x cpu a=1").is_err()); // no machine
        assert!(PerfModel::from_text("machine m\nbogus line").is_err());
        assert!(PerfModel::from_text("machine m\ndevice x cpu a=zero").is_err());
        assert!(PerfModel::from_text("machine m\ndevice x cpu a=-1").is_err());
        assert!(PerfModel::from_text("machine m\ndevice x cpu q=1").is_err());
    }

    #[test]
    fn fingerprint_tracks_fitted_parameters() {
        let m = sample();
        let fp = m.fingerprint();
        // Deterministic for identical parameters.
        assert_eq!(fp, sample().fingerprint());
        // Any fitted figure moving moves the fingerprint.
        let mut drifted = sample();
        drifted.devices[1].a *= 1.01;
        assert_ne!(fp, drifted.fingerprint());
        // A machine with fewer devices cannot collide by truncation.
        let mut short = sample();
        short.devices.truncate(2);
        assert_ne!(fp, short.fingerprint());
    }

    #[test]
    fn model_inputs_match() {
        let mut m = sample();
        m.assign_priorities();
        let inputs = m.model_inputs();
        assert_eq!(inputs.len(), 3);
        assert!(inputs[0].is_cpu);
        assert_eq!(inputs[2].dtype_bytes, 2.0);
        assert_eq!(inputs[2].priority, 2);
    }
}
