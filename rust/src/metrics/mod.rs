//! Statistics used across the evaluation: relative error, RMSE, speedup.
//!
//! These implement exactly the paper's §5.2 definitions: the prediction
//! error `e = 100 * (v - v_pred) / v` (relative, in percent) and the
//! root-mean-square error over a set of inputs.

/// The paper's relative prediction error, percent:
/// `e = 100 * (v - v_pred) / v`. The paper's tables report magnitudes,
/// so callers usually take `.abs()`.
pub fn prediction_error_pct(measured: f64, predicted: f64) -> f64 {
    if measured == 0.0 {
        return if predicted == 0.0 { 0.0 } else { f64::INFINITY };
    }
    100.0 * (measured - predicted) / measured
}

/// RMSE of a set of (already percentual) errors — Table 5 aggregates the
/// per-input errors of Table 4 this way.
pub fn rmse(errors: &[f64]) -> f64 {
    if errors.is_empty() {
        return 0.0;
    }
    (errors.iter().map(|e| e * e).sum::<f64>() / errors.len() as f64).sqrt()
}

/// Speedup of `ours` relative to `baseline` (Table 7: baseline time /
/// hgemms time).
pub fn speedup(baseline_s: f64, ours_s: f64) -> f64 {
    baseline_s / ours_s
}

/// Sample mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (population).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile of a sample, `p` in [0, 100] — the
/// service layer reports tail latencies (p50/p95/p99) with this.
/// Empty input yields 0.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p.clamp(0.0, 100.0) / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Geometric mean (used for speedup summaries).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// A simple wall-clock stopwatch for the real execution path.
#[derive(Debug)]
pub struct Stopwatch {
    start: std::time::Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch {
            start: std::time::Instant::now(),
        }
    }

    /// Elapsed seconds.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_pct_matches_paper_definition() {
        // measured 10s, predicted 9.5s -> e = 5%.
        assert!((prediction_error_pct(10.0, 9.5) - 5.0).abs() < 1e-12);
        // over-prediction is negative.
        assert!(prediction_error_pct(10.0, 10.5) < 0.0);
        assert_eq!(prediction_error_pct(0.0, 0.0), 0.0);
        assert!(prediction_error_pct(0.0, 1.0).is_infinite());
    }

    #[test]
    fn rmse_known_values() {
        assert!((rmse(&[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(rmse(&[]), 0.0);
        // RMSE >= mean magnitude.
        let errs = [1.0, 2.0, 6.0];
        assert!(rmse(&errs) >= mean(&errs));
    }

    #[test]
    fn speedup_simple() {
        assert_eq!(speedup(10.0, 2.0), 5.0);
    }

    #[test]
    fn stats_basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!(stddev(&[2.0, 2.0, 2.0]) < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [4.0, 1.0, 3.0, 2.0]; // sorted: 1 2 3 4
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
        // Out-of-range p clamps instead of panicking.
        assert_eq!(percentile(&xs, 150.0), 4.0);
        assert_eq!(percentile(&xs, -5.0), 1.0);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.elapsed_s() >= 0.004);
    }
}
