//! Library-wide error type.
//!
//! The library keeps a small hand-rolled error enum (no `thiserror`
//! dependency); binaries and examples wrap it in `eyre` for reporting.

use std::fmt;

/// Errors produced by the POAS library.
#[derive(Debug)]
pub enum Error {
    /// Configuration file/preset problems (parse errors, missing keys...).
    Config(String),
    /// The optimizer could not produce a feasible work split.
    Infeasible(String),
    /// The LP/MILP is unbounded (a modelling bug by construction).
    Unbounded(String),
    /// Profiling or prediction failed (degenerate regression, bad ranges).
    Predict(String),
    /// The adapt phase could not map ops onto matrix dimensions.
    Adapt(String),
    /// PJRT runtime failures (artifact missing, compile/execute errors).
    Runtime(String),
    /// Workload / matrix shape errors.
    Workload(String),
    /// Underlying I/O error.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Infeasible(m) => write!(f, "infeasible problem: {m}"),
            Error::Unbounded(m) => write!(f, "unbounded problem: {m}"),
            Error::Predict(m) => write!(f, "prediction error: {m}"),
            Error::Adapt(m) => write!(f, "adapt error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Workload(m) => write!(f, "workload error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Convenience alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = Error::Infeasible("sum c_i = N unsatisfiable".into());
        assert!(e.to_string().contains("infeasible"));
        assert!(e.to_string().contains("unsatisfiable"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
