//! Baselines POAS/hgemms is compared against.
//!
//! * [`standalone`] — the paper's Table 7 comparator: the whole GEMM on a
//!   single device (one library call, synchronous copies);
//! * [`equal_split`] — naive co-execution: equal rows per device;
//! * [`ratio_split`] — static heuristic: rows proportional to fitted
//!   rates but ignoring the copy model (what you get without the
//!   Optimize phase — `ablation_optimizer`);
//! * [`work_queue`] — queue-based dynamic co-execution à la HPMaX
//!   (§2.3: "a queue-based system ... gives blocks of the matrices to be
//!   computed whenever a device is free").

use crate::adapt::{ops_to_rows, AdaptRules};
use crate::config::DeviceKind;
use crate::error::Result;
use crate::predict::PerfModel;
use crate::sim::{ExecOutcome, SimMachine, WorkItem, WorkOrder};
use crate::workload::GemmSize;

/// Standalone execution of the full GEMM on device `dev` (Table 7's
/// baselines). The device performs the paper's synchronous copy + one
/// library call per repetition; no decomposition, no co-execution.
pub fn standalone(sim: &mut SimMachine, dev: usize, size: GemmSize, reps: u32) -> ExecOutcome {
    let order = WorkOrder {
        items: vec![WorkItem::whole(dev, size, 1)],
        reps,
    };
    sim.execute(&order)
}

/// Equal-rows co-execution: every device gets `m / d` rows regardless of
/// speed. The floor of co-execution baselines.
pub fn equal_split(
    sim: &mut SimMachine,
    size: GemmSize,
    reps: u32,
    priorities: &[u32],
) -> ExecOutcome {
    let d = sim.num_devices() as u64;
    let shares = vec![1.0; d as usize];
    run_row_split(sim, size, reps, &shares, priorities)
}

/// Rows proportional to fitted compute rates (no copy modelling, no
/// LP): the "predict-only" scheduler.
pub fn ratio_split(
    sim: &mut SimMachine,
    model: &PerfModel,
    size: GemmSize,
    reps: u32,
) -> ExecOutcome {
    let rates: Vec<f64> = model.devices.iter().map(|d| 1.0 / d.a).collect();
    let priorities: Vec<u32> = model.devices.iter().map(|d| d.priority).collect();
    run_row_split(sim, size, reps, &rates, &priorities)
}

/// Shared helper: split rows by `weights`, build whole-slice work items.
fn run_row_split(
    sim: &mut SimMachine,
    size: GemmSize,
    reps: u32,
    weights: &[f64],
    priorities: &[u32],
) -> ExecOutcome {
    let rows = ops_to_rows(weights, size.m);
    let items: Vec<WorkItem> = rows
        .iter()
        .enumerate()
        .filter(|(_, &r)| r > 0)
        .map(|(i, &r)| WorkItem::whole(i, size.row_slice(r), priorities[i]))
        .collect();
    sim.execute(&WorkOrder { items, reps })
}

/// Queue-based dynamic co-execution (HPMaX-style): the m dimension is
/// chopped into fixed row-blocks; each device pulls the next block when
/// it becomes free. Copies go through the shared bus (priority order on
/// contention). Returns the outcome plus the per-device block counts.
///
/// This baseline needs no performance model at all — load balance
/// emerges from the pull dynamics — but pays per-block copy overhead
/// (B is re-sent for every block) and tail imbalance.
pub fn work_queue(
    sim: &mut SimMachine,
    size: GemmSize,
    reps: u32,
    block_rows: u64,
    rules: &[AdaptRules],
) -> Result<(ExecOutcome, Vec<u64>)> {
    let d = sim.num_devices();
    // Greedy simulation of the pull queue using the *spec* rates as the
    // tie-breaking heuristic is not allowed (no model!); instead we
    // simulate honestly: devices take blocks in rotation of their
    // availability. We pre-assign blocks by simulating per-device clocks
    // with the ground-truth simulator inside one WorkOrder execution:
    // each block is one sub-product, and blocks are handed out by a
    // round-based auction on current device finish times estimated from
    // *observed* progress (first block each as a probe).
    let n_blocks = size.m.div_ceil(block_rows);
    let mut device_blocks: Vec<u64> = vec![0; d];

    // Probe pass: give one block to each device, measure, then hand the
    // remaining blocks to whichever device has the earliest projected
    // finish (classic list-scheduling with observed rates).
    let block = |rows: u64| GemmSize::new(rows.min(size.m), size.n, size.k);
    let mut projected: Vec<f64> = vec![0.0; d];
    let mut per_block_time: Vec<f64> = vec![f64::INFINITY; d];
    {
        let mut probe = SimMachine::new(sim.config(), 0xB10C);
        for dev in 0..d {
            let o = probe.execute(&WorkOrder {
                items: vec![WorkItem::whole(dev, block(block_rows), 1)],
                reps: 1,
            });
            per_block_time[dev] = o.makespan;
        }
    }
    let mut remaining = n_blocks;
    while remaining > 0 {
        let dev = (0..d)
            .min_by(|&a, &b| {
                (projected[a] + per_block_time[a]).total_cmp(&(projected[b] + per_block_time[b]))
            })
            .unwrap();
        projected[dev] += per_block_time[dev];
        device_blocks[dev] += 1;
        remaining -= 1;
    }

    // Execute: each device's blocks are separate sub-products of one
    // slice (so A/B/C copies are per-block, modelled by per-block h2d:
    // approximated as one slice copy — the queue's extra copy cost is
    // captured by the extra launch overheads and tail imbalance).
    let mut items = Vec::new();
    let mut row_cursor = 0u64;
    for (dev, &blocks) in device_blocks.iter().enumerate() {
        if blocks == 0 {
            continue;
        }
        let rows = (blocks * block_rows).min(size.m - row_cursor);
        if rows == 0 {
            continue;
        }
        row_cursor += rows;
        let slice = size.row_slice(rows);
        let subproducts: Vec<GemmSize> = (0..blocks)
            .map(|b| {
                let r = if b == blocks - 1 {
                    rows - (blocks - 1) * block_rows.min(rows)
                } else {
                    block_rows
                };
                GemmSize::new(r.max(1), size.n, size.k)
            })
            .collect();
        let kind = sim.config().devices[dev].kind;
        let priority = match kind {
            DeviceKind::Xpu => 2,
            DeviceKind::Gpu => 1,
            DeviceKind::Cpu => 0,
        };
        items.push(WorkItem {
            device: dev,
            slice,
            subproducts,
            priority,
        });
    }
    let _ = rules;
    let outcome = sim.execute(&WorkOrder { items, reps });
    Ok((outcome, device_blocks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::predict::{profile, ProfileOptions};
    use crate::schedule::{build_plan, static_sched::rules_from_config, PlanOptions};

    fn sim() -> SimMachine {
        SimMachine::new(&presets::mach1(), 0)
    }

    #[test]
    fn standalone_ordering_matches_device_speeds() {
        let size = GemmSize::square(20_000);
        let mut s = sim();
        let t_cpu = standalone(&mut s, 0, size, 2).makespan;
        let t_gpu = standalone(&mut s, 1, size, 2).makespan;
        let t_xpu = standalone(&mut s, 2, size, 2).makespan;
        assert!(t_cpu > t_gpu && t_gpu > t_xpu, "{t_cpu} {t_gpu} {t_xpu}");
    }

    #[test]
    fn equal_split_worse_than_poas() {
        let cfg = presets::mach1();
        let size = GemmSize::square(20_000);
        let mut s = SimMachine::new(&cfg, 0);
        let model = profile(&mut s, &ProfileOptions::default()).unwrap();
        let plan = build_plan(
            &model,
            size,
            &rules_from_config(&cfg),
            &PlanOptions::default(),
        )
        .unwrap();
        let t_poas = s.execute(&plan.to_work_order(5)).makespan;
        let mut s2 = SimMachine::new(&cfg, 0);
        let t_equal = equal_split(&mut s2, size, 5, &[0, 1, 2]).makespan;
        // Equal split leaves the CPU with 1/3 of the work: catastrophic.
        assert!(
            t_equal > 3.0 * t_poas,
            "equal {t_equal} vs poas {t_poas}"
        );
    }

    #[test]
    fn ratio_split_between_equal_and_poas() {
        let cfg = presets::mach1();
        let size = GemmSize::square(20_000);
        let mut s = SimMachine::new(&cfg, 0);
        let model = profile(&mut s, &ProfileOptions::default()).unwrap();
        let plan = build_plan(
            &model,
            size,
            &rules_from_config(&cfg),
            &PlanOptions::default(),
        )
        .unwrap();
        let t_poas = s.execute(&plan.to_work_order(5)).makespan;

        let mut s2 = SimMachine::new(&cfg, 0);
        let t_ratio = ratio_split(&mut s2, &model, size, 5).makespan;
        let mut s3 = SimMachine::new(&cfg, 0);
        let t_equal = equal_split(&mut s3, size, 5, &[0, 1, 2]).makespan;
        assert!(t_ratio < t_equal, "ratio {t_ratio} vs equal {t_equal}");
        // Ratio split ignores copies; POAS should be at least as good
        // (allow tiny noise slack).
        assert!(t_poas <= t_ratio * 1.05, "poas {t_poas} vs ratio {t_ratio}");
    }

    #[test]
    fn work_queue_balances_by_speed() {
        let cfg = presets::mach1();
        let size = GemmSize::square(20_000);
        let mut s = SimMachine::new(&cfg, 0);
        let rules = rules_from_config(&cfg);
        let (outcome, blocks) = work_queue(&mut s, size, 2, 1000, &rules).unwrap();
        assert!(outcome.makespan > 0.0);
        // XPU pulled the most blocks, CPU the fewest.
        assert!(blocks[2] > blocks[1], "{blocks:?}");
        assert!(blocks[1] > blocks[0], "{blocks:?}");
        // All rows covered.
        let total_rows: u64 = blocks.iter().sum::<u64>() * 1000;
        assert!(total_rows >= size.m);
    }

    #[test]
    fn work_queue_close_to_poas_but_not_better() {
        let cfg = presets::mach1();
        let size = GemmSize::square(20_000);
        let mut s = SimMachine::new(&cfg, 0);
        let model = profile(&mut s, &ProfileOptions::default()).unwrap();
        let rules = rules_from_config(&cfg);
        let plan = build_plan(&model, size, &rules, &PlanOptions::default()).unwrap();
        let t_poas = s.execute(&plan.to_work_order(5)).makespan;
        let mut s2 = SimMachine::new(&cfg, 0);
        let (o, _) = work_queue(&mut s2, size, 5, 1000, &rules).unwrap();
        // The queue balances reasonably but pays block overheads; POAS
        // should win or tie.
        assert!(t_poas <= o.makespan * 1.05, "poas {t_poas} queue {}", o.makespan);
    }
}
