//! Table and figure rendering for the evaluation regenerators.
//!
//! Every bench prints the same rows/series the paper reports; this
//! module owns the formatting so tables look uniform: fixed-width text
//! tables (paper tables) and ASCII bar charts (Figs. 3–4).

/// A simple fixed-width text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience for string-slices.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:>w$} |", w = w));
            }
            line.push('\n');
            line
        };
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s.push('\n');
            s
        };
        out.push_str(&sep);
        out.push_str(&fmt_row(&self.header, &widths));
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out.push_str(&sep);
        let _ = ncols;
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Horizontal ASCII bar chart: one group of bars per label (Figs. 3–4
/// style: execution time per input, one bar per device/system).
#[derive(Debug, Clone, Default)]
pub struct BarChart {
    title: String,
    unit: String,
    groups: Vec<(String, Vec<(String, f64)>)>,
}

impl BarChart {
    pub fn new(title: &str, unit: &str) -> Self {
        BarChart {
            title: title.to_string(),
            unit: unit.to_string(),
            groups: Vec::new(),
        }
    }

    /// Add a group (e.g. input "i1") with (series name, value) bars.
    pub fn group(&mut self, label: &str, bars: &[(&str, f64)]) -> &mut Self {
        self.groups.push((
            label.to_string(),
            bars.iter().map(|(n, v)| (n.to_string(), *v)).collect(),
        ));
        self
    }

    /// Render with bars scaled to `width` characters at the global max.
    pub fn render(&self, width: usize) -> String {
        let max = self
            .groups
            .iter()
            .flat_map(|(_, bars)| bars.iter().map(|(_, v)| *v))
            .fold(0.0f64, f64::max);
        let mut out = format!("== {} ({}) ==\n", self.title, self.unit);
        if max <= 0.0 {
            return out;
        }
        let name_w = self
            .groups
            .iter()
            .flat_map(|(_, b)| b.iter().map(|(n, _)| n.len()))
            .max()
            .unwrap_or(4);
        for (label, bars) in &self.groups {
            out.push_str(&format!("{label}\n"));
            for (name, v) in bars {
                let len = ((v / max) * width as f64).round() as usize;
                out.push_str(&format!(
                    "  {name:>name_w$} | {:<width$} {v:.3}\n",
                    "█".repeat(len.max(if *v > 0.0 { 1 } else { 0 })),
                ));
            }
        }
        out
    }

    pub fn print(&self, width: usize) {
        print!("{}", self.render(width));
    }
}

/// Format a share as "12.34%".
pub fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

/// Format a speedup as "1.23x".
pub fn times(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format a service rate as "12.34 req/s".
pub fn rate(x: f64) -> String {
    format!("{x:.2} req/s")
}

/// Format seconds adaptively (s / ms / µs).
pub fn secs(x: f64) -> String {
    if x >= 1.0 {
        format!("{x:.3}s")
    } else if x >= 1e-3 {
        format!("{:.3}ms", x * 1e3)
    } else {
        format!("{:.1}µs", x * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["id", "value"]);
        t.row_str(&["i1", "27.0"]).row_str(&["i2", "42.0"]);
        let s = t.render();
        assert!(s.contains("Demo"));
        assert!(s.contains("i1"));
        // All body lines equal length.
        let lines: Vec<&str> = s.lines().skip(1).collect();
        let lens: Vec<usize> = lines.iter().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn barchart_scales_to_max() {
        let mut b = BarChart::new("Exec time", "s");
        b.group("i1", &[("cpu", 10.0), ("xpu", 2.0)]);
        let s = b.render(40);
        assert!(s.contains("cpu"));
        assert!(s.contains("10.000"));
        // cpu bar longer than xpu bar.
        let cpu_len = s.lines().find(|l| l.contains("cpu")).unwrap().matches('█').count();
        let xpu_len = s.lines().find(|l| l.contains("xpu")).unwrap().matches('█').count();
        assert!(cpu_len > xpu_len);
    }

    #[test]
    fn formatters() {
        assert_eq!(pct(0.1234), "12.34%");
        assert_eq!(times(1.5), "1.50x");
        assert_eq!(secs(2.5), "2.500s");
        assert_eq!(secs(0.0025), "2.500ms");
        assert_eq!(secs(2.5e-6), "2.5µs");
        assert_eq!(rate(12.345), "12.35 req/s");
    }
}
