//! The coordinator: end-to-end POAS pipelines.
//!
//! * [`pipeline`] — the simulated-testbed pipeline (profile → plan →
//!   execute on [`crate::sim::SimMachine`]): what every paper-table
//!   regenerator drives;
//! * [`pjrt`] — the real-execution pipeline: profile the PJRT
//!   executables, plan with the same POAS code, then co-execute the GEMM
//!   with one worker thread per "device", each running its row band
//!   through the AOT artifacts, and assemble + verify C.

pub mod pipeline;
pub mod pjrt;

pub use pipeline::{Pipeline, RunResult};
pub use pjrt::{PjrtCoordinator, PjrtRun};
