//! Real-execution coordinator: POAS over the PJRT artifacts.
//!
//! The full three-layer stack on a real workload: the Predict phase
//! profiles the AOT executables with wall-clock microbenchmarks, the
//! Optimize/Adapt/Schedule phases run the identical code the simulated
//! pipeline uses, and execution co-runs one worker thread per "device"
//! (cpu/gpu → f32 artifacts, xpu → bf16 artifacts), each computing its
//! row band of C through the PJRT client. The assembled C is verified
//! against a host-side reference matmul.
//!
//! On this CPU-only testbed the three "devices" share silicon, so the
//! point is not speedup — it is proving the layers compose: profiling,
//! MILP split, ops_to_mnk, priority ordering, artifact execution and
//! assembly all run exactly as they would with three real accelerators.

use crate::config::{presets, DeviceKind, MachineConfig};
use crate::error::{Error, Result};
use crate::metrics::Stopwatch;
use crate::predict::{profile, PerfModel, ProfileOptions, ProfileTarget};
use crate::schedule::{build_plan, static_sched::rules_from_config, PlanOptions, SchedulePlan};
use crate::runtime::Runtime;
use crate::workload::{GemmSize, Matrix};
use std::path::{Path, PathBuf};

/// Profiling target backed by the real PJRT runtime.
struct PjrtProfileTarget {
    cfg: MachineConfig,
    runtime: Runtime,
    rng: crate::rng::Rng,
}

impl ProfileTarget for PjrtProfileTarget {
    fn machine_name(&self) -> String {
        self.cfg.name.clone()
    }

    fn num_devices(&self) -> usize {
        self.cfg.devices.len()
    }

    fn device_meta(&self, dev: usize) -> (String, DeviceKind, u64, u64) {
        let d = &self.cfg.devices[dev];
        (d.name.clone(), d.kind, d.profile_lo, d.profile_hi)
    }

    fn device_align(&self, dev: usize) -> u64 {
        self.cfg.devices[dev].align
    }

    fn bench_compute(&mut self, dev: usize, s: u64) -> f64 {
        let kind = self.cfg.devices[dev].kind.artifact_kind();
        let a = Matrix::random(s as usize, s as usize, &mut self.rng);
        let b = Matrix::random(s as usize, s as usize, &mut self.rng);
        let sw = Stopwatch::start();
        self.runtime
            .run_gemm(kind, &a, &b)
            .expect("profiling GEMM failed");
        sw.elapsed_s()
    }

    fn bench_transfer(&mut self, dev: usize, bytes: f64) -> Option<f64> {
        if self.cfg.devices[dev].kind == DeviceKind::Cpu {
            return None;
        }
        // "Copies" on this host are memcpys; measure honestly anyway so
        // the pipeline exercises its bandwidth model.
        let n = (bytes as usize / 4).max(1);
        let src = vec![1.0f32; n];
        let sw = Stopwatch::start();
        let dst = src.clone();
        let t = sw.elapsed_s().max(1e-9);
        std::hint::black_box(&dst);
        Some(t)
    }
}

/// Per-device stats from one real co-execution.
#[derive(Debug, Clone)]
pub struct DeviceRunStats {
    pub device: usize,
    pub name: String,
    pub rows: u64,
    /// Wall-clock seconds the worker spent computing.
    pub compute_s: f64,
    /// Tiles executed through PJRT.
    pub tiles: usize,
}

/// Result of one real co-executed GEMM.
#[derive(Debug, Clone)]
pub struct PjrtRun {
    /// The product matrix.
    pub c: Matrix,
    /// Wall-clock makespan of the co-execution (seconds).
    pub makespan_s: f64,
    /// Per-device stats.
    pub devices: Vec<DeviceRunStats>,
    /// The plan that was executed.
    pub plan: SchedulePlan,
    /// Relative Frobenius error vs the host reference (if verified).
    pub verify_rel_err: Option<f64>,
}

/// The real-execution coordinator.
pub struct PjrtCoordinator {
    artifact_dir: PathBuf,
    cfg: MachineConfig,
    /// The fitted model from PJRT profiling.
    pub model: PerfModel,
    opts: PlanOptions,
}

impl PjrtCoordinator {
    /// Profile the PJRT executables and build the coordinator.
    ///
    /// `profile_sizes` shrinks the installation benchmark for tests
    /// (`None` = the pjrt_local preset's 64..256 menu).
    pub fn new(artifact_dir: &Path, prof: Option<ProfileOptions>) -> Result<Self> {
        let cfg = presets::pjrt_local();
        let runtime = Runtime::new(artifact_dir)?;
        let mut target = PjrtProfileTarget {
            cfg: cfg.clone(),
            runtime,
            rng: crate::rng::Rng::new(0xBEEF),
        };
        let prof = prof.unwrap_or(ProfileOptions {
            num_sizes: 4,
            reps: 2,
            transfer_bytes: vec![1e6, 4e6, 16e6],
            transfer_reps: 3,
            ..Default::default()
        });
        let model = profile(&mut target, &prof)?;
        Ok(PjrtCoordinator {
            artifact_dir: artifact_dir.to_path_buf(),
            cfg,
            model,
            opts: PlanOptions::default(),
        })
    }

    /// Plan a co-execution for an (m, n, k) GEMM.
    pub fn plan(&self, size: GemmSize) -> Result<SchedulePlan> {
        build_plan(&self.model, size, &rules_from_config(&self.cfg), &self.opts)
    }

    /// Co-execute `C = A @ B` across the three worker "devices".
    ///
    /// Each active device gets its row band of A (and the whole B), runs
    /// its band through its artifact family on its own PJRT client, and
    /// the bands are assembled into C. With `verify`, C is checked
    /// against the host triple-loop reference.
    pub fn run(&self, a: &Matrix, b: &Matrix, verify: bool) -> Result<PjrtRun> {
        let size = GemmSize::new(a.rows() as u64, b.cols() as u64, a.cols() as u64);
        if a.cols() != b.rows() {
            return Err(Error::Workload(format!(
                "contraction mismatch: A {}x{}, B {}x{}",
                a.rows(),
                a.cols(),
                b.rows(),
                b.cols()
            )));
        }
        let plan = self.plan(size)?;

        let sw = Stopwatch::start();
        let mut c = Matrix::zeros(a.rows(), b.cols());
        let mut stats: Vec<DeviceRunStats> = Vec::new();

        // One worker thread per active device; each creates its own PJRT
        // client (clients are cheap on CPU and per-thread ownership
        // avoids cross-thread handle questions).
        let bands: Vec<(usize, u64, u64)> = plan
            .assignments
            .iter()
            .filter(|asg| asg.rows > 0)
            .map(|asg| (asg.device, asg.row_offset, asg.rows))
            .collect();

        let artifact_dir = self.artifact_dir.clone();
        let cfg = &self.cfg;
        let results: Vec<Result<(usize, u64, u64, Matrix, f64, usize)>> =
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for &(dev, off, rows) in &bands {
                    let a_band = a.row_band(off as usize, rows as usize);
                    let b_ref = b;
                    let dir = artifact_dir.clone();
                    let kind = cfg.devices[dev].kind.artifact_kind();
                    handles.push(scope.spawn(move || {
                        let mut rt = Runtime::new(&dir)?;
                        let sw = Stopwatch::start();
                        let band_c = rt.run_gemm(kind, &a_band, b_ref)?;
                        Ok((dev, off, rows, band_c, sw.elapsed_s(), rt.executions))
                    }));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("worker panicked"))
                    .collect()
            });

        for r in results {
            let (dev, off, rows, band_c, secs, tiles) = r?;
            c.set_block(off as usize, 0, rows as usize, b.cols(), &band_c);
            stats.push(DeviceRunStats {
                device: dev,
                name: self.cfg.devices[dev].name.clone(),
                rows,
                compute_s: secs,
                tiles,
            });
        }
        let makespan_s = sw.elapsed_s();

        let verify_rel_err = if verify {
            let reference = a.matmul(b);
            Some(c.rel_frob_diff(&reference))
        } else {
            None
        };

        Ok(PjrtRun {
            c,
            makespan_s,
            devices: stats,
            plan,
            verify_rel_err,
        })
    }
}

// NOTE: integration coverage for this module lives in
// rust/tests/runtime_pjrt.rs — it needs `make artifacts` outputs, which
// unit tests must not depend on.
