//! Simulated-testbed pipeline: the four POAS phases against a
//! [`SimMachine`].
//!
//! This is the driver used by the evaluation regenerators: it profiles
//! the simulated machine exactly once (the paper profiles at installation
//! time, §4.1.2), then plans and executes workloads on demand, optionally
//! with the dynamic scheduler in the loop.

use crate::config::MachineConfig;
use crate::error::Result;
use crate::predict::{profile, PerfModel, ProfileOptions};
use crate::schedule::{
    build_plan, static_sched::rules_from_config, DynamicScheduler, PlanOptions, SchedulePlan,
};
use crate::adapt::AdaptRules;
use crate::sim::{ExecOutcome, SimMachine};
use crate::workload::GemmSize;

/// Outcome of one pipeline run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// The schedule that was executed.
    pub plan: SchedulePlan,
    /// Simulator outcome.
    pub exec: ExecOutcome,
    /// Convenience copy of `exec.makespan` (seconds, all repetitions).
    pub makespan: f64,
}

/// A POAS pipeline bound to a simulated machine.
#[derive(Debug, Clone)]
pub struct Pipeline {
    /// The machine being driven.
    pub sim: SimMachine,
    /// The fitted model (Predict output).
    pub model: PerfModel,
    /// Adapt-phase rules per device.
    pub rules: Vec<AdaptRules>,
    /// Plan construction options.
    pub opts: PlanOptions,
}

impl Pipeline {
    /// Build a pipeline for a simulated machine: constructs the
    /// simulator with `seed` and runs the installation-time profiling.
    pub fn for_simulated_machine(cfg: &MachineConfig, seed: u64) -> Self {
        Self::with_options(cfg, seed, &ProfileOptions::default(), PlanOptions::default())
    }

    /// Full-control constructor.
    pub fn with_options(
        cfg: &MachineConfig,
        seed: u64,
        prof: &ProfileOptions,
        opts: PlanOptions,
    ) -> Self {
        let mut sim = SimMachine::new(cfg, seed);
        let model = profile(&mut sim, prof).expect("profiling a valid machine cannot fail");
        // Paper: experiments run after profiling with the machine idle.
        sim.rest(120.0);
        let rules = rules_from_config(cfg);
        Pipeline {
            sim,
            model,
            rules,
            opts,
        }
    }

    /// Plan a workload (static scheduling, §3.4.1).
    pub fn plan(&self, size: GemmSize) -> Result<SchedulePlan> {
        build_plan(&self.model, size, &self.rules, &self.opts)
    }

    /// Plan + execute `reps` repetitions on the simulated machine.
    pub fn run_sim(&mut self, size: GemmSize, reps: u32) -> RunResult {
        let plan = self.plan(size).expect("planning failed");
        let exec = self.sim.execute(&plan.to_work_order(reps));
        RunResult {
            makespan: exec.makespan,
            plan,
            exec,
        }
    }

    /// Run with the dynamic scheduler (§3.4.2): execute `rounds`
    /// consecutive workloads, refreshing the model from observations and
    /// re-planning when it drifts. Returns per-round results and the
    /// scheduler state.
    pub fn run_sim_dynamic(
        &mut self,
        size: GemmSize,
        reps: u32,
        rounds: usize,
    ) -> (Vec<RunResult>, DynamicScheduler) {
        let mut dynsched = DynamicScheduler::new(self.model.clone());
        let mut plan = dynsched
            .plan(size, &self.rules, &self.opts)
            .expect("planning failed");
        let mut results = Vec::with_capacity(rounds);
        for _ in 0..rounds {
            let exec = self.sim.execute(&plan.to_work_order(reps));
            let replan = dynsched.observe(&plan, &exec, reps);
            results.push(RunResult {
                makespan: exec.makespan,
                plan: plan.clone(),
                exec,
            });
            if replan {
                plan = dynsched
                    .plan(size, &self.rules, &self.opts)
                    .expect("re-planning failed");
            }
        }
        (results, dynsched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn pipeline_end_to_end_mach1() {
        let cfg = presets::mach1();
        let mut p = Pipeline::for_simulated_machine(&cfg, 42);
        let r = p.run_sim(GemmSize::square(30_000), 5);
        assert!(r.makespan > 0.0);
        assert_eq!(r.plan.active_devices(), 3);
        // XPU dominates the split.
        let shares = r.plan.shares();
        assert!(shares[2] > 0.6);
    }

    #[test]
    fn coexecution_beats_standalone_xpu() {
        let cfg = presets::mach2();
        let mut p = Pipeline::for_simulated_machine(&cfg, 7);
        let size = GemmSize::square(30_000);
        let reps = 10;
        let co = p.run_sim(size, reps).makespan;
        let alone = crate::baselines::standalone(&mut p.sim, 2, size, reps).makespan;
        let speedup = alone / co;
        // Bounds are deliberately loose: the exact figure moves with the
        // simulator's noise/thermal draws per seed. The paper's Table 7
        // band is 1.14-1.45x; we only pin "co-execution wins, and not by
        // an impossible factor".
        assert!(
            speedup > 1.02 && speedup < 2.5,
            "speedup vs XPU = {speedup}"
        );
    }

    #[test]
    fn dynamic_run_produces_rounds() {
        let cfg = presets::mach1();
        let mut p = Pipeline::for_simulated_machine(&cfg, 3);
        let (results, dynsched) = p.run_sim_dynamic(GemmSize::square(30_000), 20, 4);
        assert_eq!(results.len(), 4);
        // mach1 throttles -> at least one replan.
        assert!(dynsched.replans >= 1);
    }

    #[test]
    fn different_seeds_different_noise() {
        let cfg = presets::mach1();
        let mut a = Pipeline::for_simulated_machine(&cfg, 1);
        let mut b = Pipeline::for_simulated_machine(&cfg, 2);
        let size = GemmSize::square(20_000);
        let ra = a.run_sim(size, 3).makespan;
        let rb = b.run_sim(size, 3).makespan;
        assert_ne!(ra, rb);
        // ... but close (same machine).
        assert!((ra - rb).abs() / ra < 0.1);
    }
}
