//! Hardware adjustments (paper §4.3.2): tensor-core alignment and row
//! rebalancing.
//!
//! Tensor cores only run at full rate when `m % 8 == 0 && k % 8 == 0`
//! (paper footnote 1). The `ops_to_mnk` algorithm therefore shaves the
//! XPU's row count down to the alignment boundary — and because every C
//! row must still be computed, the shaved rows are handed to the next
//! fastest device (the paper notes the shifted amount is "barely
//! noticeable since the size reduction is tiny compared to the global
//! size").

/// Per-device adapt-phase rules (public hardware documentation — not
/// hidden performance state: cuBLAS alignment restrictions and cache
/// sizes come from datasheets, as in the paper).
#[derive(Debug, Clone, Copy)]
pub struct AdaptRules {
    /// Row-count alignment for full-rate operation (8 on XPU, 1 else).
    pub align: u64,
    /// Smallest profiled sub-product op count.
    pub ops_lo: f64,
    /// Largest profiled sub-product op count (cache-fit bound on CPUs).
    pub ops_hi: f64,
}

impl AdaptRules {
    /// Unconstrained rules (align 1, unbounded tile size).
    pub fn none() -> Self {
        AdaptRules {
            align: 1,
            ops_lo: 0.0,
            ops_hi: f64::INFINITY,
        }
    }
}

/// Align each device's row count: rows are rounded *down* to the
/// device's alignment, and freed rows are reassigned to the device with
/// the highest `fallback_rank` (typically the fastest unaligned device).
///
/// * `rows[i]` — rows assigned by the data adjustment step;
/// * `rules[i].align` — alignment of device `i`;
/// * `fallback_rank[i]` — preference order for absorbing leftovers
///   (higher = preferred); devices with `align > 1` never absorb.
///
/// Returns the adjusted row vector; total row count is preserved.
pub fn align_rows(rows: &[u64], rules: &[AdaptRules], fallback_rank: &[u32]) -> Vec<u64> {
    assert_eq!(rows.len(), rules.len());
    assert_eq!(rows.len(), fallback_rank.len());
    let mut out = rows.to_vec();
    let mut freed = 0u64;
    for (i, r) in out.iter_mut().enumerate() {
        let a = rules[i].align.max(1);
        let rem = *r % a;
        if rem != 0 {
            *r -= rem;
            freed += rem;
        }
    }
    if freed > 0 {
        // Absorber: highest rank among devices that accept any row count.
        let absorber = (0..out.len())
            .filter(|&i| rules[i].align <= 1)
            .max_by_key(|&i| fallback_rank[i]);
        match absorber {
            Some(i) => out[i] += freed,
            None => {
                // Every device is aligned: give the freed rows to the
                // highest-ranked device anyway (they run at reduced rate
                // for the remainder stripe — still correct).
                let i = (0..out.len()).max_by_key(|&i| fallback_rank[i]).unwrap();
                out[i] += freed;
            }
        }
    }
    out
}

/// Split `total_rows` proportionally to `ops[i]`, exactly conserving the
/// total via the largest-remainder method (the data adjustment of
/// §4.3.1: `m = ops / (n*k)` per device, made integral).
pub fn ops_to_rows(ops: &[f64], total_rows: u64) -> Vec<u64> {
    let sum: f64 = ops.iter().sum();
    if sum <= 0.0 {
        let mut out = vec![0u64; ops.len()];
        if !out.is_empty() {
            out[0] = total_rows;
        }
        return out;
    }
    let exact: Vec<f64> = ops
        .iter()
        .map(|o| (o / sum) * total_rows as f64)
        .collect();
    let mut rows: Vec<u64> = exact.iter().map(|e| e.floor() as u64).collect();
    let assigned: u64 = rows.iter().sum();
    let mut leftover = total_rows - assigned;
    // Largest fractional parts first; ties by index for determinism.
    let mut order: Vec<usize> = (0..ops.len()).collect();
    order.sort_by(|&a, &b| {
        let fa = exact[a] - exact[a].floor();
        let fb = exact[b] - exact[b].floor();
        fb.total_cmp(&fa).then(a.cmp(&b))
    });
    for i in order {
        if leftover == 0 {
            break;
        }
        rows[i] += 1;
        leftover -= 1;
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(aligns: &[u64]) -> Vec<AdaptRules> {
        aligns
            .iter()
            .map(|&a| AdaptRules {
                align: a,
                ops_lo: 0.0,
                ops_hi: f64::INFINITY,
            })
            .collect()
    }

    #[test]
    fn ops_to_rows_conserves_total() {
        let rows = ops_to_rows(&[0.0032, 0.2126, 0.7842], 30_000);
        assert_eq!(rows.iter().sum::<u64>(), 30_000);
        // Proportions approximately honored.
        assert!((rows[2] as f64 - 0.7842 * 30_000.0).abs() <= 1.0);
    }

    #[test]
    fn ops_to_rows_zero_sum_fallback() {
        let rows = ops_to_rows(&[0.0, 0.0], 10);
        assert_eq!(rows.iter().sum::<u64>(), 10);
    }

    #[test]
    fn ops_to_rows_exact_split() {
        let rows = ops_to_rows(&[1.0, 1.0], 10);
        assert_eq!(rows, vec![5, 5]);
    }

    #[test]
    fn align_shaves_and_rebalances() {
        // XPU (align 8) has 23077 rows -> 23072; 5 rows go to the GPU.
        let rows = vec![96, 6827, 23_077];
        let r = rules(&[1, 1, 8]);
        let out = align_rows(&rows, &r, &[0, 1, 2]);
        assert_eq!(out[2] % 8, 0);
        assert_eq!(out.iter().sum::<u64>(), rows.iter().sum::<u64>());
        assert_eq!(out[2], 23_072);
        assert_eq!(out[1], 6827 + 5);
    }

    #[test]
    fn aligned_input_untouched() {
        let rows = vec![100, 6800, 23_072];
        let r = rules(&[1, 1, 8]);
        let out = align_rows(&rows, &r, &[0, 1, 2]);
        assert_eq!(out, rows);
    }

    #[test]
    fn all_aligned_devices_still_conserve() {
        let rows = vec![13, 27];
        let r = rules(&[8, 8]);
        let out = align_rows(&rows, &r, &[1, 2]);
        assert_eq!(out.iter().sum::<u64>(), 40);
        // device 1 (higher rank) absorbs.
        assert_eq!(out[0], 8);
        assert_eq!(out[1], 32);
    }

    #[test]
    fn zero_rows_stay_zero() {
        let rows = vec![0, 0, 16];
        let r = rules(&[1, 1, 8]);
        let out = align_rows(&rows, &r, &[0, 1, 2]);
        assert_eq!(out, vec![0, 0, 16]);
    }
}
