//! Square sub-matrix decomposition driven by the Eq. 5 heuristic.
//!
//! Profiling only measures *square* products, so prediction is accurate
//! only when real work is shaped like profiling work (§4.1.2). The Adapt
//! phase therefore expresses each device's (M, n, k) slice as a list of
//! near-square sub-products (§4.3.1):
//!
//! * `n' = n` always (splitting n would produce partial C results);
//! * `k'` ranges over divisors of `k` ("the number of horizontal
//!   dimensions in A fits perfectly: k % k' == 0");
//! * `m'` is chosen to make tiles square-ish while keeping each tile's
//!   op count inside the device's profiled range;
//! * among candidates, the decomposition maximizing the paper's
//!   squareness score (Eq. 5) wins:
//!   `sq = Σ_i min(m'_i,k'_i)/max(m'_i,k'_i) * m'_i * k'_i * n`.

use crate::workload::GemmSize;

/// All divisors of `x`, ascending. O(sqrt x).
pub fn divisors(x: u64) -> Vec<u64> {
    assert!(x >= 1);
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= x {
        if x % d == 0 {
            small.push(d);
            if d != x / d {
                large.push(x / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// The Eq. 5 squareness score of a tile list (higher = more square).
pub fn squareness_score(tiles: &[GemmSize]) -> f64 {
    tiles
        .iter()
        .map(|t| {
            let (m, k) = (t.m as f64, t.k as f64);
            (m.min(k) / m.max(k)) * m * k * t.n as f64
        })
        .sum()
}

/// One candidate decomposition of a device slice.
#[derive(Debug, Clone)]
pub struct Decomposition {
    /// Sub-products in execution order (row-major over the m×k grid).
    pub tiles: Vec<GemmSize>,
    /// Chosen k' (divides k).
    pub k_prime: u64,
    /// Chosen nominal m' (last row-stripe may be smaller).
    pub m_prime: u64,
    /// Eq. 5 score.
    pub score: f64,
}

/// Decompose a `(rows, n, k)` slice into square-ish sub-products whose
/// op counts stay within `[ops_lo, ops_hi]` (the device's profiled
/// range) in a best-effort manner, honoring the device's `align`
/// requirement on every tile's m and k (paper §4.3.2: tensor cores need
/// `m % 8 == 0 && k % 8 == 0` *per executed product*, so the
/// decomposition must not create misaligned tiles out of an aligned
/// slice). Returns the highest-scoring decomposition, or a single
/// whole-slice tile when the slice is already within range or too small
/// to split.
pub fn decompose(
    rows: u64,
    n: u64,
    k: u64,
    ops_lo: f64,
    ops_hi: f64,
    align: u64,
) -> Decomposition {
    assert!(rows >= 1 && n >= 1 && k >= 1);
    let align = align.max(1);
    let whole = GemmSize::new(rows, n, k);
    let fallback = Decomposition {
        score: squareness_score(std::slice::from_ref(&whole)),
        tiles: vec![whole],
        k_prime: k,
        m_prime: rows,
    };
    if whole.ops() <= ops_hi {
        return fallback;
    }

    // Scan candidates with an *analytic* Eq. 5 score — the tile grid is
    // (full_stripes + remainder) x k_chunks copies of at most two
    // distinct shapes, so the score needs no materialized tile list.
    // (Perf: materializing every candidate's tiles made ops_to_mnk the
    // hot spot of plan construction — see EXPERIMENTS.md §Perf.)
    let tile_score = |m_p: u64, k_p: u64| -> f64 {
        let (m, kk) = (m_p as f64, k_p as f64);
        (m.min(kk) / m.max(kk)) * m * kk * n as f64
    };
    let mut best: Option<(u64, u64, f64)> = None; // (k', m', score)
    for k_prime in divisors(k) {
        // Alignment: an aligned slice must stay aligned tile-by-tile.
        if k_prime % align != 0 && k_prime != k {
            continue;
        }
        // m' bounds from the op-range constraint for a (m', n, k') tile.
        let nk = (n * k_prime) as f64;
        let m_lo = (ops_lo / nk).ceil().max(1.0) as u64;
        let m_hi = (ops_hi / nk).floor() as u64;
        if m_hi == 0 || m_lo > m_hi {
            continue; // this k' cannot yield in-range tiles
        }
        // Best-effort square: m' as close to k' as the range allows,
        // rounded to the alignment (rows are align-multiples already, so
        // remainder stripes stay aligned too).
        let mut m_prime = k_prime.clamp(m_lo, m_hi).min(rows);
        if align > 1 && m_prime >= align {
            m_prime -= m_prime % align;
        }
        if m_prime == 0 {
            continue;
        }
        let k_chunks = k / k_prime;
        let full_stripes = rows / m_prime;
        let rem_rows = rows % m_prime;
        let mut score = (full_stripes * k_chunks) as f64 * tile_score(m_prime, k_prime);
        if rem_rows > 0 {
            score += k_chunks as f64 * tile_score(rem_rows, k_prime);
        }
        if best.map(|(_, _, s)| score > s).unwrap_or(true) {
            best = Some((k_prime, m_prime, score));
        }
    }

    let Some((k_prime, m_prime, score)) = best else {
        return fallback;
    };
    // Materialize only the winning decomposition.
    let k_chunks = k / k_prime;
    let full_stripes = rows / m_prime;
    let rem_rows = rows % m_prime;
    let mut tiles = Vec::with_capacity(((full_stripes + 1) * k_chunks) as usize);
    for _ in 0..full_stripes {
        for _ in 0..k_chunks {
            tiles.push(GemmSize::new(m_prime, n, k_prime));
        }
    }
    if rem_rows > 0 {
        for _ in 0..k_chunks {
            tiles.push(GemmSize::new(rem_rows, n, k_prime));
        }
    }
    Decomposition {
        tiles,
        k_prime,
        m_prime,
        score,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(30_000).len(), 50);
        for d in divisors(30_000) {
            assert_eq!(30_000 % d, 0);
        }
    }

    #[test]
    fn score_prefers_square() {
        let square = vec![GemmSize::new(100, 50, 100)];
        let skinny = vec![GemmSize::new(1000, 50, 10)];
        // Same volume, different squareness.
        assert_eq!(square[0].ops(), skinny[0].ops());
        assert!(squareness_score(&square) > squareness_score(&skinny));
    }

    #[test]
    fn small_slice_left_whole() {
        let d = decompose(100, 100, 100, 1e3, 1e9, 1);
        assert_eq!(d.tiles, vec![GemmSize::new(100, 100, 100)]);
    }

    #[test]
    fn tiles_conserve_ops() {
        let (rows, n, k) = (23_070, 30_000, 30_000);
        let lo = 27e9; // 3000^3
        let hi = 216e9; // 6000^3
        let d = decompose(rows, n, k, lo, hi, 1);
        let total: f64 = d.tiles.iter().map(|t| t.ops()).sum();
        let want = (rows as f64) * (n as f64) * (k as f64);
        assert!((total - want).abs() < 1.0, "ops not conserved");
        assert!(d.tiles.len() > 1);
    }

    #[test]
    fn tiles_within_profiled_range_mostly() {
        let d = decompose(23_070, 30_000, 30_000, 27e9, 216e9, 1);
        // All full stripes in range; only remainder stripes may dip below.
        let full = d
            .tiles
            .iter()
            .filter(|t| t.m == d.m_prime)
            .collect::<Vec<_>>();
        assert!(!full.is_empty());
        for t in full {
            assert!(t.ops() <= 216e9 * (1.0 + 1e-9), "tile too big: {t}");
            assert!(t.ops() >= 27e9 * (1.0 - 1e-9), "tile too small: {t}");
        }
    }

    #[test]
    fn k_prime_divides_k() {
        for k in [30_000u64, 35_000, 20_000, 40_000] {
            let d = decompose(10_000, 20_000, k, 27e9, 216e9, 1);
            assert_eq!(k % d.k_prime, 0, "k'={} !| k={}", d.k_prime, k);
        }
    }

    #[test]
    fn near_square_tiles_for_cpu_range() {
        // CPU range [1e9, 8e9] (1000^3..2000^3) with n=30000: m'*k' must
        // be small; check aspect ratio of the chosen full tiles.
        let d = decompose(96, 30_000, 30_000, 1e9, 8e9, 1);
        let t = &d.tiles[0];
        let aspect = t.squareness();
        // Thin slices (96 rows) cannot be square, but the heuristic picks
        // the best available k'.
        assert!(aspect > 0.0);
        let total: f64 = d.tiles.iter().map(|x| x.ops()).sum();
        assert!((total - GemmSize::new(96, 30_000, 30_000).ops()).abs() < 1.0);
    }

    #[test]
    fn score_matches_eq5_by_hand() {
        // Two tiles: (2,10,4) and (3,10,4).
        let tiles = vec![GemmSize::new(2, 10, 4), GemmSize::new(3, 10, 4)];
        let want = (2.0f64 / 4.0) * 2.0 * 4.0 * 10.0 + (3.0f64 / 4.0) * 3.0 * 4.0 * 10.0;
        assert!((squareness_score(&tiles) - want).abs() < 1e-12);
    }

    #[test]
    fn aligned_decomposition_tiles_stay_aligned() {
        // XPU slice: rows multiple of 8, k = 20000. Every tile's m and k
        // must stay multiples of 8 or the tensor-core path degrades.
        let d = decompose(17_240, 20_000, 20_000, 27e9, 216e9, 8);
        for t in &d.tiles {
            assert_eq!(t.m % 8, 0, "tile m misaligned: {t}");
            assert_eq!(t.k % 8, 0, "tile k misaligned: {t}");
        }
        let total: f64 = d.tiles.iter().map(|t| t.ops()).sum();
        assert!((total - GemmSize::new(17_240, 20_000, 20_000).ops()).abs() < 1.0);
    }

    #[test]
    fn deterministic() {
        let a = decompose(12_345, 20_000, 35_000, 27e9, 216e9, 1);
        let b = decompose(12_345, 20_000, 35_000, 27e9, 216e9, 1);
        assert_eq!(a.tiles, b.tiles);
    }
}
