//! The Adapt phase: `ops_to_mnk` (paper §4.3).
//!
//! The optimizer outputs an op count per device; the scheduler needs
//! concrete matrix dimensions. `ops_to_mnk` performs the two adjustment
//! families the paper describes:
//!
//! * **data adjustments** (§4.3.1) — map ops to whole C rows (`n` and `k`
//!   stay at their original values; only `m` is split), then express each
//!   device's slice as a list of near-square sub-products via the Eq. 5
//!   squareness heuristic so real work is shaped like profiling work;
//! * **hardware adjustments** (§4.3.2) — shave the XPU's rows to the
//!   tensor-core alignment (freed rows go to the next device) and keep
//!   CPU sub-products cache-resident (the `ops_hi` bound).

pub mod alignment;
pub mod squareness;

pub use alignment::{align_rows, ops_to_rows, AdaptRules};
pub use squareness::{decompose, divisors, squareness_score, Decomposition};

use crate::error::{Error, Result};
use crate::optimize::SplitSolution;
use crate::workload::GemmSize;

/// The Adapt phase's output for one device.
#[derive(Debug, Clone)]
pub struct DeviceAssignment {
    /// Device index (machine order).
    pub device: usize,
    /// Rows of C assigned (m_i). 0 = device unused.
    pub rows: u64,
    /// Row offset within the global C (for the real execution path).
    pub row_offset: u64,
    /// The whole slice (rows, n, k).
    pub slice: GemmSize,
    /// Square-ish sub-products covering the slice.
    pub subproducts: Vec<GemmSize>,
    /// Eq. 5 squareness score of the decomposition.
    pub squareness: f64,
}

/// Options for `ops_to_mnk`.
#[derive(Debug, Clone)]
pub struct AdaptOptions {
    /// Apply the square decomposition (disable for ablation).
    pub decompose: bool,
    /// Apply alignment shaving (disable for ablation).
    pub align: bool,
}

impl Default for AdaptOptions {
    fn default() -> Self {
        AdaptOptions {
            decompose: true,
            align: true,
        }
    }
}

/// The paper's `ops_to_mnk` algorithm.
///
/// * `split` — optimizer output (ops per device);
/// * `size` — the global GEMM;
/// * `rules` — per-device adapt rules (alignment, profiled op range);
/// * `fallback_rank` — preference for absorbing alignment leftovers
///   (use the bus priorities: fastest unaligned device first).
pub fn ops_to_mnk(
    split: &SplitSolution,
    size: GemmSize,
    rules: &[AdaptRules],
    fallback_rank: &[u32],
    opts: &AdaptOptions,
) -> Result<Vec<DeviceAssignment>> {
    let d = split.ops.len();
    if rules.len() != d || fallback_rank.len() != d {
        return Err(Error::Adapt(format!(
            "rules/rank arity mismatch: {d} devices, {} rules, {} ranks",
            rules.len(),
            fallback_rank.len()
        )));
    }

    // ---- Data adjustment 1: ops -> whole rows (m_i), conserving m.
    let mut rows = ops_to_rows(&split.ops, size.m);

    // ---- Hardware adjustment: alignment shaving + rebalancing.
    if opts.align {
        rows = align_rows(&rows, rules, fallback_rank);
    }

    // ---- Data adjustment 2: square decomposition per device.
    let mut out = Vec::with_capacity(d);
    let mut offset = 0u64;
    for (i, &r) in rows.iter().enumerate() {
        if r == 0 {
            out.push(DeviceAssignment {
                device: i,
                rows: 0,
                row_offset: offset,
                slice: GemmSize::new(1, size.n, size.k), // placeholder, unused
                subproducts: Vec::new(),
                squareness: 0.0,
            });
            continue;
        }
        let slice = GemmSize::new(r, size.n, size.k);
        let (subproducts, sq) = if opts.decompose {
            let dec = decompose(
                r,
                size.n,
                size.k,
                rules[i].ops_lo,
                rules[i].ops_hi,
                rules[i].align,
            );
            let sq = dec.score;
            (dec.tiles, sq)
        } else {
            (vec![slice], squareness_score(std::slice::from_ref(&slice)))
        };
        out.push(DeviceAssignment {
            device: i,
            rows: r,
            row_offset: offset,
            slice,
            subproducts,
            squareness: sq,
        });
        offset += r;
    }
    debug_assert_eq!(offset, size.m);
    Ok(out)
}

/// Invariant check used by tests and debug assertions: assignments
/// exactly tile the global GEMM.
pub fn assignments_cover(assignments: &[DeviceAssignment], size: GemmSize) -> bool {
    let total_rows: u64 = assignments.iter().map(|a| a.rows).sum();
    if total_rows != size.m {
        return false;
    }
    for a in assignments {
        if a.rows == 0 {
            continue;
        }
        let want = a.slice.ops();
        let got: f64 = a.subproducts.iter().map(|t| t.ops()).sum();
        if (got - want).abs() > want * 1e-9 + 0.5 {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimize::SplitSolution;

    fn split(ops: Vec<f64>) -> SplitSolution {
        SplitSolution {
            ops,
            t_pred: 1.0,
            compute_pred: vec![],
            copy_pred: vec![],
        }
    }

    fn mach1_rules() -> Vec<AdaptRules> {
        vec![
            AdaptRules {
                align: 1,
                ops_lo: 1e9,
                ops_hi: 8e9,
            }, // cpu
            AdaptRules {
                align: 1,
                ops_lo: 27e9,
                ops_hi: 216e9,
            }, // gpu
            AdaptRules {
                align: 8,
                ops_lo: 27e9,
                ops_hi: 216e9,
            }, // xpu
        ]
    }

    #[test]
    fn basic_assignment_covers() {
        let size = GemmSize::square(30_000);
        let n = size.ops();
        let s = split(vec![0.0032 * n, 0.2126 * n, 0.7842 * n]);
        let a = ops_to_mnk(&s, size, &mach1_rules(), &[0, 1, 2], &AdaptOptions::default())
            .unwrap();
        assert!(assignments_cover(&a, size));
        assert_eq!(a[2].rows % 8, 0, "xpu alignment");
        assert!(a[0].rows < a[1].rows && a[1].rows < a[2].rows);
    }

    #[test]
    fn offsets_are_contiguous() {
        let size = GemmSize::new(10_000, 20_000, 35_000);
        let n = size.ops();
        let s = split(vec![0.01 * n, 0.29 * n, 0.70 * n]);
        let a = ops_to_mnk(&s, size, &mach1_rules(), &[0, 1, 2], &AdaptOptions::default())
            .unwrap();
        let mut expect = 0;
        for asg in &a {
            assert_eq!(asg.row_offset, expect);
            expect += asg.rows;
        }
        assert_eq!(expect, size.m);
    }

    #[test]
    fn zero_share_device_unused() {
        let size = GemmSize::square(1000);
        let s = split(vec![0.0, size.ops()]);
        let rules = vec![AdaptRules::none(), AdaptRules::none()];
        let a = ops_to_mnk(&s, size, &rules, &[0, 1], &AdaptOptions::default()).unwrap();
        assert_eq!(a[0].rows, 0);
        assert!(a[0].subproducts.is_empty());
        assert_eq!(a[1].rows, 1000);
    }

    #[test]
    fn no_decompose_option() {
        let size = GemmSize::square(30_000);
        let n = size.ops();
        let s = split(vec![0.3 * n, 0.7 * n]);
        let rules = vec![AdaptRules::none(), AdaptRules::none()];
        let a = ops_to_mnk(
            &s,
            size,
            &rules,
            &[0, 1],
            &AdaptOptions {
                decompose: false,
                align: false,
            },
        )
        .unwrap();
        assert_eq!(a[0].subproducts.len(), 1);
        assert_eq!(a[0].subproducts[0], a[0].slice);
    }

    #[test]
    fn subproducts_respect_profiled_range() {
        let size = GemmSize::square(30_000);
        let n = size.ops();
        let s = split(vec![0.0032 * n, 0.2126 * n, 0.7842 * n]);
        let rules = mach1_rules();
        let a =
            ops_to_mnk(&s, size, &rules, &[0, 1, 2], &AdaptOptions::default()).unwrap();
        // GPU tiles (full stripes) within [27e9, 216e9].
        let gpu_full: Vec<_> = a[1]
            .subproducts
            .iter()
            .filter(|t| t.m == a[1].subproducts[0].m)
            .collect();
        for t in gpu_full {
            assert!(t.ops() <= 216e9 * 1.001);
        }
    }

    #[test]
    fn arity_mismatch_errors() {
        let size = GemmSize::square(100);
        let s = split(vec![size.ops()]);
        assert!(ops_to_mnk(&s, size, &[], &[], &AdaptOptions::default()).is_err());
    }

    #[test]
    fn squareness_reported_positive() {
        let size = GemmSize::square(30_000);
        let n = size.ops();
        let s = split(vec![0.25 * n, 0.75 * n]);
        let rules = vec![
            AdaptRules {
                align: 1,
                ops_lo: 27e9,
                ops_hi: 216e9,
            },
            AdaptRules {
                align: 8,
                ops_lo: 27e9,
                ops_hi: 216e9,
            },
        ];
        let a = ops_to_mnk(&s, size, &rules, &[1, 2], &AdaptOptions::default()).unwrap();
        for asg in &a {
            if asg.rows > 0 {
                assert!(asg.squareness > 0.0);
            }
        }
    }
}
