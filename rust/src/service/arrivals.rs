//! Online arrival processes: the request stream as a first-class input.
//!
//! The scenario harness used to drain a fixed, batch-admitted queue —
//! every request "arrived" at virtual time zero, so queueing delay was
//! an artifact of dispatch order, not of offered load. ALP's framing
//! (and every co-scheduling result worth reproducing, e.g. Aupy et al.)
//! is about workloads arriving *continuously*. This module supplies
//! deterministic arrival traces the [`super::Cluster`] replays in
//! virtual time:
//!
//! * [`PoissonArrivals`] — exponential inter-arrival times at a
//!   configurable offered rate, shapes drawn from a menu, all through
//!   [`crate::rng::Rng`] so a seed fully determines the trace;
//! * [`MixedArrivals`] — a per-class Poisson **mix**: each
//!   [`QosClass`] tier gets its own independent rate, shape menu and
//!   optional SLO, and the superposed streams merge into one trace (the
//!   superposition of Poisson processes is Poisson, so the mix stays a
//!   faithful arrival model);
//! * [`OnOffArrivals`] — a bursty **Markov-modulated** Poisson process:
//!   the stream alternates between an "on" (burst) phase and an "off"
//!   (quiet) phase, each exponentially long, with its own Poisson rate
//!   inside each phase. Real tenant traffic is bursty, not
//!   time-homogeneous — this is the canonical two-state MMPP used to
//!   model it, and it stresses queueing (and work stealing) far harder
//!   than a Poisson stream of the same average rate;
//! * [`PhasedArrivals`] — a **scheduled** piecewise-Poisson process:
//!   a fixed cycle of phases, each with its own rate and duration,
//!   repeating for as long as the trace needs (day/night diurnal
//!   cycles, ramp profiles). Unlike the Markov-modulated
//!   [`OnOffArrivals`] the phase timeline is deterministic *by
//!   construction*, which is exactly what an autoscaler acceptance
//!   test wants: the load shape is part of the spec, only the arrival
//!   instants inside each phase are random;
//! * [`fixed_trace`] — hand-written `(at, size, reps)` triples for
//!   replayable regression scenarios.
//!
//! Under a trace, `ServiceReport::mean_queue_wait` and the sojourn
//! percentiles finally measure load, not just ordering.

use super::qos::QosClass;
use crate::rng::Rng;
use crate::workload::GemmSize;

/// One scheduled request arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// Virtual time the request reaches the front-end.
    pub at: f64,
    /// The GEMM shape.
    pub size: GemmSize,
    /// Repetitions requested.
    pub reps: u32,
    /// Service tier the request is submitted under.
    pub class: QosClass,
    /// Optional sojourn SLO carried by the request.
    pub deadline_s: Option<f64>,
}

/// A deterministic Poisson arrival process over a shape menu.
///
/// Inter-arrival gaps are exponential with mean `1 / rate_rps`; each
/// arrival draws a `(shape, reps)` uniformly from `menu`. The same
/// `(seed, rate, menu)` always yields the same trace.
#[derive(Debug, Clone)]
pub struct PoissonArrivals {
    /// Offered load, requests per virtual second.
    pub rate_rps: f64,
    /// The shapes tenants submit, drawn uniformly.
    pub menu: Vec<(GemmSize, u32)>,
    /// Trace seed.
    pub seed: u64,
}

impl PoissonArrivals {
    /// A process at `rate_rps` over `menu`, seeded by `seed`.
    ///
    /// `rate_rps` must be positive and `menu` non-empty.
    pub fn new(rate_rps: f64, menu: Vec<(GemmSize, u32)>, seed: u64) -> Self {
        assert!(rate_rps > 0.0, "arrival rate must be positive");
        assert!(!menu.is_empty(), "arrival menu must be non-empty");
        PoissonArrivals {
            rate_rps,
            menu,
            seed,
        }
    }

    /// Materialize the first `n` arrivals of the process (all
    /// [`QosClass::Standard`], no SLO — the PR 2 behaviour).
    pub fn trace(&self, n: usize) -> Vec<Arrival> {
        // Domain-separate from the machine seeds so a cluster seeded
        // like its trace still draws independent streams.
        poisson_stream(
            self.seed ^ 0xA55A_D1CE_0F0F_7EA1,
            self.rate_rps,
            &self.menu,
            QosClass::Standard,
            None,
            n,
        )
    }
}

/// One inverse-CDF exponential draw with mean `mean_s`; `1 - u` keeps
/// the argument in (0, 1] so `ln` never sees zero. Every arrival
/// process in this module draws gaps (and phase lengths) through this
/// one helper so the interval convention cannot silently diverge.
fn exp_draw(rng: &mut Rng, mean_s: f64) -> f64 {
    -(1.0 - rng.uniform()).ln() * mean_s
}

/// Draw `n` Poisson arrivals for one class stream.
fn poisson_stream(
    seed: u64,
    rate_rps: f64,
    menu: &[(GemmSize, u32)],
    class: QosClass,
    deadline_s: Option<f64>,
    n: usize,
) -> Vec<Arrival> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0_f64;
    (0..n)
        .map(|_| {
            t += exp_draw(&mut rng, 1.0 / rate_rps);
            let (size, reps) = menu[rng.below(menu.len() as u64) as usize];
            Arrival {
                at: t,
                size,
                reps,
                class,
                deadline_s,
            }
        })
        .collect()
}

/// One phase of an [`OnOffArrivals`] trace (diagnostics/tests: lets a
/// caller recompute per-phase empirical rates without re-deriving the
/// phase timeline).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseSpan {
    /// True for the burst ("on") phase.
    pub burst: bool,
    /// Phase start, virtual seconds.
    pub start: f64,
    /// Phase end, virtual seconds.
    pub end: f64,
}

/// A deterministic bursty on/off (two-state Markov-modulated Poisson)
/// arrival process over a shape menu.
///
/// The stream starts in the burst phase. Phase durations are
/// exponential with means `mean_on_s` / `mean_off_s`; within a phase,
/// inter-arrival gaps are exponential at that phase's rate. Both the
/// modulation and the arrivals draw from one [`crate::rng::Rng`]
/// stream, so the same `(seed, rates, means, menu)` always yields the
/// same trace. The sampler is exact: at a phase switch the pending gap
/// is discarded and redrawn at the new rate, which is correct by
/// memorylessness of the exponential.
#[derive(Debug, Clone)]
pub struct OnOffArrivals {
    /// Offered load inside a burst, requests per virtual second.
    pub rate_on_rps: f64,
    /// Offered load between bursts, requests per virtual second.
    pub rate_off_rps: f64,
    /// Mean burst-phase duration, seconds.
    pub mean_on_s: f64,
    /// Mean quiet-phase duration, seconds.
    pub mean_off_s: f64,
    /// The shapes tenants submit, drawn uniformly.
    pub menu: Vec<(GemmSize, u32)>,
    /// Trace seed.
    pub seed: u64,
}

impl OnOffArrivals {
    /// A burst/quiet process, seeded by `seed`.
    ///
    /// Rates and phase means must be positive, the burst rate must
    /// exceed the quiet rate (otherwise it is not a burst), and `menu`
    /// must be non-empty.
    pub fn new(
        rate_on_rps: f64,
        rate_off_rps: f64,
        mean_on_s: f64,
        mean_off_s: f64,
        menu: Vec<(GemmSize, u32)>,
        seed: u64,
    ) -> Self {
        assert!(rate_off_rps > 0.0, "quiet rate must be positive");
        assert!(
            rate_on_rps > rate_off_rps,
            "burst rate must exceed the quiet rate"
        );
        assert!(
            mean_on_s > 0.0 && mean_off_s > 0.0,
            "phase means must be positive"
        );
        assert!(!menu.is_empty(), "arrival menu must be non-empty");
        OnOffArrivals {
            rate_on_rps,
            rate_off_rps,
            mean_on_s,
            mean_off_s,
            menu,
            seed,
        }
    }

    /// The burst-to-quiet rate ratio the process is specified with.
    pub fn rate_ratio(&self) -> f64 {
        self.rate_on_rps / self.rate_off_rps
    }

    /// Long-run average offered rate (phase-mean-weighted).
    pub fn mean_rate_rps(&self) -> f64 {
        (self.rate_on_rps * self.mean_on_s + self.rate_off_rps * self.mean_off_s)
            / (self.mean_on_s + self.mean_off_s)
    }

    /// Materialize the first `n` arrivals (all [`QosClass::Standard`],
    /// no SLO).
    pub fn trace(&self, n: usize) -> Vec<Arrival> {
        self.trace_with_phases(n).0
    }

    /// Like [`OnOffArrivals::trace`], but also return the phase
    /// timeline that generated the arrivals. The final phase is clamped
    /// to the last arrival, so per-phase empirical rates
    /// (`count / span`) are unbiased by truncation.
    pub fn trace_with_phases(&self, n: usize) -> (Vec<Arrival>, Vec<PhaseSpan>) {
        // Domain-separate from the machine seeds and the plain Poisson
        // stream.
        let mut rng = Rng::new(self.seed ^ 0x0F0F_A55A_0B05_7EAD);
        let mut arrivals = Vec::with_capacity(n);
        let mut phases: Vec<PhaseSpan> = Vec::new();
        let mut burst = true;
        let mut start = 0.0_f64;
        while arrivals.len() < n {
            let (rate, mean) = if burst {
                (self.rate_on_rps, self.mean_on_s)
            } else {
                (self.rate_off_rps, self.mean_off_s)
            };
            let end = start + exp_draw(&mut rng, mean);
            let mut at = start;
            let mut truncated_at = None;
            loop {
                let gap = exp_draw(&mut rng, 1.0 / rate);
                if at + gap > end {
                    break;
                }
                at += gap;
                let (size, reps) = self.menu[rng.below(self.menu.len() as u64) as usize];
                arrivals.push(Arrival {
                    at,
                    size,
                    reps,
                    class: QosClass::Standard,
                    deadline_s: None,
                });
                if arrivals.len() == n {
                    truncated_at = Some(at);
                    break;
                }
            }
            phases.push(PhaseSpan {
                burst,
                start,
                end: truncated_at.unwrap_or(end),
            });
            start = end;
            burst = !burst;
        }
        (arrivals, phases)
    }
}

/// One phase of a [`PhasedArrivals`] schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Phase {
    /// Offered load during this phase, requests per virtual second.
    pub rate_rps: f64,
    /// Fixed phase duration, virtual seconds.
    pub dur_s: f64,
}

/// A deterministic scheduled piecewise-Poisson process: the phase
/// cycle (rates and durations) is fixed, and the cycle repeats until
/// the trace has enough arrivals.
///
/// Within each phase, inter-arrival gaps are exponential at the
/// phase's rate; at a phase boundary the pending gap is discarded and
/// redrawn at the new rate (correct by memorylessness, the same
/// convention [`OnOffArrivals`] uses at its modulation switches). The
/// same `(seed, phases, menu)` always yields the same trace.
///
/// This is the diurnal / flash-crowd generator the autoscaler
/// (see [`super::elastic`]) is exercised against: a day/night cycle is
/// two phases, a ramp is several, and because the timeline is part of
/// the spec, a test can assert on per-phase behaviour without
/// re-deriving random phase boundaries.
#[derive(Debug, Clone)]
pub struct PhasedArrivals {
    /// The repeating phase cycle, in order (at least one phase).
    pub phases: Vec<Phase>,
    /// The shapes tenants submit, drawn uniformly.
    pub menu: Vec<(GemmSize, u32)>,
    /// Trace seed.
    pub seed: u64,
}

impl PhasedArrivals {
    /// A scheduled process cycling through `phases`, seeded by `seed`.
    ///
    /// Every phase needs a positive finite rate and duration, and
    /// `menu` must be non-empty.
    pub fn new(phases: Vec<Phase>, menu: Vec<(GemmSize, u32)>, seed: u64) -> Self {
        assert!(!phases.is_empty(), "phase cycle must be non-empty");
        for p in &phases {
            assert!(
                p.rate_rps.is_finite() && p.rate_rps > 0.0,
                "phase rate must be finite and positive, got {}",
                p.rate_rps
            );
            assert!(
                p.dur_s.is_finite() && p.dur_s > 0.0,
                "phase duration must be finite and positive, got {}",
                p.dur_s
            );
        }
        assert!(!menu.is_empty(), "arrival menu must be non-empty");
        PhasedArrivals { phases, menu, seed }
    }

    /// Duration of one full cycle, virtual seconds.
    pub fn cycle_s(&self) -> f64 {
        self.phases.iter().map(|p| p.dur_s).sum()
    }

    /// Long-run average offered rate (duration-weighted over a cycle).
    pub fn mean_rate_rps(&self) -> f64 {
        self.phases.iter().map(|p| p.rate_rps * p.dur_s).sum::<f64>() / self.cycle_s()
    }

    /// Materialize the first `n` arrivals (all [`QosClass::Standard`],
    /// no SLO — the scenario layer stamps class and deadline on top,
    /// exactly as for [`OnOffArrivals`]).
    pub fn trace(&self, n: usize) -> Vec<Arrival> {
        // Domain-separate from the machine seeds and the other arrival
        // processes.
        let mut rng = Rng::new(self.seed ^ 0xD1CE_0FF0_A55A_7EA5);
        let mut arrivals = Vec::with_capacity(n);
        let mut start = 0.0_f64;
        let mut k = 0usize;
        while arrivals.len() < n {
            let ph = self.phases[k % self.phases.len()];
            let end = start + ph.dur_s;
            let mut at = start;
            loop {
                let gap = exp_draw(&mut rng, 1.0 / ph.rate_rps);
                if at + gap > end {
                    break;
                }
                at += gap;
                let (size, reps) = self.menu[rng.below(self.menu.len() as u64) as usize];
                arrivals.push(Arrival {
                    at,
                    size,
                    reps,
                    class: QosClass::Standard,
                    deadline_s: None,
                });
                if arrivals.len() == n {
                    break;
                }
            }
            start = end;
            k += 1;
        }
        arrivals
    }
}

/// One tier's offered load inside a [`MixedArrivals`] mix.
#[derive(Debug, Clone)]
pub struct ClassLoad {
    /// The tier this stream submits under.
    pub class: QosClass,
    /// Offered load of the tier, requests per virtual second.
    pub rate_rps: f64,
    /// Shapes the tier submits, drawn uniformly.
    pub menu: Vec<(GemmSize, u32)>,
    /// SLO attached to every request of this stream (`None` = no
    /// deadline).
    pub deadline_s: Option<f64>,
}

/// A deterministic per-class Poisson mix: independent Poisson streams,
/// one per [`ClassLoad`], superposed into a single time-ordered trace.
/// Each stream draws from its own domain-separated PRNG, so the same
/// `(seed, loads)` always yields the same trace and adding a class
/// never perturbs another class's draws.
#[derive(Debug, Clone)]
pub struct MixedArrivals {
    /// The per-tier streams.
    pub loads: Vec<ClassLoad>,
    /// Trace seed.
    pub seed: u64,
}

impl MixedArrivals {
    /// A mix over `loads` seeded by `seed`.
    ///
    /// Every load needs a positive rate and a non-empty menu.
    pub fn new(loads: Vec<ClassLoad>, seed: u64) -> Self {
        assert!(!loads.is_empty(), "mix needs at least one class load");
        for l in &loads {
            assert!(l.rate_rps > 0.0, "{} arrival rate must be positive", l.class);
            assert!(!l.menu.is_empty(), "{} menu must be non-empty", l.class);
        }
        MixedArrivals { loads, seed }
    }

    /// Materialize the first `per_class` arrivals of **each** stream
    /// and merge them by arrival time (stable: simultaneous arrivals
    /// keep load order, so replays are exact).
    pub fn trace(&self, per_class: usize) -> Vec<Arrival> {
        let mut merged: Vec<Arrival> = Vec::with_capacity(per_class * self.loads.len());
        for (i, l) in self.loads.iter().enumerate() {
            merged.extend(poisson_stream(
                self.seed
                    ^ 0xA55A_D1CE_0F0F_7EA1
                    ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                l.rate_rps,
                &l.menu,
                l.class,
                l.deadline_s,
                per_class,
            ));
        }
        merged.sort_by(|a, b| a.at.total_cmp(&b.at));
        merged
    }
}

/// A replayable fixed trace from `(at, size, reps)` triples (all
/// [`QosClass::Standard`], no SLO). Arrivals are sorted by time so
/// out-of-order authorship is harmless.
pub fn fixed_trace(items: &[(f64, GemmSize, u32)]) -> Vec<Arrival> {
    let mut trace: Vec<Arrival> = items
        .iter()
        .map(|&(at, size, reps)| Arrival {
            at,
            size,
            reps,
            class: QosClass::Standard,
            deadline_s: None,
        })
        .collect();
    trace.sort_by(|a, b| a.at.total_cmp(&b.at));
    trace
}

#[cfg(test)]
mod tests {
    use super::*;

    fn menu() -> Vec<(GemmSize, u32)> {
        vec![
            (GemmSize::square(16_000), 2),
            (GemmSize::square(20_000), 2),
            (GemmSize::square(400), 2),
        ]
    }

    #[test]
    fn trace_is_deterministic_per_seed() {
        let p = PoissonArrivals::new(0.5, menu(), 42);
        assert_eq!(p.trace(64), p.trace(64));
        let q = PoissonArrivals::new(0.5, menu(), 43);
        assert_ne!(p.trace(64), q.trace(64));
    }

    #[test]
    fn arrivals_are_strictly_increasing_and_positive() {
        let trace = PoissonArrivals::new(2.0, menu(), 7).trace(256);
        assert_eq!(trace.len(), 256);
        let mut prev = 0.0;
        for a in &trace {
            assert!(a.at > prev, "non-increasing arrival at {}", a.at);
            prev = a.at;
        }
    }

    #[test]
    fn empirical_rate_matches_offered_rate() {
        let rate = 4.0;
        let n = 4000;
        let trace = PoissonArrivals::new(rate, menu(), 11).trace(n);
        let mean_gap = trace.last().unwrap().at / n as f64;
        let expect = 1.0 / rate;
        assert!(
            (mean_gap / expect - 1.0).abs() < 0.05,
            "mean inter-arrival {mean_gap} vs expected {expect}"
        );
    }

    #[test]
    fn menu_is_sampled_broadly() {
        let trace = PoissonArrivals::new(1.0, menu(), 3).trace(300);
        for (size, _) in menu() {
            assert!(
                trace.iter().any(|a| a.size == size),
                "menu entry {size:?} never drawn"
            );
        }
    }

    #[test]
    fn on_off_trace_is_deterministic_and_time_ordered() {
        let p = OnOffArrivals::new(8.0, 0.5, 3.0, 6.0, menu(), 13);
        let a = p.trace(256);
        assert_eq!(a.len(), 256);
        assert_eq!(a, p.trace(256));
        let q = OnOffArrivals::new(8.0, 0.5, 3.0, 6.0, menu(), 14);
        assert_ne!(a, q.trace(256));
        let mut prev = 0.0;
        for x in &a {
            assert!(x.at > prev, "non-increasing arrival at {}", x.at);
            prev = x.at;
        }
    }

    #[test]
    fn on_off_empirical_burst_rate_ratio_matches_spec() {
        // Spec: bursts at 8 req/s for ~3 s, quiet at 0.5 req/s for
        // ~6 s — a 16x modulation.
        let p = OnOffArrivals::new(8.0, 0.5, 3.0, 6.0, menu(), 29);
        assert!((p.rate_ratio() - 16.0).abs() < 1e-12);
        let (trace, phases) = p.trace_with_phases(6000);
        assert_eq!(trace.len(), 6000);
        // Phases tile the timeline, alternating burst/quiet from burst.
        let mut expect_burst = true;
        let mut cursor = 0.0;
        for ph in &phases {
            assert_eq!(ph.burst, expect_burst);
            assert!(ph.start >= cursor - 1e-12, "phases overlap");
            assert!(ph.end >= ph.start);
            cursor = ph.end;
            expect_burst = !expect_burst;
        }
        // Empirical per-phase rates recover the spec.
        let (mut t_on, mut t_off) = (0.0_f64, 0.0_f64);
        let (mut n_on, mut n_off) = (0usize, 0usize);
        for ph in &phases {
            let count = trace
                .iter()
                .filter(|a| a.at > ph.start && a.at <= ph.end + 1e-12)
                .count();
            if ph.burst {
                t_on += ph.end - ph.start;
                n_on += count;
            } else {
                t_off += ph.end - ph.start;
                n_off += count;
            }
        }
        assert_eq!(n_on + n_off, 6000, "every arrival belongs to a phase");
        let rate_on = n_on as f64 / t_on;
        let rate_off = n_off as f64 / t_off;
        assert!(
            (rate_on / 8.0 - 1.0).abs() < 0.15,
            "burst rate {rate_on} vs spec 8.0"
        );
        assert!(
            (rate_off / 0.5 - 1.0).abs() < 0.30,
            "quiet rate {rate_off} vs spec 0.5"
        );
        let ratio = rate_on / rate_off;
        assert!(
            (ratio / p.rate_ratio() - 1.0).abs() < 0.30,
            "empirical burst ratio {ratio} vs spec {}",
            p.rate_ratio()
        );
        // And the long-run average rate figure is phase-weighted.
        let avg = p.mean_rate_rps();
        assert!((avg - (8.0 * 3.0 + 0.5 * 6.0) / 9.0).abs() < 1e-12);
    }

    #[test]
    fn on_off_burstiness_exceeds_poisson_variance() {
        // Dispersion check: count arrivals in fixed windows; an MMPP
        // must be over-dispersed (variance > mean) where Poisson sits
        // at variance ~= mean. This is what makes the trace a harder
        // queueing workload at equal average rate.
        let p = OnOffArrivals::new(8.0, 0.5, 3.0, 6.0, menu(), 5);
        let trace = p.trace(4000);
        let horizon = trace.last().unwrap().at;
        let window = 3.0_f64;
        let bins = (horizon / window).floor() as usize;
        let mut counts = vec![0.0_f64; bins];
        for a in &trace {
            let b = (a.at / window) as usize;
            if b < bins {
                counts[b] += 1.0;
            }
        }
        let mean = counts.iter().sum::<f64>() / bins as f64;
        let var =
            counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>() / bins as f64;
        assert!(
            var > 2.0 * mean,
            "on/off trace not over-dispersed: var {var} mean {mean}"
        );
    }

    #[test]
    fn phased_trace_is_deterministic_and_time_ordered() {
        let p = PhasedArrivals::new(
            vec![
                Phase {
                    rate_rps: 6.0,
                    dur_s: 10.0,
                },
                Phase {
                    rate_rps: 0.5,
                    dur_s: 10.0,
                },
            ],
            menu(),
            21,
        );
        let a = p.trace(512);
        assert_eq!(a.len(), 512);
        assert_eq!(a, p.trace(512));
        let q = PhasedArrivals::new(p.phases.clone(), menu(), 22);
        assert_ne!(a, q.trace(512));
        let mut prev = 0.0;
        for x in &a {
            assert!(x.at > prev, "non-increasing arrival at {}", x.at);
            prev = x.at;
        }
        assert!((p.cycle_s() - 20.0).abs() < 1e-12);
        assert!((p.mean_rate_rps() - 3.25).abs() < 1e-12);
    }

    #[test]
    fn phased_per_phase_empirical_rates_match_schedule() {
        // Day at 8 req/s for 20 s, night at 0.4 req/s for 20 s: the
        // phase boundaries are *fixed*, so arrivals can be binned
        // against the schedule directly.
        let p = PhasedArrivals::new(
            vec![
                Phase {
                    rate_rps: 8.0,
                    dur_s: 20.0,
                },
                Phase {
                    rate_rps: 0.4,
                    dur_s: 20.0,
                },
            ],
            menu(),
            37,
        );
        let trace = p.trace(4000);
        let horizon = trace.last().unwrap().at;
        let cycles = (horizon / p.cycle_s()).floor();
        assert!(cycles >= 10.0, "trace should span many cycles");
        let (mut n_day, mut n_night) = (0usize, 0usize);
        let mut t_day = 0.0_f64;
        let mut t_night = 0.0_f64;
        // Count only whole cycles so truncation cannot bias the split.
        for a in &trace {
            if a.at >= cycles * p.cycle_s() {
                break;
            }
            if a.at % p.cycle_s() < 20.0 {
                n_day += 1;
            } else {
                n_night += 1;
            }
        }
        t_day += cycles * 20.0;
        t_night += cycles * 20.0;
        let day_rate = n_day as f64 / t_day;
        let night_rate = n_night as f64 / t_night;
        assert!(
            (day_rate / 8.0 - 1.0).abs() < 0.10,
            "day rate {day_rate} vs schedule 8.0"
        );
        assert!(
            (night_rate / 0.4 - 1.0).abs() < 0.35,
            "night rate {night_rate} vs schedule 0.4"
        );
    }

    #[test]
    fn mixed_trace_merges_streams_in_time_order() {
        let mix = MixedArrivals::new(
            vec![
                ClassLoad {
                    class: QosClass::Interactive,
                    rate_rps: 2.0,
                    menu: vec![(GemmSize::square(16_000), 2)],
                    deadline_s: Some(3.0),
                },
                ClassLoad {
                    class: QosClass::Batch,
                    rate_rps: 1.0,
                    menu: vec![(GemmSize::square(20_000), 2)],
                    deadline_s: None,
                },
            ],
            5,
        );
        let t = mix.trace(32);
        assert_eq!(t.len(), 64);
        let mut prev = 0.0;
        for a in &t {
            assert!(a.at >= prev, "trace not time-ordered");
            prev = a.at;
            match a.class {
                QosClass::Interactive => {
                    assert_eq!(a.deadline_s, Some(3.0));
                    assert_eq!(a.size, GemmSize::square(16_000));
                }
                QosClass::Batch => {
                    assert_eq!(a.deadline_s, None);
                    assert_eq!(a.size, GemmSize::square(20_000));
                }
                QosClass::Standard => panic!("no standard load in this mix"),
            }
        }
        // Deterministic, and each class drew its full allotment.
        assert_eq!(t, mix.trace(32));
        for class in [QosClass::Interactive, QosClass::Batch] {
            assert_eq!(t.iter().filter(|a| a.class == class).count(), 32);
        }
    }

    #[test]
    fn mixed_streams_are_independent_per_class() {
        // Dropping one load must not change the other's draws.
        let interactive = ClassLoad {
            class: QosClass::Interactive,
            rate_rps: 2.0,
            menu: vec![(GemmSize::square(16_000), 2)],
            deadline_s: None,
        };
        let batch = ClassLoad {
            class: QosClass::Batch,
            rate_rps: 1.0,
            menu: vec![(GemmSize::square(20_000), 2)],
            deadline_s: None,
        };
        let both = MixedArrivals::new(vec![interactive.clone(), batch], 9).trace(16);
        let alone = MixedArrivals::new(vec![interactive], 9).trace(16);
        let from_mix: Vec<Arrival> = both
            .into_iter()
            .filter(|a| a.class == QosClass::Interactive)
            .collect();
        assert_eq!(from_mix, alone);
    }

    #[test]
    fn fixed_trace_sorts_by_time() {
        let t = fixed_trace(&[
            (3.0, GemmSize::square(100), 1),
            (1.0, GemmSize::square(200), 2),
            (2.0, GemmSize::square(300), 3),
        ]);
        let times: Vec<f64> = t.iter().map(|a| a.at).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
        assert_eq!(t[0].size, GemmSize::square(200));
    }
}
