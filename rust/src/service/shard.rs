//! The executor shard: one machine's worth of the serving deployment.
//!
//! An [`ExecutorShard`] owns exactly the state that must live next to
//! one [`SimMachine`]: the installation-time [`PerfModel`] profiled on
//! *that* machine, its [`PlanCache`], its pending-request queue, and
//! (optionally) a [`DynamicScheduler`] closing the loop on drift. The
//! cluster front-end routes admitted requests onto shards and asks a
//! shard to dispatch whenever its machine is free; everything below the
//! routing decision — plan lookup, the standalone bypass pairing,
//! execution, per-tenant completion attribution, model feedback — is
//! shard-local.
//!
//! A request whose plan turns out to be infeasible completes with an
//! [`ExecMode::Rejected`] record (zero execution time, empty shares)
//! instead of propagating a panic out of the serving loop.
//!
//! A shard models execution in virtual time; it never talks to a real
//! device. Under the wall-clock driver
//! ([`super::driver::WallClockDriver`]) each shard's dispatches are
//! additionally mirrored — via the cluster's tap — onto a dedicated
//! worker thread whose [`super::driver::Executor`] really spends wall
//! time, but every scheduling decision still comes from the state
//! here.

use super::batch::{BatchMember, FusedBatch};
use super::cache::PlanCache;
use super::qos::{QosClass, NUM_CLASSES};
use super::queue::{QueuedRequest, RequestQueue};
use super::request::{ExecMode, ServedRequest, ShardStats};
use super::server::ServerOptions;
use crate::adapt::AdaptRules;
use crate::baselines;
use crate::coordinator::Pipeline;
use crate::error::{Error, Result};
use crate::optimize::energy::DevicePower;
use crate::predict::PerfModel;
use crate::schedule::suitability::predicted_standalone;
use crate::schedule::{build_plan_excluding, DynamicScheduler, PlanOptions, SchedulePlan};
use crate::sim::{SimMachine, WorkItem, WorkOrder};
use crate::workload::GemmSize;

/// What one dispatch did to the shard.
#[derive(Debug, Clone, Copy)]
pub struct DispatchResult {
    /// Virtual time the shard's machine goes free again.
    pub finish: f64,
    /// True when the dynamic scheduler re-planned on this dispatch: the
    /// front-end should refresh its admission model from this shard.
    pub replanned: bool,
}

/// One machine of a serving cluster: simulator + profile + plan cache +
/// local queue + (optional) closed-loop scheduler.
#[derive(Debug, Clone)]
pub struct ExecutorShard {
    /// Shard index in the cluster (0 for a single-machine server).
    pub id: usize,
    /// The machine being driven.
    pub sim: SimMachine,
    /// The live performance model (profiled at construction; refreshed
    /// by the dynamic scheduler when `dynamic` is on).
    pub model: PerfModel,
    /// The plan memo.
    pub cache: PlanCache,
    rules: Vec<AdaptRules>,
    plan_opts: PlanOptions,
    opts: ServerOptions,
    dynsched: Option<DynamicScheduler>,
    queue: RequestQueue,
    /// Virtual (service-time) instant the machine goes idle.
    free_at: f64,
    /// Virtual seconds spent executing (for utilization accounting).
    busy_s: f64,
    dispatches: usize,
    stolen: usize,
    /// Fused batches dispatched (each is one entry in `dispatches`).
    batches: usize,
    /// Requests completed per QoS class (riders included).
    served_by_class: [usize; NUM_CLASSES],
    /// Requests this shard rejected at planning time.
    rejected: usize,
    /// Requests displaced off this shard by a crash (see
    /// [`ExecutorShard::crash`] and [`ExecutorShard::note_requeued`]).
    requeued: usize,
    /// Sum of admission-time service predictions over everything this
    /// shard executed (placement-quality denominator).
    predicted_sum_s: f64,
    /// Sum of realized execution seconds over the same requests
    /// (placement-quality numerator).
    realized_sum_s: f64,
    /// Virtual instant this shard's machine was provisioned (0 for
    /// construction-time shards; the join instant for scale-outs).
    provisioned_at: f64,
    /// Virtual instant the machine was handed back after a graceful
    /// drain (`None` while provisioned). The machine-seconds meter
    /// stops here, not at the drain event: an in-flight execution runs
    /// to its finish before the machine can be released.
    retired_at: Option<f64>,
    /// Machine-seconds accumulated over *earlier* provisioned spans
    /// (a drained shard the autoscaler later revives starts a fresh
    /// span; the old one is folded in here).
    provisioned_s_prior: f64,
    /// Seconds spent in the parked (drained) low-power state over
    /// *earlier* retire-to-revive spans; the open parked span, if any,
    /// runs from `retired_at` to the report clock.
    parked_s_prior: f64,
    /// Per-device power model (active/idle watts), copied from the
    /// machine config at provision time.
    power: Vec<DevicePower>,
    /// Cached Σ active watts across devices — the draw of a full
    /// co-execution on this machine.
    active_w_total: f64,
    /// Cached Σ idle watts across devices — the draw of a provisioned
    /// machine with nothing running.
    idle_w_total: f64,
    /// Static energy cost of routing work here: predicted joules per
    /// unit of work at full co-execution (Σ active watts over the
    /// machine's aggregate throughput under the live model). The
    /// cluster's energy index ranks shards by this.
    joules_per_op: f64,
}

impl ExecutorShard {
    /// Promote a profiled pipeline (machine + model + plan options)
    /// into shard `id` of a cluster.
    pub fn from_pipeline(id: usize, pipeline: Pipeline, opts: &ServerOptions) -> Self {
        let Pipeline {
            sim,
            model,
            rules,
            opts: plan_opts,
        } = pipeline;
        let dynsched = if opts.dynamic {
            Some(DynamicScheduler::new(model.clone()))
        } else {
            None
        };
        let power: Vec<DevicePower> = sim
            .config()
            .devices
            .iter()
            .map(|d| DevicePower {
                active_w: d.active_w,
                idle_w: d.idle_w,
            })
            .collect();
        let active_w_total: f64 = power.iter().map(|p| p.active_w).sum();
        let idle_w_total: f64 = power.iter().map(|p| p.idle_w).sum();
        let joules_per_op = Self::joules_per_unit(active_w_total, &model);
        ExecutorShard {
            id,
            sim,
            cache: PlanCache::new(opts.cache_capacity),
            rules,
            plan_opts,
            queue: RequestQueue::new(opts.policy),
            free_at: 0.0,
            busy_s: 0.0,
            dispatches: 0,
            stolen: 0,
            batches: 0,
            served_by_class: [0; NUM_CLASSES],
            rejected: 0,
            requeued: 0,
            predicted_sum_s: 0.0,
            realized_sum_s: 0.0,
            provisioned_at: 0.0,
            retired_at: None,
            provisioned_s_prior: 0.0,
            parked_s_prior: 0.0,
            power,
            active_w_total,
            idle_w_total,
            joules_per_op,
            dynsched,
            opts: opts.clone(),
            model,
        }
    }

    /// Mark this shard as provisioned at virtual time `now`: the
    /// machine-seconds meter starts here and the machine is idle (a
    /// freshly joined shard has no history, so `free_at` snaps to the
    /// join instant instead of 0).
    pub fn provision(&mut self, now: f64) {
        self.provisioned_at = now;
        self.retired_at = None;
        self.free_at = now;
    }

    /// Stop the machine-seconds meter for a graceful drain issued at
    /// `now`. The machine is released only once its in-flight execution
    /// (if any) finishes, so the meter runs to `free_at` when that lies
    /// beyond the drain instant — a drain displaces zero in-flight
    /// work, and the machine-seconds bill reflects that.
    pub fn retire(&mut self, now: f64) {
        self.retired_at = Some(self.free_at.max(now));
    }

    /// Revive a drained shard at `now`: the retired span is folded into
    /// the prior-span accumulator and a fresh provisioned span begins.
    /// No-op when the shard was never retired.
    pub fn unretire(&mut self, now: f64) {
        if let Some(end) = self.retired_at.take() {
            self.provisioned_s_prior += (end - self.provisioned_at).max(0.0);
            self.parked_s_prior += (now - end).max(0.0);
            self.provisioned_at = now;
            self.free_at = self.free_at.max(now);
        }
    }

    /// Machine-seconds this shard was provisioned for, with the current
    /// span closed at `end` (the report clock) unless a drain already
    /// closed it earlier.
    pub fn provisioned_s(&self, end: f64) -> f64 {
        let span_end = self.retired_at.unwrap_or(end).max(self.provisioned_at);
        self.provisioned_s_prior + (span_end - self.provisioned_at)
    }

    /// Seconds this shard has spent parked — drained, with the machine
    /// held at the low-power parked rate — with the open parked span
    /// (if any) closed at `end` (the report clock).
    pub fn parked_s(&self, end: f64) -> f64 {
        self.parked_s_prior + self.retired_at.map_or(0.0, |r| (end - r).max(0.0))
    }

    /// True once a graceful drain retired this shard (and no revival
    /// followed).
    pub fn is_retired(&self) -> bool {
        self.retired_at.is_some()
    }

    /// Per-device power model (active/idle watts), as provisioned.
    pub fn device_power(&self) -> &[DevicePower] {
        &self.power
    }

    /// Σ active watts across this shard's devices — the draw of a full
    /// co-execution.
    pub fn active_w_total(&self) -> f64 {
        self.active_w_total
    }

    /// Σ idle watts across this shard's devices — the draw of a
    /// provisioned machine with nothing running.
    pub fn idle_w_total(&self) -> f64 {
        self.idle_w_total
    }

    /// Predicted joules per unit of work at full co-execution under the
    /// live model — the static key the cluster's energy index ranks
    /// shards by.
    pub fn joules_per_op(&self) -> f64 {
        self.joules_per_op
    }

    /// Re-derive the energy cost key from the live model (the cluster
    /// calls this whenever a dispatch re-planned and refreshed the
    /// shard's model).
    pub fn refresh_energy_cost(&mut self) {
        self.joules_per_op = Self::joules_per_unit(self.active_w_total, &self.model);
    }

    /// Σ active watts divided by the machine's aggregate throughput
    /// (Σ 1/slope): watts × seconds-per-op = joules per op. Falls back
    /// to the raw watt total for a degenerate (zero-throughput) model
    /// so the key stays finite and orderable.
    fn joules_per_unit(active_w_total: f64, model: &PerfModel) -> f64 {
        let throughput: f64 = model
            .devices
            .iter()
            .map(|d| if d.a > 0.0 { 1.0 / d.a } else { 0.0 })
            .sum();
        if throughput > 0.0 {
            active_w_total / throughput
        } else {
            active_w_total
        }
    }

    /// Drain and return every *queued* request (in the order the
    /// shard's own policy would have dispatched them — deterministic)
    /// without touching the execution clocks: unlike
    /// [`ExecutorShard::crash`], a graceful drain leaves the in-flight
    /// execution (everything up to `free_at`) untouched, so `busy_s`
    /// and `free_at` keep their honest values.
    pub fn drain_queue(&mut self) -> Vec<QueuedRequest> {
        let mut drained = Vec::new();
        while let Some(q) = self.queue.pop_next() {
            drained.push(q);
        }
        drained
    }

    /// Pending request count on this shard's queue.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Virtual time the machine goes idle (0 before the first dispatch).
    pub fn free_at(&self) -> f64 {
        self.free_at
    }

    /// Sum of admission-time predictions of everything queued here.
    /// O(1): the queue maintains per-lane totals incrementally, so the
    /// cluster's routing/steal indexes can read backlogs per mutation
    /// without scanning the queue.
    pub fn backlog_s(&self) -> f64 {
        self.queue.predicted_backlog()
    }

    /// Predicted completion of a hypothetical request with service
    /// prediction `predicted_s` routed to this shard at time `now`:
    /// current execution + queued backlog + the request itself. The
    /// class-blind estimate (every queued second counts at face value).
    pub fn predicted_finish(&self, now: f64, predicted_s: f64) -> f64 {
        self.free_at.max(now) + self.backlog_s() + predicted_s
    }

    /// Class-weighted predicted completion: like
    /// [`ExecutorShard::predicted_finish`], but the queued backlog is
    /// discounted to the interleave the weighted drain actually allows
    /// ahead of a `class` arrival (see
    /// [`RequestQueue::backlog_ahead_of`]). The cluster routes (and
    /// deadline-admits) each arrival by the shard minimizing this.
    pub fn predicted_finish_for(&self, now: f64, predicted_s: f64, class: QosClass) -> f64 {
        self.free_at.max(now) + self.queue.backlog_ahead_of(class, predicted_s) + predicted_s
    }

    /// Predicted backlog of one class's lane on this shard.
    pub fn class_backlog(&self, class: QosClass) -> f64 {
        self.queue.class_backlog(class)
    }

    /// Class-weighted backlog of this shard's queue — the work-stealing
    /// urgency signal (see [`RequestQueue::weighted_backlog`]).
    pub fn weighted_backlog(&self) -> f64 {
        self.queue.weighted_backlog()
    }

    /// Dynamic-scheduler re-plans performed so far (0 without `dynamic`).
    pub fn replans(&self) -> usize {
        self.dynsched.as_ref().map(|d| d.replans).unwrap_or(0)
    }

    /// Number of devices on this shard's machine (shards of a
    /// heterogeneous cluster disagree here).
    pub fn num_devices(&self) -> usize {
        self.sim.num_devices()
    }

    /// Snapshot the shard's accounting for the session report.
    pub fn stats(&self) -> ShardStats {
        ShardStats {
            dispatches: self.dispatches,
            busy_s: self.busy_s,
            last_finish: self.free_at,
            stolen: self.stolen,
            batches: self.batches,
            served_by_class: self.served_by_class,
            rejected: self.rejected,
            requeued: self.requeued,
            model_fp: self.model.fingerprint(),
            predicted_s: self.predicted_sum_s,
            realized_s: self.realized_sum_s,
            // Closed at `free_at` when the caller has no better clock;
            // the cluster report re-closes the span at its own clock.
            provisioned_s: self.provisioned_s(self.free_at),
            // Energy is attributed at report time by the cluster, which
            // owns the completion records and the parked-rate option.
            joules_active: 0.0,
            joules_idle: 0.0,
            joules_parked: 0.0,
        }
    }

    /// Admit an already-gated request into this shard's queue.
    pub fn enqueue(&mut self, q: QueuedRequest) {
        self.queue.push(q);
    }

    /// The request this shard would dispatch (or yield to a thief)
    /// next, without removing it or advancing the queue's round-robin
    /// state — the steal *offer* a thief inspects before committing.
    pub fn peek_next(&self) -> Option<&QueuedRequest> {
        self.queue.peek_next()
    }

    /// Give up the request this shard would dispatch next (under its own
    /// policy) so an idle shard can run it instead.
    pub fn yield_next(&mut self) -> Option<QueuedRequest> {
        self.queue.pop_next()
    }

    /// Record that this shard stole a request from a busier one.
    pub fn note_steal(&mut self) {
        self.stolen += 1;
    }

    /// Record that `n` requests were displaced off this shard by a
    /// crash and re-admitted elsewhere.
    pub fn note_requeued(&mut self, n: usize) {
        self.requeued += n;
    }

    /// Kill this shard's machine at virtual time `now`: drain and
    /// return every queued request (in the order the shard's own policy
    /// would have dispatched them — deterministic) and stop the busy
    /// clock at the crash instant. In-flight work is rolled back
    /// separately, record by record, via
    /// [`ExecutorShard::abort_record`]; the cluster owns those records.
    ///
    /// `busy_s` keeps the machine-seconds actually elapsed before the
    /// crash (dispatches are serialized, so the only execution spanning
    /// `now` is the one ending at `free_at`) — the un-elapsed tail of
    /// that execution never happened and is subtracted.
    pub fn crash(&mut self, now: f64) -> Vec<QueuedRequest> {
        self.busy_s -= (self.free_at - now).max(0.0);
        self.free_at = now;
        let mut drained = Vec::new();
        while let Some(q) = self.queue.pop_next() {
            drained.push(q);
        }
        drained
    }

    /// Roll one aborted in-flight completion record back out of this
    /// shard's accounting, so its re-admission elsewhere cannot
    /// double-count: the class attribution and the placement sums
    /// (predicted / realized) are reversed. For a fused-batch member
    /// the reversal is pro-rata by the member's own record — close to,
    /// but not exactly, the carrier-level figure the dispatch added —
    /// so both sums clamp at zero. `dispatches`/`batches` stay: the
    /// dispatch did happen, its results were just lost.
    pub fn abort_record(&mut self, r: &ServedRequest) {
        let lane = &mut self.served_by_class[r.class.index()];
        *lane = lane.saturating_sub(1);
        self.predicted_sum_s = (self.predicted_sum_s - r.predicted_s).max(0.0);
        self.realized_sum_s = (self.realized_sum_s - r.exec_s).max(0.0);
    }

    /// The device the bypass frees for standalone riders: the slowest
    /// one (largest fitted slope), whose loss barely moves the co-exec
    /// optimum — on the paper's machines this is the CPU with its ~1%
    /// share.
    pub fn bypass_host(&self) -> usize {
        self.model
            .devices
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.a.total_cmp(&b.1.a))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Plan `size` with device `host` excluded from the split problem,
    /// so the resulting work order leaves it idle for a bypass rider.
    fn plan_excluding(&self, size: GemmSize, host: usize) -> Result<SchedulePlan> {
        let plan = build_plan_excluding(&self.model, size, &self.rules, &self.plan_opts, &[host])?;
        if plan.assignments[host].rows > 0 {
            // Defensive: alignment rebalancing handed leftover rows to
            // the host (possible only in degenerate configs).
            return Err(Error::Infeasible(format!(
                "bypass host {host} still assigned {} rows",
                plan.assignments[host].rows
            )));
        }
        Ok(plan)
    }

    fn cached_plan(&mut self, size: GemmSize) -> Result<(SchedulePlan, bool)> {
        self.cache
            .get_or_build(&self.model, size, &self.rules, &self.plan_opts)
    }

    /// Serve this shard's next queued request (possibly two, when the
    /// bypass pairs a rider), starting execution at virtual time
    /// `start` (`>= free_at()`). Completion records are appended to
    /// `out`. Returns `None` when the queue is empty.
    pub fn dispatch_next(
        &mut self,
        start: f64,
        out: &mut Vec<ServedRequest>,
    ) -> Option<DispatchResult> {
        let q = self.queue.pop_next()?;
        self.dispatches += 1;
        let result = if q.batch.is_some() {
            self.serve_batch(q, start, out)
        } else if q.co_execute {
            self.serve_coexec(q, start, out)
        } else {
            self.serve_standalone(q, start, out)
        };
        self.free_at = result.finish;
        Some(result)
    }

    /// Serve a fused admission-time batch (see [`super::batch`]): one
    /// dispatch, one execution of the row-stacked problem, and one
    /// completion record **per member** — attributed with
    /// [`crate::sim::ExecOutcome::finish_of`] over the devices that
    /// computed the member's rows, so a member on the fast device's
    /// slice finishes before the batch's slowest straggler.
    fn serve_batch(
        &mut self,
        mut q: QueuedRequest,
        start: f64,
        out: &mut Vec<ServedRequest>,
    ) -> DispatchResult {
        let batch = q.batch.take().expect("serve_batch requires a fused batch");
        self.batches += 1;
        if q.co_execute {
            self.serve_batch_coexec(&q, &batch, start, out)
        } else {
            self.serve_batch_standalone(&q, &batch, start, out)
        }
    }

    /// One member's slice of a batch fan-out: class attribution plus
    /// the completion record. The member keeps its own identity,
    /// arrival and SLO; the carrier prediction is split pro-rata by row
    /// count (the one attribution rule all batch outcomes share).
    #[allow(clippy::too_many_arguments)]
    fn push_member(
        &mut self,
        q: &QueuedRequest,
        m: &BatchMember,
        mode: ExecMode,
        start: f64,
        exec_s: f64,
        cache_hit: bool,
        shares: Vec<f64>,
        out: &mut Vec<ServedRequest>,
    ) {
        self.served_by_class[m.req.class.index()] += 1;
        out.push(ServedRequest {
            id: m.req.id,
            size: m.req.size,
            reps: m.req.reps,
            class: m.req.class,
            deadline_s: m.req.deadline_s,
            mode,
            shard: Some(self.id),
            arrival: m.arrival,
            start,
            finish: start + exec_s,
            exec_s,
            predicted_s: q.predicted_s * m.req.size.m as f64 / q.req.size.m as f64,
            cache_hit,
            shares,
        });
    }

    /// The fused batch passed the §6 gate: plan and split it across
    /// devices like any large GEMM (plans come from the shard's
    /// [`PlanCache`], keyed by the fused shape), then fan completions
    /// out per member by intersecting each member's row span with the
    /// per-device assignments.
    fn serve_batch_coexec(
        &mut self,
        q: &QueuedRequest,
        batch: &FusedBatch,
        start: f64,
        out: &mut Vec<ServedRequest>,
    ) -> DispatchResult {
        let (plan, cache_hit) = match self.cached_plan(q.req.size) {
            Ok(pc) => pc,
            Err(_) => {
                self.reject_batch(q, batch, start, out);
                return DispatchResult {
                    finish: start,
                    replanned: false,
                };
            }
        };
        let order = plan.to_work_order(q.req.reps);
        let sim_start = self.sim.now();
        let outcome = self.sim.execute(&order);
        self.busy_s += self.sim.busy_until() - sim_start;
        // Placement quality treats the batch as the single unit routing
        // predicted: one predicted figure against one realized figure.
        let finish_all = outcome.finish_of(&plan.active_device_indices());
        self.predicted_sum_s += q.predicted_s;
        self.realized_sum_s += finish_all;
        let shares = plan.shares();
        let mut row = 0u64;
        for m in &batch.members {
            let span = (row, row + m.req.size.m);
            row = span.1;
            let devices: Vec<usize> = plan
                .assignments
                .iter()
                .filter(|a| a.rows > 0 && a.row_offset < span.1 && a.row_offset + a.rows > span.0)
                .map(|a| a.device)
                .collect();
            let finish_m = outcome.finish_of(&devices);
            let mode = ExecMode::Batched { batch: batch.id };
            self.push_member(q, m, mode, start, finish_m, cache_hit, shares.clone(), out);
        }
        let mut replanned = false;
        if let Some(ds) = &mut self.dynsched {
            if ds.observe(&plan, &outcome, q.req.reps) {
                self.model = ds.model.clone();
                self.cache.bump_epoch();
                replanned = true;
            }
        }
        DispatchResult {
            finish: start + outcome.makespan,
            replanned,
        }
    }

    /// The fused batch stayed standalone-bound: one library call of the
    /// row-stacked problem on the best device — the shared `B` operand
    /// still crosses the bus once instead of once per member, which is
    /// where the throughput win over serving the members one by one
    /// comes from. Every member finishes with the call.
    fn serve_batch_standalone(
        &mut self,
        q: &QueuedRequest,
        batch: &FusedBatch,
        start: f64,
        out: &mut Vec<ServedRequest>,
    ) -> DispatchResult {
        let dev = q.best_device;
        let sim_start = self.sim.now();
        let outcome = baselines::standalone(&mut self.sim, dev, q.req.size, q.req.reps);
        self.busy_s += self.sim.busy_until() - sim_start;
        self.predicted_sum_s += q.predicted_s;
        self.realized_sum_s += outcome.makespan;
        let mut shares = vec![0.0; self.sim.num_devices()];
        shares[dev] = 1.0;
        for m in &batch.members {
            let mode = ExecMode::Batched { batch: batch.id };
            self.push_member(q, m, mode, start, outcome.makespan, false, shares.clone(), out);
        }
        DispatchResult {
            finish: start + outcome.makespan,
            replanned: false,
        }
    }

    /// The fused plan was infeasible: every member completes as
    /// [`ExecMode::Rejected`] (zero time, empty shares), mirroring the
    /// single-request path — the shard and its queue live on.
    fn reject_batch(
        &mut self,
        q: &QueuedRequest,
        batch: &FusedBatch,
        start: f64,
        out: &mut Vec<ServedRequest>,
    ) {
        for m in &batch.members {
            let zero_shares = vec![0.0; self.sim.num_devices()];
            self.rejected += 1;
            self.push_member(q, m, ExecMode::Rejected, start, 0.0, false, zero_shares, out);
        }
    }

    fn serve_coexec(
        &mut self,
        q: QueuedRequest,
        start: f64,
        out: &mut Vec<ServedRequest>,
    ) -> DispatchResult {
        // ---- Bypass pairing: a standalone-bound request that fits on
        // the host device within this request's predicted window rides
        // along instead of waiting for its own turn.
        let host = self.bypass_host();
        let mut rider: Option<QueuedRequest> = None;
        let mut rider_host_pred = 0.0_f64;
        if self.opts.standalone_bypass {
            let inputs = self.model.model_inputs();
            let budget = q.predicted_s;
            let reps = q.req.reps;
            rider = self.queue.take_first(|c| {
                // A fused batch never rides the bypass: its carrier is
                // one queue slot but fans out per member at dispatch,
                // which the single-record rider path cannot do.
                c.batch.is_none()
                    && !c.co_execute
                    && c.req.reps == reps
                    && predicted_standalone(&inputs[host], c.req.size) * reps.max(1) as f64
                        <= budget
            });
            if let Some(c) = &rider {
                // The rider runs on the host, so record the host-device
                // prediction (its admission-time one was for its best
                // standalone device).
                rider_host_pred =
                    predicted_standalone(&inputs[host], c.req.size) * reps.max(1) as f64;
            }
        }

        // ---- Plan: cached for the ordinary path; the bypass path plans
        // around the freed host (not cached — it is shape- and
        // pairing-specific).
        let plan_result = if rider.is_some() {
            match self.plan_excluding(q.req.size, host) {
                Ok(p) => Ok((p, false)),
                Err(_) => {
                    // Could not free the host: undo the pairing.
                    self.queue.push_front(rider.take().unwrap());
                    self.cached_plan(q.req.size)
                }
            }
        } else {
            self.cached_plan(q.req.size)
        };
        let (plan, cache_hit) = match plan_result {
            Ok(pc) => pc,
            Err(_) => {
                // Infeasible plan: the request completes rejected; the
                // shard (and the rest of the queue) lives on.
                self.serve_rejected(q, start, out);
                return DispatchResult {
                    finish: start,
                    replanned: false,
                };
            }
        };

        // ---- Build the (possibly merged) work order.
        let mut order = plan.to_work_order(q.req.reps);
        if let Some(c) = &rider {
            let priority = self.model.devices[host].priority;
            let small = WorkOrder {
                items: vec![WorkItem::whole(host, c.req.size, priority)],
                reps: c.req.reps,
            };
            // Guaranteed disjoint: plan_excluding left the host with zero
            // rows, and the rider predicate enforced equal reps.
            order = order
                .merge(&small)
                .expect("bypass invariant: host idle and reps equal");
        }

        // ---- Execute once; attribute completions per tenant.
        let sim_start = self.sim.now();
        let outcome = self.sim.execute(&order);
        // `busy_until - start` is exactly the makespan: the machine's
        // own busy-until hook backs the shard's utilization accounting.
        self.busy_s += self.sim.busy_until() - sim_start;
        let finish_big = outcome.finish_of(&plan.active_device_indices());
        self.served_by_class[q.req.class.index()] += 1;
        self.predicted_sum_s += q.predicted_s;
        self.realized_sum_s += finish_big;
        out.push(ServedRequest {
            id: q.req.id,
            size: q.req.size,
            reps: q.req.reps,
            class: q.req.class,
            deadline_s: q.req.deadline_s,
            mode: ExecMode::CoExec,
            shard: Some(self.id),
            arrival: q.arrival,
            start,
            finish: start + finish_big,
            exec_s: finish_big,
            predicted_s: q.predicted_s,
            cache_hit,
            shares: plan.shares(),
        });
        if let Some(c) = &rider {
            let finish_small = outcome.finish_of(&[host]);
            let mut shares = vec![0.0; self.sim.num_devices()];
            shares[host] = 1.0;
            self.served_by_class[c.req.class.index()] += 1;
            self.predicted_sum_s += rider_host_pred;
            self.realized_sum_s += finish_small;
            out.push(ServedRequest {
                id: c.req.id,
                size: c.req.size,
                reps: c.req.reps,
                class: c.req.class,
                deadline_s: c.req.deadline_s,
                mode: ExecMode::BypassStandalone { device: host },
                shard: Some(self.id),
                arrival: c.arrival,
                start,
                finish: start + finish_small,
                exec_s: finish_small,
                predicted_s: rider_host_pred,
                cache_hit: false,
                shares,
            });
        }

        // ---- Closed loop: observe, refresh, invalidate.
        let mut replanned = false;
        if let Some(ds) = &mut self.dynsched {
            if ds.observe(&plan, &outcome, q.req.reps) {
                self.model = ds.model.clone();
                self.cache.bump_epoch();
                replanned = true;
            }
        }
        DispatchResult {
            finish: start + outcome.makespan,
            replanned,
        }
    }

    fn serve_standalone(
        &mut self,
        q: QueuedRequest,
        start: f64,
        out: &mut Vec<ServedRequest>,
    ) -> DispatchResult {
        let dev = q.best_device;
        let sim_start = self.sim.now();
        let outcome = baselines::standalone(&mut self.sim, dev, q.req.size, q.req.reps);
        self.busy_s += self.sim.busy_until() - sim_start;
        let mut shares = vec![0.0; self.sim.num_devices()];
        shares[dev] = 1.0;
        self.served_by_class[q.req.class.index()] += 1;
        self.predicted_sum_s += q.predicted_s;
        self.realized_sum_s += outcome.makespan;
        out.push(ServedRequest {
            id: q.req.id,
            size: q.req.size,
            reps: q.req.reps,
            class: q.req.class,
            deadline_s: q.req.deadline_s,
            mode: ExecMode::Standalone { device: dev },
            shard: Some(self.id),
            arrival: q.arrival,
            start,
            finish: start + outcome.makespan,
            exec_s: outcome.makespan,
            predicted_s: q.predicted_s,
            cache_hit: false,
            shares,
        });
        DispatchResult {
            finish: start + outcome.makespan,
            replanned: false,
        }
    }

    fn serve_rejected(&mut self, q: QueuedRequest, start: f64, out: &mut Vec<ServedRequest>) {
        self.served_by_class[q.req.class.index()] += 1;
        self.rejected += 1;
        out.push(ServedRequest {
            id: q.req.id,
            size: q.req.size,
            reps: q.req.reps,
            class: q.req.class,
            deadline_s: q.req.deadline_s,
            mode: ExecMode::Rejected,
            shard: Some(self.id),
            arrival: q.arrival,
            start,
            finish: start,
            exec_s: 0.0,
            predicted_s: q.predicted_s,
            cache_hit: false,
            shares: vec![0.0; self.sim.num_devices()],
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::service::request::GemmRequest;

    fn shard(seed: u64, opts: ServerOptions) -> ExecutorShard {
        ExecutorShard::from_pipeline(
            0,
            Pipeline::for_simulated_machine(&presets::mach2(), seed),
            &opts,
        )
    }

    fn queued(id: u64, size: GemmSize, reps: u32, co: bool, predicted_s: f64) -> QueuedRequest {
        QueuedRequest {
            req: GemmRequest::new(id, size, reps),
            arrival: 0.0,
            co_execute: co,
            best_device: 2,
            predicted_s,
            batch: None,
        }
    }

    /// A hand-fused 2-member batch carrier (the cluster normally builds
    /// these through the `BatchFormer`).
    fn queued_batch(m0: u64, m1: u64, n: u64, k: u64, co: bool, dev: usize) -> QueuedRequest {
        use crate::service::batch::{BatchMember, FusedBatch};
        use crate::service::request::BatchId;
        let member = |id: u64, m: u64| BatchMember {
            req: GemmRequest::new(id, GemmSize::new(m, n, k), 2),
            arrival: 0.0,
        };
        let fused = GemmSize::new(m0 + m1, n, k);
        QueuedRequest {
            req: GemmRequest::new(0, fused, 2),
            arrival: 0.0,
            co_execute: co,
            best_device: dev,
            predicted_s: 1.0,
            batch: Some(FusedBatch {
                id: BatchId(0),
                size: fused,
                reps: 2,
                class: QosClass::Standard,
                deadline_abs: None,
                members: vec![member(0, m0), member(1, m1)],
            }),
        }
    }

    #[test]
    fn dispatch_advances_free_time_and_accounts_busy_seconds() {
        let mut s = shard(0, ServerOptions::default());
        assert_eq!(s.pending(), 0);
        s.enqueue(queued(0, GemmSize::square(18_000), 2, true, 1.0));
        let mut out = Vec::new();
        let r = s.dispatch_next(5.0, &mut out).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].start, 5.0);
        assert!(r.finish > 5.0);
        assert_eq!(s.free_at(), r.finish);
        // Busy accounting comes from the machine's own busy-until hook.
        assert!((s.stats().busy_s - (r.finish - 5.0)).abs() < 1e-9);
        assert_eq!(s.stats().dispatches, 1);
        assert!(s.dispatch_next(r.finish, &mut out).is_none());
    }

    #[test]
    fn predicted_finish_folds_backlog_and_clock() {
        let mut s = shard(1, ServerOptions::default());
        s.enqueue(queued(0, GemmSize::square(16_000), 1, true, 2.0));
        s.enqueue(queued(1, GemmSize::square(16_000), 1, true, 3.0));
        assert!((s.backlog_s() - 5.0).abs() < 1e-12);
        // Idle shard, now=10: finish = 10 + backlog + request.
        assert!((s.predicted_finish(10.0, 4.0) - 19.0).abs() < 1e-12);
        // A busy shard counts from its free time instead.
        s.free_at = 50.0;
        assert!((s.predicted_finish(10.0, 4.0) - 59.0).abs() < 1e-12);
    }

    #[test]
    fn infeasible_plan_rejects_request_instead_of_panicking() {
        let mut s = shard(2, ServerOptions::default());
        // Corrupt the adapt rules (arity mismatch) so every plan build
        // fails — the seam a degenerate config would hit in production.
        s.rules = Vec::new();
        s.enqueue(queued(7, GemmSize::square(20_000), 3, true, 1.0));
        // A standalone request behind it must still be served.
        s.enqueue(queued(8, GemmSize::square(300), 3, false, 0.5));
        let mut out = Vec::new();
        let r = s.dispatch_next(0.0, &mut out).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].mode, ExecMode::Rejected);
        assert_eq!(out[0].id, 7);
        assert_eq!(out[0].exec_s, 0.0);
        assert_eq!(out[0].finish, out[0].start);
        assert_eq!(out[0].shares.iter().sum::<f64>(), 0.0);
        assert_eq!(r.finish, 0.0, "rejection consumes no machine time");
        // The shard survives and serves the rest of its queue.
        let r2 = s.dispatch_next(r.finish, &mut out).unwrap();
        assert!(r2.finish > 0.0);
        assert_eq!(out[1].id, 8);
        assert!(matches!(out[1].mode, ExecMode::Standalone { .. }));
    }

    #[test]
    fn class_aware_predicted_finish_discounts_lighter_lanes() {
        let mut s = shard(5, ServerOptions::default());
        let mut batch = queued(0, GemmSize::square(16_000), 1, true, 4.0);
        batch.req.class = QosClass::Batch;
        s.enqueue(batch);
        // Class-blind estimate counts the queued batch second-for-second.
        assert!((s.predicted_finish(0.0, 1.0) - 5.0).abs() < 1e-12);
        // A 1s interactive arrival only waits for the interleave the
        // weighted drain allows (1/4 of its own 1s drain); a batch
        // arrival waits at face value.
        assert!((s.predicted_finish_for(0.0, 1.0, QosClass::Interactive) - 1.25).abs() < 1e-12);
        assert!((s.predicted_finish_for(0.0, 1.0, QosClass::Batch) - 5.0).abs() < 1e-12);
        assert!((s.class_backlog(QosClass::Batch) - 4.0).abs() < 1e-12);
        assert!((s.weighted_backlog() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn dispatches_are_attributed_to_their_class() {
        let mut s = shard(6, ServerOptions::default());
        let mut q1 = queued(0, GemmSize::square(18_000), 2, true, 1.0);
        q1.req.class = QosClass::Interactive;
        s.enqueue(q1);
        s.enqueue(queued(1, GemmSize::square(300), 2, false, 0.5));
        let mut out = Vec::new();
        let r = s.dispatch_next(0.0, &mut out).unwrap();
        s.dispatch_next(r.finish, &mut out);
        assert_eq!(s.stats().served_by_class, [1, 1, 0]);
        assert_eq!(out[0].class, QosClass::Interactive);
        assert_eq!(out[1].class, QosClass::Standard);
    }

    #[test]
    fn coexec_batch_fans_out_per_member_with_row_attribution() {
        let mut s = shard(7, ServerOptions::default());
        // Two heavy members row-stacked into a co-executable batch.
        s.enqueue(queued_batch(16_000, 16_000, 16_000, 16_000, true, 0));
        let mut out = Vec::new();
        let r = s.dispatch_next(1.0, &mut out).unwrap();
        assert_eq!(out.len(), 2, "one record per member");
        assert_eq!(s.stats().dispatches, 1, "the batch is one dispatch");
        assert_eq!(s.stats().batches, 1);
        assert_eq!(s.stats().served_by_class, [0, 2, 0]);
        for m in &out {
            assert!(matches!(m.mode, ExecMode::Batched { .. }));
            assert_eq!(m.start, 1.0);
            assert!(m.finish > m.start);
            assert!(m.finish <= r.finish + 1e-9, "member outlived the batch");
            assert!((m.shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(m.predicted_s > 0.0);
        }
        // Members keep their own ids and sizes.
        assert_eq!(out[0].id, 0);
        assert_eq!(out[1].id, 1);
        assert_eq!(out[0].size, GemmSize::new(16_000, 16_000, 16_000));
        // Equal members split the carrier prediction evenly.
        assert!((out[0].predicted_s - 0.5).abs() < 1e-12);
        // The plan solved once for the fused shape.
        assert_eq!(s.cache.misses, 1);
    }

    #[test]
    fn standalone_batch_runs_one_fused_call_on_the_best_device() {
        let mut s = shard(8, ServerOptions::default());
        s.enqueue(queued_batch(1024, 1536, 1024, 1024, false, 1));
        let mut out = Vec::new();
        let r = s.dispatch_next(0.0, &mut out).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(s.stats().batches, 1);
        for m in &out {
            assert!(matches!(m.mode, ExecMode::Batched { .. }));
            assert_eq!(m.finish, r.finish, "a fused call completes together");
            assert_eq!(m.shares[1], 1.0, "the whole batch ran on device 1");
            assert!(!m.cache_hit);
        }
        // The carrier prediction splits by row share: 1024 : 1536.
        assert!((out[0].predicted_s - 0.4).abs() < 1e-12);
        assert!((out[1].predicted_s - 0.6).abs() < 1e-12);
    }

    #[test]
    fn infeasible_batch_plan_rejects_every_member() {
        let mut s = shard(9, ServerOptions::default());
        s.rules = Vec::new(); // sabotage planning, as in the single test
        s.enqueue(queued_batch(16_000, 16_000, 16_000, 16_000, true, 0));
        let mut out = Vec::new();
        let r = s.dispatch_next(0.0, &mut out).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(r.finish, 0.0, "rejection consumes no machine time");
        for m in &out {
            assert_eq!(m.mode, ExecMode::Rejected);
            assert_eq!(m.exec_s, 0.0);
        }
    }

    #[test]
    fn yield_next_hands_over_the_policy_choice() {
        let mut s = shard(3, ServerOptions::default());
        s.enqueue(queued(0, GemmSize::square(16_000), 1, true, 2.0));
        s.enqueue(queued(1, GemmSize::square(16_000), 1, true, 3.0));
        assert_eq!(s.peek_next().unwrap().req.id, 0, "peek shows the offer");
        let stolen = s.yield_next().unwrap();
        assert_eq!(stolen.req.id, 0, "FIFO yields the head");
        assert_eq!(s.pending(), 1);
        s.note_steal();
        assert_eq!(s.stats().stolen, 1);
    }
}
