//! The cluster front-end: POAS serving sharded across machines.
//!
//! A [`Cluster`] drives N [`ExecutorShard`]s — each a full machine with
//! its own installation-time profile, plan cache and local queue —
//! through one **event-driven virtual-time loop**. The single
//! monolithic `clock: f64` of the old server is replaced by a binary
//! heap of timestamped events:
//!
//! * **arrival** — a request reaches the front-end (either submitted
//!   "now" or scheduled by an [`super::arrivals`] trace). Under
//!   [`BatchPolicy::Windowed`], a *small* arrival that no shard's own
//!   gate would co-execute alone first visits the **batch former**
//!   ([`super::batch::BatchFormer`]): compatible small requests wait in
//!   a short window and fuse into one row-stacked [`FusedBatch`] that
//!   is admitted, deadline-checked, routed, stolen and dispatched as a
//!   single unit (one queue slot on the strictest member's lane),
//!   fanning back out into per-member completion records at dispatch.
//!   Everything below treats "request" and "fused batch" uniformly
//!   through the batch carrier request. Anything else is scored
//!   against **every shard's own [`Admission`] gate** — one gate per
//!   shard, each predicting with that shard's installation-time
//!   profile, so a heterogeneous cluster (see
//!   [`crate::config::presets::hetero_mix`]) routes a large GEMM to its
//!   GPU-heavy shard and a tiny one to its CPU shard from predictions
//!   alone. A deadline-bound request then faces **deadline admission**:
//!   only shards whose *own* model passes the machine-level feasibility
//!   probe (the deadline-constrained LP reused from the energy
//!   formulation) are eligible, and the queueing-aware sojourn
//!   prediction at the chosen shard must fit the slack guard band. An
//!   SLO no shard can meet is turned away as [`ExecMode::Denied`] or
//!   demoted to [`QosClass::Batch`] with the SLO stripped, per
//!   [`super::DeadlinePolicy`]. Accepted requests route to the shard
//!   with the earliest **class-weighted predicted finish**:
//!   `max(shard free time, now) + class-discounted backlog + this
//!   request under this shard's model`, all from admission-time
//!   predictions, so routing never re-runs the optimizer;
//! * **wake** — scheduled behind every arrival at the same timestamp so
//!   that simultaneous arrivals are all admitted (and visible to queue
//!   policies and the bypass scan) before any of them starts a machine;
//! * **batch-flush** — a batch window's timer fired: the window's
//!   members fuse and enter admission as one batch. Windows also flush
//!   early when full or under SLO deadline pressure (see
//!   [`super::batch`]); a fused batch whose tightest member SLO fails
//!   batch-level deadline admission is **disbanded** — every member
//!   re-enters admission solo (with its window wait charged against its
//!   remaining deadline budget) rather than being denied wholesale;
//! * **shard-free** — a machine finished its dispatch. It drains its
//!   own queue first and, when empty, **steals** the next request
//!   (under the victim's own weighted pick, so high classes move first)
//!   from the shard with the largest *class-weighted* backlog — a
//!   minute of queued interactive work makes a hotter victim than a
//!   minute of batch. A stolen request is **re-gated under the thief's
//!   own model** before it is enqueued: the victim's verdict (co-exec
//!   vs standalone, best device, service prediction) may be wrong —
//!   even out of device range — on a different machine;
//! * **faults** — injected by the scenario layer (see
//!   [`super::scenario`]) through [`Cluster::inject_crash`],
//!   [`Cluster::inject_restart`] and [`Cluster::inject_slowdown`]. A
//!   **crash** kills one shard mid-run: its queue drains and its
//!   in-flight work (completion records written at dispatch time with
//!   future finishes) is aborted, rolled back out of the shard's
//!   accounting, and every displaced request **re-enters front-end
//!   admission** — original arrival time kept, elapsed wait charged
//!   against any remaining SLO budget, re-gated under the surviving
//!   shards' own models; members of a displaced fused batch disband
//!   and re-admit solo. A **restart** brings the shard back (and
//!   releases requests parked while every machine was down). A
//!   **rate-scale** multiplies one machine's device rates — the
//!   straggler/degraded-machine hook: realized times drift away from
//!   the model fitted at install time until the dynamic loop (or a
//!   recovery event) closes the gap;
//! * **membership** — the shard *set* itself changes mid-run (see
//!   [`super::elastic`]). A **join** ([`Cluster::inject_join`])
//!   provisions a new shard at the event instant: its machine is
//!   profiled then (installation time), it gets its own admission gate
//!   and a cold [`super::PlanCache`], and both tournament-tree indexes
//!   are rebuilt one leaf wider (a rare event — the steady state still
//!   allocates nothing). A **graceful drain**
//!   ([`Cluster::inject_drain`]) is the voluntary opposite of a crash:
//!   the shard is disabled in both indexes so no new work lands, its
//!   **in-flight execution runs to completion untouched** (zero
//!   displaced records — the machine-seconds meter stops only once it
//!   finishes), and only *queued* work is redistributed through
//!   front-end admission with original arrivals and SLO budgets. A
//!   configured [`AutoscalerPolicy`] arms a recurring evaluation event
//!   that drives joins/drains/revivals from predicted backlog and
//!   deadline-risk.
//!
//! Ties in virtual time break by submission sequence number, which
//! keeps every replay byte-identical for a fixed seed. A one-shard
//! cluster degenerates to exactly the old single-machine behaviour —
//! [`super::Server`] is now a thin wrapper over `Cluster`. A run with
//! no injected faults behaves byte-identically to a build without the
//! fault machinery: every guard below is a no-op while no shard is
//! down.
//!
//! **The front-end hot path** (see `docs/hotpath.md`) keeps decision
//! cost sublinear in shard count: routing can sample d candidates
//! ([`RoutePolicy::Sampled`]) seeded by a [`TournamentTree`] index
//! over each shard's predicted-finish proxy, steal victims come from a
//! second tree over class-weighted backlog (both kept incrementally
//! current by `reindex` on every queue/fault mutation), and the event
//! loop batch-drains same-timestamp events through a reusable buffer
//! so the steady state allocates nothing per decision.

use super::admission::{Admission, GateVerdict};
use super::arrivals::Arrival;
use super::batch::{BatchFormer, BatchPolicy, FusedBatch, JoinOutcome};
use super::clock::{Clock, VirtualClock};
use super::elastic::{Autoscaler, AutoscalerPolicy};
use super::index::{Ranking, TournamentTree};
use super::qos::{DeadlinePolicy, QosClass};
use super::queue::QueuedRequest;
use super::request::{ExecMode, GemmRequest, ServedRequest, ServiceReport};
use super::server::ServerOptions;
use super::shard::ExecutorShard;
use crate::config::MachineConfig;
use crate::coordinator::Pipeline;
use crate::rng::Rng;
use crate::workload::GemmSize;
use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, VecDeque};

/// Seed of the router's candidate-sampling stream. A fixed constant —
/// not derived from workload seeds — so two identically-constructed
/// clusters replay byte-identically; the stream is consumed **only**
/// when [`RoutePolicy::Sampled`] actually samples (never under
/// [`RoutePolicy::Full`], and never when `d` covers every live shard).
const ROUTER_RNG_SEED: u64 = 0x504f_4153_726f_7574; // "POASrout"

/// Minimum affinity advantage (ratio) a runner-up steal victim's head
/// request must offer before a thief abandons the backlog winner for
/// it. Wide enough that profiling noise between clone shards of a
/// homogeneous cluster never moves the pick — only genuinely different
/// hardware (a GPU node eyeing CPU-planned work, or vice versa) does.
const HETERO_STEAL_TILT: f64 = 1.25;

/// Which performance model the front-end's prediction call sites use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GatePolicy {
    /// One [`Admission`] gate per shard, each predicting with that
    /// shard's own installation-time profile. Routing, deadline
    /// feasibility and steal re-planning all consult the model of the
    /// shard actually being considered. The only correct choice on a
    /// heterogeneous cluster, and the default everywhere.
    #[default]
    PerShard,
    /// The pre-heterogeneous behaviour, kept **only** as the ablation
    /// baseline for benches and acceptance tests: a single gate built
    /// from shard 0's model predicts for every shard, as if the cluster
    /// were a fleet of clones. On genuinely mixed machines its
    /// standalone device pick can be out of range on a smaller shard
    /// and is clamped so the baseline can run at all.
    Shard0,
}

/// How the front-end picks the target shard for an admitted work unit.
///
/// Both policies score candidates **exactly** the same way (per-shard
/// gate verdict, class-weighted predicted finish, ties to the lowest
/// index); they differ only in *which* shards are scored. See
/// `docs/hotpath.md` for the determinism contract and the measured
/// cost of each.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    /// Gate and score every live shard — the exact argmin, O(shards)
    /// per decision. The default, and the ablation baseline the
    /// sampled router is benched against.
    #[default]
    Full,
    /// Power-of-d-choices: score only `d` candidates — the routing
    /// index's winner (the shard with the smallest request-independent
    /// finish proxy) plus `d - 1` distinct live shards drawn from the
    /// deterministic router stream — for O(d + log shards) decisions.
    /// Whenever `d` covers every live shard the router takes the exact
    /// full scan instead and consumes **no** randomness, so
    /// `Sampled { d >= shards }` is byte-identical to [`Full`]
    /// (`RoutePolicy::Full`).
    Sampled {
        /// Candidates scored per decision (the index winner included).
        d: usize,
    },
}

/// What the router optimizes when several shards can take a work unit.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum RouteObjective {
    /// Earliest class-weighted predicted finish — the latency-first
    /// pick, and the default (byte-identical to every pre-energy run).
    #[default]
    Latency,
    /// Prefer the feasible shard with the lowest predicted joules for
    /// this unit, as long as its predicted finish stays within `slack`
    /// times the latency winner's — under pressure (no candidate within
    /// the band) the pick falls back to earliest-predicted-finish. For
    /// a deadline-bound unit the band is additionally clamped to the
    /// admission slack guard, so energy-awareness never converts an
    /// admit into a denial. See `docs/energy.md`.
    EnergyAware {
        /// Latency-stretch tolerance, `>= 1.0`: how many times the
        /// latency winner's predicted sojourn an energy-cheaper shard
        /// may cost before it stops being acceptable.
        slack: f64,
    },
}

/// Cluster-level power management knobs (see `docs/energy.md`).
#[derive(Debug, Clone, Copy)]
pub struct PowerOptions {
    /// Cluster-wide power cap in watts, enforced at admission: an
    /// arrival whose marginal draw would push the predicted aggregate
    /// draw over the cap is denied (or demoted, per
    /// [`super::DeadlinePolicy`]) like a deadline-infeasible one.
    /// `None` (the default) enforces nothing.
    pub cap_w: Option<f64>,
    /// Fraction of a machine's idle watts it keeps drawing while
    /// parked (drained by the autoscaler or a scenario fault) — the
    /// low-power state that makes scale-down actually save energy.
    pub parked_frac: f64,
}

impl Default for PowerOptions {
    fn default() -> Self {
        PowerOptions {
            cap_w: None,
            parked_frac: 0.1,
        }
    }
}

/// Cluster construction options.
#[derive(Debug, Clone)]
pub struct ClusterOptions {
    /// Number of machines (min 1). Each shard profiles its own
    /// [`crate::sim::SimMachine`] seeded `seed + shard index`.
    pub shards: usize,
    /// Per-shard serving options (queue policy, bypass, dynamic loop)
    /// plus the admission-gate knobs shared by the front-end.
    pub shard: ServerOptions,
    /// Let an idle shard steal queued work from the most backlogged
    /// shard instead of sitting idle.
    pub work_stealing: bool,
    /// Whose model predicts at the front-end (see [`GatePolicy`];
    /// default [`GatePolicy::PerShard`]).
    pub gate: GatePolicy,
    /// Admission-time batching of small standalone-bound arrivals (see
    /// [`super::batch`]; default [`BatchPolicy::Off`], which reproduces
    /// the pre-batching behaviour exactly).
    pub batching: BatchPolicy,
    /// Shard-selection policy (see [`RoutePolicy`]; default
    /// [`RoutePolicy::Full`], the exact scan).
    pub route: RoutePolicy,
    /// Elastic-membership policy (see [`super::elastic`]): when set,
    /// a recurring evaluation event provisions/drains shards from the
    /// policy's preset pool against predicted backlog and
    /// deadline-risk. `None` (the default) arms nothing and reproduces
    /// fixed membership exactly.
    pub autoscaler: Option<AutoscalerPolicy>,
    /// Routing objective (see [`RouteObjective`]; default
    /// [`RouteObjective::Latency`], the pre-energy behaviour exactly).
    pub objective: RouteObjective,
    /// Power-management knobs: cluster-wide cap and the parked idle
    /// rate (see [`PowerOptions`]).
    pub power: PowerOptions,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        ClusterOptions {
            shards: 1,
            shard: ServerOptions::default(),
            work_stealing: true,
            gate: GatePolicy::PerShard,
            batching: BatchPolicy::Off,
            route: RoutePolicy::Full,
            autoscaler: None,
            objective: RouteObjective::default(),
            power: PowerOptions::default(),
        }
    }
}

/// One routing decision: the chosen shard, *its* gate verdict and the
/// class-weighted predicted finish it was chosen on.
#[derive(Debug, Clone, Copy)]
struct Routed {
    shard: usize,
    verdict: GateVerdict,
    finish: f64,
}

#[derive(Debug, Clone)]
enum EventKind {
    /// A request reaches the front-end.
    Arrival(GemmRequest),
    /// Post-arrival nudge: dispatch on this shard if it is idle.
    Wake(usize),
    /// This shard's machine went idle.
    ShardFree(usize),
    /// A batch window's flush timer fired. Flush bounds only ever
    /// tighten, so a timer for a window that already flushed (or whose
    /// bound moved earlier, arming an earlier timer) is a no-op.
    BatchFlush(u64),
    /// Injected fault: this shard's machine dies. Queued and in-flight
    /// work re-enters admission; a crash of an already-down shard is a
    /// no-op.
    Crash(usize),
    /// Injected fault recovery: a crashed shard rejoins the cluster
    /// (no-op when the shard is up).
    Restart(usize),
    /// Injected fault: multiply every device rate on this shard's
    /// machine by the factor (straggler onset `< 1`, recovery `> 1`;
    /// scales compose multiplicatively).
    RateScale(usize, f64),
    /// Membership: a new shard joins the cluster, its machine profiled
    /// at the event instant on the carried seed (boxed — joins are
    /// rare, and the config must not widen every heap event).
    Join(Box<MachineConfig>, u64),
    /// Membership: gracefully drain this shard — stop routing to it,
    /// let its in-flight execution finish untouched, redistribute its
    /// *queued* work through admission. A drain of a shard that is
    /// already down (crashed or drained), or that has not joined yet,
    /// is a no-op.
    Drain(usize),
    /// Recurring autoscaler evaluation (armed only when
    /// [`ClusterOptions::autoscaler`] is set). Like
    /// [`EventKind::BatchFlush`], a terminal tick — nothing pending,
    /// every machine idle — must not advance the virtual clock, so the
    /// makespan stays the instant real work last moved.
    AutoscaleEval,
    /// Injected power event: the cluster-wide cap changes to the
    /// carried value (`None` removes it) from this instant on. The cap
    /// gates *admissions*; already-queued work is never revisited.
    PowerCap(Option<f64>),
}

#[derive(Debug, Clone)]
struct Event {
    time: f64,
    /// Tie-break for simultaneous events: strictly increasing push
    /// order, so replays are exact.
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time
            .total_cmp(&other.time)
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

/// One core dispatch, as mirrored to the wall-clock driver's tap (see
/// [`TapAction::Dispatch`]).
#[derive(Debug, Clone)]
pub struct DispatchNote {
    /// Dispatch ordinal, assigned in decision order — the exactly-once
    /// accounting key the wall-clock driver tracks terminal events by.
    pub unit: u64,
    /// The shard the unit was dispatched on.
    pub shard: usize,
    /// Virtual instant execution started.
    pub start: f64,
    /// Virtual instant execution finishes.
    pub finish: f64,
    /// Virtual execution seconds charged (`finish - start`).
    pub exec_s: f64,
    /// Ids of the completion records this dispatch wrote (several for
    /// a fused batch, including any [`ExecMode::Rejected`] members).
    pub records: Vec<u64>,
}

/// One entry in the core's action tap: the stream of externally
/// visible decisions a wall-clock driver mirrors onto real worker
/// threads (see [`super::driver::wall_clock`]). Appended in decision
/// order, and **only** while the tap is enabled ([`Cluster::set_tap`])
/// — with the tap off (the default, and always under the virtual
/// driver) none of this machinery runs, keeping the virtual path
/// byte-identical to the pre-tap code.
#[derive(Debug, Clone)]
pub enum TapAction {
    /// A work unit was dispatched on a shard.
    Dispatch(DispatchNote),
    /// An idle `thief` stole the head of `victim`'s queue.
    Steal {
        /// The stealing shard.
        thief: usize,
        /// The shard it stole from.
        victim: usize,
    },
    /// The shard crashed: its queued mirror backlog is invalid.
    Crash {
        /// The crashed shard.
        shard: usize,
    },
    /// The shard started a graceful drain (in-flight work finishes).
    Drain {
        /// The draining shard.
        shard: usize,
    },
    /// A shard joined (a fresh index) or revived (an existing one).
    Join {
        /// The joining shard's index.
        shard: usize,
    },
    /// A crashed or drained shard came back.
    Restart {
        /// The restarted shard.
        shard: usize,
    },
}

/// Fluent construction of a [`Cluster`] — the one supported
/// construction path, consolidating the old `new` / `from_machines` /
/// `HeterogeneousSpec` trio (each still available as a thin
/// `#[deprecated]` shim). Machines are appended in shard-index order;
/// shard `i` profiles at install time on a simulator seeded
/// `seed + i`, so the per-shard admission gates genuinely disagree
/// wherever the hardware does.
///
/// ```no_run
/// use poas::config::presets;
/// use poas::service::{Cluster, PowerOptions, RouteObjective};
///
/// let cluster = Cluster::builder()
///     .machine(&presets::gpu_node())
///     .replicas(&presets::cpu_node(), 2)
///     .seed(7)
///     .objective(RouteObjective::EnergyAware { slack: 2.0 })
///     .power(PowerOptions {
///         cap_w: Some(900.0),
///         ..Default::default()
///     })
///     .build();
/// assert_eq!(cluster.num_shards(), 3);
/// ```
#[derive(Debug, Clone, Default)]
pub struct ClusterBuilder {
    machines: Vec<MachineConfig>,
    seed: u64,
    opts: ClusterOptions,
}

impl ClusterBuilder {
    /// Append one shard running `cfg`.
    pub fn machine(mut self, cfg: &MachineConfig) -> Self {
        self.machines.push(cfg.clone());
        self
    }

    /// Append one shard per config, in order.
    pub fn machines(mut self, cfgs: &[MachineConfig]) -> Self {
        self.machines.extend(cfgs.iter().cloned());
        self
    }

    /// Append `count` shards all running `cfg` (each still profiles on
    /// its own seed, so their fitted models differ by profiling noise).
    pub fn replicas(mut self, cfg: &MachineConfig, count: usize) -> Self {
        for _ in 0..count {
            self.machines.push(cfg.clone());
        }
        self
    }

    /// Base profiling seed (default 0): shard `i` profiles on
    /// `seed + i`.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Replace the serving options wholesale. The shard count is taken
    /// from the machine list, never from `opts.shards`. Call this
    /// *before* the field-level setters below — it overwrites them.
    pub fn options(mut self, opts: ClusterOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Arm an autoscaler policy (see [`super::elastic`]).
    pub fn autoscaler(mut self, policy: AutoscalerPolicy) -> Self {
        self.opts.autoscaler = Some(policy);
        self
    }

    /// Set the power-management knobs (see [`PowerOptions`]).
    pub fn power(mut self, power: PowerOptions) -> Self {
        self.opts.power = power;
        self
    }

    /// Set the routing objective (see [`RouteObjective`]).
    pub fn objective(mut self, objective: RouteObjective) -> Self {
        self.opts.objective = objective;
        self
    }

    /// Profile every machine and build the cluster. Panics when no
    /// machine was added.
    pub fn build(self) -> Cluster {
        assert!(
            !self.machines.is_empty(),
            "Cluster::builder() needs at least one machine"
        );
        let pipelines = self
            .machines
            .iter()
            .enumerate()
            .map(|(i, cfg)| Pipeline::for_simulated_machine(cfg, self.seed.wrapping_add(i as u64)))
            .collect();
        Cluster::from_pipelines(pipelines, self.opts)
    }
}

/// Assemble a [`Cluster`] from *distinct* machine configs — the old
/// heterogeneous construction path, superseded by [`ClusterBuilder`]
/// (`Cluster::builder()`), which covers the same ground plus seeds,
/// autoscaler, power and objective in one fluent chain.
#[deprecated(note = "use Cluster::builder()")]
#[derive(Debug, Clone)]
pub struct HeterogeneousSpec {
    machines: Vec<MachineConfig>,
    seed: u64,
    opts: ClusterOptions,
}

#[allow(deprecated)]
impl HeterogeneousSpec {
    /// An empty spec; shard `i` will profile on a simulator seeded
    /// `seed + i`.
    pub fn new(seed: u64) -> Self {
        HeterogeneousSpec {
            machines: Vec::new(),
            seed,
            opts: ClusterOptions::default(),
        }
    }

    /// Append one shard running `cfg`.
    pub fn machine(mut self, cfg: MachineConfig) -> Self {
        self.machines.push(cfg);
        self
    }

    /// Append `count` shards all running `cfg` (each still profiles on
    /// its own seed, so their fitted models differ by profiling noise).
    pub fn machines(mut self, cfg: MachineConfig, count: usize) -> Self {
        for _ in 0..count {
            self.machines.push(cfg.clone());
        }
        self
    }

    /// Replace the serving options (shard count is taken from the
    /// machine list, not from `opts.shards`).
    pub fn options(mut self, opts: ClusterOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Profile every machine and build the cluster. Panics when no
    /// machine was added.
    pub fn build(self) -> Cluster {
        Cluster::builder()
            .machines(&self.machines)
            .seed(self.seed)
            .options(self.opts)
            .build()
    }
}

/// A request-serving POAS deployment across one or more machines.
#[derive(Debug, Clone)]
pub struct Cluster {
    shards: Vec<ExecutorShard>,
    /// Per-shard admission gates under [`GatePolicy::PerShard`]
    /// (`admissions[i]` predicts with `shards[i].model`); a single
    /// shard-0 gate under the legacy [`GatePolicy::Shard0`] ablation.
    admissions: Vec<Admission>,
    opts: ClusterOptions,
    /// The admission-time batch former (inert under
    /// [`BatchPolicy::Off`]).
    former: BatchFormer,
    events: BinaryHeap<Reverse<Event>>,
    /// Same-timestamp events batch-drained off the heap, consumed
    /// before the next heap pop. Reuses its capacity run-long, so the
    /// steady-state event path performs no per-event allocation.
    drain: VecDeque<Event>,
    seq: u64,
    clock: VirtualClock,
    served: Vec<ServedRequest>,
    /// All-time completion-record count. Tracks `served.len()` while
    /// records accumulate, but survives [`Cluster::run_to_completion`]
    /// moving the records into the returned report.
    finished: usize,
    next_id: u64,
    /// Min-tree over each live shard's request-independent finish
    /// proxy (`free_at + class-blind backlog`), kept current by
    /// [`Cluster::reindex`]; seeds the sampled router's candidate set.
    route_idx: TournamentTree,
    /// Max-tree over the class-weighted backlog of shards with queued
    /// work (empty or down shards are disabled); serves steal-victim
    /// selection in O(log shards).
    steal_idx: TournamentTree,
    /// Min-tree over each live shard's static joules-per-op figure
    /// (active watts over fitted throughput — see
    /// [`ExecutorShard::joules_per_op`]), refreshed when a shard
    /// replans; under [`RouteObjective::EnergyAware`] it seeds the
    /// sampled router's candidate set with the energy-cheapest shard.
    energy_idx: TournamentTree,
    /// Deterministic candidate-sampling stream (see
    /// [`ROUTER_RNG_SEED`]).
    router_rng: Rng,
    /// Reusable scratch for the sampled router's candidate set.
    cand_buf: Vec<usize>,
    /// Per-shard down flags (crashed and not yet restarted). All-false
    /// on every fault-free run, where the fault guards are no-ops.
    down: Vec<bool>,
    /// Requests that arrived while *every* shard was down, parked at
    /// the front-end with their true arrival times until a restart
    /// re-admits them (their wait keeps charging against any SLO).
    parked: Vec<(GemmRequest, f64)>,
    /// Requests displaced by crashes and re-admitted (batch members
    /// counted individually; a request moved by two crashes counts
    /// twice).
    requeued: usize,
    /// Joins scheduled but not necessarily fired yet: lets fault
    /// injection target a shard index that will only exist once its
    /// join event fires (the scenario layer validates against
    /// `machines + joins`).
    joins_scheduled: usize,
    /// Autoscaler runtime state (see [`super::elastic`]); `None`
    /// without a configured policy.
    scaler: Option<Autoscaler>,
    /// When true, externally visible actions (dispatches, steals,
    /// faults, membership moves) are also appended to `tap_log` for a
    /// driver to mirror. Off by default; every tap site is guarded, so
    /// the untapped event loop is byte-identical to the pre-tap code.
    tap: bool,
    /// The pending tap entries, drained by [`Cluster::drain_tap`].
    tap_log: Vec<TapAction>,
    /// Next dispatch ordinal handed to the tap.
    tap_units: u64,
}

impl Cluster {
    /// Start a fluent [`ClusterBuilder`] — the one supported
    /// construction path.
    pub fn builder() -> ClusterBuilder {
        ClusterBuilder::default()
    }

    /// Build a homogeneous cluster of `opts.shards` machines from
    /// `cfg`: shard `i` is profiled at installation time on its own
    /// simulator seeded `seed + i`, and every shard gets its own
    /// admission gate over its own fitted profile.
    #[deprecated(note = "use Cluster::builder().replicas(cfg, n)")]
    pub fn new(cfg: &MachineConfig, seed: u64, opts: ClusterOptions) -> Self {
        let n = opts.shards.max(1);
        let pipelines = (0..n)
            .map(|i| Pipeline::for_simulated_machine(cfg, seed.wrapping_add(i as u64)))
            .collect();
        Self::from_pipelines(pipelines, opts)
    }

    /// Build a heterogeneous cluster: one shard per machine config,
    /// each profiled at install time on its own simulator seeded
    /// `seed + shard index`.
    #[deprecated(note = "use Cluster::builder().machines(cfgs)")]
    pub fn from_machines(cfgs: &[MachineConfig], seed: u64, opts: ClusterOptions) -> Self {
        assert!(!cfgs.is_empty(), "cluster needs at least one machine");
        let pipelines = cfgs
            .iter()
            .enumerate()
            .map(|(i, cfg)| Pipeline::for_simulated_machine(cfg, seed.wrapping_add(i as u64)))
            .collect();
        Self::from_pipelines(pipelines, opts)
    }

    /// Promote already-profiled pipelines into a cluster (one shard per
    /// pipeline; `pipelines` must be non-empty).
    pub fn from_pipelines(pipelines: Vec<Pipeline>, mut opts: ClusterOptions) -> Self {
        assert!(!pipelines.is_empty(), "cluster needs at least one shard");
        assert!(
            opts.shard.deadline_slack > 0.0 && opts.shard.deadline_slack <= 1.0,
            "deadline_slack must be in (0, 1], got {}",
            opts.shard.deadline_slack
        );
        assert!(
            opts.power.parked_frac >= 0.0 && opts.power.parked_frac <= 1.0,
            "parked_frac must be in [0, 1], got {}",
            opts.power.parked_frac
        );
        if let Some(w) = opts.power.cap_w {
            assert!(
                w.is_finite() && w > 0.0,
                "power cap must be finite and positive, got {w}"
            );
        }
        if let RouteObjective::EnergyAware { slack } = opts.objective {
            assert!(
                slack.is_finite() && slack >= 1.0,
                "energy slack must be finite and >= 1, got {slack}"
            );
        }
        // One source of truth for the shard count.
        opts.shards = pipelines.len();
        let shards: Vec<ExecutorShard> = pipelines
            .into_iter()
            .enumerate()
            .map(|(i, p)| ExecutorShard::from_pipeline(i, p, &opts.shard))
            .collect();
        let gate_of = |model: &crate::predict::PerfModel| {
            Admission::new(
                model.clone(),
                opts.shard.min_gain,
                opts.shard.overhead_s,
                opts.shard.gate_capacity,
            )
        };
        let admissions = match opts.gate {
            GatePolicy::PerShard => shards.iter().map(|s| gate_of(&s.model)).collect(),
            GatePolicy::Shard0 => vec![gate_of(&shards[0].model)],
        };
        let former = BatchFormer::new(&opts.batching, opts.shard.deadline_slack);
        let down = vec![false; shards.len()];
        let n = shards.len();
        let mut route_idx = TournamentTree::new(n, Ranking::Min);
        for i in 0..n {
            // Every shard starts idle and empty: finish proxy 0.
            route_idx.update(i, 0.0);
        }
        // Nothing is queued yet, so every steal leaf starts disabled.
        let steal_idx = TournamentTree::new(n, Ranking::Max);
        let mut energy_idx = TournamentTree::new(n, Ranking::Min);
        for (i, s) in shards.iter().enumerate() {
            energy_idx.update(i, s.joules_per_op());
        }
        let scaler = opts.autoscaler.clone().map(Autoscaler::new);
        let mut cluster = Cluster {
            shards,
            admissions,
            opts,
            former,
            events: BinaryHeap::new(),
            drain: VecDeque::new(),
            seq: 0,
            clock: VirtualClock::new(),
            served: Vec::new(),
            finished: 0,
            next_id: 0,
            route_idx,
            steal_idx,
            energy_idx,
            router_rng: Rng::new(ROUTER_RNG_SEED),
            cand_buf: Vec::new(),
            down,
            parked: Vec::new(),
            requeued: 0,
            joins_scheduled: 0,
            scaler,
            tap: false,
            tap_log: Vec::new(),
            tap_units: 0,
        };
        if let Some(scaler) = &cluster.scaler {
            let first = scaler.policy.eval_interval_s;
            cluster.push_event(first, EventKind::AutoscaleEval);
        }
        cluster
    }

    /// Recompute shard `s`'s keys in both front-end indexes — called
    /// after every mutation that can move them (enqueue, dispatch,
    /// steal transfer, crash, restart). Down shards are disabled in
    /// both trees; a shard with nothing queued is disabled as a steal
    /// victim. O(log shards).
    fn reindex(&mut self, s: usize) {
        if self.down[s] {
            self.route_idx.disable(s);
            self.steal_idx.disable(s);
            self.energy_idx.disable(s);
            return;
        }
        let sh = &self.shards[s];
        self.route_idx.update(s, sh.free_at() + sh.backlog_s());
        self.energy_idx.update(s, sh.joules_per_op());
        if sh.pending() > 0 {
            self.steal_idx.update(s, sh.weighted_backlog());
        } else {
            self.steal_idx.disable(s);
        }
    }

    /// Debug-only invariant: the incremental index keys must equal a
    /// from-scratch recomputation (and the tree winners their linear
    /// scans) after every processed event. Compiled out of release
    /// builds, so the hot path never pays for it; every debug test run
    /// exercises it on every event of every scenario.
    #[cfg(debug_assertions)]
    fn verify_indexes(&self) {
        for (s, sh) in self.shards.iter().enumerate() {
            if self.down[s] {
                debug_assert!(!self.route_idx.is_enabled(s), "down shard {s} routable");
                debug_assert!(!self.steal_idx.is_enabled(s), "down shard {s} stealable");
                debug_assert!(
                    !self.energy_idx.is_enabled(s),
                    "down shard {s} energy-routable"
                );
                continue;
            }
            debug_assert_eq!(
                self.route_idx.key(s),
                sh.free_at() + sh.backlog_s(),
                "stale route key for shard {s}"
            );
            debug_assert_eq!(
                self.energy_idx.key(s),
                sh.joules_per_op(),
                "stale energy key for shard {s}"
            );
            if sh.pending() > 0 {
                debug_assert_eq!(
                    self.steal_idx.key(s),
                    sh.weighted_backlog(),
                    "stale steal key for shard {s}"
                );
            } else {
                debug_assert!(!self.steal_idx.is_enabled(s), "empty shard {s} stealable");
            }
        }
        debug_assert_eq!(self.route_idx.winner(), self.route_idx.scan_winner());
        debug_assert_eq!(self.steal_idx.winner(), self.steal_idx.scan_winner());
        debug_assert_eq!(self.energy_idx.winner(), self.energy_idx.scan_winner());
    }

    /// Index into `admissions` of the gate that predicts for `shard`.
    fn gate_idx(&self, shard: usize) -> usize {
        match self.opts.gate {
            GatePolicy::PerShard => shard,
            GatePolicy::Shard0 => 0,
        }
    }

    /// Current virtual service time (the latest processed event).
    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    /// Enable (or disable) the action tap — the stream of dispatches,
    /// steals, faults and membership moves a wall-clock driver mirrors
    /// onto worker threads (see [`super::driver::wall_clock`]). Off by
    /// default; scheduling decisions are identical either way, the tap
    /// only *records* them.
    pub fn set_tap(&mut self, on: bool) {
        self.tap = on;
        if !on {
            self.tap_log.clear();
        }
    }

    /// Move every pending tap entry into `out` (appending, in decision
    /// order). Drivers call this between [`Cluster::step_event`] steps.
    pub fn drain_tap(&mut self, out: &mut Vec<TapAction>) {
        out.append(&mut self.tap_log);
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard accessor (diagnostics/tests).
    pub fn shard(&self, i: usize) -> &ExecutorShard {
        &self.shards[i]
    }

    /// Shard 0's admission gate (diagnostics/tests; exact for the
    /// single-machine [`super::Server`], which has only one shard).
    pub fn admission(&self) -> &Admission {
        &self.admissions[0]
    }

    /// The admission gate that predicts for shard `i` (diagnostics /
    /// tests). Under [`GatePolicy::Shard0`] every shard maps to the one
    /// legacy gate.
    pub fn admission_for(&self, i: usize) -> &Admission {
        assert!(i < self.shards.len(), "no shard {i}");
        &self.admissions[self.gate_idx(i)]
    }

    /// True while shard `i` is crashed and not yet restarted.
    pub fn is_down(&self, i: usize) -> bool {
        self.down[i]
    }

    /// Requests displaced by crashes and re-admitted so far (batch
    /// members counted individually).
    pub fn requeued(&self) -> usize {
        self.requeued
    }

    /// Requests not yet dispatched: queued on shards, waiting in a
    /// batch window, parked behind an all-shards-down outage, or still
    /// in the arrival event stream.
    pub fn pending(&self) -> usize {
        let queued: usize = self.shards.iter().map(|s| s.pending()).sum();
        let in_flight = self
            .events
            .iter()
            .filter(|r| matches!(r.0.kind, EventKind::Arrival(_)))
            .count()
            + self
                .drain
                .iter()
                .filter(|e| matches!(e.kind, EventKind::Arrival(_)))
                .count();
        queued + in_flight + self.former.pending() + self.parked.len()
    }

    /// Requests completed so far (still correct after
    /// [`Cluster::run_to_completion`] has moved the records into its
    /// report).
    pub fn completed(&self) -> usize {
        self.finished
    }

    /// Submit a [`QosClass::Standard`] request with no SLO arriving at
    /// the current virtual time; returns its id.
    pub fn submit(&mut self, size: GemmSize, reps: u32) -> u64 {
        let id = self.next_id;
        self.submit_request(GemmRequest::new(id, size, reps));
        id
    }

    /// Submit a request under `class` with an optional sojourn SLO,
    /// arriving at the current virtual time; returns its id.
    pub fn submit_qos(
        &mut self,
        size: GemmSize,
        reps: u32,
        class: QosClass,
        deadline_s: Option<f64>,
    ) -> u64 {
        let id = self.next_id;
        let mut req = GemmRequest::new(id, size, reps).with_class(class);
        req.deadline_s = deadline_s;
        self.submit_request(req);
        id
    }

    /// Submit a caller-identified request arriving at the current
    /// virtual time.
    pub fn submit_request(&mut self, req: GemmRequest) {
        self.submit_request_at(self.clock.now(), req);
    }

    /// Submit a caller-identified request arriving at virtual time `at`
    /// (clamped to the present — the past is already simulated).
    pub fn submit_request_at(&mut self, at: f64, req: GemmRequest) {
        self.next_id = self.next_id.max(req.id + 1);
        self.push_event(at.max(self.clock.now()), EventKind::Arrival(req));
    }

    /// Schedule a whole arrival trace (see [`super::arrivals`]);
    /// returns the assigned request ids in trace order.
    pub fn submit_trace(&mut self, trace: &[Arrival]) -> Vec<u64> {
        trace
            .iter()
            .map(|a| {
                let id = self.next_id;
                self.submit_request_at(a.at, GemmRequest {
                    id,
                    size: a.size,
                    reps: a.reps,
                    class: a.class,
                    deadline_s: a.deadline_s,
                });
                id
            })
            .collect()
    }

    fn push_event(&mut self, time: f64, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.events.push(Reverse(Event { time, seq, kind }));
    }

    /// Largest shard index a scheduled fault may legally name: every
    /// constructed shard plus every join already scheduled (a joined
    /// shard exists only once its event fires, but faults targeting it
    /// must be schedulable up front — the scenario layer does exactly
    /// that). A fault that fires before its target shard has joined is
    /// a deterministic no-op.
    fn addressable_shards(&self) -> usize {
        self.shards.len() + self.joins_scheduled
    }

    /// Schedule shard `shard` to crash at virtual time `at` (clamped to
    /// the present, like every submission). Queued and in-flight work
    /// re-enters admission when the event fires; crashing a shard that
    /// is already down is a no-op. `shard` may name a shard whose
    /// [`Cluster::inject_join`] is scheduled but has not fired yet.
    pub fn inject_crash(&mut self, at: f64, shard: usize) {
        assert!(shard < self.addressable_shards(), "no shard {shard}");
        self.push_event(at.max(self.clock.now()), EventKind::Crash(shard));
    }

    /// Schedule shard `shard` to restart at virtual time `at` (no-op if
    /// the shard is up when the event fires). Restarting a *drained*
    /// shard revives it: a fresh provisioned span starts on the
    /// machine-seconds meter and routing resumes.
    pub fn inject_restart(&mut self, at: f64, shard: usize) {
        assert!(shard < self.addressable_shards(), "no shard {shard}");
        self.push_event(at.max(self.clock.now()), EventKind::Restart(shard));
    }

    /// Schedule shard `shard`'s machine to change speed at virtual time
    /// `at`: every device rate is multiplied by `factor` (`< 1` makes
    /// it a straggler whose realized times drift away from the model
    /// that routes work to it; a later event with `1 / factor` restores
    /// the original rate, since scales compose multiplicatively).
    pub fn inject_slowdown(&mut self, at: f64, shard: usize, factor: f64) {
        assert!(shard < self.addressable_shards(), "no shard {shard}");
        assert!(
            factor.is_finite() && factor > 0.0,
            "rate factor must be finite and positive, got {factor}"
        );
        self.push_event(
            at.max(self.clock.now()),
            EventKind::RateScale(shard, factor),
        );
    }

    /// Schedule a new shard running `cfg` to join the cluster at
    /// virtual time `at`. The machine is profiled when the event fires
    /// (installation happens at provision time) on `profile_seed`, so
    /// replays are exact; the new shard takes the next free index —
    /// joins are numbered in event order (time, then injection order).
    pub fn inject_join(&mut self, at: f64, cfg: MachineConfig, profile_seed: u64) {
        self.joins_scheduled += 1;
        self.push_event(
            at.max(self.clock.now()),
            EventKind::Join(Box::new(cfg), profile_seed),
        );
    }

    /// Schedule shard `shard` to drain gracefully at virtual time `at`:
    /// routing stops, the in-flight execution (if any) finishes
    /// untouched, queued work redistributes through front-end admission
    /// with original arrivals and SLO budgets. Draining a shard that is
    /// already down is a no-op; like [`Cluster::inject_crash`], `shard`
    /// may name a scheduled-but-not-yet-fired join.
    pub fn inject_drain(&mut self, at: f64, shard: usize) {
        assert!(shard < self.addressable_shards(), "no shard {shard}");
        self.push_event(at.max(self.clock.now()), EventKind::Drain(shard));
    }

    /// Schedule the cluster-wide power cap to change at virtual time
    /// `at`: `Some(watts)` sets (tightens or relaxes) the cap enforced
    /// at admission from that instant on, `None` removes it. The cap
    /// gates arrivals only — work already queued or executing is never
    /// revisited, so a mid-run tightening sheds load rather than
    /// preempting it.
    pub fn inject_power_cap(&mut self, at: f64, cap_w: Option<f64>) {
        if let Some(w) = cap_w {
            assert!(
                w.is_finite() && w > 0.0,
                "power cap must be finite and positive, got {w}"
            );
        }
        self.push_event(at.max(self.clock.now()), EventKind::PowerCap(cap_w));
    }

    /// Gate one work unit — a plain request (`members == 1`) or a fused
    /// batch of `members` — on shard `s`'s own admission gate and,
    /// under the legacy [`GatePolicy::Shard0`] ablation, clamp the
    /// standalone device pick into `s`'s device range (shard 0's model
    /// can name a device a smaller heterogeneous shard does not have).
    fn gate_on(&mut self, s: usize, size: GemmSize, reps: u32, members: u32) -> GateVerdict {
        let g = self.gate_idx(s);
        let (co_execute, mut best_device, predicted_s) =
            self.admissions[g].admit_batch(size, reps, members);
        match self.opts.gate {
            GatePolicy::Shard0 => {
                best_device = best_device.min(self.shards[s].num_devices() - 1);
            }
            GatePolicy::PerShard => {
                // The shard's own model named the device: out of range
                // would mean the gate and the machine disagree — a bug
                // worth failing loudly on, not remapping.
                debug_assert!(
                    best_device < self.shards[s].num_devices(),
                    "shard {s}'s own gate picked device {best_device} of {}",
                    self.shards[s].num_devices()
                );
            }
        }
        (co_execute, best_device, predicted_s)
    }

    /// Route one work unit (`req` is a plain request or a batch
    /// carrier, gated as `members`) to the shard with the earliest
    /// class-weighted predicted finish **under each shard's own gate
    /// verdict** (ties: lowest shard index). With `deadline_only`,
    /// shards whose own model fails the machine-level SLO feasibility
    /// probe are skipped — `None` then means *no* shard can meet the
    /// deadline at all (without the restriction a shard is always
    /// found). Returns the chosen shard, its gate verdict and its
    /// predicted finish, so deadline admission and the enqueue reuse
    /// the same predictions.
    fn route(
        &mut self,
        now: f64,
        req: &GemmRequest,
        members: u32,
        deadline_only: bool,
    ) -> Option<Routed> {
        let n = self.shards.len();
        let live = n - self.down.iter().filter(|&&d| d).count();
        let d = match self.opts.route {
            RoutePolicy::Full => live,
            RoutePolicy::Sampled { d } => d,
        };
        if d >= live {
            // Exact path (always under `Full`): score every live shard
            // in index order. No randomness is consumed here, so
            // `Sampled { d >= live shards }` stays byte-identical to
            // `Full` — the contract the routing-equivalence property
            // tests pin.
            return self.route_among(now, req, members, deadline_only, None);
        }
        // Power-of-d-choices: the routing index's winner — the shard
        // with the smallest request-independent finish proxy — is
        // always a candidate, plus d-1 distinct live shards from the
        // deterministic router stream. Rejection sampling terminates
        // because live > d. Candidates are sorted so ties in the exact
        // scoring below break toward the lowest index, exactly like
        // the full scan.
        let mut cands = std::mem::take(&mut self.cand_buf);
        cands.clear();
        if let Some(w) = self.route_idx.winner() {
            cands.push(w);
        }
        if let RouteObjective::EnergyAware { .. } = self.opts.objective {
            // The energy-cheapest live shard is always a candidate too
            // (the energy pass needs its best case on the table), so an
            // energy-aware sample scores up to d + 1 shards when the
            // two index winners differ.
            if let Some(w) = self.energy_idx.winner() {
                if !cands.contains(&w) {
                    cands.push(w);
                }
            }
        }
        while cands.len() < d {
            let i = self.router_rng.below(n as u64) as usize;
            if !self.down[i] && !cands.contains(&i) {
                cands.push(i);
            }
        }
        cands.sort_unstable();
        let best = self.route_among(now, req, members, deadline_only, Some(&cands));
        self.cand_buf = cands;
        if best.is_none() && deadline_only {
            // A `None` here must mean *no* shard can meet the SLO —
            // never that the sample happened to miss the feasible
            // ones. Fall back to the exact scan before the caller
            // turns the request away.
            return self.route_among(now, req, members, deadline_only, None);
        }
        best
    }

    /// Score candidate shards `cands` (every shard when `None`)
    /// exactly: per-shard gate verdict, optional machine-level
    /// deadline-feasibility filter, class-weighted predicted finish.
    /// Smallest finish wins; ties break to the lowest shard index
    /// (callers pass candidates in ascending index order).
    fn route_among(
        &mut self,
        now: f64,
        req: &GemmRequest,
        members: u32,
        deadline_only: bool,
        cands: Option<&[usize]>,
    ) -> Option<Routed> {
        let mut best: Option<Routed> = None;
        match cands {
            Some(list) => {
                for &i in list {
                    self.consider_shard(now, req, members, deadline_only, i, &mut best);
                }
            }
            None => {
                for i in 0..self.shards.len() {
                    self.consider_shard(now, req, members, deadline_only, i, &mut best);
                }
            }
        }
        if let (Some(b), RouteObjective::EnergyAware { slack }) = (best, self.opts.objective) {
            best = Some(self.energy_refine(now, req, members, deadline_only, cands, b, slack));
        }
        best
    }

    /// Second routing pass under [`RouteObjective::EnergyAware`]: among
    /// the *same* candidates, pick the lowest predicted-joules shard
    /// whose predicted finish stays inside the latency band around the
    /// latency winner (`now + slack * winner sojourn`; ties to the
    /// lowest index). Gate verdicts are memoized, so this pass re-reads
    /// them for free. When no candidate fits the band — pressure — the
    /// latency winner stands.
    #[allow(clippy::too_many_arguments)]
    fn energy_refine(
        &mut self,
        now: f64,
        req: &GemmRequest,
        members: u32,
        deadline_only: bool,
        cands: Option<&[usize]>,
        latency_best: Routed,
        slack: f64,
    ) -> Routed {
        let mut threshold = now + slack * (latency_best.finish - now).max(0.0);
        if deadline_only {
            // Deadline admission accepts the returned pick only inside
            // its own slack band; clamping the energy band to it keeps
            // energy-awareness from ever converting an admit into a
            // denial (`req.deadline_s` is the remaining budget here).
            let deadline_s = req.deadline_s.expect("deadline_only needs an SLO");
            threshold = threshold.min(now + self.opts.shard.deadline_slack * deadline_s);
        }
        let mut pick: Option<Routed> = None;
        let mut pick_joules = f64::INFINITY;
        match cands {
            Some(list) => {
                for &i in list {
                    self.consider_energy(
                        now,
                        req,
                        members,
                        deadline_only,
                        threshold,
                        i,
                        &mut pick,
                        &mut pick_joules,
                    );
                }
            }
            None => {
                for i in 0..self.shards.len() {
                    self.consider_energy(
                        now,
                        req,
                        members,
                        deadline_only,
                        threshold,
                        i,
                        &mut pick,
                        &mut pick_joules,
                    );
                }
            }
        }
        pick.unwrap_or(latency_best)
    }

    /// Score shard `i` for the energy pass: skip down or
    /// deadline-infeasible shards and anything finishing past
    /// `threshold`, then fold the lowest predicted joules into `pick`
    /// (strict `<`, candidates visited in ascending index order, so
    /// ties break to the lowest index like every other scan).
    #[allow(clippy::too_many_arguments)]
    fn consider_energy(
        &mut self,
        now: f64,
        req: &GemmRequest,
        members: u32,
        deadline_only: bool,
        threshold: f64,
        i: usize,
        pick: &mut Option<Routed>,
        pick_joules: &mut f64,
    ) {
        if self.down[i] {
            return;
        }
        let verdict = self.gate_on(i, req.size, req.reps, members);
        if deadline_only {
            let deadline_s = req.deadline_s.expect("deadline_only needs an SLO");
            let g = self.gate_idx(i);
            if !self.admissions[g].deadline_feasible(
                verdict.0,
                verdict.2,
                req.size,
                req.reps,
                deadline_s,
            ) {
                return;
            }
        }
        let finish = self.shards[i].predicted_finish_for(now, verdict.2, req.class);
        if finish > threshold {
            return;
        }
        let joules = self.predicted_joules(i, verdict);
        if joules < *pick_joules {
            *pick = Some(Routed {
                shard: i,
                verdict,
                finish,
            });
            *pick_joules = joules;
        }
    }

    /// Predicted joules shard `i` would spend executing one work unit
    /// under its gate verdict: the service prediction times the active
    /// watts of the devices the verdict engages (every device when
    /// co-executing, the best device alone otherwise).
    fn predicted_joules(&self, i: usize, verdict: GateVerdict) -> f64 {
        let (co_execute, best_device, predicted_s) = verdict;
        let sh = &self.shards[i];
        let watts = if co_execute {
            sh.active_w_total()
        } else {
            sh.device_power()[best_device].active_w
        };
        predicted_s * watts
    }

    /// Score shard `i` for `req` and fold it into `best` (smallest
    /// class-weighted predicted finish; ties keep the earlier shard).
    fn consider_shard(
        &mut self,
        now: f64,
        req: &GemmRequest,
        members: u32,
        deadline_only: bool,
        i: usize,
        best: &mut Option<Routed>,
    ) {
        if self.down[i] {
            return; // a crashed shard takes no new work
        }
        let verdict = self.gate_on(i, req.size, req.reps, members);
        if deadline_only {
            let deadline_s = req.deadline_s.expect("deadline_only needs an SLO");
            let g = self.gate_idx(i);
            if !self.admissions[g].deadline_feasible(
                verdict.0,
                verdict.2,
                req.size,
                req.reps,
                deadline_s,
            ) {
                return;
            }
        }
        let finish = self.shards[i].predicted_finish_for(now, verdict.2, req.class);
        let wins = match best {
            None => true,
            Some(b) => finish < b.finish,
        };
        if wins {
            *best = Some(Routed {
                shard: i,
                verdict,
                finish,
            });
        }
    }

    /// The routing decision the front-end would make for `req` right
    /// now — chosen shard and class-weighted predicted finish —
    /// **without** admitting anything: no queue mutation, no events.
    /// This is the exact per-arrival decision the hot-path bench times
    /// and allocation-counts; it also answers "where would this go?"
    /// diagnostics. Under [`RoutePolicy::Sampled`] it consumes the
    /// router stream just like a real admission.
    pub fn probe_route(&mut self, req: &GemmRequest) -> Option<(usize, f64)> {
        self.route(self.clock.now(), req, 1, false)
            .map(|r| (r.shard, r.finish))
    }

    /// Pre-populate every shard's gate memo for the given
    /// `(size, reps)` menu. After a warming pass, steady-state routing
    /// of these shapes is pure memo reads: no optimizer solves and no
    /// allocation on the decision path (the zero-alloc property the
    /// hot-path bench gates).
    pub fn warm_gates(&mut self, menu: &[(GemmSize, u32)]) {
        for &(size, reps) in menu {
            for s in 0..self.shards.len() {
                let _ = self.gate_on(s, size, reps, 1);
            }
        }
    }

    /// The smallest machine-level service prediction any shard's own
    /// gate gives one work unit — the backlog-free figure denial
    /// records carry (stable across queue states) and the batch
    /// former's flush-pressure service hint (every gate lookup is
    /// memoized, making this an O(shards) memo read).
    fn best_service_prediction(&mut self, size: GemmSize, reps: u32, members: u32) -> f64 {
        (0..self.shards.len())
            .map(|i| self.gate_on(i, size, reps, members).2)
            .fold(f64::INFINITY, f64::min)
    }

    /// Predicted aggregate cluster draw at `now`, in watts — the figure
    /// the admission-time power cap compares against. Engaged shards
    /// (executing, or idle with queued work) bill their full active
    /// watts, idle live shards their idle watts, parked (drained)
    /// shards the parked fraction of idle watts, and crashed machines
    /// nothing. Only computed while a cap is armed.
    fn predicted_draw(&self, now: f64) -> f64 {
        let mut draw = 0.0;
        for (s, sh) in self.shards.iter().enumerate() {
            if sh.is_retired() {
                draw += sh.idle_w_total() * self.opts.power.parked_frac;
            } else if self.down[s] {
                // Crashed: the machine is gone until its restart.
            } else if sh.free_at() > now || sh.pending() > 0 {
                draw += sh.active_w_total();
            } else {
                draw += sh.idle_w_total();
            }
        }
        draw
    }

    /// The idle-to-active draw delta of admitting one unit onto idle
    /// shard `target` under its gate verdict: the devices the verdict
    /// engages (all of them when co-executing, the best device alone
    /// otherwise) switch from their idle to their active watts; the
    /// rest keep idling, already counted in [`Cluster::predicted_draw`].
    fn marginal_draw(&self, target: usize, co_execute: bool, best_device: usize) -> f64 {
        let sh = &self.shards[target];
        if co_execute {
            sh.active_w_total() - sh.idle_w_total()
        } else {
            let p = sh.device_power()[best_device];
            p.active_w - p.idle_w
        }
    }

    /// The steal victim for idle `thief`: the shard with the largest
    /// class-weighted backlog, answered by the steal index in O(log
    /// shards) instead of the old O(shards) scan (ties: lowest index —
    /// the tournament tree preserves the scan's tie-break). Weighting
    /// by class makes stealing relieve the queue whose waiting work is
    /// most latency-sensitive, not merely the longest.
    ///
    /// On heterogeneous clusters the pick is tilted by **affinity**:
    /// when the runner-up victim's head request is one the thief's own
    /// hardware serves disproportionately well — at least
    /// [`HETERO_STEAL_TILT`] times the affinity of the backlog
    /// winner's head — the thief takes that one instead, so work
    /// migrates toward machines that are actually fast at it. Clone
    /// shards tie well inside the margin, leaving homogeneous picks
    /// unchanged.
    fn steal_victim(&mut self, thief: usize) -> Option<usize> {
        // The thief is idle with an empty queue, so its own leaf is
        // disabled and the winner (if any) is a genuine victim. Down
        // and empty shards are disabled too.
        let first = self.steal_idx.winner()?;
        debug_assert_ne!(first, thief, "an idle thief cannot be a steal victim");
        let second = match self.steal_idx.winner_excluding(first) {
            Some(s) if s != thief => s,
            _ => return Some(first),
        };
        let aff_first = self.steal_affinity(thief, first);
        let aff_second = self.steal_affinity(thief, second);
        if aff_second > aff_first * HETERO_STEAL_TILT {
            Some(second)
        } else {
            Some(first)
        }
    }

    /// How disproportionately well `thief`'s hardware would serve the
    /// head of `victim`'s queue: the victim-recorded service
    /// prediction over the thief's own (memoized) gate prediction.
    /// `> 1` means the thief beats the plan of record; the ratio is
    /// reps-invariant, so heads of different depths compare fairly.
    fn steal_affinity(&mut self, thief: usize, victim: usize) -> f64 {
        let Some((size, reps, members, recorded)) = self.shards[victim].peek_next().map(|q| {
            let members = q.batch.as_ref().map_or(1, |b| b.members.len() as u32);
            (q.req.size, q.req.reps, members, q.predicted_s)
        }) else {
            return 0.0;
        };
        let mine = self.gate_on(thief, size, reps, members).2;
        if mine <= 0.0 {
            return 0.0;
        }
        recorded / mine
    }

    /// Record an admission denial: the request completes immediately as
    /// [`ExecMode::Denied`], consuming no machine time on any shard.
    /// Shares are empty — a denial never touched a machine, and shards
    /// of a heterogeneous cluster disagree on the device count anyway.
    /// (`arrival == now` except for disbanded batch members, whose
    /// window wait stays visible in the record.)
    fn deny(&mut self, now: f64, req: GemmRequest, arrival: f64, predicted_s: f64) {
        self.finished += 1;
        self.served.push(ServedRequest {
            id: req.id,
            size: req.size,
            reps: req.reps,
            class: req.class,
            deadline_s: req.deadline_s,
            mode: ExecMode::Denied,
            shard: None,
            arrival,
            start: now,
            finish: now,
            exec_s: 0.0,
            predicted_s,
            cache_hit: false,
            shares: Vec::new(),
        });
    }

    /// Offer an arrival to the batch former. Returns `false` when the
    /// request is not a batching candidate (batching off, too big, or
    /// some shard's own gate would co-execute it alone — a request
    /// worth splitting by itself never waits for a window) and must be
    /// admitted solo by the caller.
    fn try_batch(&mut self, now: f64, req: GemmRequest) -> bool {
        if !self.former.candidate(&req) {
            return false;
        }
        if (0..self.shards.len())
            .any(|i| !self.down[i] && self.gate_on(i, req.size, req.reps, 1).0)
        {
            return false;
        }
        // Flush-pressure hint: the best-shard predicted service time of
        // the batch this request would fuse into (memoized gate reads).
        let (fused, members) = self.former.preview(&req);
        let hint = self.best_service_prediction(fused, req.reps, members);
        match self.former.join(req, now, hint) {
            JoinOutcome::Pending { window, flush_at } => {
                self.push_event(flush_at, EventKind::BatchFlush(window));
            }
            JoinOutcome::FlushNow { window } => self.flush_window(now, window),
        }
        true
    }

    /// Flush a batch window (timer fired, window full, or SLO pressure)
    /// and hand the fused result to admission. One-member windows admit
    /// solo — a "batch" of one is just a request that waited.
    fn flush_window(&mut self, now: f64, window: u64) {
        let Some(batch) = self.former.flush(window) else {
            return; // stale timer: the window already flushed
        };
        if batch.members.len() == 1 {
            let m = batch.members[0];
            // The degenerate "batch" is unpacked right here, so its
            // carrier goes back to the former's spare pool: the
            // light-load open/flush-solo cycle allocates no carriers.
            self.former.recycle(batch.members);
            self.admit_request(now, m.req, m.arrival);
        } else {
            self.admit_fused(now, batch);
        }
    }

    /// Admit one plain request at time `now`. `arrival` is its true
    /// front-end arrival (earlier than `now` for members of a disbanded
    /// batch, whose window wait is charged against any SLO budget).
    ///
    /// Deadline admission: an SLO no shard can meet — machine-level (no
    /// shard's own model passes the deadline-constrained LP / service
    /// prediction) or queueing-level (the best feasible shard's
    /// predicted sojourn overruns the slack guard band) — is turned
    /// away (or demoted, per policy) *now*, before it consumes queue
    /// space it cannot use.
    fn admit_request(&mut self, now: f64, mut req: GemmRequest, arrival: f64) {
        if self.down.iter().all(|&d| d) {
            // Total outage: every machine is down, so there is nowhere
            // to route. Park the request at the front-end — original
            // arrival kept, so the outage keeps charging against any
            // SLO budget — until a restart re-admits it.
            self.parked.push((req, arrival));
            return;
        }
        let mut routed = None;
        if let Some(deadline_s) = req.deadline_s {
            // The budget that remains once time already spent waiting
            // (zero for a fresh arrival) is charged.
            let remaining = deadline_s - (now - arrival);
            let mut gate_req = req;
            gate_req.deadline_s = Some(remaining);
            routed = if remaining > 0.0 {
                self.route(now, &gate_req, 1, true)
                    .filter(|r| r.finish - now <= self.opts.shard.deadline_slack * remaining)
            } else {
                None
            };
            if routed.is_none() {
                match self.opts.shard.deadline_policy {
                    DeadlinePolicy::Reject => {
                        // Record the denial with the best machine-level
                        // service prediction any shard's own gate
                        // offers — backlog-free, so the same request
                        // denied under different queue states logs the
                        // same figure.
                        let predicted_s = self.best_service_prediction(req.size, req.reps, 1);
                        self.deny(now, req, arrival, predicted_s);
                        return;
                    }
                    DeadlinePolicy::Downclass => {
                        // Best-effort from here on: the SLO is given
                        // up, not silently missed — and the route is
                        // recomputed for the new class below.
                        req.class = QosClass::Batch;
                        req.deadline_s = None;
                    }
                }
            }
        }
        // Every shard is scored with its *own* gate's verdict: on a
        // heterogeneous cluster the per-shard predictions (and even the
        // co-execute decision) legitimately disagree, and the enqueue
        // below records the verdict of the shard actually chosen.
        let Routed {
            shard: target,
            verdict: (co_execute, best_device, predicted_s),
            ..
        } = match routed {
            Some(r) => r,
            None => self
                .route(now, &req, 1, false)
                .expect("a cluster has at least one shard"),
        };
        // Cluster-wide power cap: waking an idle shard raises the
        // predicted aggregate draw by the idle-to-active delta of the
        // devices this unit engages (the shard's idle watts are already
        // in the aggregate). An arrival whose marginal draw would cross
        // the cap is turned away like a deadline-infeasible one — or,
        // under [`DeadlinePolicy::Downclass`], demoted to best-effort
        // batch and admitted at the same placement (a *soft* cap that
        // sheds SLO guarantees first). Work landing on an
        // already-engaged shard adds no marginal draw and always
        // passes.
        if let Some(cap_w) = self.opts.power.cap_w {
            let sh = &self.shards[target];
            let engaged = sh.free_at() > now || sh.pending() > 0;
            if !engaged {
                let marginal = self.marginal_draw(target, co_execute, best_device);
                if self.predicted_draw(now) + marginal > cap_w {
                    match self.opts.shard.deadline_policy {
                        DeadlinePolicy::Reject => {
                            let denied_pred =
                                self.best_service_prediction(req.size, req.reps, 1);
                            self.deny(now, req, arrival, denied_pred);
                            return;
                        }
                        DeadlinePolicy::Downclass => {
                            req.class = QosClass::Batch;
                            req.deadline_s = None;
                        }
                    }
                }
            }
        }
        self.shards[target].enqueue(QueuedRequest {
            req,
            arrival,
            co_execute,
            best_device,
            predicted_s,
            batch: None,
        });
        self.reindex(target);
        // Defer the dispatch behind simultaneous arrivals so queue
        // policies and the bypass see the whole burst. A shard still
        // executing needs no wake at all: its pending shard-free event
        // (at `free_at > now`) will drain the queue, and the wake
        // would be a no-op — skipping it halves the event volume under
        // sustained load.
        if self.shards[target].free_at() <= now {
            self.push_event(now, EventKind::Wake(target));
        }
    }

    /// Admit a fused batch as one work unit: batch-level gate verdicts
    /// at every shard, batch-level deadline admission against the
    /// tightest member SLO, one routing decision, one queue slot. A
    /// batch whose SLO fails admission is **disbanded** — every member
    /// re-enters solo admission (where its own SLO is judged with the
    /// window wait already charged) instead of the whole batch being
    /// denied.
    fn admit_fused(&mut self, now: f64, mut batch: FusedBatch) {
        if self.down.iter().all(|&d| d) {
            // Total outage: the batch disbands and its members park
            // solo (fusing again after the outage would misattribute
            // the window wait).
            let freed = std::mem::take(&mut batch.members);
            for m in &freed {
                self.parked.push((m.req, m.arrival));
            }
            self.former.recycle(freed);
            return;
        }
        let members = batch.members.len() as u32;
        let carrier = batch.carrier(now);
        let mut routed = None;
        if let Some(remaining) = carrier.deadline_s {
            routed = if remaining > 0.0 {
                self.route(now, &carrier, members, true)
                    .filter(|r| r.finish - now <= self.opts.shard.deadline_slack * remaining)
            } else {
                None
            };
            if routed.is_none() {
                // Disband: members re-enter admission solo and the
                // carrier returns to the former's spare pool.
                let freed = std::mem::take(&mut batch.members);
                for m in &freed {
                    self.admit_request(now, m.req, m.arrival);
                }
                self.former.recycle(freed);
                return;
            }
        }
        let Routed {
            shard: target,
            verdict: (co_execute, best_device, predicted_s),
            ..
        } = match routed {
            Some(r) => r,
            None => self
                .route(now, &carrier, members, false)
                .expect("a cluster has at least one shard"),
        };
        // The power cap sees a fused batch as one unit. An over-cap
        // batch disbands so each member faces the cap — and the
        // configured over-cap policy — solo.
        if let Some(cap_w) = self.opts.power.cap_w {
            let sh = &self.shards[target];
            let engaged = sh.free_at() > now || sh.pending() > 0;
            let marginal = self.marginal_draw(target, co_execute, best_device);
            if !engaged && self.predicted_draw(now) + marginal > cap_w {
                let freed = std::mem::take(&mut batch.members);
                for m in &freed {
                    self.admit_request(now, m.req, m.arrival);
                }
                self.former.recycle(freed);
                return;
            }
        }
        self.shards[target].enqueue(QueuedRequest {
            req: carrier,
            arrival: now,
            co_execute,
            best_device,
            predicted_s,
            batch: Some(batch),
        });
        self.reindex(target);
        if self.shards[target].free_at() <= now {
            self.push_event(now, EventKind::Wake(target));
        }
    }

    /// A [`EventKind::Crash`] fired: kill shard `s` at virtual time
    /// `now` and displace its work.
    ///
    /// In-flight work first: completion records are written into
    /// `served` at **dispatch** time with future finishes, and
    /// dispatches are serialized per shard, so everything still running
    /// on `s` is exactly the records with `finish > now`. Those records
    /// are removed (the results are lost), rolled back out of the
    /// shard's accounting ([`ExecutorShard::abort_record`]), and
    /// re-admitted — so each displaced request appears **exactly once**
    /// in the final report, under whatever outcome its re-admission
    /// earns. Members of an aborted fused batch each had their own
    /// record and re-admit **solo** (only fresh arrivals visit the
    /// batch former). Then the queue drains in the shard's own
    /// dispatch order, queued batch carriers disbanding the same way.
    ///
    /// Every re-admission goes through [`Cluster::admit_request`] with
    /// its *original* arrival time: elapsed wait is charged against any
    /// remaining SLO budget, and the surviving shards' own gates re-plan
    /// the work from scratch.
    fn crash_shard(&mut self, s: usize, now: f64) {
        if self.down[s] {
            return;
        }
        self.down[s] = true;
        self.reindex(s);
        if self.tap {
            self.tap_log.push(TapAction::Crash { shard: s });
        }
        let mut aborted = Vec::new();
        let mut kept = Vec::with_capacity(self.served.len());
        for r in std::mem::take(&mut self.served) {
            if r.shard == Some(s) && r.finish > now && !r.mode.is_unserved() {
                aborted.push(r);
            } else {
                kept.push(r);
            }
        }
        self.served = kept;
        // The aborted completions never happened; their re-admissions
        // below re-count them under whatever outcome they earn.
        self.finished -= aborted.len();
        for r in &aborted {
            self.shards[s].abort_record(r);
        }
        let drained = self.shards[s].crash(now);
        let displaced = aborted.len()
            + drained
                .iter()
                .map(|q| q.batch.as_ref().map_or(1, |b| b.members.len()))
                .sum::<usize>();
        self.shards[s].note_requeued(displaced);
        self.requeued += displaced;
        for r in aborted {
            let req = GemmRequest {
                id: r.id,
                size: r.size,
                reps: r.reps,
                class: r.class,
                deadline_s: r.deadline_s,
            };
            self.admit_request(now, req, r.arrival);
        }
        for q in drained {
            match q.batch {
                Some(b) => {
                    for m in &b.members {
                        self.admit_request(now, m.req, m.arrival);
                    }
                    self.former.recycle(b.members);
                }
                None => self.admit_request(now, q.req, q.arrival),
            }
        }
    }

    /// A [`EventKind::Restart`] fired: shard `s` rejoins at `now`.
    /// Requests parked behind a total outage re-enter admission, and a
    /// shard-free event lets the shard pick up routed or stealable work
    /// immediately. A *drained* shard revives the same way — its
    /// machine-seconds meter starts a fresh provisioned span
    /// ([`ExecutorShard::unretire`]; a no-op after a crash, which never
    /// stopped the meter).
    fn restart_shard(&mut self, s: usize, now: f64) {
        if !self.down[s] {
            return;
        }
        self.down[s] = false;
        self.shards[s].unretire(now);
        self.reindex(s);
        if self.tap {
            self.tap_log.push(TapAction::Restart { shard: s });
        }
        for (req, arrival) in std::mem::take(&mut self.parked) {
            self.admit_request(now, req, arrival);
        }
        self.push_event(now, EventKind::ShardFree(s));
    }

    /// A [`EventKind::Join`] fired: provision a new shard running `cfg`
    /// at virtual time `now`. Installation happens here — the machine
    /// is profiled on `profile_seed` (deterministic), the shard starts
    /// with a cold [`super::PlanCache`] and, under
    /// [`GatePolicy::PerShard`], its own admission gate over its own
    /// fitted model. Both tournament-tree indexes are rebuilt one leaf
    /// wider and every key re-derived — a rare O(shards log shards)
    /// event that keeps the steady state allocation-free. A join ends a
    /// total outage the way a restart does: parked requests re-enter
    /// admission, and a shard-free event lets the newcomer steal backlog
    /// immediately.
    fn join_shard(&mut self, cfg: &MachineConfig, profile_seed: u64, now: f64) -> usize {
        let idx = self.shards.len();
        let pipeline = Pipeline::for_simulated_machine(cfg, profile_seed);
        let mut shard = ExecutorShard::from_pipeline(idx, pipeline, &self.opts.shard);
        shard.provision(now);
        if self.opts.gate == GatePolicy::PerShard {
            self.admissions.push(Admission::new(
                shard.model.clone(),
                self.opts.shard.min_gain,
                self.opts.shard.overhead_s,
                self.opts.shard.gate_capacity,
            ));
        }
        self.shards.push(shard);
        self.down.push(false);
        // One source of truth for the shard count, as at construction.
        self.opts.shards = self.shards.len();
        let n = self.shards.len();
        self.route_idx = TournamentTree::new(n, Ranking::Min);
        self.steal_idx = TournamentTree::new(n, Ranking::Max);
        self.energy_idx = TournamentTree::new(n, Ranking::Min);
        for s in 0..n {
            self.reindex(s);
        }
        if self.tap {
            self.tap_log.push(TapAction::Join { shard: idx });
        }
        for (req, arrival) in std::mem::take(&mut self.parked) {
            self.admit_request(now, req, arrival);
        }
        self.push_event(now, EventKind::ShardFree(idx));
        idx
    }

    /// A [`EventKind::Drain`] fired: gracefully retire shard `s` at
    /// virtual time `now`. The voluntary counterpart of
    /// [`Cluster::crash_shard`], with the crucial difference that
    /// **zero in-flight work is displaced**: completion records on `s`
    /// (including any with `finish > now`) stand, the machine runs its
    /// current execution to the end (its machine-seconds meter stops at
    /// that finish — [`ExecutorShard::retire`]), and only *queued* work
    /// is redistributed through [`Cluster::admit_request`] with its
    /// original arrival time and SLO budget (queued batch carriers
    /// disband; members re-admit solo). The down flag reuses every
    /// routing/wake/steal exclusion a crash uses, so no new work can
    /// land; the shard's eventual shard-free event is a no-op.
    fn drain_shard(&mut self, s: usize, now: f64) {
        if self.down[s] {
            return;
        }
        self.down[s] = true;
        self.shards[s].retire(now);
        self.reindex(s);
        if self.tap {
            self.tap_log.push(TapAction::Drain { shard: s });
        }
        let drained = self.shards[s].drain_queue();
        let displaced: usize = drained
            .iter()
            .map(|q| q.batch.as_ref().map_or(1, |b| b.members.len()))
            .sum();
        self.shards[s].note_requeued(displaced);
        self.requeued += displaced;
        for q in drained {
            match q.batch {
                Some(b) => {
                    for m in &b.members {
                        self.admit_request(now, m.req, m.arrival);
                    }
                    self.former.recycle(b.members);
                }
                None => self.admit_request(now, q.req, q.arrival),
            }
        }
    }

    /// Mean pressure across live shards at `now`: residual execution
    /// plus queued backlog, in predicted seconds — the autoscaler's
    /// load signal. Infinite when nothing is live (a total outage is
    /// maximal pressure).
    fn mean_live_pressure(&self, now: f64) -> f64 {
        let mut live = 0usize;
        let mut pressure = 0.0;
        for (s, sh) in self.shards.iter().enumerate() {
            if self.down[s] {
                continue;
            }
            live += 1;
            pressure += (sh.free_at() - now).max(0.0) + sh.backlog_s();
        }
        if live == 0 {
            f64::INFINITY
        } else {
            pressure / live as f64
        }
    }

    /// An [`EventKind::AutoscaleEval`] fired mid-run: read the load
    /// signals and move membership at most one shard per evaluation
    /// (see [`super::elastic`] for the policy). Scale-up provisions the
    /// first pool entry that is not live — never-joined entries join
    /// fresh, drained entries revive. Scale-down needs a full
    /// hysteresis streak and drains the lowest-pressure live pool
    /// shard; construction-time shards are never drained.
    fn autoscale_eval(&mut self, now: f64) {
        // Take the state out so the handler can call membership methods
        // on `self`; put it back at the end.
        let Some(mut scaler) = self.scaler.take() else {
            return;
        };
        let pressure = self.mean_live_pressure(now);
        let denied_now = self.served.iter().filter(|r| r.mode.is_denied()).count();
        let deadline_risk = denied_now > scaler.last_denied;
        scaler.last_denied = denied_now;
        if pressure > scaler.policy.scale_up_pressure_s || deadline_risk {
            scaler.low_streak = 0;
            let slot = (0..scaler.policy.pool.len()).find(|&k| match scaler.pool_shard[k] {
                None => true,
                Some(s) => self.down[s],
            });
            if let Some(k) = slot {
                match scaler.pool_shard[k] {
                    None => {
                        let cfg = scaler.policy.pool[k].clone();
                        let seed = scaler.policy.profile_seed.wrapping_add(k as u64);
                        scaler.pool_shard[k] = Some(self.join_shard(&cfg, seed, now));
                    }
                    Some(s) => self.restart_shard(s, now),
                }
            }
        } else if pressure < scaler.policy.scale_down_pressure_s {
            scaler.low_streak += 1;
            if scaler.low_streak >= scaler.policy.scale_down_evals {
                scaler.low_streak = 0;
                // Lowest-pressure live pool shard; ties to the lowest
                // index (deterministic).
                let mut pick: Option<(usize, f64)> = None;
                for slot in scaler.pool_shard.iter().flatten() {
                    let s = *slot;
                    if self.down[s] {
                        continue;
                    }
                    let sh = &self.shards[s];
                    let p = (sh.free_at() - now).max(0.0) + sh.backlog_s();
                    let better = match pick {
                        None => true,
                        Some((_, best)) => p < best,
                    };
                    if better {
                        pick = Some((s, p));
                    }
                }
                if let Some((s, _)) = pick {
                    self.drain_shard(s, now);
                }
            }
        } else {
            scaler.low_streak = 0;
        }
        self.scaler = Some(scaler);
    }

    fn dispatch_on(&mut self, s: usize, at: f64) {
        let start = self.shards[s].free_at().max(at);
        let before = self.served.len();
        if let Some(res) = self.shards[s].dispatch_next(start, &mut self.served) {
            if self.tap {
                let unit = self.tap_units;
                self.tap_units += 1;
                self.tap_log.push(TapAction::Dispatch(DispatchNote {
                    unit,
                    shard: s,
                    start,
                    finish: res.finish,
                    exec_s: res.finish - start,
                    records: self.served[before..].iter().map(|r| r.id).collect(),
                }));
            }
            if res.replanned {
                // This shard observed drift and refreshed its model:
                // *its* gate adopts it so future admissions (and their
                // memoized verdicts) track the live machine; other
                // shards' gates are untouched. (Under the legacy
                // [`GatePolicy::Shard0`] ablation every shard maps to
                // the one shared gate, which therefore adopts whichever
                // shard replanned last — exactly the pre-heterogeneous
                // behaviour the baseline exists to reproduce.)
                let model = self.shards[s].model.clone();
                let g = self.gate_idx(s);
                self.admissions[g].refresh(model);
                // The refreshed model moves this shard's joules-per-op
                // figure too; the reindex below carries it into the
                // energy tree.
                self.shards[s].refresh_energy_cost();
            }
            self.push_event(res.finish, EventKind::ShardFree(s));
        }
        self.finished += self.served.len() - before;
        self.reindex(s);
    }

    /// Process the earliest pending event. Returns `false` when the
    /// event heap is empty (every submitted request has completed).
    pub fn step_event(&mut self) -> bool {
        #[cfg(debug_assertions)]
        self.verify_indexes();
        let ev = match self.drain.pop_front() {
            Some(ev) => ev,
            None => {
                let Some(Reverse(ev)) = self.events.pop() else {
                    return false;
                };
                // Batch-drain everything else sharing this instant into
                // the reusable buffer: one O(log heap) pop per distinct
                // timestamp instead of per event. Events pushed while
                // processing carry strictly larger sequence numbers
                // than anything drained, so the (time, seq) order is
                // preserved: the drained prefix is consumed first, new
                // same-instant events pop from the heap after it.
                while let Some(Reverse(next)) = self.events.peek() {
                    if next.time == ev.time {
                        let Some(Reverse(n)) = self.events.pop() else {
                            unreachable!("peeked event vanished");
                        };
                        self.drain.push_back(n);
                    } else {
                        break;
                    }
                }
                ev
            }
        };
        if let EventKind::BatchFlush(window) = ev.kind {
            // Flush bounds only tighten, so a window that flushed early
            // (full, SLO pressure, or an earlier re-armed timer) leaves
            // stale timers behind. They must not even advance the
            // virtual clock — the flush they were armed for already
            // happened at an earlier instant.
            if self.former.has_window(window) {
                self.clock.advance_to(ev.time);
                self.flush_window(ev.time, window);
            }
            return true;
        }
        if let EventKind::AutoscaleEval = ev.kind {
            // Terminal tick: nothing pending anywhere and every machine
            // idle. Like a stale batch timer, it must not advance the
            // clock (the session's real work ended earlier — the
            // makespan, and every live shard's machine-seconds span,
            // close at that instant) and it does not re-arm, so the
            // event heap drains and the run completes.
            let idle = self.pending() == 0 && self.shards.iter().all(|s| s.free_at() <= ev.time);
            if idle {
                return true;
            }
            self.clock.advance_to(ev.time);
            self.autoscale_eval(ev.time);
            if let Some(scaler) = &self.scaler {
                let next = ev.time + scaler.policy.eval_interval_s;
                self.push_event(next, EventKind::AutoscaleEval);
            }
            return true;
        }
        self.clock.advance_to(ev.time);
        match ev.kind {
            EventKind::Arrival(req) => {
                // Small standalone-bound arrivals visit the batch
                // former first; everything else (and everything when
                // batching is off) admits solo.
                if !self.try_batch(ev.time, req) {
                    self.admit_request(ev.time, req, ev.time);
                }
            }
            EventKind::BatchFlush(_) | EventKind::AutoscaleEval => {
                unreachable!("handled before the clock advance")
            }
            // Faults may legally target a scheduled join that has not
            // fired yet (see `addressable_shards`); firing before the
            // target exists is a deterministic no-op.
            EventKind::Crash(s) => {
                if s < self.shards.len() {
                    self.crash_shard(s, ev.time);
                }
            }
            EventKind::Restart(s) => {
                if s < self.shards.len() {
                    self.restart_shard(s, ev.time);
                }
            }
            EventKind::RateScale(s, factor) => {
                if s < self.shards.len() {
                    self.shards[s].sim.scale_rates(factor);
                }
            }
            EventKind::Join(cfg, profile_seed) => {
                // The scheduled join materializes: it stops being a
                // promise and becomes a real shard index.
                self.joins_scheduled -= 1;
                self.join_shard(&cfg, profile_seed, ev.time);
            }
            EventKind::Drain(s) => {
                if s < self.shards.len() {
                    self.drain_shard(s, ev.time);
                }
            }
            EventKind::PowerCap(cap_w) => {
                self.opts.power.cap_w = cap_w;
            }
            EventKind::Wake(s) => {
                if !self.down[s]
                    && self.shards[s].free_at() <= ev.time
                    && self.shards[s].pending() > 0
                {
                    self.dispatch_on(s, ev.time);
                }
            }
            EventKind::ShardFree(s) => {
                if self.down[s] {
                    // Stale free event from a dispatch the crash
                    // aborted: the machine is gone, nothing to do.
                } else if self.shards[s].pending() > 0 {
                    self.dispatch_on(s, ev.time);
                } else if self.opts.work_stealing {
                    if let Some(victim) = self.steal_victim(s) {
                        // Peek the victim's offer before committing:
                        // popping and then vetoing would burn one of
                        // the head class's weighted-round-robin turns
                        // without a dispatch.
                        let offer = self.shards[victim].peek_next().map(|q| {
                            let members =
                                q.batch.as_ref().map_or(1, |b| b.members.len() as u32);
                            (q.req, q.arrival, members)
                        });
                        if let Some((req, arrival, members)) = offer {
                            // Re-plan the offered work unit under the
                            // thief's own model: the victim's verdict
                            // (co-exec vs standalone, best device,
                            // service prediction) was computed against
                            // a different machine, so the thief re-runs
                            // its gate (memoized) and dispatch will use
                            // the thief's PlanCache. A fused batch
                            // moves whole — `req` is then the batch
                            // carrier and `members` its size, so the
                            // thief re-gates it batch-level.
                            let (co_execute, best_device, predicted_s) =
                                self.gate_on(s, req.size, req.reps, members);
                            // Deadline guard: admission promised this
                            // SLO against a shard whose own model could
                            // meet it — a thief whose machine cannot
                            // (e.g. the CPU node eyeing a GPU-sized
                            // request) must not un-promise it. The
                            // budget is what *remains* of the sojourn
                            // SLO at steal time, under the same slack
                            // band admission used — time already spent
                            // queued on the victim is gone. Veto the
                            // whole attempt (conservative: the victim's
                            // weighted pick chose this offer; we do not
                            // scan past it for easier prey).
                            let slo_safe = match req.deadline_s {
                                None => true,
                                Some(d) => {
                                    let remaining = self.opts.shard.deadline_slack * d
                                        - (ev.time - arrival);
                                    let g = self.gate_idx(s);
                                    self.admissions[g].deadline_feasible(
                                        co_execute,
                                        predicted_s,
                                        req.size,
                                        req.reps,
                                        remaining,
                                    )
                                }
                            };
                            if slo_safe {
                                let mut q = self.shards[victim]
                                    .yield_next()
                                    .expect("peeked offer must still be queued");
                                debug_assert_eq!(q.req.id, req.id, "offer changed under us");
                                q.co_execute = co_execute;
                                q.best_device = best_device;
                                q.predicted_s = predicted_s;
                                self.reindex(victim);
                                self.shards[s].note_steal();
                                if self.tap {
                                    self.tap_log.push(TapAction::Steal { thief: s, victim });
                                }
                                self.shards[s].enqueue(q);
                                self.reindex(s);
                                self.dispatch_on(s, ev.time);
                            }
                        }
                    }
                }
            }
        }
        true
    }

    /// Drain every event (arrivals included) and return the session
    /// report. The completion records are **moved** into the report —
    /// no O(served) clone — so repeated end-of-run extraction stays
    /// linear; [`Cluster::completed`] remains correct afterwards, and
    /// a subsequent mid-run [`Cluster::report`] snapshot starts empty.
    pub fn run_to_completion(&mut self) -> ServiceReport {
        while self.step_event() {}
        let served = std::mem::take(&mut self.served);
        self.build_report(served)
    }

    /// Snapshot the session statistics, aggregated across shards. This
    /// **clones** the completion records accumulated so far — the
    /// mid-run diagnostic path; end-of-run extraction goes through
    /// [`Cluster::run_to_completion`], which moves them instead.
    pub fn report(&self) -> ServiceReport {
        self.build_report(self.served.clone())
    }

    /// Assemble a [`ServiceReport`] around an owned record set.
    fn build_report(&self, served: Vec<ServedRequest>) -> ServiceReport {
        let denied = served.iter().filter(|r| r.mode.is_denied()).count();
        let rejected = served.iter().filter(|r| r.mode.is_rejected()).count();
        let mut report = ServiceReport {
            served,
            makespan: self.clock.now(),
            cache_hits: 0,
            cache_misses: 0,
            epoch_bumps: 0,
            replans: 0,
            denied,
            rejected,
            requeued: self.requeued,
            machine_seconds: 0.0,
            joules_active: 0.0,
            joules_idle: 0.0,
            joules_parked: 0.0,
            joules_by_class: [0.0; super::qos::NUM_CLASSES],
            shards: self.shards.iter().map(|s| s.stats()).collect(),
        };
        for (i, s) in self.shards.iter().enumerate() {
            report.cache_hits += s.cache.hits;
            report.cache_misses += s.cache.misses;
            report.epoch_bumps += s.cache.invalidations;
            report.replans += s.replans();
            // Close every still-provisioned span at the report clock
            // (shard-local stats closed it at the shard's own free_at,
            // which undercounts idle tails).
            let provisioned = s.provisioned_s(self.clock.now());
            report.shards[i].provisioned_s = provisioned;
            report.machine_seconds += provisioned;
        }
        // Energy accounting (see `docs/energy.md`). Active joules are
        // attributed per completion record — execution seconds times
        // the active watts of the devices the record occupied — so the
        // per-class and per-shard breakdowns are two partitions of the
        // *same* sum and the conservation law holds by construction.
        // Idle joules close each shard's provisioned-but-not-busy span
        // at the report clock; parked (drained) spans bill the
        // configured fraction of idle watts.
        let now = self.clock.now();
        for (i, sh) in self.shards.iter().enumerate() {
            let st = &mut report.shards[i];
            st.joules_idle = sh.idle_w_total() * (st.provisioned_s - st.busy_s).max(0.0);
            st.joules_parked = sh.idle_w_total() * self.opts.power.parked_frac * sh.parked_s(now);
        }
        for k in 0..report.served.len() {
            let (s, joules, class) = {
                let r = &report.served[k];
                let Some(s) = r.shard else { continue };
                if r.mode.is_unserved() {
                    continue;
                }
                let watts: f64 = r
                    .shares
                    .iter()
                    .zip(self.shards[s].device_power())
                    .filter(|(share, _)| **share > 0.0)
                    .map(|(_, p)| p.active_w)
                    .sum();
                (s, r.exec_s * watts, r.class.index())
            };
            report.shards[s].joules_active += joules;
            report.joules_by_class[class] += joules;
        }
        report.joules_active = report.shards.iter().map(|s| s.joules_active).sum();
        report.joules_idle = report.shards.iter().map(|s| s.joules_idle).sum();
        report.joules_parked = report.shards.iter().map(|s| s.joules_parked).sum();
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::service::request::ExecMode;

    fn big() -> GemmSize {
        GemmSize::square(20_000)
    }

    #[test]
    fn one_shard_cluster_serves_like_a_server() {
        let mut c = Cluster::builder().machine(&presets::mach2()).build();
        assert_eq!(c.num_shards(), 1);
        let b = c.submit(big(), 3);
        let s = c.submit(GemmSize::square(300), 3);
        assert_eq!(c.pending(), 2);
        let report = c.run_to_completion();
        assert_eq!(report.served.len(), 2);
        assert_eq!(c.pending(), 0);
        assert_eq!(c.completed(), 2);
        assert_eq!(report.request(b).unwrap().mode, ExecMode::CoExec);
        assert!(matches!(
            report.request(s).unwrap().mode,
            ExecMode::Standalone { .. }
        ));
        assert_eq!(report.shards.len(), 1);
        assert_eq!(report.shards[0].dispatches, 2);
        assert!(report.shards[0].busy_s > 0.0);
    }

    #[test]
    fn burst_arrivals_are_admitted_before_any_dispatch() {
        // Under SPJF, the shortest of a simultaneous burst must
        // dispatch first even though it was submitted last — i.e. the
        // wake ran after the whole burst was admitted.
        let opts = ClusterOptions {
            shard: ServerOptions {
                policy: crate::service::QueuePolicy::Spjf,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut c = Cluster::builder()
            .machine(&presets::mach2())
            .seed(1)
            .options(opts)
            .build();
        let slow = c.submit(GemmSize::square(24_000), 3);
        let fast = c.submit(GemmSize::square(16_000), 3);
        let report = c.run_to_completion();
        let r_slow = report.request(slow).unwrap();
        let r_fast = report.request(fast).unwrap();
        assert!(r_fast.start < r_slow.start, "SPJF saw the whole burst");
    }

    #[test]
    fn two_shards_split_a_burst_across_machines() {
        let mut c = Cluster::builder().replicas(&presets::mach2(), 2).build();
        for _ in 0..4 {
            c.submit(big(), 2);
        }
        let report = c.run_to_completion();
        assert_eq!(report.served.len(), 4);
        assert_eq!(report.shards.len(), 2);
        // Earliest-predicted-finish routing load-balances a uniform
        // burst: both machines worked.
        assert!(report.shards[0].dispatches > 0);
        assert!(report.shards[1].dispatches > 0);
        // Two concurrent machines overlap execution: the session ends
        // before the serialized sum of both shards' busy time.
        let total_busy: f64 = report.shards.iter().map(|s| s.busy_s).sum();
        assert!(report.makespan < total_busy);
    }

    /// Steal trigger: routing trusts admission-time predictions, so an
    /// inversion between predicted and actual finish order is what
    /// leaves work queued on a busy shard while another goes idle.
    /// mach1's thermal throttling makes a sustained 50-rep job overrun
    /// its (cold-profile) prediction by ~10%, while short 3-rep jobs
    /// run as predicted — a deterministic inversion:
    ///
    /// * shard 0 gets the 50-rep job (pred 50p) plus, once shard 1's
    ///   backlog passes it, one 3-rep job queued behind (at 53p vs 54p);
    /// * shard 1 gets seventeen 3-rep jobs (51p of backlog) and frees at
    ///   ~51p — while the throttled long job still runs until ~55p.
    fn steal_scenario(stealing: bool) -> ServiceReport {
        let opts = ClusterOptions {
            work_stealing: stealing,
            ..Default::default()
        };
        let mut c = Cluster::builder()
            .replicas(&presets::mach1(), 2)
            .seed(5)
            .options(opts)
            .build();
        c.submit(big(), 50);
        for _ in 0..18 {
            c.submit(big(), 3);
        }
        c.run_to_completion()
    }

    #[test]
    fn idle_shard_steals_work_queued_behind_an_overrunning_job() {
        let report = steal_scenario(true);
        assert_eq!(report.served.len(), 19);
        let stolen: usize = report.shards.iter().map(|s| s.stolen).sum();
        assert!(stolen >= 1, "no work was stolen: {:?}", report.shards);
        // Every request still served exactly once.
        let mut ids: Vec<u64> = report.served.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        let expect: Vec<u64> = (0..19).collect();
        assert_eq!(ids, expect);
    }

    #[test]
    fn per_shard_gates_route_by_each_shards_own_predictions() {
        let mut c = Cluster::builder()
            .machine(&presets::gpu_node())
            .machine(&presets::cpu_node())
            .build();
        assert_eq!(c.num_shards(), 2);
        assert_ne!(
            c.admission_for(0).model().fingerprint(),
            c.admission_for(1).model().fingerprint(),
            "per-shard gates must predict with per-shard models"
        );
        assert_eq!(c.shard(1).num_devices(), 1);
        // Submitted tiny-first so both shards are idle when the tiny
        // request routes: the decision is purely the per-shard service
        // predictions, not backlog avoidance.
        let tiny = c.submit(GemmSize::square(300), 2);
        let big = c.submit(big(), 2);
        let report = c.run_to_completion();
        assert_eq!(report.served.len(), 2);
        let r_tiny = report.request(tiny).unwrap();
        let r_big = report.request(big).unwrap();
        assert_eq!(
            r_tiny.shard,
            Some(1),
            "tiny GEMM belongs on the CPU node (stronger host, no copies)"
        );
        assert_eq!(
            r_big.shard,
            Some(0),
            "large GEMM belongs on the GPU-heavy node"
        );
        assert_eq!(r_big.mode, ExecMode::CoExec);
        assert!(matches!(r_tiny.mode, ExecMode::Standalone { device: 0 }));
        // Device-count asymmetry flows through the records and stats.
        assert_eq!(r_big.shares.len(), 3);
        assert_eq!(r_tiny.shares.len(), 1);
        assert_ne!(report.shards[0].model_fp, report.shards[1].model_fp);
        assert!(report.placement_quality() > 0.0);
    }

    #[test]
    fn shard0_gate_is_the_legacy_uniform_baseline() {
        let opts = ClusterOptions {
            gate: GatePolicy::Shard0,
            ..Default::default()
        };
        let mut c = Cluster::builder()
            .machines(&[presets::gpu_node(), presets::cpu_node()])
            .seed(1)
            .options(opts)
            .build();
        // One legacy gate, mapped to every shard.
        assert_eq!(
            c.admission_for(0).model().fingerprint(),
            c.admission_for(1).model().fingerprint(),
            "the ablation baseline predicts with one model everywhere"
        );
        // A standalone-bound request whose best device under shard 0's
        // model does not exist on the CPU shard must still complete
        // (clamped), wherever it lands.
        for _ in 0..4 {
            c.submit(GemmSize::square(300), 2);
        }
        let report = c.run_to_completion();
        assert_eq!(report.served.len(), 4);
        for r in &report.served {
            assert!(matches!(r.mode, ExecMode::Standalone { .. }));
            if r.shard == Some(1) {
                assert!(matches!(r.mode, ExecMode::Standalone { device: 0 }));
            }
        }
    }

    #[test]
    fn impossible_slo_is_denied_under_reject_policy() {
        let mut c = Cluster::builder().machine(&presets::mach2()).build();
        // A deadline tighter than any split can run: denied at arrival.
        let doomed = c.submit_qos(big(), 3, QosClass::Interactive, Some(1e-9));
        // A deadline-free neighbour is untouched.
        let ok = c.submit(big(), 3);
        let report = c.run_to_completion();
        assert_eq!(report.served.len(), 2);
        let r = report.request(doomed).unwrap();
        assert_eq!(r.mode, ExecMode::Denied);
        assert_eq!(r.exec_s, 0.0);
        assert_eq!(r.finish, r.arrival, "denial consumes no time");
        assert_eq!(report.denied, 1);
        assert_eq!(report.request(ok).unwrap().mode, ExecMode::CoExec);
        // The denial never reached a shard.
        assert_eq!(report.shards[0].dispatches, 1);
        // Aggregates describe only the executed request.
        assert_eq!(report.latencies().len(), 1);
    }

    #[test]
    fn impossible_slo_is_demoted_under_downclass_policy() {
        let opts = ClusterOptions {
            shard: ServerOptions {
                deadline_policy: crate::service::DeadlinePolicy::Downclass,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut c = Cluster::builder()
            .machine(&presets::mach2())
            .options(opts)
            .build();
        let demoted = c.submit_qos(big(), 3, QosClass::Interactive, Some(1e-9));
        let report = c.run_to_completion();
        let r = report.request(demoted).unwrap();
        // Served — as best-effort batch with the SLO stripped.
        assert_eq!(r.mode, ExecMode::CoExec);
        assert_eq!(r.class, QosClass::Batch);
        assert_eq!(r.deadline_s, None);
        assert_eq!(report.denied, 0);
        assert_eq!(r.deadline_met(), None, "stripped SLO is not a miss");
    }

    #[test]
    fn generous_slo_is_admitted_and_met() {
        let mut c = Cluster::builder().machine(&presets::mach2()).seed(3).build();
        let id = c.submit_qos(big(), 2, QosClass::Interactive, Some(1e6));
        let report = c.run_to_completion();
        let r = report.request(id).unwrap();
        assert_eq!(r.mode, ExecMode::CoExec);
        assert_eq!(r.class, QosClass::Interactive);
        assert_eq!(r.deadline_met(), Some(true));
        assert!((report.deadline_hit_rate() - 1.0).abs() < 1e-12);
        assert_eq!(report.shards[0].served_by_class, [1, 0, 0]);
    }

    #[test]
    fn weighted_drain_prefers_interactive_over_batch_backlog() {
        // One shard, a simultaneous burst: 2 batch + 1 interactive.
        // The interactive request must start before the second batch
        // request despite arriving last.
        let mut c = Cluster::builder().machine(&presets::mach2()).seed(4).build();
        let b0 = c.submit_qos(big(), 2, QosClass::Batch, None);
        let b1 = c.submit_qos(big(), 2, QosClass::Batch, None);
        let i0 = c.submit_qos(big(), 2, QosClass::Interactive, None);
        let report = c.run_to_completion();
        let start = |id| report.request(id).unwrap().start;
        // The weighted pick credits interactive 4:1, so it dispatches
        // first even though both batch requests were admitted ahead of
        // it in the same burst.
        assert!(start(i0) < start(b0), "interactive jumped the batch queue");
        assert!(start(i0) < start(b1));
        assert_eq!(
            report.shards[0].served_by_class,
            [1, 0, 2],
            "per-class attribution"
        );
    }

    #[test]
    fn windowed_batching_fuses_a_simultaneous_small_burst() {
        use crate::service::batch::{BatchPolicy, BatchWindow};
        let batching = BatchPolicy::Windowed(BatchWindow {
            window_s: 10.0,
            max_members: 8,
            ..Default::default()
        });
        // gpu_node: the weak host cannot make tiny GEMMs co-executable,
        // so 1024^3 is a standalone-bound batching candidate by every
        // verdict.
        let run = |batching: BatchPolicy| {
            let mut c = Cluster::builder()
                .machine(&presets::gpu_node())
                .seed(6)
                .options(ClusterOptions {
                    batching,
                    ..Default::default()
                })
                .build();
            for _ in 0..8 {
                c.submit(GemmSize::square(1024), 2);
            }
            c.run_to_completion()
        };
        let fused = run(batching);
        let off = run(BatchPolicy::Off);

        // Off: eight standalone dispatches. Windowed: the burst fills
        // the window before its timer, so everything fuses into ONE
        // batch served as one dispatch.
        assert_eq!(off.served.len(), 8);
        assert_eq!(fused.served.len(), 8);
        assert_eq!(off.fused(), 0);
        assert_eq!(fused.fused(), 8);
        assert_eq!(fused.num_batches(), 1);
        assert!((fused.mean_batch_members() - 8.0).abs() < 1e-12);
        assert!((fused.fusion_rate() - 1.0).abs() < 1e-12);
        assert_eq!(fused.shards[0].dispatches, 1);
        assert_eq!(fused.shards[0].batches, 1);
        assert_eq!(fused.shards[0].served_by_class, [0, 8, 0]);
        let id = fused.served[0].mode.batch().expect("batched member");
        assert!(fused.served.iter().all(|r| r.mode.batch() == Some(id)));
        // The members share the fused execution: one `B` operand
        // crossing the bus per repetition instead of eight — the
        // session must end strictly earlier than serving them one by
        // one.
        assert!(
            fused.makespan < off.makespan,
            "fusion must beat one-by-one dispatch: {} vs {}",
            fused.makespan,
            off.makespan
        );
        // Per-member accounting stays sane.
        let mut ids: Vec<u64> = fused.served.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<u64>>());
        for r in &fused.served {
            assert_eq!(r.arrival, 0.0);
            assert!((r.shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn lone_candidate_flushes_on_the_window_timer_and_serves_solo() {
        use crate::service::batch::{BatchPolicy, BatchWindow};
        let mut c = Cluster::builder()
            .machine(&presets::gpu_node())
            .seed(6)
            .options(ClusterOptions {
                batching: BatchPolicy::Windowed(BatchWindow {
                    window_s: 0.25,
                    ..Default::default()
                }),
                ..Default::default()
            })
            .build();
        let id = c.submit(GemmSize::square(1024), 2);
        assert_eq!(c.pending(), 1, "window members count as pending");
        let report = c.run_to_completion();
        let r = report.request(id).unwrap();
        // A window of one is not a batch: the request admits solo after
        // the window timer, its wait visible as queueing delay.
        assert!(matches!(r.mode, ExecMode::Standalone { .. }));
        assert_eq!(report.fused(), 0);
        assert!((r.start - 0.25).abs() < 1e-9, "start {}", r.start);
        assert_eq!(r.arrival, 0.0);
    }

    #[test]
    fn co_executable_requests_never_wait_for_a_window() {
        use crate::service::batch::BatchPolicy;
        let mut c = Cluster::builder()
            .machine(&presets::gpu_node())
            .seed(6)
            .options(ClusterOptions {
                batching: BatchPolicy::windowed(),
                ..Default::default()
            })
            .build();
        let id = c.submit(big(), 2);
        let report = c.run_to_completion();
        let r = report.request(id).unwrap();
        assert_eq!(r.mode, ExecMode::CoExec);
        assert_eq!(r.start, 0.0, "no window wait for co-executable work");
        assert_eq!(report.fused(), 0);
    }

    /// A mixed workload — classes, SLOs, staggered arrivals — used by
    /// the routing-policy equivalence tests.
    fn mixed_trace(c: &mut Cluster) {
        for i in 0..12u64 {
            let (size, reps, class, slo) = match i % 4 {
                0 => (big(), 2, QosClass::Interactive, Some(1e5)),
                1 => (GemmSize::square(300), 3, QosClass::Standard, None),
                2 => (big(), 1, QosClass::Batch, None),
                _ => (GemmSize::square(16_000), 2, QosClass::Interactive, Some(1e-9)),
            };
            let mut req = GemmRequest::new(i, size, reps).with_class(class);
            req.deadline_s = slo;
            c.submit_request_at(0.3 * i as f64, req);
        }
    }

    #[test]
    fn sampled_with_d_covering_the_cluster_matches_full_exactly() {
        // `Sampled { d >= shards }` must take the exact scan and touch
        // no randomness: the whole session replays byte-identically to
        // `Full`, denials and SLO decisions included.
        let run = |route: RoutePolicy| {
            let opts = ClusterOptions {
                route,
                ..Default::default()
            };
            let mut c = Cluster::builder()
                .replicas(&presets::mach2(), 4)
                .seed(9)
                .options(opts)
                .build();
            mixed_trace(&mut c);
            c.run_to_completion()
        };
        let full = run(RoutePolicy::Full);
        let sampled = run(RoutePolicy::Sampled { d: 4 });
        assert_eq!(full, sampled);
        assert_eq!(format!("{full:?}"), format!("{sampled:?}"));
    }

    #[test]
    fn sampled_routing_with_small_d_serves_everything_deterministically() {
        let run = || {
            let opts = ClusterOptions {
                route: RoutePolicy::Sampled { d: 2 },
                ..Default::default()
            };
            let mut c = Cluster::builder()
                .replicas(&presets::mach2(), 8)
                .seed(11)
                .options(opts)
                .build();
            mixed_trace(&mut c);
            c.run_to_completion()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "sampled routing must replay exactly");
        assert_eq!(a.served.len(), 12);
        let mut ids: Vec<u64> = a.served.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..12).collect::<Vec<u64>>());
        // The impossible SLOs are denied under sampling too: the
        // deadline path falls back to the exact scan before denying.
        assert_eq!(a.denied, 3);
        // Sampling spread load: more than one shard worked.
        assert!(a.shards.iter().filter(|s| s.dispatches > 0).count() > 1);
    }

    #[test]
    fn probe_route_inspects_without_admitting() {
        let mut c = Cluster::builder().replicas(&presets::mach2(), 2).build();
        let req = GemmRequest::new(0, big(), 2);
        let (shard, finish) = c.probe_route(&req).unwrap();
        assert!(shard < 2);
        assert!(finish > 0.0);
        assert_eq!(c.pending(), 0, "a probe admits nothing");
        assert_eq!(c.completed(), 0);
        // On the idle cluster the probe names where a real admission
        // then goes (`Full` consumes no randomness between the two).
        let id = c.submit(big(), 2);
        let report = c.run_to_completion();
        assert_eq!(report.request(id).unwrap().shard, Some(shard));
    }

    #[test]
    fn end_of_run_report_moves_records_and_keeps_counters() {
        let mut c = Cluster::builder().machine(&presets::mach2()).build();
        c.submit(big(), 2);
        let report = c.run_to_completion();
        assert_eq!(report.served.len(), 1);
        assert_eq!(c.completed(), 1, "the move must not lose the count");
        // The records moved into `report`; a later snapshot starts
        // empty but keeps the shard-level aggregates.
        let snap = c.report();
        assert!(snap.served.is_empty());
        assert_eq!(snap.shards[0].dispatches, 1);
    }

    #[test]
    fn hetero_thief_steals_work_its_hardware_serves_disproportionately_well() {
        // Shard 0: GPU node (idle thief). Shards 1, 2: CPU nodes, each
        // with one queued request. Shard 1 holds a deep tiny-GEMM job
        // (the larger class-weighted backlog — the plain winner);
        // shard 2 holds a big GEMM the CPU planned slowly but the GPU
        // thief would serve far faster. The affinity tilt must send
        // the thief to shard 2.
        let mut c = Cluster::builder()
            .machine(&presets::gpu_node())
            .replicas(&presets::cpu_node(), 2)
            .build();
        let tiny = GemmSize::square(300);
        let tiny_pred = c.gate_on(1, tiny, 1, 1).2;
        let (big_co, big_dev, big_pred) = c.gate_on(2, big(), 1, 1);
        // Enough repetitions that the tiny job's backlog strictly
        // out-weighs the big one's.
        let reps = ((big_pred / tiny_pred) * 2.0).ceil().max(2.0) as u32;
        let (tiny_co, tiny_dev, tiny_deep_pred) = c.gate_on(1, tiny, reps, 1);
        c.shards[1].enqueue(QueuedRequest {
            req: GemmRequest::new(0, tiny, reps),
            arrival: 0.0,
            co_execute: tiny_co,
            best_device: tiny_dev,
            predicted_s: tiny_deep_pred,
            batch: None,
        });
        c.reindex(1);
        c.shards[2].enqueue(QueuedRequest {
            req: GemmRequest::new(1, big(), 1),
            arrival: 0.0,
            co_execute: big_co,
            best_device: big_dev,
            predicted_s: big_pred,
            batch: None,
        });
        c.reindex(2);
        assert!(c.shards[1].weighted_backlog() > c.shards[2].weighted_backlog());
        assert_eq!(c.steal_idx.winner(), Some(1), "backlog alone picks shard 1");
        assert_eq!(
            c.steal_victim(0),
            Some(2),
            "the GPU thief must prefer the GPU-friendly head"
        );
    }

    #[test]
    fn homogeneous_steal_pick_is_unchanged_by_the_affinity_tilt() {
        // Three clone shards: the thief's affinity for both victims'
        // heads differs only by profiling noise, far inside the tilt
        // margin — the class-weighted backlog winner must stand.
        let mut c = Cluster::builder()
            .replicas(&presets::mach2(), 3)
            .seed(2)
            .build();
        for victim in [1usize, 2] {
            let (co, dev, pred) = c.gate_on(victim, big(), 2, 1);
            let depth = if victim == 1 { 2 } else { 1 };
            for j in 0..depth {
                c.shards[victim].enqueue(QueuedRequest {
                    req: GemmRequest::new((victim * 10 + j) as u64, big(), 2),
                    arrival: 0.0,
                    co_execute: co,
                    best_device: dev,
                    predicted_s: pred,
                    batch: None,
                });
            }
            c.reindex(victim);
        }
        assert_eq!(c.steal_victim(0), Some(1), "deeper backlog wins on clones");
    }

    #[test]
    fn work_stealing_can_be_disabled() {
        let with = steal_scenario(true);
        let without = steal_scenario(false);
        assert!(without.shards.iter().all(|s| s.stolen == 0));
        assert_eq!(with.served.len(), without.served.len());
        // Stealing starts the stranded request earlier than waiting for
        // the overrunning job would have.
        let waits_with = with.mean_queue_wait();
        let waits_without = without.mean_queue_wait();
        assert!(
            waits_with <= waits_without + 1e-9,
            "stealing must not increase mean queueing delay: {waits_with} vs {waits_without}"
        );
    }

    /// The deprecated constructors are thin shims over the builder:
    /// same machines + same seeds must yield the same fitted models.
    #[test]
    #[allow(deprecated)]
    fn deprecated_constructors_match_the_builder() {
        let old = Cluster::new(
            &presets::mach2(),
            0,
            ClusterOptions {
                shards: 2,
                ..Default::default()
            },
        );
        let new = Cluster::builder().replicas(&presets::mach2(), 2).build();
        assert_eq!(old.num_shards(), new.num_shards());
        assert_eq!(
            old.shard(1).model.fingerprint(),
            new.shard(1).model.fingerprint()
        );
        let spec = HeterogeneousSpec::new(7)
            .machine(presets::gpu_node())
            .machines(presets::cpu_node(), 2)
            .build();
        let built = Cluster::builder()
            .machine(&presets::gpu_node())
            .replicas(&presets::cpu_node(), 2)
            .seed(7)
            .build();
        assert_eq!(spec.num_shards(), built.num_shards());
        assert_eq!(
            spec.shard(2).model.fingerprint(),
            built.shard(2).model.fingerprint(),
            "same machines, same seeds, same fitted models"
        );
        let from = Cluster::from_machines(
            &[presets::gpu_node(), presets::cpu_node()],
            3,
            ClusterOptions::default(),
        );
        let machines = Cluster::builder()
            .machines(&[presets::gpu_node(), presets::cpu_node()])
            .seed(3)
            .build();
        assert_eq!(
            from.shard(0).model.fingerprint(),
            machines.shard(0).model.fingerprint()
        );
    }

    #[test]
    fn energy_objective_prefers_the_cheaper_shard_when_slack_allows() {
        // Two same-speed machines, one drawing ~8x the active watts:
        // with generous slack the energy pass must route the whole
        // burst to the cheap shard; under Latency it load-balances.
        let mut hot = presets::mach2();
        for d in &mut hot.devices {
            d.active_w *= 8.0;
        }
        let build = |objective: RouteObjective| {
            Cluster::builder()
                .machine(&presets::mach2())
                .machine(&hot)
                .objective(objective)
                .build()
        };
        let mut lat = build(RouteObjective::Latency);
        let mut eco = build(RouteObjective::EnergyAware { slack: 50.0 });
        for c in [&mut lat, &mut eco] {
            for _ in 0..4 {
                c.submit(big(), 2);
            }
        }
        let lat_report = lat.run_to_completion();
        let eco_report = eco.run_to_completion();
        assert_eq!(eco_report.served.len(), 4);
        assert_eq!(
            eco_report.shards[1].dispatches, 0,
            "with slack to spare, nothing should land on the hot shard"
        );
        assert!(lat_report.shards[1].dispatches > 0, "Latency load-balances");
        assert!(
            eco_report.joules_active < lat_report.joules_active,
            "energy-aware routing must cut active joules: {} vs {}",
            eco_report.joules_active,
            lat_report.joules_active
        );
        // Conservation: per-class and per-shard actives partition the
        // same sum.
        let by_class: f64 = eco_report.joules_by_class.iter().sum();
        assert!((by_class - eco_report.joules_active).abs() < 1e-9);
    }

    #[test]
    fn power_cap_denies_the_arrival_that_would_cross_it() {
        // mach2 idles at 61 W and draws 565 W fully engaged. With two
        // shards a 700 W cap admits the first co-exec arrival
        // (122 -> 626 W predicted) and must deny the simultaneous
        // second (626 + 504 would cross it); uncapping re-opens
        // admission.
        let mut c = Cluster::builder()
            .replicas(&presets::mach2(), 2)
            .power(PowerOptions {
                cap_w: Some(700.0),
                ..Default::default()
            })
            .build();
        c.submit(big(), 2);
        c.submit(big(), 2);
        c.inject_power_cap(1e6, None);
        let late = GemmRequest::new(9, big(), 2);
        c.submit_request_at(2e6, late);
        let report = c.run_to_completion();
        assert_eq!(report.denied, 1, "the over-cap arrival is turned away");
        assert!(
            !report.request(9).unwrap().mode.is_denied(),
            "after the uncap event admission re-opens"
        );
    }
