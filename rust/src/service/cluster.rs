//! The cluster front-end: POAS serving sharded across machines.
//!
//! A [`Cluster`] drives N [`ExecutorShard`]s — each a full machine with
//! its own installation-time profile, plan cache and local queue —
//! through one **event-driven virtual-time loop**. The single
//! monolithic `clock: f64` of the old server is replaced by a binary
//! heap of timestamped events:
//!
//! * **arrival** — a request reaches the front-end (either submitted
//!   "now" or scheduled by an [`super::arrivals`] trace). It passes the
//!   [`Admission`] gate once; a deadline-bound request then faces
//!   **deadline admission**: the machine-level feasibility probe (the
//!   deadline-constrained LP reused from the energy formulation) plus
//!   the queueing-aware sojourn prediction at the best shard. An SLO
//!   predicted infeasible is turned away as [`ExecMode::Denied`] or
//!   demoted to [`QosClass::Batch`] with the SLO stripped, per
//!   [`super::DeadlinePolicy`]. Accepted requests route to the shard
//!   with the earliest **class-weighted predicted finish**:
//!   `max(shard free time, now) + class-discounted backlog + this
//!   request`, all from admission-time predictions, so routing never
//!   re-runs the optimizer;
//! * **wake** — scheduled behind every arrival at the same timestamp so
//!   that simultaneous arrivals are all admitted (and visible to queue
//!   policies and the bypass scan) before any of them starts a machine;
//! * **shard-free** — a machine finished its dispatch. It drains its
//!   own queue first and, when empty, **steals** the next request
//!   (under the victim's own weighted pick, so high classes move first)
//!   from the shard with the largest *class-weighted* backlog — a
//!   minute of queued interactive work makes a hotter victim than a
//!   minute of batch.
//!
//! Ties in virtual time break by submission sequence number, which
//! keeps every replay byte-identical for a fixed seed. A one-shard
//! cluster degenerates to exactly the old single-machine behaviour —
//! [`super::Server`] is now a thin wrapper over `Cluster`.

use super::admission::Admission;
use super::arrivals::Arrival;
use super::qos::{DeadlinePolicy, QosClass};
use super::queue::QueuedRequest;
use super::request::{ExecMode, GemmRequest, ServedRequest, ServiceReport};
use super::server::ServerOptions;
use super::shard::ExecutorShard;
use crate::config::MachineConfig;
use crate::coordinator::Pipeline;
use crate::workload::GemmSize;
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// Cluster construction options.
#[derive(Debug, Clone)]
pub struct ClusterOptions {
    /// Number of machines (min 1). Each shard profiles its own
    /// [`crate::sim::SimMachine`] seeded `seed + shard index`.
    pub shards: usize,
    /// Per-shard serving options (queue policy, bypass, dynamic loop)
    /// plus the admission-gate knobs shared by the front-end.
    pub shard: ServerOptions,
    /// Let an idle shard steal queued work from the most backlogged
    /// shard instead of sitting idle.
    pub work_stealing: bool,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        ClusterOptions {
            shards: 1,
            shard: ServerOptions::default(),
            work_stealing: true,
        }
    }
}

#[derive(Debug, Clone)]
enum EventKind {
    /// A request reaches the front-end.
    Arrival(GemmRequest),
    /// Post-arrival nudge: dispatch on this shard if it is idle.
    Wake(usize),
    /// This shard's machine went idle.
    ShardFree(usize),
}

#[derive(Debug, Clone)]
struct Event {
    time: f64,
    /// Tie-break for simultaneous events: strictly increasing push
    /// order, so replays are exact.
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time
            .total_cmp(&other.time)
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

/// A request-serving POAS deployment across one or more machines.
#[derive(Debug, Clone)]
pub struct Cluster {
    shards: Vec<ExecutorShard>,
    admission: Admission,
    opts: ClusterOptions,
    events: BinaryHeap<Reverse<Event>>,
    seq: u64,
    clock: f64,
    served: Vec<ServedRequest>,
    next_id: u64,
}

impl Cluster {
    /// Build a cluster of `opts.shards` machines from `cfg`: shard `i`
    /// is profiled at installation time on its own simulator seeded
    /// `seed + i`; the admission gate predicts with shard 0's profile.
    pub fn new(cfg: &MachineConfig, seed: u64, opts: ClusterOptions) -> Self {
        let n = opts.shards.max(1);
        let pipelines = (0..n)
            .map(|i| Pipeline::for_simulated_machine(cfg, seed.wrapping_add(i as u64)))
            .collect();
        Self::from_pipelines(pipelines, opts)
    }

    /// Promote already-profiled pipelines into a cluster (one shard per
    /// pipeline; `pipelines` must be non-empty).
    pub fn from_pipelines(pipelines: Vec<Pipeline>, mut opts: ClusterOptions) -> Self {
        assert!(!pipelines.is_empty(), "cluster needs at least one shard");
        assert!(
            opts.shard.deadline_slack > 0.0 && opts.shard.deadline_slack <= 1.0,
            "deadline_slack must be in (0, 1], got {}",
            opts.shard.deadline_slack
        );
        // One source of truth for the shard count.
        opts.shards = pipelines.len();
        let shards: Vec<ExecutorShard> = pipelines
            .into_iter()
            .enumerate()
            .map(|(i, p)| ExecutorShard::from_pipeline(i, p, &opts.shard))
            .collect();
        let admission = Admission::new(
            shards[0].model.clone(),
            opts.shard.min_gain,
            opts.shard.overhead_s,
            opts.shard.gate_capacity,
        );
        Cluster {
            shards,
            admission,
            opts,
            events: BinaryHeap::new(),
            seq: 0,
            clock: 0.0,
            served: Vec::new(),
            next_id: 0,
        }
    }

    /// Current virtual service time (the latest processed event).
    pub fn now(&self) -> f64 {
        self.clock
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard accessor (diagnostics/tests).
    pub fn shard(&self, i: usize) -> &ExecutorShard {
        &self.shards[i]
    }

    /// The admission component (diagnostics/tests).
    pub fn admission(&self) -> &Admission {
        &self.admission
    }

    /// Requests not yet dispatched: queued on shards or still in the
    /// arrival event stream.
    pub fn pending(&self) -> usize {
        let queued: usize = self.shards.iter().map(|s| s.pending()).sum();
        let in_flight = self
            .events
            .iter()
            .filter(|r| matches!(r.0.kind, EventKind::Arrival(_)))
            .count();
        queued + in_flight
    }

    /// Requests completed so far.
    pub fn completed(&self) -> usize {
        self.served.len()
    }

    /// Submit a [`QosClass::Standard`] request with no SLO arriving at
    /// the current virtual time; returns its id.
    pub fn submit(&mut self, size: GemmSize, reps: u32) -> u64 {
        let id = self.next_id;
        self.submit_request(GemmRequest::new(id, size, reps));
        id
    }

    /// Submit a request under `class` with an optional sojourn SLO,
    /// arriving at the current virtual time; returns its id.
    pub fn submit_qos(
        &mut self,
        size: GemmSize,
        reps: u32,
        class: QosClass,
        deadline_s: Option<f64>,
    ) -> u64 {
        let id = self.next_id;
        let mut req = GemmRequest::new(id, size, reps).with_class(class);
        req.deadline_s = deadline_s;
        self.submit_request(req);
        id
    }

    /// Submit a caller-identified request arriving at the current
    /// virtual time.
    pub fn submit_request(&mut self, req: GemmRequest) {
        self.submit_request_at(self.clock, req);
    }

    /// Submit a caller-identified request arriving at virtual time `at`
    /// (clamped to the present — the past is already simulated).
    pub fn submit_request_at(&mut self, at: f64, req: GemmRequest) {
        self.next_id = self.next_id.max(req.id + 1);
        self.push_event(at.max(self.clock), EventKind::Arrival(req));
    }

    /// Schedule a whole arrival trace (see [`super::arrivals`]);
    /// returns the assigned request ids in trace order.
    pub fn submit_trace(&mut self, trace: &[Arrival]) -> Vec<u64> {
        trace
            .iter()
            .map(|a| {
                let id = self.next_id;
                self.submit_request_at(a.at, GemmRequest {
                    id,
                    size: a.size,
                    reps: a.reps,
                    class: a.class,
                    deadline_s: a.deadline_s,
                });
                id
            })
            .collect()
    }

    fn push_event(&mut self, time: f64, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.events.push(Reverse(Event { time, seq, kind }));
    }

    /// Route an admitted request to the shard with the earliest
    /// class-weighted predicted finish (ties: lowest shard index).
    /// Returns `(shard, predicted finish)` so deadline admission can
    /// reuse the sojourn estimate without recomputing it.
    fn route(&self, now: f64, predicted_s: f64, class: QosClass) -> (usize, f64) {
        let mut best = 0usize;
        let mut best_t = f64::INFINITY;
        for (i, sh) in self.shards.iter().enumerate() {
            let t = sh.predicted_finish_for(now, predicted_s, class);
            if t < best_t {
                best_t = t;
                best = i;
            }
        }
        (best, best_t)
    }

    /// The shard with the largest class-weighted backlog other than
    /// `thief` (ties: lowest index), if any has queued work to give up.
    /// Weighting by class makes stealing relieve the queue whose
    /// waiting work is most latency-sensitive, not merely the longest.
    fn steal_victim(&self, thief: usize) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, sh) in self.shards.iter().enumerate() {
            if i == thief || sh.pending() == 0 {
                continue;
            }
            match best {
                None => best = Some(i),
                Some(b) => {
                    if sh.weighted_backlog() > self.shards[b].weighted_backlog() {
                        best = Some(i);
                    }
                }
            }
        }
        best
    }

    /// Record an admission denial: the request completes immediately as
    /// [`ExecMode::Denied`], consuming no machine time on any shard.
    /// Shares are empty — a denial never touched a machine, and shards
    /// of a heterogeneous cluster disagree on the device count anyway.
    fn deny(&mut self, now: f64, req: GemmRequest, predicted_s: f64) {
        self.served.push(ServedRequest {
            id: req.id,
            size: req.size,
            reps: req.reps,
            class: req.class,
            deadline_s: req.deadline_s,
            mode: ExecMode::Denied,
            arrival: now,
            start: now,
            finish: now,
            exec_s: 0.0,
            predicted_s,
            cache_hit: false,
            shares: Vec::new(),
        });
    }

    fn dispatch_on(&mut self, s: usize, at: f64) {
        let start = self.shards[s].free_at().max(at);
        if let Some(res) = self.shards[s].dispatch_next(start, &mut self.served) {
            if res.replanned {
                // A shard observed drift and refreshed its model: the
                // front-end gate adopts it so future admissions (and
                // their memoized verdicts) track the live machine.
                let model = self.shards[s].model.clone();
                self.admission.refresh(model);
            }
            self.push_event(res.finish, EventKind::ShardFree(s));
        }
    }

    /// Process the earliest pending event. Returns `false` when the
    /// event heap is empty (every submitted request has completed).
    pub fn step_event(&mut self) -> bool {
        let Some(Reverse(ev)) = self.events.pop() else {
            return false;
        };
        self.clock = self.clock.max(ev.time);
        match ev.kind {
            EventKind::Arrival(mut req) => {
                let (co_execute, best_device, predicted_s) =
                    self.admission.admit(req.size, req.reps);
                let (mut target, finish) = self.route(ev.time, predicted_s, req.class);
                // Deadline admission: an SLO predicted infeasible —
                // machine-level (the deadline-constrained LP / service
                // prediction) or queueing-level (the routed shard's
                // predicted sojourn, within the slack guard band) — is
                // turned away (or demoted, per policy) *now*, before it
                // consumes queue space it cannot use.
                if let Some(deadline_s) = req.deadline_s {
                    let feasible = self.admission.deadline_feasible(
                        co_execute,
                        predicted_s,
                        req.size,
                        req.reps,
                        deadline_s,
                    ) && finish - ev.time
                        <= self.opts.shard.deadline_slack * deadline_s;
                    if !feasible {
                        match self.opts.shard.deadline_policy {
                            DeadlinePolicy::Reject => {
                                self.deny(ev.time, req, predicted_s);
                                return true;
                            }
                            DeadlinePolicy::Downclass => {
                                // Best-effort from here on: the SLO is
                                // given up, not silently missed — and
                                // the route is recomputed for the new
                                // class.
                                req.class = QosClass::Batch;
                                req.deadline_s = None;
                                target = self.route(ev.time, predicted_s, req.class).0;
                            }
                        }
                    }
                }
                self.shards[target].enqueue(QueuedRequest {
                    req,
                    arrival: ev.time,
                    co_execute,
                    best_device,
                    predicted_s,
                });
                // Defer the dispatch behind simultaneous arrivals so
                // queue policies and the bypass see the whole burst.
                self.push_event(ev.time, EventKind::Wake(target));
            }
            EventKind::Wake(s) => {
                if self.shards[s].free_at() <= ev.time && self.shards[s].pending() > 0 {
                    self.dispatch_on(s, ev.time);
                }
            }
            EventKind::ShardFree(s) => {
                if self.shards[s].pending() > 0 {
                    self.dispatch_on(s, ev.time);
                } else if self.opts.work_stealing {
                    if let Some(victim) = self.steal_victim(s) {
                        if let Some(q) = self.shards[victim].yield_next() {
                            self.shards[s].note_steal();
                            self.shards[s].enqueue(q);
                            self.dispatch_on(s, ev.time);
                        }
                    }
                }
            }
        }
        true
    }

    /// Drain every event (arrivals included) and return the session
    /// report.
    pub fn run_to_completion(&mut self) -> ServiceReport {
        while self.step_event() {}
        self.report()
    }

    /// Snapshot the session statistics, aggregated across shards.
    pub fn report(&self) -> ServiceReport {
        let mut report = ServiceReport {
            served: self.served.clone(),
            makespan: self.clock,
            cache_hits: 0,
            cache_misses: 0,
            epoch_bumps: 0,
            replans: 0,
            shards: self.shards.iter().map(|s| s.stats()).collect(),
        };
        for s in &self.shards {
            report.cache_hits += s.cache.hits;
            report.cache_misses += s.cache.misses;
            report.epoch_bumps += s.cache.invalidations;
            report.replans += s.replans();
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::service::request::ExecMode;

    fn big() -> GemmSize {
        GemmSize::square(20_000)
    }

    #[test]
    fn one_shard_cluster_serves_like_a_server() {
        let mut c = Cluster::new(&presets::mach2(), 0, ClusterOptions::default());
        assert_eq!(c.num_shards(), 1);
        let b = c.submit(big(), 3);
        let s = c.submit(GemmSize::square(300), 3);
        assert_eq!(c.pending(), 2);
        let report = c.run_to_completion();
        assert_eq!(report.served.len(), 2);
        assert_eq!(c.pending(), 0);
        assert_eq!(c.completed(), 2);
        assert_eq!(report.request(b).unwrap().mode, ExecMode::CoExec);
        assert!(matches!(
            report.request(s).unwrap().mode,
            ExecMode::Standalone { .. }
        ));
        assert_eq!(report.shards.len(), 1);
        assert_eq!(report.shards[0].dispatches, 2);
        assert!(report.shards[0].busy_s > 0.0);
    }

    #[test]
    fn burst_arrivals_are_admitted_before_any_dispatch() {
        // Under SPJF, the shortest of a simultaneous burst must
        // dispatch first even though it was submitted last — i.e. the
        // wake ran after the whole burst was admitted.
        let opts = ClusterOptions {
            shard: ServerOptions {
                policy: crate::service::QueuePolicy::Spjf,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut c = Cluster::new(&presets::mach2(), 1, opts);
        let slow = c.submit(GemmSize::square(24_000), 3);
        let fast = c.submit(GemmSize::square(16_000), 3);
        let report = c.run_to_completion();
        let r_slow = report.request(slow).unwrap();
        let r_fast = report.request(fast).unwrap();
        assert!(r_fast.start < r_slow.start, "SPJF saw the whole burst");
    }

    #[test]
    fn two_shards_split_a_burst_across_machines() {
        let opts = ClusterOptions {
            shards: 2,
            ..Default::default()
        };
        let mut c = Cluster::new(&presets::mach2(), 0, opts);
        for _ in 0..4 {
            c.submit(big(), 2);
        }
        let report = c.run_to_completion();
        assert_eq!(report.served.len(), 4);
        assert_eq!(report.shards.len(), 2);
        // Earliest-predicted-finish routing load-balances a uniform
        // burst: both machines worked.
        assert!(report.shards[0].dispatches > 0);
        assert!(report.shards[1].dispatches > 0);
        // Two concurrent machines overlap execution: the session ends
        // before the serialized sum of both shards' busy time.
        let total_busy: f64 = report.shards.iter().map(|s| s.busy_s).sum();
        assert!(report.makespan < total_busy);
    }

    /// Steal trigger: routing trusts admission-time predictions, so an
    /// inversion between predicted and actual finish order is what
    /// leaves work queued on a busy shard while another goes idle.
    /// mach1's thermal throttling makes a sustained 50-rep job overrun
    /// its (cold-profile) prediction by ~10%, while short 3-rep jobs
    /// run as predicted — a deterministic inversion:
    ///
    /// * shard 0 gets the 50-rep job (pred 50p) plus, once shard 1's
    ///   backlog passes it, one 3-rep job queued behind (at 53p vs 54p);
    /// * shard 1 gets seventeen 3-rep jobs (51p of backlog) and frees at
    ///   ~51p — while the throttled long job still runs until ~55p.
    fn steal_scenario(stealing: bool) -> ServiceReport {
        let opts = ClusterOptions {
            shards: 2,
            work_stealing: stealing,
            ..Default::default()
        };
        let mut c = Cluster::new(&presets::mach1(), 5, opts);
        c.submit(big(), 50);
        for _ in 0..18 {
            c.submit(big(), 3);
        }
        c.run_to_completion()
    }

    #[test]
    fn idle_shard_steals_work_queued_behind_an_overrunning_job() {
        let report = steal_scenario(true);
        assert_eq!(report.served.len(), 19);
        let stolen: usize = report.shards.iter().map(|s| s.stolen).sum();
        assert!(stolen >= 1, "no work was stolen: {:?}", report.shards);
        // Every request still served exactly once.
        let mut ids: Vec<u64> = report.served.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        let expect: Vec<u64> = (0..19).collect();
        assert_eq!(ids, expect);
    }

    #[test]
    fn impossible_slo_is_denied_under_reject_policy() {
        let mut c = Cluster::new(&presets::mach2(), 0, ClusterOptions::default());
        // A deadline tighter than any split can run: denied at arrival.
        let doomed = c.submit_qos(big(), 3, QosClass::Interactive, Some(1e-9));
        // A deadline-free neighbour is untouched.
        let ok = c.submit(big(), 3);
        let report = c.run_to_completion();
        assert_eq!(report.served.len(), 2);
        let r = report.request(doomed).unwrap();
        assert_eq!(r.mode, ExecMode::Denied);
        assert_eq!(r.exec_s, 0.0);
        assert_eq!(r.finish, r.arrival, "denial consumes no time");
        assert_eq!(report.denied(), 1);
        assert_eq!(report.request(ok).unwrap().mode, ExecMode::CoExec);
        // The denial never reached a shard.
        assert_eq!(report.shards[0].dispatches, 1);
        // Aggregates describe only the executed request.
        assert_eq!(report.latencies().len(), 1);
    }

    #[test]
    fn impossible_slo_is_demoted_under_downclass_policy() {
        let opts = ClusterOptions {
            shard: ServerOptions {
                deadline_policy: crate::service::DeadlinePolicy::Downclass,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut c = Cluster::new(&presets::mach2(), 0, opts);
        let demoted = c.submit_qos(big(), 3, QosClass::Interactive, Some(1e-9));
        let report = c.run_to_completion();
        let r = report.request(demoted).unwrap();
        // Served — as best-effort batch with the SLO stripped.
        assert_eq!(r.mode, ExecMode::CoExec);
        assert_eq!(r.class, QosClass::Batch);
        assert_eq!(r.deadline_s, None);
        assert_eq!(report.denied(), 0);
        assert_eq!(r.deadline_met(), None, "stripped SLO is not a miss");
    }

    #[test]
    fn generous_slo_is_admitted_and_met() {
        let mut c = Cluster::new(&presets::mach2(), 3, ClusterOptions::default());
        let id = c.submit_qos(big(), 2, QosClass::Interactive, Some(1e6));
        let report = c.run_to_completion();
        let r = report.request(id).unwrap();
        assert_eq!(r.mode, ExecMode::CoExec);
        assert_eq!(r.class, QosClass::Interactive);
        assert_eq!(r.deadline_met(), Some(true));
        assert!((report.deadline_hit_rate() - 1.0).abs() < 1e-12);
        assert_eq!(report.shards[0].served_by_class, [1, 0, 0]);
    }

    #[test]
    fn weighted_drain_prefers_interactive_over_batch_backlog() {
        // One shard, a simultaneous burst: 2 batch + 1 interactive.
        // The interactive request must start before the second batch
        // request despite arriving last.
        let mut c = Cluster::new(&presets::mach2(), 4, ClusterOptions::default());
        let b0 = c.submit_qos(big(), 2, QosClass::Batch, None);
        let b1 = c.submit_qos(big(), 2, QosClass::Batch, None);
        let i0 = c.submit_qos(big(), 2, QosClass::Interactive, None);
        let report = c.run_to_completion();
        let start = |id| report.request(id).unwrap().start;
        // The weighted pick credits interactive 4:1, so it dispatches
        // first even though both batch requests were admitted ahead of
        // it in the same burst.
        assert!(start(i0) < start(b0), "interactive jumped the batch queue");
        assert!(start(i0) < start(b1));
        assert_eq!(
            report.shards[0].served_by_class,
            [1, 0, 2],
            "per-class attribution"
        );
    }

    #[test]
    fn work_stealing_can_be_disabled() {
        let with = steal_scenario(true);
        let without = steal_scenario(false);
        assert!(without.shards.iter().all(|s| s.stolen == 0));
        assert_eq!(with.served.len(), without.served.len());
        // Stealing starts the stranded request earlier than waiting for
        // the overrunning job would have.
        let waits_with = with.mean_queue_wait();
        let waits_without = without.mean_queue_wait();
        assert!(
            waits_with <= waits_without + 1e-9,
            "stealing must not increase mean queueing delay: {waits_with} vs {waits_without}"
        );
    }
}
