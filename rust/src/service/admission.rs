//! Admission control: the §6 suitability gate as a front-end component.
//!
//! Every request entering the cluster passes the gate exactly once: the
//! fitted performance model predicts the co-execution makespan and the
//! best standalone device, and the verdict plus the per-repetition
//! service prediction are recorded on the [`super::QueuedRequest`] so
//! queue policies and the routing front-end never re-run the optimizer.
//!
//! The gate's own LP solve is as cacheable as the plan solve, so
//! verdicts are memoized by `(shape, epoch)` in a **bounded LRU**: a
//! lookup refreshes its entry's recency and eviction removes the least
//! recently used key, so a hot working set survives arbitrarily many
//! cold shapes streaming past (a wholesale `clear()` at capacity would
//! discard it). A model refresh (dynamic-scheduler replan on any shard)
//! bumps the epoch, which retires every memoized verdict at once.

use crate::predict::PerfModel;
use crate::schedule::suitability::{recommend, Recommendation};
use crate::workload::GemmSize;
use std::collections::{HashMap, VecDeque};

/// One memoized gate verdict: (co-execute?, best single device,
/// predicted seconds per repetition under the verdict).
pub type GateVerdict = (bool, usize, f64);

/// The admission component: suitability gate + bounded-LRU memo.
#[derive(Debug, Clone)]
pub struct Admission {
    /// The front-end's view of machine performance (refreshed when a
    /// shard's dynamic scheduler re-plans).
    model: PerfModel,
    epoch: u64,
    min_gain: f64,
    overhead_s: f64,
    memo: HashMap<(GemmSize, u64), GateVerdict>,
    /// Recency order: front = least recently used, back = most.
    recency: VecDeque<(GemmSize, u64)>,
    capacity: usize,
    /// Gate lookups answered from the memo.
    pub hits: u64,
    /// Gate lookups that had to solve.
    pub misses: u64,
}

impl Admission {
    /// New gate over `model`: require `min_gain` predicted speedup for
    /// co-execution, charge it `overhead_s` scheduling overhead, and
    /// memoize at most `capacity` verdicts (min 1).
    pub fn new(model: PerfModel, min_gain: f64, overhead_s: f64, capacity: usize) -> Self {
        Admission {
            model,
            epoch: 0,
            min_gain,
            overhead_s,
            memo: HashMap::new(),
            recency: VecDeque::new(),
            capacity: capacity.max(1),
            hits: 0,
            misses: 0,
        }
    }

    /// The current model epoch (bumped on every [`Admission::refresh`]).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Number of memoized verdicts.
    pub fn len(&self) -> usize {
        self.memo.len()
    }

    /// True when nothing is memoized.
    pub fn is_empty(&self) -> bool {
        self.memo.is_empty()
    }

    /// The model the gate currently predicts with.
    pub fn model(&self) -> &PerfModel {
        &self.model
    }

    /// Gate one request: returns (co-execute?, best single device,
    /// predicted **total** service seconds for all `reps`).
    pub fn admit(&mut self, size: GemmSize, reps: u32) -> (bool, usize, f64) {
        let key = (size, self.epoch);
        let (co_execute, device, t_rep) = match self.memo.get(&key) {
            Some(&hit) => {
                self.hits += 1;
                self.touch(key);
                hit
            }
            None => {
                self.misses += 1;
                let fresh = match recommend(&self.model, size, self.min_gain, self.overhead_s) {
                    Recommendation::CoExecute {
                        t_coexec,
                        best_device,
                        ..
                    } => (true, best_device, t_coexec),
                    Recommendation::Standalone {
                        device, t_single, ..
                    } => (false, device, t_single),
                };
                self.insert(key, fresh);
                fresh
            }
        };
        (co_execute, device, t_rep * reps.max(1) as f64)
    }

    /// The model changed (a shard's dynamic scheduler re-planned):
    /// adopt the refreshed model and retire every memoized verdict.
    pub fn refresh(&mut self, model: PerfModel) {
        self.model = model;
        self.epoch += 1;
        // Old-epoch entries can never be read again (the key carries
        // the epoch); drop them eagerly rather than waiting for LRU
        // pressure.
        self.memo.clear();
        self.recency.clear();
    }

    fn touch(&mut self, key: (GemmSize, u64)) {
        if let Some(pos) = self.recency.iter().position(|k| *k == key) {
            self.recency.remove(pos);
            self.recency.push_back(key);
        }
    }

    fn insert(&mut self, key: (GemmSize, u64), verdict: GateVerdict) {
        if self.memo.insert(key, verdict).is_none() {
            self.recency.push_back(key);
        }
        while self.memo.len() > self.capacity {
            match self.recency.pop_front() {
                Some(old) => {
                    self.memo.remove(&old);
                }
                None => break,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::predict::{profile, ProfileOptions};
    use crate::sim::SimMachine;

    fn model() -> PerfModel {
        let mut sim = SimMachine::new(&presets::mach1(), 0);
        profile(&mut sim, &ProfileOptions::default()).unwrap()
    }

    #[test]
    fn memoizes_and_scales_by_reps() {
        let mut gate = Admission::new(model(), 1.05, 20e-6, 64);
        let size = GemmSize::square(20_000);
        let (co1, dev1, t1) = gate.admit(size, 1);
        let (co2, dev2, t3) = gate.admit(size, 3);
        assert!(co1, "20K is worth co-executing");
        assert_eq!((co1, dev1), (co2, dev2));
        assert!((t3 / t1 - 3.0).abs() < 1e-9, "reps scale the prediction");
        assert_eq!(gate.misses, 1);
        assert_eq!(gate.hits, 1);
        assert_eq!(gate.len(), 1);
    }

    #[test]
    fn small_shapes_stay_standalone() {
        let mut gate = Admission::new(model(), 1.05, 20e-6, 64);
        let (co, _, t) = gate.admit(GemmSize::square(256), 2);
        assert!(!co);
        assert!(t > 0.0);
    }

    #[test]
    fn lru_keeps_the_hot_set_under_cold_pressure() {
        let mut gate = Admission::new(model(), 1.05, 20e-6, 4);
        let hot = GemmSize::square(20_000);
        gate.admit(hot, 1);
        // A stream of cold shapes, with the hot shape touched between
        // each: the touch refreshes recency, so the hot entry must
        // survive while the cold ones evict each other.
        for s in 0..8u64 {
            gate.admit(GemmSize::square(10_000 + 128 * s), 1);
            gate.admit(hot, 1);
        }
        assert!(gate.len() <= 4);
        let misses_before = gate.misses;
        gate.admit(hot, 1);
        assert_eq!(gate.misses, misses_before, "hot entry was evicted");
        assert_eq!(gate.hits, 9);
    }

    #[test]
    fn fifo_style_clear_would_have_lost_the_hot_set() {
        // Regression shape for the old wholesale-clear behaviour: fill
        // far past capacity; the most recently used entries remain.
        let mut gate = Admission::new(model(), 1.05, 20e-6, 4);
        for s in 0..10u64 {
            gate.admit(GemmSize::square(8_000 + 256 * s), 1);
        }
        assert_eq!(gate.len(), 4, "bounded, not cleared to zero");
        let misses_before = gate.misses;
        gate.admit(GemmSize::square(8_000 + 256 * 9), 1);
        assert_eq!(gate.misses, misses_before, "newest entry still memoized");
    }

    #[test]
    fn refresh_bumps_epoch_and_drops_memo() {
        let m = model();
        let mut gate = Admission::new(m.clone(), 1.05, 20e-6, 64);
        gate.admit(GemmSize::square(20_000), 1);
        assert_eq!(gate.len(), 1);
        gate.refresh(m);
        assert_eq!(gate.epoch(), 1);
        assert!(gate.is_empty());
        gate.admit(GemmSize::square(20_000), 1);
        assert_eq!(gate.misses, 2, "post-refresh lookup re-solves");
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut gate = Admission::new(model(), 1.05, 20e-6, 0);
        gate.admit(GemmSize::square(20_000), 1);
        assert_eq!(gate.len(), 1);
        let (_, _, _) = gate.admit(GemmSize::square(20_000), 1);
        assert_eq!(gate.hits, 1);
    }
}
