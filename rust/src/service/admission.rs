//! Admission control: the §6 suitability gate as a front-end component.
//!
//! Since heterogeneous clusters landed there is one `Admission` gate
//! **per shard**, each predicting with *that shard's* installation-time
//! [`PerfModel`]: the fitted model predicts the co-execution makespan
//! and the best standalone device on that machine, and the verdict of
//! the shard a request is finally routed to is recorded on the
//! [`super::QueuedRequest`] so queue policies and dispatch never re-run
//! the optimizer. An arrival is scored against every shard's gate (all
//! memoized), which is exactly what lets the cluster route a large GEMM
//! to a GPU-heavy shard and a tiny one to a CPU-only shard from
//! predictions alone.
//!
//! Since the QoS tiers landed the gate is also the **deadline
//! feasibility oracle**: a deadline-bound co-executable request is
//! probed with the deadline-constrained LP already built for the energy
//! objective ([`crate::optimize::EnergyProblem`] with unit power
//! figures — same constraint rows, `T <= deadline` included), so "can
//! this machine meet the SLO at all?" is answered by the same
//! formulation that plans deadline-bound energy runs. The *queueing*
//! side of the sojourn prediction stays with the cluster front-end,
//! which already computes per-shard backlogs for routing.
//!
//! Since admission-time batching landed the gate also scores **fused
//! batches** ([`Admission::admit_batch`]): a row-stacked batch of small
//! compatible requests is re-scored as one large GEMM, with the
//! scheduling overhead charged per member, under the batch-level memo
//! key `(shape id, reps, members)`.
//!
//! The gate's own LP solve is as cacheable as the plan solve, so
//! verdicts are memoized by `(shape id, reps, members)` in a
//! **bounded LRU**: a lookup refreshes its entry's recency and eviction removes
//! the least recently used key, so a hot working set survives
//! arbitrarily many cold shapes streaming past (a wholesale `clear()`
//! at capacity would discard it). Shapes are **interned** to dense
//! `u32` ids ([`FxHashMap`]-backed), so a hot lookup hashes three
//! machine words instead of rebuilding the full shape tuple per
//! arrival. A model refresh (this shard's dynamic scheduler
//! re-planned) clears both memos eagerly — which is what retires every
//! memoized verdict at once (keys no longer carry the epoch) — and
//! other shards' gates are untouched.

use super::cache::{FxHashMap, LruMap};
use crate::optimize::energy::{DevicePower, EnergyProblem};
use crate::optimize::problem::BusModel;
use crate::optimize::SplitSolution;
use crate::predict::PerfModel;
use crate::schedule::suitability::{recommend, Recommendation};
use crate::workload::GemmSize;

/// One memoized gate verdict: (co-execute?, best single device,
/// predicted **total** service seconds for all repetitions under the
/// verdict).
pub type GateVerdict = (bool, usize, f64);

/// Interned handle for a `GemmSize` this gate has seen: hot memo keys
/// hash three machine words of dense ids instead of rebuilding and
/// hashing the full shape tuple on every lookup. Ids are assigned
/// densely in first-seen order and never reused, so two keys collide
/// iff their shapes are identical.
type ShapeId = u32;

/// Key of a memoized gate verdict: interned shape, repetition count,
/// fused member count (1 for a plain request — a batch of `l` members
/// pays `l` times the scheduling overhead, so its verdict is a distinct
/// memo entry). The model epoch is *not* part of the key:
/// [`Admission::refresh`] clears both memos eagerly, so a stale-epoch
/// entry can never be observed.
type GateKey = (ShapeId, u32, u32);

/// Key of a memoized deadline-feasibility probe: interned shape and the
/// per-rep budget's bit pattern (deadlines are continuous, but SLO
/// streams reuse a handful of values). Epoch-free for the same reason
/// as [`GateKey`].
type DeadlineKey = (ShapeId, u64);

/// The admission component: suitability gate + bounded-LRU memo.
#[derive(Debug, Clone)]
pub struct Admission {
    /// This gate's view of its shard's performance (refreshed when the
    /// shard's dynamic scheduler re-plans).
    model: PerfModel,
    epoch: u64,
    min_gain: f64,
    overhead_s: f64,
    /// Dense [`ShapeId`] per distinct `GemmSize` seen. Kept across
    /// [`Admission::refresh`] (ids stay stable, memos are cleared
    /// anyway) and grows with the number of *distinct* shapes, which a
    /// serving menu keeps small.
    shapes: FxHashMap<GemmSize, ShapeId>,
    /// Gate-verdict memo (bounded, touch-on-hit LRU) keyed
    /// `(shape id, reps, members)`.
    memo: LruMap<GateKey, GateVerdict>,
    /// Deadline-feasibility memo: `(shape id, per-rep deadline bits)`
    /// → can any split meet it? Same bounded-LRU discipline as the
    /// gate memo, so an SLO-bound stream over a stable menu never
    /// re-solves the deadline LP per arrival.
    deadline_memo: LruMap<DeadlineKey, bool>,
    /// Gate lookups answered from the memo.
    pub hits: u64,
    /// Gate lookups that had to solve.
    pub misses: u64,
    /// Deadline-feasibility probes that had to solve the LP (memo
    /// misses of the deadline memo).
    pub deadline_lp_solves: u64,
}

impl Admission {
    /// New gate over `model`: require `min_gain` predicted speedup for
    /// co-execution, charge it `overhead_s` scheduling overhead, and
    /// memoize at most `capacity` verdicts (min 1).
    pub fn new(model: PerfModel, min_gain: f64, overhead_s: f64, capacity: usize) -> Self {
        Admission {
            model,
            epoch: 0,
            min_gain,
            overhead_s,
            shapes: FxHashMap::default(),
            memo: LruMap::new(capacity),
            deadline_memo: LruMap::new(capacity),
            hits: 0,
            misses: 0,
            deadline_lp_solves: 0,
        }
    }

    /// The interned id for `size`, assigning the next dense id on first
    /// sight. O(1) amortized; the hot path pays one small Fx hash of
    /// the shape instead of carrying the full tuple into every memo key.
    fn shape_id(&mut self, size: GemmSize) -> ShapeId {
        if let Some(&id) = self.shapes.get(&size) {
            return id;
        }
        let id = u32::try_from(self.shapes.len()).expect("more than u32::MAX distinct shapes");
        self.shapes.insert(size, id);
        id
    }

    /// The current model epoch (bumped on every [`Admission::refresh`]).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Number of memoized verdicts.
    pub fn len(&self) -> usize {
        self.memo.len()
    }

    /// True when nothing is memoized.
    pub fn is_empty(&self) -> bool {
        self.memo.is_empty()
    }

    /// The model the gate currently predicts with.
    pub fn model(&self) -> &PerfModel {
        &self.model
    }

    /// Gate one request: returns (co-execute?, best single device,
    /// predicted **total** service seconds for all `reps`). Memoized by
    /// `(shape id, reps, 1)`, so an SLO-free stream over a stable
    /// `(shape, reps)` menu solves each entry once per epoch.
    pub fn admit(&mut self, size: GemmSize, reps: u32) -> GateVerdict {
        self.admit_batch(size, reps, 1)
    }

    /// Gate a **fused batch**: `members` compatible small requests
    /// row-stacked into one `size` (see [`super::batch`]). The verdict
    /// has the same shape as [`Admission::admit`] — the batch is
    /// re-scored as if it were one large GEMM, so a batch that passes
    /// suitability is split across devices like any large GEMM — but
    /// the scheduling overhead is charged once per member (each member
    /// still pays its admission bookkeeping). Memoized under the
    /// batch-level key `(shape id, reps, members)`, so a steady
    /// stream of same-composition batches solves once per epoch.
    pub fn admit_batch(&mut self, size: GemmSize, reps: u32, members: u32) -> GateVerdict {
        let members = members.max(1);
        let key = (self.shape_id(size), reps, members);
        match self.memo.get_touch(&key) {
            Some(&hit) => {
                self.hits += 1;
                hit
            }
            None => {
                self.misses += 1;
                let scale = reps.max(1) as f64;
                let overhead = self.overhead_s * members as f64;
                let fresh = match recommend(&self.model, size, self.min_gain, overhead) {
                    Recommendation::CoExecute {
                        t_coexec,
                        best_device,
                        ..
                    } => (true, best_device, t_coexec * scale),
                    Recommendation::Standalone {
                        device, t_single, ..
                    } => (false, device, t_single * scale),
                };
                self.memo.insert(key, fresh);
                fresh
            }
        }
    }

    /// Solve the deadline-constrained split for `size`: the energy
    /// formulation with unit active power and zero idle power, so the
    /// objective degenerates to "least active device-seconds meeting
    /// `T <= deadline_per_rep`". `Err` means no split of this machine
    /// can meet the per-repetition budget — the SLO is infeasible even
    /// on an empty queue.
    pub fn deadline_plan(
        &self,
        size: GemmSize,
        deadline_per_rep: f64,
    ) -> crate::error::Result<(SplitSolution, f64)> {
        let devices = self.model.model_inputs();
        let unit = DevicePower {
            active_w: 1.0,
            idle_w: 0.0,
        };
        let power = vec![unit; devices.len()];
        EnergyProblem {
            devices,
            power,
            size,
            bus: BusModel::SharedPriority,
            deadline_s: Some(deadline_per_rep),
        }
        .solve()
    }

    /// Machine-level SLO feasibility for an already-gated request: can
    /// this machine finish `reps` repetitions within `deadline_s`
    /// *ignoring queueing*? Co-executable requests are probed with the
    /// deadline-constrained LP ([`Admission::deadline_plan`]), memoized
    /// by `(shape id, per-rep budget)` so a steady SLO stream never
    /// re-solves per arrival; standalone-bound requests simply compare
    /// their predicted service time. Queueing is the front-end's half
    /// of the verdict (it owns the per-shard backlogs).
    pub fn deadline_feasible(
        &mut self,
        co_execute: bool,
        predicted_s: f64,
        size: GemmSize,
        reps: u32,
        deadline_s: f64,
    ) -> bool {
        if deadline_s <= 0.0 {
            return false;
        }
        if !co_execute {
            return predicted_s <= deadline_s;
        }
        let per_rep = deadline_s / reps.max(1) as f64;
        let key = (self.shape_id(size), per_rep.to_bits());
        if let Some(&feasible) = self.deadline_memo.get_touch(&key) {
            return feasible;
        }
        self.deadline_lp_solves += 1;
        let feasible = self.deadline_plan(size, per_rep).is_ok();
        self.deadline_memo.insert(key, feasible);
        feasible
    }

    /// The model changed (a shard's dynamic scheduler re-planned):
    /// adopt the refreshed model and retire every memoized verdict.
    /// Memo keys do not carry the epoch, so the eager clear here is
    /// what makes stale verdicts unobservable; the epoch counter
    /// remains as a diagnostic. The shape interner survives the
    /// refresh — ids name shapes, not verdicts.
    pub fn refresh(&mut self, model: PerfModel) {
        self.model = model;
        self.epoch += 1;
        self.memo.clear();
        self.deadline_memo.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::predict::{profile, ProfileOptions};
    use crate::sim::SimMachine;

    fn model() -> PerfModel {
        let mut sim = SimMachine::new(&presets::mach1(), 0);
        profile(&mut sim, &ProfileOptions::default()).unwrap()
    }

    #[test]
    fn memoizes_by_shape_and_reps_and_scales_linearly() {
        let mut gate = Admission::new(model(), 1.05, 20e-6, 64);
        let size = GemmSize::square(20_000);
        let (co1, dev1, t1) = gate.admit(size, 1);
        // A different repetition count is a different memo entry...
        let (co2, dev2, t3) = gate.admit(size, 3);
        assert!(co1, "20K is worth co-executing");
        assert_eq!((co1, dev1), (co2, dev2));
        assert!((t3 / t1 - 3.0).abs() < 1e-9, "reps scale the prediction");
        assert_eq!(gate.misses, 2);
        assert_eq!(gate.len(), 2);
        // ...and the same (shape, reps) is answered from the memo.
        let (co3, dev3, t3b) = gate.admit(size, 3);
        assert_eq!((co3, dev3, t3b), (co2, dev2, t3));
        assert_eq!(gate.hits, 1);
        assert_eq!(gate.misses, 2);
    }

    #[test]
    fn cpu_only_shard_always_recommends_standalone() {
        let mut sim = SimMachine::new(&presets::cpu_node(), 0);
        let m = profile(&mut sim, &ProfileOptions::default()).unwrap();
        let mut gate = Admission::new(m, 1.05, 20e-6, 16);
        let (co, dev, t) = gate.admit(GemmSize::square(20_000), 2);
        assert!(!co, "a single device has no co-executors");
        assert_eq!(dev, 0);
        assert!(t > 0.0);
        // Standalone deadline feasibility compares the prediction.
        assert!(gate.deadline_feasible(co, t, GemmSize::square(20_000), 2, t * 2.0));
        assert!(!gate.deadline_feasible(co, t, GemmSize::square(20_000), 2, t * 0.5));
    }

    #[test]
    fn batch_verdicts_are_memoized_under_their_own_key() {
        let mut gate = Admission::new(model(), 1.05, 20e-6, 64);
        let size = GemmSize::square(20_000);
        let plain = gate.admit(size, 2);
        // Same shape gated as an 8-member batch: a distinct memo entry
        // (the overhead charge differs), not a hit on the plain one.
        let batch = gate.admit_batch(size, 2, 8);
        assert_eq!(gate.misses, 2);
        assert_eq!(gate.len(), 2);
        // Both co-execute; the batch's prediction carries 8x overhead,
        // so it can only be >= the plain one.
        assert!(plain.0 && batch.0);
        assert!(batch.2 >= plain.2);
        // Repeats of either key are memo hits.
        assert_eq!(gate.admit_batch(size, 2, 8), batch);
        assert_eq!(gate.admit(size, 2), plain);
        assert_eq!(gate.hits, 2);
        // members = 0 clamps to 1: exactly the plain verdict.
        assert_eq!(gate.admit_batch(size, 2, 0), plain);
        assert_eq!(gate.hits, 3);
    }

    #[test]
    fn small_shapes_stay_standalone() {
        let mut gate = Admission::new(model(), 1.05, 20e-6, 64);
        let (co, _, t) = gate.admit(GemmSize::square(256), 2);
        assert!(!co);
        assert!(t > 0.0);
    }

    #[test]
    fn lru_keeps_the_hot_set_under_cold_pressure() {
        let mut gate = Admission::new(model(), 1.05, 20e-6, 4);
        let hot = GemmSize::square(20_000);
        gate.admit(hot, 1);
        // A stream of cold shapes, with the hot shape touched between
        // each: the touch refreshes recency, so the hot entry must
        // survive while the cold ones evict each other.
        for s in 0..8u64 {
            gate.admit(GemmSize::square(10_000 + 128 * s), 1);
            gate.admit(hot, 1);
        }
        assert!(gate.len() <= 4);
        let misses_before = gate.misses;
        gate.admit(hot, 1);
        assert_eq!(gate.misses, misses_before, "hot entry was evicted");
        assert_eq!(gate.hits, 9);
    }

    #[test]
    fn fifo_style_clear_would_have_lost_the_hot_set() {
        // Regression shape for the old wholesale-clear behaviour: fill
        // far past capacity; the most recently used entries remain.
        let mut gate = Admission::new(model(), 1.05, 20e-6, 4);
        for s in 0..10u64 {
            gate.admit(GemmSize::square(8_000 + 256 * s), 1);
        }
        assert_eq!(gate.len(), 4, "bounded, not cleared to zero");
        let misses_before = gate.misses;
        gate.admit(GemmSize::square(8_000 + 256 * 9), 1);
        assert_eq!(gate.misses, misses_before, "newest entry still memoized");
    }

    #[test]
    fn refresh_bumps_epoch_and_drops_memo() {
        let m = model();
        let mut gate = Admission::new(m.clone(), 1.05, 20e-6, 64);
        gate.admit(GemmSize::square(20_000), 1);
        assert_eq!(gate.len(), 1);
        gate.refresh(m);
        assert_eq!(gate.epoch(), 1);
        assert!(gate.is_empty());
        gate.admit(GemmSize::square(20_000), 1);
        assert_eq!(gate.misses, 2, "post-refresh lookup re-solves");
    }

    #[test]
    fn deadline_plan_reuses_the_energy_lp_constraint() {
        let gate = Admission::new(model(), 1.05, 20e-6, 64);
        let size = GemmSize::square(20_000);
        // A generous per-rep budget is feasible and respects the cap.
        let (sol, _) = gate.deadline_plan(size, 10.0).unwrap();
        assert!(sol.t_pred <= 10.0 + 1e-9);
        // An impossible budget is infeasible.
        assert!(gate.deadline_plan(size, 1e-9).is_err());
    }

    #[test]
    fn deadline_feasibility_splits_by_verdict() {
        let mut gate = Admission::new(model(), 1.05, 20e-6, 64);
        let big = GemmSize::square(20_000);
        let (co, _, predicted_s) = gate.admit(big, 2);
        assert!(co);
        // Far above the predicted service time: feasible.
        assert!(gate.deadline_feasible(co, predicted_s, big, 2, predicted_s * 10.0));
        // Tighter than any split can run: infeasible.
        assert!(!gate.deadline_feasible(co, predicted_s, big, 2, predicted_s * 1e-4));
        // Standalone verdicts compare the predicted service time.
        let small = GemmSize::square(256);
        let (co_s, _, t_small) = gate.admit(small, 2);
        assert!(!co_s);
        assert!(gate.deadline_feasible(co_s, t_small, small, 2, t_small * 2.0));
        assert!(!gate.deadline_feasible(co_s, t_small, small, 2, t_small * 0.5));
        // Nonsense budgets are never feasible.
        assert!(!gate.deadline_feasible(co, predicted_s, big, 2, 0.0));
    }

    #[test]
    fn deadline_probes_are_memoized_per_shape_and_budget() {
        let mut gate = Admission::new(model(), 1.05, 20e-6, 64);
        let big = GemmSize::square(20_000);
        let (co, _, predicted_s) = gate.admit(big, 2);
        let budget = predicted_s * 10.0;
        assert!(gate.deadline_feasible(co, predicted_s, big, 2, budget));
        assert_eq!(gate.deadline_lp_solves, 1);
        // Same (shape, budget): answered from the memo, no new solve.
        for _ in 0..5 {
            assert!(gate.deadline_feasible(co, predicted_s, big, 2, budget));
        }
        assert_eq!(gate.deadline_lp_solves, 1);
        // A different budget is a different probe.
        assert!(!gate.deadline_feasible(co, predicted_s, big, 2, budget * 1e-5));
        assert_eq!(gate.deadline_lp_solves, 2);
        // A model refresh retires the memo: the next probe re-solves.
        let m = gate.model().clone();
        gate.refresh(m);
        assert!(gate.deadline_feasible(co, predicted_s, big, 2, budget));
        assert_eq!(gate.deadline_lp_solves, 3);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut gate = Admission::new(model(), 1.05, 20e-6, 0);
        gate.admit(GemmSize::square(20_000), 1);
        assert_eq!(gate.len(), 1);
        let (_, _, _) = gate.admit(GemmSize::square(20_000), 1);
        assert_eq!(gate.hits, 1);
    }

    #[test]
    fn shape_ids_are_dense_stable_and_survive_refresh() {
        let mut gate = Admission::new(model(), 1.05, 20e-6, 64);
        let a = GemmSize::square(10_000);
        let b = GemmSize::square(12_000);
        assert_eq!(gate.shape_id(a), 0);
        assert_eq!(gate.shape_id(b), 1);
        assert_eq!(gate.shape_id(a), 0, "interning is stable");
        let m = gate.model().clone();
        gate.refresh(m);
        assert_eq!(gate.shape_id(b), 1, "ids survive a model refresh");
        assert_eq!(gate.shape_id(GemmSize::square(14_000)), 2);
    }
}
