//! Stable JSON digests of a [`ServiceReport`].
//!
//! The digest is the regression surface of the scenario corpus: one
//! compact JSON object per scenario capturing counts, per-class tail
//! latencies, deadline accounting, placement quality and per-shard
//! activity. Everything is emitted in a fixed key order with floats
//! rounded to six decimals, so two runs of the same binary on the same
//! scenario produce byte-identical strings and CI can diff the
//! runner's output against the blessed `ci/scenario_digests.json`.

use crate::service::qos::QosClass;
use crate::service::request::ServiceReport;

/// A float as a JSON token: fixed six-decimal form, with non-finite
/// values (empty percentiles, 0/0 rates) mapped to `null` so the
/// output stays valid JSON.
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".to_string()
    }
}

/// Fold a report into its one-line JSON digest (fixed key order,
/// deterministic for a deterministic report).
pub fn digest(report: &ServiceReport) -> String {
    let executed = report
        .served
        .iter()
        .filter(|r| !r.mode.is_unserved())
        .count();
    let mut out = String::with_capacity(1024);
    out.push('{');
    out.push_str(&format!("\"served\":{}", report.served.len()));
    out.push_str(&format!(",\"executed\":{executed}"));
    out.push_str(&format!(",\"denied\":{}", report.denied));
    out.push_str(&format!(",\"rejected\":{}", report.rejected));
    out.push_str(&format!(",\"requeued\":{}", report.requeued));
    out.push_str(&format!(",\"fused\":{}", report.fused()));
    out.push_str(&format!(",\"batches\":{}", report.num_batches()));
    out.push_str(&format!(",\"bypassed\":{}", report.bypassed()));
    out.push_str(&format!(",\"fusion_rate\":{}", num(report.fusion_rate())));
    out.push_str(&format!(
        ",\"deadline_hit_rate\":{}",
        num(report.deadline_hit_rate())
    ));
    out.push_str(&format!(
        ",\"placement_quality\":{}",
        num(report.placement_quality())
    ));
    out.push_str(&format!(",\"makespan_s\":{}", num(report.makespan)));
    out.push_str(&format!(
        ",\"machine_seconds\":{}",
        num(report.machine_seconds)
    ));
    out.push_str(&format!(",\"utilization\":{}", num(report.utilization())));
    out.push_str(&format!(",\"joules\":{}", num(report.total_joules())));
    out.push_str(&format!(",\"joules_active\":{}", num(report.joules_active)));
    out.push_str(&format!(",\"joules_idle\":{}", num(report.joules_idle)));
    out.push_str(&format!(",\"joules_parked\":{}", num(report.joules_parked)));
    out.push_str(&format!(",\"replans\":{}", report.replans));
    out.push_str(&format!(",\"epoch_bumps\":{}", report.epoch_bumps));

    out.push_str(",\"classes\":{");
    for (i, class) in QosClass::ALL.into_iter().enumerate() {
        let b = report.class_breakdown(class);
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\"{}\":{{\"executed\":{},\"p50_sojourn_s\":{},\"p99_sojourn_s\":{},\
             \"deadline_hits\":{},\"deadline_bound\":{},\"denied\":{},\"rejected\":{},\
             \"joules\":{}}}",
            class.label(),
            b.executed,
            num(b.p50_sojourn),
            num(b.p99_sojourn),
            b.deadline_hits,
            b.deadline_bound,
            b.denied,
            b.rejected,
            num(report.class_joules(class)),
        ));
    }
    out.push('}');

    out.push_str(",\"shards\":[");
    for (i, s) in report.shards.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let served: usize = s.served_by_class.iter().sum();
        out.push_str(&format!(
            "{{\"dispatches\":{},\"served\":{},\"stolen\":{},\"batches\":{},\
             \"rejected\":{},\"requeued\":{},\"busy_s\":{},\"provisioned_s\":{},\
             \"joules_active\":{},\"joules_idle\":{},\"joules_parked\":{}}}",
            s.dispatches,
            served,
            s.stolen,
            s.batches,
            s.rejected,
            s.requeued,
            num(s.busy_s),
            num(s.provisioned_s),
            num(s.joules_active),
            num(s.joules_idle),
            num(s.joules_parked),
        ));
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_report_digest_is_valid_and_stable() {
        let report = ServiceReport::default();
        let d = digest(&report);
        assert_eq!(d, digest(&report), "digest must be deterministic");
        assert!(d.starts_with('{') && d.ends_with('}'));
        // Empty aggregates have defined values (1.0 / 0.0), never NaN.
        assert!(d.contains("\"deadline_hit_rate\":1.000000"));
        assert!(d.contains("\"placement_quality\":1.000000"));
        assert!(!d.contains("NaN"));
        assert!(d.contains("\"served\":0"));
        assert!(d.contains("\"machine_seconds\":0.000000"));
        assert!(d.contains("\"utilization\":0.000000"));
        assert!(d.contains("\"joules\":0.000000"));
        assert!(d.contains("\"joules_parked\":0.000000"));
        assert!(d.contains("\"classes\":{\"interactive\":"));
        assert!(d.contains("\"shards\":[]"));
    }

    #[test]
    fn digest_reflects_a_real_run() {
        let sc: crate::service::scenario::Scenario = r#"
            name = "digesttest"
            seed = 3
            [[shard]]
            preset = "mach1"
            [[arrivals]]
            rate_rps = 20.0
            count = 3
            menu = "256"
        "#
        .parse()
        .unwrap();
        let d = digest(&sc.run());
        assert!(d.contains("\"served\":3"));
        assert!(d.contains("\"requeued\":0"));
        assert_eq!(d, digest(&sc.run()), "same scenario, same digest");
    }
}
