//! Declarative fault-injection scenarios for the serving cluster.
//!
//! A scenario is a TOML file (the same minimal subset
//! [`crate::config::parser`] reads for machine configs) that describes
//! an entire service session in one place:
//!
//! * **the cluster** — `[[shard]]` tables naming node presets from
//!   [`crate::config::presets`] (`mach1`, `mach2`, `gpu_node`,
//!   `cpu_node`, `xpu_node`), plus top-level knobs for queue policy,
//!   work stealing, gate policy, deadline policy and admission-time
//!   batching;
//! * **the offered load** — `[[arrivals]]` streams (deterministic
//!   Poisson, bursty on/off, or scheduled piecewise-Poisson phase
//!   cycles for diurnal day/night profiles, per QoS class, each with a
//!   shape menu and optional SLO) and `[[request]]` entries for
//!   hand-placed arrivals;
//! * **the event schedule** — `[[fault]]` tables injecting shard
//!   crashes and restarts, straggler slowdowns (realized rates drift
//!   away from the fitted model mid-run), load spikes, power-budget
//!   changes (the cluster-wide cap tightens or lifts mid-run), and
//!   membership events — scale-out joins (a new preset machine is
//!   profiled and inserted mid-run) and graceful drains — at given
//!   virtual times;
//! * **the autoscaler** — an optional `[[autoscaler]]` table arming
//!   the elastic policy of [`crate::service::elastic`] with a preset
//!   machine pool and pressure thresholds, so membership follows the
//!   offered load instead of a fixed schedule;
//! * **the power envelope** — an optional `[[power]]` table setting
//!   the cluster-wide cap, the parked rate for drained shards and the
//!   routing objective (`latency` or `energy` with an SLO slack) of
//!   [`crate::service::cluster::PowerOptions`] and
//!   [`crate::service::cluster::RouteObjective`];
//! * **the driver** — an optional top-level `driver = "virtual" |
//!   "wallclock"` knob. `"virtual"` (the default) is the deterministic
//!   heap loop; `"wallclock"` executes the same scenario through the
//!   actor-per-shard [`WallClockDriver`] with simulated executors.
//!   Decisions — and therefore the digest — are identical either way
//!   (the report is the core's deterministic accounting); what changes
//!   is that execution really runs on one thread per shard.
//!
//! [`Scenario::run`] realizes the streams into one merged arrival
//! trace, builds the [`Cluster`] and executes everything on the same
//! event-driven virtual-time loop the rest of the serving layer uses —
//! faults are ordinary heap events, so a run is exactly as
//! deterministic as the fault-free simulator: the same file and seed
//! always produce the same [`ServiceReport`], and a scenario with no
//! `[[fault]]` tables is byte-identical to driving the equivalent
//! cluster directly (property-tested in `tests/prop_invariants.rs`).
//!
//! [`digest`] folds a report into a stable JSON summary; the
//! `scenario_runner` binary runs the committed corpus under
//! `scenarios/` and CI diffs its output against the blessed
//! `ci/scenario_digests.json` (see `docs/scenarios.md` for the schema
//! and the blessing workflow).
//!
//! ```no_run
//! use poas::service::scenario::Scenario;
//!
//! let sc = Scenario::from_file(std::path::Path::new("scenarios/crash_mid_burst.toml"))?;
//! let report = sc.run();
//! println!("{}", poas::service::scenario::digest(&report));
//! # Ok::<(), poas::Error>(())
//! ```

mod digest;
mod parser;

pub use digest::digest;

use crate::config::MachineConfig;
use crate::error::{Error, Result};
use crate::service::arrivals::{
    Arrival, ClassLoad, MixedArrivals, OnOffArrivals, Phase, PhasedArrivals,
};
use crate::service::cluster::{Cluster, ClusterOptions};
use crate::service::driver::{DriverKind, WallClockDriver};
use crate::service::qos::QosClass;
use crate::service::request::ServiceReport;
use crate::workload::GemmSize;
use std::path::Path;

/// How one `[[arrivals]]` stream generates inter-arrival times.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamKind {
    /// A deterministic Poisson stream ([`MixedArrivals`] with a single
    /// [`ClassLoad`]).
    Poisson {
        /// Offered load, requests per virtual second.
        rate_rps: f64,
    },
    /// A bursty Markov-modulated on/off stream ([`OnOffArrivals`]);
    /// the scenario's class and SLO are stamped onto the realized
    /// arrivals afterwards.
    OnOff {
        /// Arrival rate while the source is ON.
        rate_on_rps: f64,
        /// Arrival rate while the source is OFF (must be positive and
        /// below the ON rate).
        rate_off_rps: f64,
        /// Mean ON-phase duration, virtual seconds.
        mean_on_s: f64,
        /// Mean OFF-phase duration, virtual seconds.
        mean_off_s: f64,
    },
    /// A scheduled piecewise-Poisson phase cycle ([`PhasedArrivals`]):
    /// fixed-duration phases (e.g. day/night) cycling for as long as
    /// the requested arrival count lasts. Like on/off, the scenario's
    /// class and SLO are stamped onto the realized arrivals.
    Phased {
        /// The repeating phase schedule, in order.
        phases: Vec<Phase>,
    },
}

/// One `[[arrivals]]` table: a generated request stream.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSpec {
    /// The arrival process.
    pub kind: StreamKind,
    /// QoS tier of every request in the stream.
    pub class: QosClass,
    /// Number of arrivals to realize.
    pub count: usize,
    /// SLO attached to every request (`None` = no deadline).
    pub deadline_s: Option<f64>,
    /// Shapes drawn uniformly per arrival (see the menu DSL in
    /// `docs/scenarios.md`: `MxNxK*reps` or square `S*reps`).
    pub menu: Vec<(GemmSize, u32)>,
}

/// One `[[request]]` table: a hand-placed arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FixedRequest {
    /// Arrival time, virtual seconds.
    pub at: f64,
    /// The GEMM shape.
    pub size: GemmSize,
    /// Repetitions.
    pub reps: u32,
    /// QoS tier.
    pub class: QosClass,
    /// Optional sojourn SLO.
    pub deadline_s: Option<f64>,
}

/// One `[[fault]]` table: a scheduled disturbance.
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// Shard `shard` crashes at `at`: queued and in-flight work is
    /// displaced and re-enters admission on the surviving shards (see
    /// [`Cluster::inject_crash`]).
    Crash {
        /// Virtual time of the crash.
        at: f64,
        /// Shard index.
        shard: usize,
    },
    /// Shard `shard` comes back at `at` and parked arrivals re-enter
    /// admission (see [`Cluster::inject_restart`]).
    Restart {
        /// Virtual time of the restart.
        at: f64,
        /// Shard index.
        shard: usize,
    },
    /// Straggler / degraded machine: shard `shard`'s realized device
    /// rates are multiplied by `factor` at `at`, so executions drift
    /// away from the installation-time model until a dynamic replan
    /// refreshes it (see [`Cluster::inject_slowdown`]).
    Slow {
        /// Virtual time the drift starts.
        at: f64,
        /// Shard index.
        shard: usize,
        /// Rate multiplier in (0, ∞); `< 1` slows the machine down.
        factor: f64,
    },
    /// A load spike: an extra Poisson burst superposed on the
    /// scenario's streams starting at `at`. Realized in
    /// [`Scenario::trace`], not as a heap event.
    Spike {
        /// Virtual time the burst starts.
        at: f64,
        /// Burst arrival rate, requests per virtual second.
        rate_rps: f64,
        /// Number of burst arrivals.
        count: usize,
        /// QoS tier of the burst.
        class: QosClass,
        /// Optional SLO on every burst request.
        deadline_s: Option<f64>,
        /// Shapes drawn uniformly per burst arrival.
        menu: Vec<(GemmSize, u32)>,
    },
    /// Scale-out: a new shard built from `machine` joins the cluster at
    /// `at` — profiled at provision time, own admission gate, cold plan
    /// cache (see [`Cluster::inject_join`]). Joined shards are numbered
    /// after the construction-time ones, in `[[fault]]` document order.
    Join {
        /// Virtual time the shard comes online.
        at: f64,
        /// The machine to provision.
        machine: MachineConfig,
        /// Profiling seed; `None` derives one deterministically from
        /// the scenario seed and the join's ordinal.
        seed: Option<u64>,
    },
    /// Graceful drain: shard `shard` leaves the routing set at `at`,
    /// in-flight work runs to completion, and queued work redistributes
    /// through front-end admission (see [`Cluster::inject_drain`]).
    /// Unlike [`Fault::Crash`], zero in-flight work is displaced.
    Drain {
        /// Virtual time the drain starts.
        at: f64,
        /// Shard index (may target a not-yet-joined shard; if the drain
        /// fires before its join, it is a deterministic no-op).
        shard: usize,
    },
    /// Power-budget change: the cluster-wide power cap is re-set (or
    /// lifted, when `cap_w` is `None`) at `at` — e.g. a facility
    /// brown-out tightening the budget mid-run (see
    /// [`Cluster::inject_power_cap`]).
    PowerCap {
        /// Virtual time the new budget takes effect.
        at: f64,
        /// New cap in watts; `None` removes the cap.
        cap_w: Option<f64>,
    },
}

/// A parsed scenario: cluster + offered load + fault schedule.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Scenario name (the digest key in the runner's output).
    pub name: String,
    /// Master seed: drives shard profiling (shard `i` profiles on a
    /// simulator seeded `seed + i`) and every arrival stream.
    pub seed: u64,
    /// One entry per shard, expanded from the `[[shard]]` presets.
    pub machines: Vec<MachineConfig>,
    /// Cluster/serving options assembled from the top-level keys
    /// (`opts.shards` is overridden by `machines.len()` at build time).
    pub opts: ClusterOptions,
    /// Generated arrival streams, document order.
    pub streams: Vec<StreamSpec>,
    /// Hand-placed arrivals, document order.
    pub requests: Vec<FixedRequest>,
    /// Scheduled faults, document order.
    pub faults: Vec<Fault>,
    /// Which driver executes the run (top-level `driver` key;
    /// [`DriverKind::Virtual`] when absent). The report — and thus the
    /// digest — is identical under both; see [`Scenario::run`].
    pub driver: DriverKind,
}

/// Seed for stream `index`: domain-separated from the master seed so
/// adding a stream never perturbs the ones before it.
fn stream_seed(base: u64, index: usize) -> u64 {
    base ^ (index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

impl Scenario {
    /// Parse a scenario from TOML-subset text (see `docs/scenarios.md`
    /// for the schema). Also available as [`std::str::FromStr`].
    pub fn parse(text: &str) -> Result<Self> {
        parser::parse_scenario(text)
    }

    /// Read and parse a scenario file.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))?;
        Self::parse(&text)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))
    }

    /// Realize every stream, spike and fixed request into one merged
    /// arrival trace, time-ordered with a stable sort (ties keep
    /// document order: streams, then spikes, then fixed requests).
    pub fn trace(&self) -> Vec<Arrival> {
        let mut all = Vec::new();
        let mut next_stream = 0usize;
        for s in &self.streams {
            let seed = stream_seed(self.seed, next_stream);
            next_stream += 1;
            match s.kind {
                StreamKind::Poisson { rate_rps } => {
                    let load = ClassLoad {
                        class: s.class,
                        rate_rps,
                        menu: s.menu.clone(),
                        deadline_s: s.deadline_s,
                    };
                    all.extend(MixedArrivals::new(vec![load], seed).trace(s.count));
                }
                StreamKind::Phased { ref phases } => {
                    // Like on/off, `PhasedArrivals` realizes
                    // Standard/no-SLO arrivals; stamp the stream's tier
                    // and deadline on afterwards.
                    let mut t =
                        PhasedArrivals::new(phases.clone(), s.menu.clone(), seed).trace(s.count);
                    for a in &mut t {
                        a.class = s.class;
                        a.deadline_s = s.deadline_s;
                    }
                    all.extend(t);
                }
                StreamKind::OnOff {
                    rate_on_rps,
                    rate_off_rps,
                    mean_on_s,
                    mean_off_s,
                } => {
                    // `OnOffArrivals` realizes Standard/no-SLO arrivals;
                    // the stream's tier and deadline are stamped on here.
                    let mut t = OnOffArrivals::new(
                        rate_on_rps,
                        rate_off_rps,
                        mean_on_s,
                        mean_off_s,
                        s.menu.clone(),
                        seed,
                    )
                    .trace(s.count);
                    for a in &mut t {
                        a.class = s.class;
                        a.deadline_s = s.deadline_s;
                    }
                    all.extend(t);
                }
            }
        }
        for f in &self.faults {
            if let Fault::Spike {
                at,
                rate_rps,
                count,
                class,
                deadline_s,
                menu,
            } = f
            {
                let seed = stream_seed(self.seed, next_stream);
                next_stream += 1;
                let load = ClassLoad {
                    class: *class,
                    rate_rps: *rate_rps,
                    menu: menu.clone(),
                    deadline_s: *deadline_s,
                };
                let mut t = MixedArrivals::new(vec![load], seed).trace(*count);
                for a in &mut t {
                    a.at += at;
                }
                all.extend(t);
            }
        }
        for r in &self.requests {
            all.push(Arrival {
                at: r.at,
                size: r.size,
                reps: r.reps,
                class: r.class,
                deadline_s: r.deadline_s,
            });
        }
        all.sort_by(|a, b| a.at.total_cmp(&b.at));
        all
    }

    /// Build the cluster and schedule the heap faults (crash, restart,
    /// slowdown, join, drain). Spikes live in [`Scenario::trace`]
    /// instead. The returned cluster has no arrivals submitted yet.
    ///
    /// Joins are scheduled first so crash/restart/slow/drain faults may
    /// target the shard indexes the joins will occupy
    /// (`machines.len()..`); a fault that fires before its target has
    /// joined is a deterministic no-op.
    pub fn build(&self) -> Cluster {
        let mut cluster = Cluster::builder()
            .machines(&self.machines)
            .seed(self.seed)
            .options(self.opts.clone())
            .build();
        let mut join_ordinal = 0u64;
        for f in &self.faults {
            if let Fault::Join { at, machine, seed } = f {
                // Default profiling seed: domain-separated from both the
                // construction-time shards (seed + i) and earlier joins.
                let profile_seed = seed.unwrap_or_else(|| {
                    self.seed
                        .wrapping_add(self.machines.len() as u64)
                        .wrapping_add(join_ordinal)
                });
                join_ordinal += 1;
                cluster.inject_join(*at, machine.clone(), profile_seed);
            }
        }
        for f in &self.faults {
            match f {
                Fault::Crash { at, shard } => cluster.inject_crash(*at, *shard),
                Fault::Restart { at, shard } => cluster.inject_restart(*at, *shard),
                Fault::Slow { at, shard, factor } => cluster.inject_slowdown(*at, *shard, *factor),
                Fault::Drain { at, shard } => cluster.inject_drain(*at, *shard),
                Fault::PowerCap { at, cap_w } => cluster.inject_power_cap(*at, *cap_w),
                Fault::Spike { .. } | Fault::Join { .. } => {}
            }
        }
        cluster
    }

    /// Execute the scenario to completion: build, submit the realized
    /// trace, drain the event loop under the configured driver.
    /// Deterministic: same file, same seed, same report — under
    /// **either** driver, since every decision (and the report) comes
    /// from the shared core; the wall-clock driver only adds real
    /// per-shard execution threads.
    pub fn run(&self) -> ServiceReport {
        let mut cluster = self.build();
        cluster.submit_trace(&self.trace());
        match self.driver {
            DriverKind::Virtual => cluster.run_to_completion(),
            DriverKind::WallClock => WallClockDriver::new(cluster).run_to_completion(),
        }
    }
}

impl std::str::FromStr for Scenario {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        Scenario::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINIMAL: &str = r#"
        name = "minimal"
        seed = 7

        [[shard]]
        preset = "mach1"

        [[arrivals]]
        process = "poisson"
        class = "standard"
        rate_rps = 50.0
        count = 4
        menu = "256*2, 192x256x128"
    "#;

    #[test]
    fn minimal_scenario_parses_and_runs() {
        let sc: Scenario = MINIMAL.parse().expect("parse");
        assert_eq!(sc.name, "minimal");
        assert_eq!(sc.seed, 7);
        assert_eq!(sc.machines.len(), 1);
        assert_eq!(sc.streams.len(), 1);
        assert_eq!(sc.driver, DriverKind::Virtual);
        assert_eq!(sc.trace().len(), 4);
        let report = sc.run();
        assert_eq!(report.served.len(), 4);
        assert_eq!(report.requeued, 0);
    }

    #[test]
    fn trace_is_time_ordered_and_deterministic() {
        let sc: Scenario = MINIMAL.parse().unwrap();
        let t1 = sc.trace();
        let t2 = sc.trace();
        assert_eq!(t1, t2);
        for w in t1.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }

    #[test]
    fn spike_arrivals_are_offset_and_merged() {
        let text = r#"
            name = "spiked"
            [[shard]]
            preset = "mach1"
            [[fault]]
            kind = "spike"
            at = 2.5
            rate_rps = 100.0
            count = 3
            class = "interactive"
            deadline_s = 1.0
            menu = "128"
        "#;
        let sc: Scenario = text.parse().unwrap();
        let t = sc.trace();
        assert_eq!(t.len(), 3);
        assert!(t.iter().all(|a| a.at >= 2.5));
        assert!(t.iter().all(|a| a.class == QosClass::Interactive));
        assert!(t.iter().all(|a| a.deadline_s == Some(1.0)));
    }

    #[test]
    fn adding_a_stream_does_not_perturb_earlier_streams() {
        let one: Scenario = MINIMAL.parse().unwrap();
        let two: Scenario = format!(
            "{MINIMAL}\n[[arrivals]]\nprocess = \"poisson\"\nclass = \"batch\"\nrate_rps = 5.0\ncount = 2\nmenu = \"512\"\n"
        )
        .parse()
        .unwrap();
        let t1 = one.trace();
        let mut t2 = two.trace();
        t2.retain(|a| a.class == QosClass::Standard);
        assert_eq!(t1, t2);
    }

    #[test]
    fn phased_stream_realizes_with_class_and_slo() {
        let text = r#"
            name = "phased"
            seed = 3
            [[shard]]
            preset = "mach1"
            [[arrivals]]
            process = "phased"
            phases = "20.0:0.5, 2.0:0.5"
            class = "batch"
            deadline_s = 4.0
            count = 12
            menu = "128"
        "#;
        let sc: Scenario = text.parse().unwrap();
        assert!(matches!(
            sc.streams[0].kind,
            StreamKind::Phased { ref phases } if phases.len() == 2
        ));
        let t1 = sc.trace();
        assert_eq!(t1.len(), 12);
        assert!(t1
            .iter()
            .all(|a| a.class == QosClass::Batch && a.deadline_s == Some(4.0)));
        assert_eq!(t1, sc.trace());
    }

    #[test]
    fn membership_faults_schedule_and_conserve_requests() {
        let text = r#"
            name = "elastic"
            seed = 9
            [[shard]]
            preset = "mach1"
            [[arrivals]]
            rate_rps = 200.0
            count = 24
            menu = "128, 192"
            [[fault]]
            kind = "join"
            at = 0.0
            preset = "mach2"
            [[fault]]
            kind = "drain"
            at = 0.05
            shard = 0
        "#;
        let sc: Scenario = text.parse().unwrap();
        assert!(matches!(sc.faults[0], Fault::Join { seed: None, .. }));
        assert!(matches!(sc.faults[1], Fault::Drain { shard: 0, .. }));
        let report = sc.run();
        // Every arrival is accounted for despite the membership churn.
        assert_eq!(report.served.len(), 24);
    }

    #[test]
    fn faults_schedule_on_the_cluster() {
        let text = r#"
            name = "faulty"
            [[shard]]
            preset = "mach1"
            count = 2
            [[fault]]
            kind = "crash"
            at = 0.5
            shard = 1
            [[fault]]
            kind = "restart"
            at = 1.5
            shard = 1
            [[fault]]
            kind = "slow"
            at = 0.25
            shard = 0
            factor = 0.5
        "#;
        let sc: Scenario = text.parse().unwrap();
        assert_eq!(sc.machines.len(), 2);
        assert_eq!(sc.faults.len(), 3);
        // Runs to completion with zero arrivals: fault events drain.
        let report = sc.run();
        assert_eq!(report.served.len(), 0);
    }

    #[test]
    fn power_capped_scenario_is_deterministic_and_accounts_energy() {
        let text = r#"
            name = "capped"
            seed = 11
            deadline_policy = "reject"
            [[shard]]
            preset = "mach2"
            count = 2
            [[power]]
            cap_w = 700.0
            objective = "energy"
            slack = 3.0
            [[arrivals]]
            rate_rps = 40.0
            count = 12
            menu = "12000, 16000*2"
            [[fault]]
            kind = "cap"
            at = 0.4
            cap_w = 650.0
        "#;
        let sc: Scenario = text.parse().unwrap();
        let r1 = sc.run();
        let r2 = sc.run();
        assert_eq!(r1, r2, "capped runs must replay byte-identically");
        assert_eq!(digest(&r1), digest(&r2));
        assert_eq!(r1.served.len(), 12);
        // Energy accounting is live: executed work drew active watts.
        assert!(r1.joules_active > 0.0);
        let by_class: f64 = r1.joules_by_class.iter().sum();
        assert!((by_class - r1.joules_active).abs() < 1e-6);
    }
}
