//! TOML-subset parser for scenario files.
//!
//! Reuses [`crate::config::parser`]'s splitter so scenarios get the
//! exact comment/string/number handling of machine configs, with the
//! section headers `[[shard]]`, `[[arrivals]]`, `[[request]]`,
//! `[[fault]]`, `[[autoscaler]]` and `[[power]]`. See
//! `docs/scenarios.md` for the full schema and a worked example.

use super::{Fault, FixedRequest, Scenario, StreamKind, StreamSpec};
use crate::config::parser::{get, num_or, req, split_sections, Section};
use crate::config::{presets, MachineConfig};
use crate::error::{Error, Result};
use crate::service::arrivals::Phase;
use crate::service::batch::{BatchPolicy, BatchWindow};
use crate::service::cluster::{ClusterOptions, GatePolicy, RouteObjective};
use crate::service::driver::DriverKind;
use crate::service::elastic::AutoscalerPolicy;
use crate::service::qos::{DeadlinePolicy, QosClass};
use crate::service::queue::QueuePolicy;
use crate::workload::GemmSize;

const HEADERS: [&str; 6] = ["shard", "arrivals", "request", "fault", "autoscaler", "power"];

/// Parse one scenario document.
pub(super) fn parse_scenario(text: &str) -> Result<Scenario> {
    let (top, tables) = split_sections(text, &HEADERS)?;

    let name = req(&top, "name", "scenario")?.as_str("name")?.to_string();
    if name.is_empty() {
        return Err(Error::Config("scenario: `name` must not be empty".into()));
    }
    let seed = match get(&top, "seed") {
        Some(v) => v.as_u64("seed")?,
        None => 0,
    };
    let mut opts = parse_options(&top)?;
    let driver = match get(&top, "driver") {
        None => DriverKind::Virtual,
        Some(v) => match v.as_str("driver")? {
            "virtual" => DriverKind::Virtual,
            "wallclock" => DriverKind::WallClock,
            other => {
                return Err(Error::Config(format!(
                    "`driver` must be \"virtual\" or \"wallclock\", got \"{other}\""
                )))
            }
        },
    };

    let mut machines = Vec::new();
    let mut streams = Vec::new();
    let mut requests = Vec::new();
    let mut faults = Vec::new();
    let mut saw_power = false;
    for (header, sec) in &tables {
        match header.as_str() {
            "shard" => parse_shard(sec, &mut machines)?,
            "arrivals" => streams.push(parse_arrivals(sec)?),
            "request" => requests.push(parse_request(sec)?),
            "fault" => faults.push(parse_fault(sec)?),
            "autoscaler" => {
                if opts.autoscaler.is_some() {
                    return Err(Error::Config(format!(
                        "scenario `{name}`: at most one [[autoscaler]] table"
                    )));
                }
                opts.autoscaler = Some(parse_autoscaler(sec)?);
            }
            "power" => {
                if saw_power {
                    return Err(Error::Config(format!(
                        "scenario `{name}`: at most one [[power]] table"
                    )));
                }
                saw_power = true;
                parse_power(sec, &mut opts)?;
            }
            _ => unreachable!("split_sections only yields accepted headers"),
        }
    }
    if machines.is_empty() {
        return Err(Error::Config(format!(
            "scenario `{name}`: needs at least one [[shard]] table"
        )));
    }
    // Faults may address shards the `[[fault]]` joins will create
    // (numbered after the construction-time ones), so the bound
    // includes the scheduled joins.
    let addressable = machines.len()
        + faults.iter().filter(|f| matches!(f, Fault::Join { .. })).count();
    for f in &faults {
        let shard = match f {
            Fault::Crash { shard, .. }
            | Fault::Restart { shard, .. }
            | Fault::Slow { shard, .. }
            | Fault::Drain { shard, .. } => *shard,
            Fault::Spike { .. } | Fault::Join { .. } | Fault::PowerCap { .. } => continue,
        };
        if shard >= addressable {
            return Err(Error::Config(format!(
                "scenario `{name}`: fault targets shard {shard} but the cluster has only \
                 {addressable} addressable shards (including scheduled joins)"
            )));
        }
    }

    Ok(Scenario {
        name,
        seed,
        machines,
        opts,
        streams,
        requests,
        faults,
        driver,
    })
}

fn flag(sec: &Section, key: &str, default: bool) -> Result<bool> {
    Ok(num_or(sec, key, if default { 1.0 } else { 0.0 })? != 0.0)
}

fn parse_options(top: &Section) -> Result<ClusterOptions> {
    let mut opts = ClusterOptions::default();

    if let Some(v) = get(top, "queue") {
        opts.shard.policy = match v.as_str("queue")? {
            "fifo" => QueuePolicy::Fifo,
            "spjf" => QueuePolicy::Spjf,
            other => {
                return Err(Error::Config(format!(
                    "`queue` must be \"fifo\" or \"spjf\", got \"{other}\""
                )))
            }
        };
    }
    if let Some(v) = get(top, "gate") {
        opts.gate = match v.as_str("gate")? {
            "per_shard" => GatePolicy::PerShard,
            "shard0" => GatePolicy::Shard0,
            other => {
                return Err(Error::Config(format!(
                    "`gate` must be \"per_shard\" or \"shard0\", got \"{other}\""
                )))
            }
        };
    }
    if let Some(v) = get(top, "deadline_policy") {
        opts.shard.deadline_policy = match v.as_str("deadline_policy")? {
            "reject" => DeadlinePolicy::Reject,
            "downclass" => DeadlinePolicy::Downclass,
            other => {
                return Err(Error::Config(format!(
                    "`deadline_policy` must be \"reject\" or \"downclass\", got \"{other}\""
                )))
            }
        };
    }
    opts.work_stealing = flag(top, "work_stealing", opts.work_stealing)?;
    opts.shard.standalone_bypass = flag(top, "standalone_bypass", opts.shard.standalone_bypass)?;
    opts.shard.dynamic = flag(top, "dynamic", opts.shard.dynamic)?;
    opts.shard.min_gain = num_or(top, "min_gain", opts.shard.min_gain)?;
    opts.shard.overhead_s = num_or(top, "overhead_s", opts.shard.overhead_s)?;
    opts.shard.deadline_slack = num_or(top, "deadline_slack", opts.shard.deadline_slack)?;
    if !(opts.shard.deadline_slack > 0.0 && opts.shard.deadline_slack <= 1.0) {
        return Err(Error::Config(format!(
            "`deadline_slack` must be in (0, 1], got {}",
            opts.shard.deadline_slack
        )));
    }
    if let Some(v) = get(top, "cache_capacity") {
        opts.shard.cache_capacity = v.as_u64("cache_capacity")? as usize;
    }
    if let Some(v) = get(top, "gate_capacity") {
        opts.shard.gate_capacity = v.as_u64("gate_capacity")? as usize;
    }

    // Presence of any batching knob switches windowed batching on;
    // unspecified knobs keep the `BatchWindow` defaults.
    let batch_keys = ["batch_window_s", "batch_max_members", "batch_max_member_ops"];
    if batch_keys.iter().any(|k| get(top, k).is_some()) {
        let defaults = BatchWindow::default();
        let window = BatchWindow {
            window_s: num_or(top, "batch_window_s", defaults.window_s)?,
            max_members: match get(top, "batch_max_members") {
                Some(v) => v.as_u64("batch_max_members")? as usize,
                None => defaults.max_members,
            },
            max_member_ops: num_or(top, "batch_max_member_ops", defaults.max_member_ops)?,
        };
        if !(window.window_s > 0.0) || window.max_members < 2 || !(window.max_member_ops > 0.0) {
            return Err(Error::Config(
                "batching knobs must satisfy batch_window_s > 0, batch_max_members >= 2, \
                 batch_max_member_ops > 0"
                    .into(),
            ));
        }
        opts.batching = BatchPolicy::Windowed(window);
    }

    Ok(opts)
}

fn preset_config(name: &str, what: &str) -> Result<MachineConfig> {
    match name {
        "mach1" => Ok(presets::mach1()),
        "mach2" => Ok(presets::mach2()),
        "gpu_node" => Ok(presets::gpu_node()),
        "cpu_node" => Ok(presets::cpu_node()),
        "xpu_node" => Ok(presets::xpu_node()),
        other => Err(Error::Config(format!(
            "{what}: unknown preset \"{other}\" (expected mach1, mach2, gpu_node, cpu_node \
             or xpu_node)"
        ))),
    }
}

fn parse_shard(sec: &Section, machines: &mut Vec<MachineConfig>) -> Result<()> {
    let preset = req(sec, "preset", "[[shard]]")?.as_str("preset")?;
    let count = match get(sec, "count") {
        Some(v) => v.as_u64("count")? as usize,
        None => 1,
    };
    if count == 0 {
        return Err(Error::Config("[[shard]]: `count` must be >= 1".into()));
    }
    for _ in 0..count {
        machines.push(preset_config(preset, "[[shard]]")?);
    }
    Ok(())
}

/// The pool DSL: comma-separated `preset*count` items, count
/// defaulting to 1 — same shape as the menu DSL, over machine presets.
fn parse_pool(raw: &str, what: &str) -> Result<Vec<MachineConfig>> {
    let mut pool = Vec::new();
    for item in raw.split(',') {
        let item = item.trim();
        if item.is_empty() {
            continue;
        }
        let (name, count) = match item.split_once('*') {
            Some((n, c)) => {
                let count = c.trim().parse::<usize>().map_err(|_| {
                    Error::Config(format!("{what}: bad count `{c}` in pool item `{item}`"))
                })?;
                (n.trim(), count)
            }
            None => (item, 1),
        };
        if count == 0 {
            return Err(Error::Config(format!(
                "{what}: count must be >= 1 in pool item `{item}`"
            )));
        }
        for _ in 0..count {
            pool.push(preset_config(name, what)?);
        }
    }
    if pool.is_empty() {
        return Err(Error::Config(format!("{what}: `pool` must not be empty")));
    }
    Ok(pool)
}

fn parse_autoscaler(sec: &Section) -> Result<AutoscalerPolicy> {
    const WHAT: &str = "[[autoscaler]]";
    let pool = parse_pool(req(sec, "pool", WHAT)?.as_str("pool")?, WHAT)?;
    let mut policy = AutoscalerPolicy::new(pool);
    policy.eval_interval_s = num_or(sec, "eval_interval_s", policy.eval_interval_s)?;
    policy.scale_up_pressure_s = num_or(sec, "scale_up_pressure_s", policy.scale_up_pressure_s)?;
    policy.scale_down_pressure_s =
        num_or(sec, "scale_down_pressure_s", policy.scale_down_pressure_s)?;
    if let Some(v) = get(sec, "scale_down_evals") {
        policy.scale_down_evals = v.as_u64("scale_down_evals")? as u32;
    }
    if let Some(v) = get(sec, "profile_seed") {
        policy.profile_seed = v.as_u64("profile_seed")?;
    }
    if !(policy.eval_interval_s.is_finite() && policy.eval_interval_s > 0.0) {
        return Err(Error::Config(format!(
            "{WHAT}: `eval_interval_s` must be finite and positive, got {}",
            policy.eval_interval_s
        )));
    }
    if !(policy.scale_up_pressure_s.is_finite() && policy.scale_up_pressure_s > 0.0) {
        return Err(Error::Config(format!(
            "{WHAT}: `scale_up_pressure_s` must be finite and positive, got {}",
            policy.scale_up_pressure_s
        )));
    }
    if !(policy.scale_down_pressure_s.is_finite()
        && policy.scale_down_pressure_s >= 0.0
        && policy.scale_down_pressure_s < policy.scale_up_pressure_s)
    {
        return Err(Error::Config(format!(
            "{WHAT}: `scale_down_pressure_s` must be finite, non-negative and below \
             `scale_up_pressure_s`, got {}",
            policy.scale_down_pressure_s
        )));
    }
    if policy.scale_down_evals == 0 {
        return Err(Error::Config(format!(
            "{WHAT}: `scale_down_evals` must be >= 1"
        )));
    }
    Ok(policy)
}

/// The `[[power]]` table: cluster-wide cap, parked rate and routing
/// objective (see [`crate::service::cluster::PowerOptions`] and
/// [`crate::service::cluster::RouteObjective`]).
fn parse_power(sec: &Section, opts: &mut ClusterOptions) -> Result<()> {
    const WHAT: &str = "[[power]]";
    if get(sec, "cap_w").is_some() {
        let cap_w = parse_positive(sec, "cap_w", WHAT)?;
        opts.power.cap_w = Some(cap_w);
    }
    opts.power.parked_frac = num_or(sec, "parked_frac", opts.power.parked_frac)?;
    if !(opts.power.parked_frac.is_finite()
        && (0.0..=1.0).contains(&opts.power.parked_frac))
    {
        return Err(Error::Config(format!(
            "{WHAT}: `parked_frac` must be in [0, 1], got {}",
            opts.power.parked_frac
        )));
    }
    let objective = match get(sec, "objective") {
        None => "latency",
        Some(v) => v.as_str("objective")?,
    };
    match objective {
        "latency" => {
            if get(sec, "slack").is_some() {
                return Err(Error::Config(format!(
                    "{WHAT}: `slack` only applies to objective = \"energy\""
                )));
            }
            opts.objective = RouteObjective::Latency;
        }
        "energy" => {
            let slack = num_or(sec, "slack", 1.5)?;
            if !(slack.is_finite() && slack >= 1.0) {
                return Err(Error::Config(format!(
                    "{WHAT}: `slack` must be finite and >= 1, got {slack}"
                )));
            }
            opts.objective = RouteObjective::EnergyAware { slack };
        }
        other => {
            return Err(Error::Config(format!(
                "{WHAT}: `objective` must be \"latency\" or \"energy\", got \"{other}\""
            )))
        }
    }
    Ok(())
}

fn parse_class(sec: &Section, what: &str) -> Result<QosClass> {
    match get(sec, "class") {
        None => Ok(QosClass::Standard),
        Some(v) => match v.as_str("class")? {
            "interactive" => Ok(QosClass::Interactive),
            "standard" => Ok(QosClass::Standard),
            "batch" => Ok(QosClass::Batch),
            other => Err(Error::Config(format!(
                "{what}: `class` must be \"interactive\", \"standard\" or \"batch\", \
                 got \"{other}\""
            ))),
        },
    }
}

fn parse_deadline(sec: &Section, what: &str) -> Result<Option<f64>> {
    match get(sec, "deadline_s") {
        None => Ok(None),
        Some(v) => {
            let d = v.as_f64("deadline_s")?;
            if !(d.is_finite() && d > 0.0) {
                return Err(Error::Config(format!(
                    "{what}: `deadline_s` must be finite and positive, got {d}"
                )));
            }
            Ok(Some(d))
        }
    }
}

/// One menu/size token: `MxNxK` or square `S`, dimensions >= 1.
fn parse_size(tok: &str, what: &str) -> Result<GemmSize> {
    let dims: Vec<&str> = tok.split('x').collect();
    let dim = |d: &str| -> Result<u64> {
        let n = d
            .trim()
            .parse::<u64>()
            .map_err(|_| Error::Config(format!("{what}: bad dimension `{d}` in `{tok}`")))?;
        if n == 0 {
            return Err(Error::Config(format!(
                "{what}: dimensions must be >= 1 in `{tok}`"
            )));
        }
        Ok(n)
    };
    match dims.as_slice() {
        [s] => {
            let s = dim(s)?;
            Ok(GemmSize::new(s, s, s))
        }
        [m, n, k] => Ok(GemmSize::new(dim(m)?, dim(n)?, dim(k)?)),
        _ => Err(Error::Config(format!(
            "{what}: size must be `MxNxK` or square `S`, got `{tok}`"
        ))),
    }
}

/// The menu DSL: comma-separated `MxNxK*reps` / `S*reps` items, reps
/// defaulting to 1.
fn parse_menu(raw: &str, what: &str) -> Result<Vec<(GemmSize, u32)>> {
    let mut menu = Vec::new();
    for item in raw.split(',') {
        let item = item.trim();
        if item.is_empty() {
            continue;
        }
        let (size_tok, reps) = match item.split_once('*') {
            Some((s, r)) => {
                let reps = r.trim().parse::<u32>().map_err(|_| {
                    Error::Config(format!("{what}: bad reps `{r}` in menu item `{item}`"))
                })?;
                (s.trim(), reps)
            }
            None => (item, 1),
        };
        if reps == 0 {
            return Err(Error::Config(format!(
                "{what}: reps must be >= 1 in menu item `{item}`"
            )));
        }
        menu.push((parse_size(size_tok, what)?, reps));
    }
    if menu.is_empty() {
        return Err(Error::Config(format!("{what}: `menu` must not be empty")));
    }
    Ok(menu)
}

fn parse_positive(sec: &Section, key: &str, what: &str) -> Result<f64> {
    let v = req(sec, key, what)?.as_f64(key)?;
    if !(v.is_finite() && v > 0.0) {
        return Err(Error::Config(format!(
            "{what}: `{key}` must be finite and positive, got {v}"
        )));
    }
    Ok(v)
}

/// The phases DSL: comma-separated `rate_rps:dur_s` items, both finite
/// and positive.
fn parse_phases(raw: &str, what: &str) -> Result<Vec<Phase>> {
    let mut phases = Vec::new();
    for item in raw.split(',') {
        let item = item.trim();
        if item.is_empty() {
            continue;
        }
        let (rate, dur) = item.split_once(':').ok_or_else(|| {
            Error::Config(format!(
                "{what}: phase must be `rate_rps:dur_s`, got `{item}`"
            ))
        })?;
        let field = |tok: &str, name: &str| -> Result<f64> {
            let v = tok.trim().parse::<f64>().map_err(|_| {
                Error::Config(format!("{what}: bad {name} `{tok}` in phase `{item}`"))
            })?;
            if !(v.is_finite() && v > 0.0) {
                return Err(Error::Config(format!(
                    "{what}: {name} must be finite and positive in phase `{item}`"
                )));
            }
            Ok(v)
        };
        phases.push(Phase {
            rate_rps: field(rate, "rate")?,
            dur_s: field(dur, "duration")?,
        });
    }
    if phases.is_empty() {
        return Err(Error::Config(format!("{what}: `phases` must not be empty")));
    }
    Ok(phases)
}

fn parse_arrivals(sec: &Section) -> Result<StreamSpec> {
    const WHAT: &str = "[[arrivals]]";
    let process = match get(sec, "process") {
        None => "poisson",
        Some(v) => v.as_str("process")?,
    };
    let kind = match process {
        "poisson" => StreamKind::Poisson {
            rate_rps: parse_positive(sec, "rate_rps", WHAT)?,
        },
        "onoff" => {
            let rate_on_rps = parse_positive(sec, "rate_on_rps", WHAT)?;
            let rate_off_rps = parse_positive(sec, "rate_off_rps", WHAT)?;
            if rate_on_rps <= rate_off_rps {
                return Err(Error::Config(format!(
                    "{WHAT}: `rate_on_rps` ({rate_on_rps}) must exceed `rate_off_rps` \
                     ({rate_off_rps})"
                )));
            }
            StreamKind::OnOff {
                rate_on_rps,
                rate_off_rps,
                mean_on_s: parse_positive(sec, "mean_on_s", WHAT)?,
                mean_off_s: parse_positive(sec, "mean_off_s", WHAT)?,
            }
        }
        "phased" => StreamKind::Phased {
            phases: parse_phases(req(sec, "phases", WHAT)?.as_str("phases")?, WHAT)?,
        },
        other => {
            return Err(Error::Config(format!(
                "{WHAT}: `process` must be \"poisson\", \"onoff\" or \"phased\", got \"{other}\""
            )))
        }
    };
    let count = req(sec, "count", WHAT)?.as_u64("count")? as usize;
    if count == 0 {
        return Err(Error::Config(format!("{WHAT}: `count` must be >= 1")));
    }
    Ok(StreamSpec {
        kind,
        class: parse_class(sec, WHAT)?,
        count,
        deadline_s: parse_deadline(sec, WHAT)?,
        menu: parse_menu(req(sec, "menu", WHAT)?.as_str("menu")?, WHAT)?,
    })
}

fn parse_at(sec: &Section, what: &str) -> Result<f64> {
    let at = num_or(sec, "at", 0.0)?;
    if !(at.is_finite() && at >= 0.0) {
        return Err(Error::Config(format!(
            "{what}: `at` must be finite and non-negative, got {at}"
        )));
    }
    Ok(at)
}

fn parse_request(sec: &Section) -> Result<FixedRequest> {
    const WHAT: &str = "[[request]]";
    let reps = match get(sec, "reps") {
        Some(v) => v.as_u64("reps")? as u32,
        None => 1,
    };
    if reps == 0 {
        return Err(Error::Config(format!("{WHAT}: `reps` must be >= 1")));
    }
    Ok(FixedRequest {
        at: parse_at(sec, WHAT)?,
        size: parse_size(req(sec, "size", WHAT)?.as_str("size")?, WHAT)?,
        reps,
        class: parse_class(sec, WHAT)?,
        deadline_s: parse_deadline(sec, WHAT)?,
    })
}

fn parse_fault(sec: &Section) -> Result<Fault> {
    const WHAT: &str = "[[fault]]";
    let kind = req(sec, "kind", WHAT)?.as_str("kind")?;
    let at = parse_at(sec, WHAT)?;
    let shard = |sec: &Section| -> Result<usize> {
        Ok(req(sec, "shard", WHAT)?.as_u64("shard")? as usize)
    };
    match kind {
        "crash" => Ok(Fault::Crash {
            at,
            shard: shard(sec)?,
        }),
        "restart" => Ok(Fault::Restart {
            at,
            shard: shard(sec)?,
        }),
        "slow" => Ok(Fault::Slow {
            at,
            shard: shard(sec)?,
            factor: parse_positive(sec, "factor", WHAT)?,
        }),
        "spike" => {
            let count = req(sec, "count", WHAT)?.as_u64("count")? as usize;
            if count == 0 {
                return Err(Error::Config(format!("{WHAT}: spike `count` must be >= 1")));
            }
            Ok(Fault::Spike {
                at,
                rate_rps: parse_positive(sec, "rate_rps", WHAT)?,
                count,
                class: parse_class(sec, WHAT)?,
                deadline_s: parse_deadline(sec, WHAT)?,
                menu: parse_menu(req(sec, "menu", WHAT)?.as_str("menu")?, WHAT)?,
            })
        }
        "join" => Ok(Fault::Join {
            at,
            machine: preset_config(req(sec, "preset", WHAT)?.as_str("preset")?, WHAT)?,
            seed: match get(sec, "seed") {
                Some(v) => Some(v.as_u64("seed")?),
                None => None,
            },
        }),
        "drain" => Ok(Fault::Drain {
            at,
            shard: shard(sec)?,
        }),
        "cap" => Ok(Fault::PowerCap {
            at,
            // Absent `cap_w` lifts the cap.
            cap_w: match get(sec, "cap_w") {
                Some(_) => Some(parse_positive(sec, "cap_w", WHAT)?),
                None => None,
            },
        }),
        other => Err(Error::Config(format!(
            "{WHAT}: `kind` must be \"crash\", \"restart\", \"slow\", \"spike\", \"join\", \
             \"drain\" or \"cap\", got \"{other}\""
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Result<Scenario> {
        parse_scenario(text)
    }

    #[test]
    fn full_schema_round_trips_into_types() {
        let sc = parse(
            r#"
            name = "everything"
            seed = 42
            driver = "wallclock"
            queue = "spjf"
            gate = "per_shard"
            work_stealing = 1
            standalone_bypass = 1
            dynamic = 1
            deadline_policy = "downclass"
            deadline_slack = 0.8
            min_gain = 1.1
            batch_window_s = 0.02
            batch_max_members = 4

            [[shard]]
            preset = "gpu_node"
            count = 2

            [[shard]]
            preset = "cpu_node"

            [[arrivals]]
            process = "onoff"
            class = "batch"
            rate_on_rps = 40.0
            rate_off_rps = 2.0
            mean_on_s = 0.5
            mean_off_s = 1.0
            count = 6
            menu = "512x256x128*2"

            [[request]]
            at = 0.1
            size = "1024"
            reps = 3
            class = "interactive"
            deadline_s = 0.5

            [[fault]]
            kind = "slow"
            at = 1.0
            shard = 2
            factor = 0.4
        "#,
        )
        .expect("parse");
        assert_eq!(sc.machines.len(), 3);
        assert_eq!(sc.driver, DriverKind::WallClock);
        assert_eq!(sc.opts.shard.policy, QueuePolicy::Spjf);
        assert_eq!(sc.opts.shard.deadline_policy, DeadlinePolicy::Downclass);
        assert!(sc.opts.shard.dynamic);
        assert!(matches!(
            sc.opts.batching,
            BatchPolicy::Windowed(w) if w.max_members == 4 && w.window_s == 0.02
        ));
        assert!(matches!(sc.streams[0].kind, StreamKind::OnOff { .. }));
        assert_eq!(sc.requests[0].size, GemmSize::new(1024, 1024, 1024));
        assert_eq!(sc.requests[0].deadline_s, Some(0.5));
        assert!(matches!(sc.faults[0], Fault::Slow { shard: 2, .. }));
    }

    #[test]
    fn parses_phased_autoscaler_and_membership_faults() {
        let sc = parse(
            r#"
            name = "elastic"
            [[shard]]
            preset = "mach1"

            [[autoscaler]]
            pool = "mach2*2, gpu_node"
            eval_interval_s = 0.5
            scale_up_pressure_s = 1.5
            scale_down_pressure_s = 0.1
            scale_down_evals = 2
            profile_seed = 99

            [[arrivals]]
            process = "phased"
            phases = "8.0:2.0, 0.5:2.0"
            count = 10
            menu = "64"

            [[fault]]
            kind = "join"
            at = 1.0
            preset = "cpu_node"
            seed = 7

            [[fault]]
            kind = "drain"
            at = 3.0
            shard = 1
        "#,
        )
        .expect("parse");
        let scaler = sc.opts.autoscaler.as_ref().expect("autoscaler policy");
        assert_eq!(scaler.pool.len(), 3);
        assert_eq!(scaler.eval_interval_s, 0.5);
        assert_eq!(scaler.scale_down_evals, 2);
        assert_eq!(scaler.profile_seed, 99);
        assert!(matches!(
            sc.streams[0].kind,
            StreamKind::Phased { ref phases }
                if phases.len() == 2 && phases[0].rate_rps == 8.0 && phases[1].dur_s == 2.0
        ));
        assert!(matches!(sc.faults[0], Fault::Join { seed: Some(7), .. }));
        // Shard 1 only exists after the join: the bound counts it.
        assert!(matches!(sc.faults[1], Fault::Drain { shard: 1, .. }));
    }

    #[test]
    fn parses_power_table_and_cap_fault() {
        let sc = parse(
            r#"
            name = "powered"
            [[shard]]
            preset = "mach2"
            count = 2

            [[power]]
            cap_w = 900.0
            parked_frac = 0.25
            objective = "energy"
            slack = 2.0

            [[fault]]
            kind = "cap"
            at = 1.0
            cap_w = 600.0

            [[fault]]
            kind = "cap"
            at = 2.0
        "#,
        )
        .expect("parse");
        assert_eq!(sc.opts.power.cap_w, Some(900.0));
        assert_eq!(sc.opts.power.parked_frac, 0.25);
        assert_eq!(sc.opts.objective, RouteObjective::EnergyAware { slack: 2.0 });
        assert!(matches!(
            sc.faults[0],
            Fault::PowerCap {
                cap_w: Some(c), ..
            } if c == 600.0
        ));
        // A `cap` fault with no `cap_w` lifts the cap.
        assert!(matches!(sc.faults[1], Fault::PowerCap { cap_w: None, .. }));
    }

    #[test]
    fn rejects_bad_power_tables() {
        let with_power = |body: &str| {
            parse(&format!(
                "name = \"x\"\n[[shard]]\npreset = \"mach1\"\n[[power]]\n{body}"
            ))
        };
        // Defaults alone are fine (latency objective, no cap).
        let sc = with_power("").expect("empty power table");
        assert_eq!(sc.opts.objective, RouteObjective::Latency);
        assert_eq!(sc.opts.power.cap_w, None);
        // Out-of-range knobs.
        assert!(with_power("cap_w = 0.0").is_err());
        assert!(with_power("parked_frac = 1.5").is_err());
        assert!(with_power("objective = \"energy\"\nslack = 0.5").is_err());
        assert!(with_power("objective = \"thermal\"").is_err());
        // `slack` is an energy-objective knob.
        assert!(with_power("objective = \"latency\"\nslack = 2.0").is_err());
        // At most one [[power]] table.
        assert!(parse(
            "name = \"x\"\n[[shard]]\npreset = \"mach1\"\n[[power]]\ncap_w = 100.0\n[[power]]\ncap_w = 200.0"
        )
        .is_err());
    }

    #[test]
    fn menu_dsl_parses_squares_and_triples() {
        let menu = parse_menu("256*4, 512x256x128, 64 * 2", "test").unwrap();
        assert_eq!(menu[0], (GemmSize::new(256, 256, 256), 4));
        assert_eq!(menu[1], (GemmSize::new(512, 256, 128), 1));
        assert_eq!(menu[2], (GemmSize::new(64, 64, 64), 2));
    }

    #[test]
    fn rejects_bad_inputs() {
        // Missing name.
        assert!(parse("seed = 1\n[[shard]]\npreset = \"mach1\"").is_err());
        // No shards.
        assert!(parse("name = \"x\"").is_err());
        // Unknown preset.
        assert!(parse("name = \"x\"\n[[shard]]\npreset = \"nope\"").is_err());
        // Fault shard out of range.
        assert!(parse(
            "name = \"x\"\n[[shard]]\npreset = \"mach1\"\n[[fault]]\nkind = \"crash\"\nat = 1.0\nshard = 3"
        )
        .is_err());
        // Unknown fault kind.
        assert!(parse(
            "name = \"x\"\n[[shard]]\npreset = \"mach1\"\n[[fault]]\nkind = \"meteor\"\nat = 1.0"
        )
        .is_err());
        // onoff with on-rate below off-rate.
        assert!(parse(
            "name = \"x\"\n[[shard]]\npreset = \"mach1\"\n[[arrivals]]\nprocess = \"onoff\"\nrate_on_rps = 1.0\nrate_off_rps = 2.0\nmean_on_s = 1.0\nmean_off_s = 1.0\ncount = 1\nmenu = \"64\""
        )
        .is_err());
        // Zero-dimension size.
        assert!(parse_size("0x2x3", "test").is_err());
        // Empty menu.
        assert!(parse_menu(" , ", "test").is_err());
        // Drain beyond machines + scheduled joins.
        assert!(parse(
            "name = \"x\"\n[[shard]]\npreset = \"mach1\"\n[[fault]]\nkind = \"join\"\npreset = \"mach2\"\n[[fault]]\nkind = \"drain\"\nat = 1.0\nshard = 2"
        )
        .is_err());
        // Second [[autoscaler]] table.
        assert!(parse(
            "name = \"x\"\n[[shard]]\npreset = \"mach1\"\n[[autoscaler]]\npool = \"mach2\"\n[[autoscaler]]\npool = \"mach2\""
        )
        .is_err());
        // Autoscaler with an unknown pool preset.
        assert!(parse(
            "name = \"x\"\n[[shard]]\npreset = \"mach1\"\n[[autoscaler]]\npool = \"warp_drive\""
        )
        .is_err());
        // Phase items must be rate:dur pairs.
        assert!(parse_phases("4.0", "test").is_err());
        assert!(parse_phases("4.0:0", "test").is_err());
        assert!(parse_phases(" , ", "test").is_err());
        // Unknown driver.
        assert!(
            parse("name = \"x\"\ndriver = \"sundial\"\n[[shard]]\npreset = \"mach1\"").is_err()
        );
    }
}
