//! The serving layer: POAS as installation-time infrastructure behind a
//! request stream.
//!
//! The paper frames the framework as something deployed once and then
//! consulted as "real matrix multiplication workloads arrive" (§4.1.2),
//! and ALP (Hill & Reddi) presumes many concurrent workloads. This
//! module is that deployment shape, layered so each concern lives in
//! exactly one component:
//!
//! * [`qos`] — the QoS vocabulary: [`QosClass`] service tiers
//!   (Interactive / Standard / Batch with 4 : 2 : 1 scheduling weights)
//!   attached to every [`GemmRequest`], plus the [`DeadlinePolicy`]
//!   deciding whether an infeasible SLO is rejected or down-classed;
//! * [`admission`] — the [`Admission`] gates: **one per shard**, each
//!   running the §6 suitability detector against *that shard's*
//!   installation-time model, so heterogeneous clusters score every
//!   arrival with the profile of the machine actually being considered;
//!   verdicts and service predictions are memoized in a bounded LRU
//!   keyed by interned `(shape id, reps, members)` handles; deadline-bound
//!   requests are additionally probed with the deadline-constrained LP
//!   reused from the energy formulation, again per shard;
//! * [`batch`] — admission-time batching: the [`BatchFormer`] holds
//!   *small* standalone-bound arrivals in a short window and fuses
//!   compatible ones (same `GemmSize` shape class, same reps, adjacent
//!   QoS classes — see the module doc for the full predicate and the
//!   window/flush rules) into one row-stacked [`FusedBatch`] the gate
//!   re-scores as a batch, so work that would bypass one device at a
//!   time co-executes like any large GEMM instead; SLO-bound members
//!   flush their window early (deadline pressure) so batching never
//!   pushes an admitted deadline past its budget;
//! * [`shard`] — the [`ExecutorShard`]: one machine's simulator,
//!   installation-time profile, [`PlanCache`], local queue and optional
//!   dynamic-scheduler loop; dispatch (including the standalone bypass
//!   pairing and per-tenant completion attribution) is shard-local, and
//!   an infeasible plan completes as [`ExecMode::Rejected`] instead of
//!   panicking;
//! * [`clock`] — time, abstracted: the [`Clock`] trait with the
//!   core-owned [`VirtualClock`] (simulated service time, advanced by
//!   the event loop) and the shared-origin [`MonotonicClock`] (real
//!   elapsed seconds) the wall-clock driver hands its workers;
//! * [`driver`] — the two ways to advance the core: the
//!   [`VirtualDriver`] (the deterministic heap loop, byte-identical to
//!   driving the cluster directly) and the [`WallClockDriver`]
//!   (actor-per-shard worker threads fed by the core's decision tap
//!   over bounded command channels, reporting on one unified event
//!   stream — same decisions, really concurrent execution; the seam
//!   where a PJRT-backed [`driver::wall_clock::Executor`] plugs in);
//! * [`cluster`] — the [`Cluster`] front-end: N shards (possibly over
//!   *different* machines — built through the fluent [`ClusterBuilder`]
//!   via [`Cluster::builder`] with the node presets in
//!   [`crate::config::presets`]) driven by an event-driven virtual-time
//!   loop (a binary heap of arrival / wake / shard-free events),
//!   deadline-admitting SLO-bound arrivals against the predicted
//!   sojourn at shards whose own model can meet the SLO, routing each
//!   accepted request to the shard with the earliest class-weighted
//!   predicted finish *under that shard's own gate verdict* (exact
//!   full scan by default, or sampled power-of-d-choices routing via
//!   [`RoutePolicy::Sampled`] at scale — see `docs/hotpath.md`;
//!   [`RouteObjective::EnergyAware`] instead prefers the cheapest
//!   predicted-joules shard whose finish stays inside the SLO slack,
//!   and [`PowerOptions`] meters per-shard watts, enforces a
//!   cluster-wide power cap at admission and bills drained shards at a
//!   low-power parked rate — see `docs/energy.md`), and
//!   letting idle shards steal queued work from the shard with the
//!   largest class-weighted backlog (stolen requests are re-gated under
//!   the thief's model, and thieves prefer work their own hardware
//!   serves disproportionately well);
//! * [`index`] — the [`TournamentTree`]: incremental argmin/argmax
//!   indexes over per-shard keys (predicted finish for routing,
//!   weighted backlog for stealing), so front-end decisions cost
//!   O(log shards) maintenance instead of an O(shards) scan per
//!   arrival;
//! * [`arrivals`] — online arrival processes: deterministic Poisson
//!   traces ([`PoissonArrivals`]), per-class Poisson mixes
//!   ([`MixedArrivals`]), bursty Markov-modulated on/off streams
//!   ([`OnOffArrivals`]), scheduled piecewise-Poisson phase cycles
//!   ([`PhasedArrivals`] — diurnal day/night and ramp profiles with a
//!   deterministic timeline) and replayable fixed traces, so reports
//!   measure queueing delay and p50/p99 sojourn time — per tier —
//!   under offered load instead of draining a batch;
//! * [`elastic`] — elastic membership: the cluster's shard *set*
//!   changes mid-run through join events (a new shard is profiled at
//!   provision time, gets its own gate and a cold cache, and both
//!   tournament trees grow a leaf) and graceful drains (routing stops,
//!   in-flight work finishes untouched, queued work redistributes
//!   through admission), plus the [`AutoscalerPolicy`] that drives
//!   both from predicted backlog and deadline-risk against a preset
//!   machine pool — billed as machine-seconds and utilization on the
//!   [`ServiceReport`];
//! * [`scenario`] — declarative fault-injection scenarios: a TOML
//!   file describing the cluster, the arrival mix, an optional
//!   autoscaler pool and a schedule of injected faults (shard
//!   crashes/restarts, straggler drift, load spikes, membership joins
//!   and graceful drains), executed deterministically on the cluster's
//!   event loop
//!   via [`scenario::Scenario`] and folded into stable JSON digests
//!   ([`scenario::digest`]) that the `scenario_runner` binary diffs
//!   against the blessed corpus in CI (see `docs/scenarios.md`);
//! * [`server`] — the classic single-machine [`Server`], now a thin
//!   wrapper over a 1-shard cluster (same submit / run-to-completion /
//!   report surface; the old public fields and `step()` gave way to
//!   the layered components, reachable via `cluster()` / `shard()` /
//!   `admission()`);
//! * [`cache`] — the [`PlanCache`]: Optimize-phase output memoized by
//!   `(shape, model epoch)` so repeated shapes skip the MILP solve; a
//!   model refresh bumps the epoch and invalidates everything;
//! * [`queue`] — per-class lanes drained by a smooth weighted
//!   round-robin (no non-empty class ever starves), FIFO and
//!   shortest-predicted-job-first orderings within a lane, the backlog
//!   accounting the router reads, and the scan used by the standalone
//!   bypass;
//! * [`request`] — request/outcome records, per-shard stats and the
//!   per-session latency/throughput report, with per-class breakdowns
//!   (p50/p99 sojourn, deadline-hit rate, denials) via
//!   [`request::ClassBreakdown`] and per-shard model fingerprints plus
//!   the realized-vs-predicted **placement quality** metric
//!   ([`ShardStats::placement_ratio`],
//!   [`ServiceReport::placement_quality`]) that shows whether routing's
//!   per-shard predictions are honoured by the machines.
//!
//! See `rust/tests/service_scenarios.rs` for the deterministic scenario
//! harness (batch and Poisson), `rust/benches/service_throughput.rs`
//! for the cache and policy numbers, and
//! `rust/benches/cluster_scaling.rs` for throughput versus shard count.

pub mod admission;
pub mod arrivals;
pub mod batch;
pub mod cache;
pub mod clock;
pub mod cluster;
pub mod driver;
pub mod elastic;
pub mod index;
pub mod qos;
pub mod queue;
pub mod request;
pub mod scenario;
pub mod server;
pub mod shard;

pub use admission::Admission;
pub use arrivals::{
    fixed_trace, Arrival, ClassLoad, MixedArrivals, OnOffArrivals, Phase, PhasedArrivals,
    PoissonArrivals,
};
pub use batch::{BatchFormer, BatchMember, BatchPolicy, BatchWindow, FusedBatch, ShapeClass};
pub use cache::{LruMap, PlanCache};
pub use clock::{Clock, MonotonicClock, VirtualClock};
pub use cluster::{
    Cluster, ClusterBuilder, ClusterOptions, DispatchNote, GatePolicy, PowerOptions, RouteObjective,
    RoutePolicy, TapAction,
};
#[allow(deprecated)]
pub use cluster::HeterogeneousSpec;
pub use driver::{
    Driver, DriverKind, SimulatedExecutor, VirtualDriver, WallClockDriver, WallClockOptions,
    WallClockStats,
};
pub use elastic::AutoscalerPolicy;
pub use index::{Ranking, TournamentTree};
pub use qos::{DeadlinePolicy, QosClass};
pub use queue::{QueuePolicy, QueuedRequest, RequestQueue};
pub use request::{
    BatchId, ClassBreakdown, ExecMode, GemmRequest, ServedRequest, ServiceReport, ShardStats,
};
pub use scenario::{Fault, FixedRequest, Scenario, StreamKind, StreamSpec};
pub use server::{Server, ServerOptions};
pub use shard::{DispatchResult, ExecutorShard};
