//! The serving layer: POAS as installation-time infrastructure behind a
//! request stream.
//!
//! The paper frames the framework as something deployed once and then
//! consulted as "real matrix multiplication workloads arrive" (§4.1.2),
//! and ALP (Hill & Reddi) presumes many concurrent workloads. This
//! module is that deployment shape, built on [`crate::coordinator`]:
//!
//! * [`server`] — a multi-tenant [`Server`]: owns the machine + profile,
//!   gates every request through the §6 suitability detector, dispatches
//!   under a pluggable queue policy, and optionally closes the loop with
//!   the dynamic scheduler;
//! * [`cache`] — the [`PlanCache`]: Optimize-phase output memoized by
//!   `(shape, model epoch)` so repeated shapes skip the MILP solve; a
//!   model refresh bumps the epoch and invalidates everything;
//! * [`queue`] — FIFO and shortest-predicted-job-first orderings, plus
//!   the scan used by the standalone bypass (a small standalone-bound
//!   request co-scheduled on a device the plan leaves idle);
//! * [`request`] — request/outcome records and the per-session
//!   latency/throughput report.
//!
//! See `rust/tests/service_scenarios.rs` for the deterministic scenario
//! harness and `rust/benches/service_throughput.rs` for the cache and
//! policy numbers.

pub mod cache;
pub mod queue;
pub mod request;
pub mod server;

pub use cache::PlanCache;
pub use queue::{QueuePolicy, QueuedRequest, RequestQueue};
pub use request::{ExecMode, GemmRequest, ServedRequest, ServiceReport};
pub use server::{Server, ServerOptions};
