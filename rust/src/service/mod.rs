//! The serving layer: POAS as installation-time infrastructure behind a
//! request stream.
//!
//! The paper frames the framework as something deployed once and then
//! consulted as "real matrix multiplication workloads arrive" (§4.1.2),
//! and ALP (Hill & Reddi) presumes many concurrent workloads. This
//! module is that deployment shape, layered so each concern lives in
//! exactly one component:
//!
//! * [`admission`] — the [`Admission`] front-end gate: every request
//!   passes the §6 suitability detector once; verdicts and service
//!   predictions are memoized in a bounded LRU keyed by
//!   `(shape, model epoch)`;
//! * [`shard`] — the [`ExecutorShard`]: one machine's simulator,
//!   installation-time profile, [`PlanCache`], local queue and optional
//!   dynamic-scheduler loop; dispatch (including the standalone bypass
//!   pairing and per-tenant completion attribution) is shard-local, and
//!   an infeasible plan completes as [`ExecMode::Rejected`] instead of
//!   panicking;
//! * [`cluster`] — the [`Cluster`] front-end: N shards driven by an
//!   event-driven virtual-time loop (a binary heap of arrival / wake /
//!   shard-free events), routing each admitted request to the shard
//!   with the earliest predicted finish and letting idle shards steal
//!   queued work from backlogged ones;
//! * [`arrivals`] — online arrival processes: deterministic Poisson
//!   traces ([`PoissonArrivals`]) and replayable fixed traces, so
//!   reports measure queueing delay and p50/p99 sojourn time under
//!   offered load instead of draining a batch;
//! * [`server`] — the classic single-machine [`Server`], now a thin
//!   wrapper over a 1-shard cluster (same submit / run-to-completion /
//!   report surface; the old public fields and `step()` gave way to
//!   the layered components, reachable via `cluster()` / `shard()` /
//!   `admission()`);
//! * [`cache`] — the [`PlanCache`]: Optimize-phase output memoized by
//!   `(shape, model epoch)` so repeated shapes skip the MILP solve; a
//!   model refresh bumps the epoch and invalidates everything;
//! * [`queue`] — FIFO and shortest-predicted-job-first orderings, the
//!   backlog accounting the router reads, and the scan used by the
//!   standalone bypass;
//! * [`request`] — request/outcome records, per-shard stats and the
//!   per-session latency/throughput report.
//!
//! See `rust/tests/service_scenarios.rs` for the deterministic scenario
//! harness (batch and Poisson), `rust/benches/service_throughput.rs`
//! for the cache and policy numbers, and
//! `rust/benches/cluster_scaling.rs` for throughput versus shard count.

pub mod admission;
pub mod arrivals;
pub mod cache;
pub mod cluster;
pub mod queue;
pub mod request;
pub mod server;
pub mod shard;

pub use admission::Admission;
pub use arrivals::{fixed_trace, Arrival, PoissonArrivals};
pub use cache::PlanCache;
pub use cluster::{Cluster, ClusterOptions};
pub use queue::{QueuePolicy, QueuedRequest, RequestQueue};
pub use request::{ExecMode, GemmRequest, ServedRequest, ServiceReport, ShardStats};
pub use server::{Server, ServerOptions};
pub use shard::{DispatchResult, ExecutorShard};
