//! Incremental tournament-tree indexes over per-shard scalar keys.
//!
//! The cluster front-end answers two argmin/argmax questions on every
//! hot-path decision: *which shard has the earliest predicted finish*
//! (routing) and *which shard has the largest class-weighted backlog*
//! (steal-victim selection). Scanning every shard per decision is
//! O(shards) — fine at 4, hopeless at 400 (HTS, PAPERS.md, argues
//! scheduler decisions only reach ALP scale through aggregation /
//! indexing, not per-arrival scans). A [`TournamentTree`] keeps the
//! winner in O(1) with O(log shards) updates, so the cluster pays the
//! scan cost once per *mutation* of a shard's key, not once per
//! *decision*.
//!
//! The tree is a classic segment tree of winners: leaf `i` holds shard
//! `i`'s key, every internal node holds the index of the winning leaf
//! of its subtree. Ties break toward the **lower index**, which is
//! exactly the tie-break the old linear scans used (first strict
//! improvement wins), so swapping the scans for the tree changes no
//! decision. Shards that must not win (down, empty queue) park on the
//! sentinel key ([`TournamentTree::disable`]), and [`winner`] returns
//! `None` when every leaf is disabled.

/// Whether the tree tracks the minimum or the maximum key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ranking {
    /// Winner is the leaf with the smallest key (router: earliest
    /// predicted finish).
    Min,
    /// Winner is the leaf with the largest key (stealing: largest
    /// weighted backlog).
    Max,
}

/// A fixed-capacity tournament (winner) tree over `f64` keys.
///
/// Built once for `n` leaves; `update` is O(log n), `winner` is O(1),
/// `winner_excluding` is O(log n). See the module doc for why the
/// cluster keeps two of these instead of scanning shards.
#[derive(Debug, Clone)]
pub struct TournamentTree {
    ranking: Ranking,
    /// Per-leaf keys; disabled leaves hold `sentinel()`.
    keys: Vec<f64>,
    /// Winner index per internal node, 1-based heap layout: node 1 is
    /// the root, node `i`'s children are `2i` and `2i+1`. Leaves start
    /// at `base`.
    tree: Vec<usize>,
    /// First leaf slot in `tree` (a power of two >= n).
    base: usize,
    /// Leaf marker for "no shard here" padding slots.
    invalid: usize,
}

impl TournamentTree {
    /// An index over `n` leaves, all starting disabled.
    pub fn new(n: usize, ranking: Ranking) -> Self {
        let base = n.max(1).next_power_of_two();
        let mut t = TournamentTree {
            ranking,
            keys: vec![f64::NAN; n],
            tree: vec![n; 2 * base],
            base,
            invalid: n,
        };
        for i in 0..n {
            t.keys[i] = t.sentinel();
            t.tree[t.base + i] = i;
        }
        for node in (1..t.base).rev() {
            t.tree[node] = t.play(t.tree[2 * node], t.tree[2 * node + 1]);
        }
        t
    }

    /// Number of leaves.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// True when the tree has no leaves.
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// The key that can never win: +inf for [`Ranking::Min`], -inf for
    /// [`Ranking::Max`].
    fn sentinel(&self) -> f64 {
        match self.ranking {
            Ranking::Min => f64::INFINITY,
            Ranking::Max => f64::NEG_INFINITY,
        }
    }

    /// Winner of a two-leaf match. Lower index wins ties, matching the
    /// first-strict-improvement tie-break of the linear scans this tree
    /// replaces.
    fn play(&self, a: usize, b: usize) -> usize {
        if a == self.invalid {
            return b;
        }
        if b == self.invalid {
            return a;
        }
        let (ka, kb) = (self.keys[a], self.keys[b]);
        let b_wins = match self.ranking {
            Ranking::Min => kb < ka,
            Ranking::Max => kb > ka,
        };
        if b_wins ^ (b < a) {
            // Exactly one of "b strictly beats a" / "b is the lower
            // index" holds; strict beat dominates, otherwise lower
            // index keeps the slot.
            if b_wins {
                b
            } else {
                a
            }
        } else if b_wins {
            b
        } else {
            a
        }
    }

    /// Set leaf `i`'s key and replay its path to the root. O(log n).
    pub fn update(&mut self, i: usize, key: f64) {
        debug_assert!(!key.is_nan(), "tournament keys must be orderable");
        self.keys[i] = key;
        let mut node = (self.base + i) / 2;
        while node >= 1 {
            self.tree[node] = self.play(self.tree[2 * node], self.tree[2 * node + 1]);
            node /= 2;
        }
    }

    /// Park leaf `i` on the sentinel so it cannot win (down shard,
    /// empty queue).
    pub fn disable(&mut self, i: usize) {
        let s = self.sentinel();
        self.update(i, s);
    }

    /// Leaf `i`'s current key (the sentinel when disabled).
    pub fn key(&self, i: usize) -> f64 {
        self.keys[i]
    }

    /// True when leaf `i` holds a real key (not the sentinel).
    pub fn is_enabled(&self, i: usize) -> bool {
        self.keys[i] != self.sentinel()
    }

    /// The winning leaf, or `None` when every leaf is disabled. O(1).
    pub fn winner(&self) -> Option<usize> {
        let w = self.tree[1];
        (w != self.invalid && self.is_enabled(w)).then_some(w)
    }

    /// The winning leaf with leaf `skip` excluded — the steal path's
    /// "best victim that is not the thief". O(log n): temporarily
    /// parks `skip` on the sentinel and restores it.
    pub fn winner_excluding(&mut self, skip: usize) -> Option<usize> {
        let saved = self.keys[skip];
        self.disable(skip);
        let w = self.winner();
        self.update(skip, saved);
        w
    }

    /// Recompute the winner of every leaf by linear scan — the oracle
    /// the incremental tree must agree with (debug assertions and the
    /// property tests call this).
    pub fn scan_winner(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for i in 0..self.keys.len() {
            if !self.is_enabled(i) {
                continue;
            }
            match best {
                None => best = Some(i),
                Some(b) => {
                    let better = match self.ranking {
                        Ranking::Min => self.keys[i] < self.keys[b],
                        Ranking::Max => self.keys[i] > self.keys[b],
                    };
                    if better {
                        best = Some(i);
                    }
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn empty_and_all_disabled_have_no_winner() {
        let t = TournamentTree::new(0, Ranking::Min);
        assert!(t.is_empty());
        assert_eq!(t.winner(), None);
        let t = TournamentTree::new(5, Ranking::Max);
        assert_eq!(t.len(), 5);
        assert_eq!(t.winner(), None);
        assert_eq!(t.scan_winner(), None);
    }

    #[test]
    fn min_tree_tracks_updates_and_ties_break_low() {
        let mut t = TournamentTree::new(4, Ranking::Min);
        t.update(2, 3.0);
        assert_eq!(t.winner(), Some(2));
        t.update(0, 3.0); // tie: lower index wins
        assert_eq!(t.winner(), Some(0));
        t.update(3, 1.0);
        assert_eq!(t.winner(), Some(3));
        t.disable(3);
        assert_eq!(t.winner(), Some(0));
        assert!(!t.is_enabled(3));
        assert_eq!(t.key(0), 3.0);
    }

    #[test]
    fn max_tree_and_winner_excluding() {
        let mut t = TournamentTree::new(3, Ranking::Max);
        t.update(0, 5.0);
        t.update(1, 9.0);
        t.update(2, 7.0);
        assert_eq!(t.winner(), Some(1));
        assert_eq!(t.winner_excluding(1), Some(2));
        // The exclusion is transient: the winner is restored after.
        assert_eq!(t.winner(), Some(1));
        assert_eq!(t.key(1), 9.0);
        assert_eq!(t.winner_excluding(0), Some(1));
    }

    #[test]
    fn single_leaf_tree() {
        let mut t = TournamentTree::new(1, Ranking::Min);
        assert_eq!(t.winner(), None);
        t.update(0, 2.0);
        assert_eq!(t.winner(), Some(0));
        assert_eq!(t.winner_excluding(0), None);
        assert_eq!(t.winner(), Some(0));
    }

    #[test]
    fn tree_agrees_with_linear_scan_after_every_mutation() {
        // Deterministic fuzz across sizes (including non-powers of two)
        // and both rankings: after every update/disable the incremental
        // winner must equal the from-scratch scan.
        for &n in &[1usize, 2, 3, 5, 8, 13, 64, 100] {
            for ranking in [Ranking::Min, Ranking::Max] {
                let mut t = TournamentTree::new(n, ranking);
                let mut rng = Rng::new(0xA11CE ^ n as u64);
                for step in 0..400 {
                    let i = rng.below(n as u64) as usize;
                    if rng.below(5) == 0 {
                        t.disable(i);
                    } else {
                        // Coarse keys force plenty of exact ties.
                        t.update(i, rng.below(8) as f64);
                    }
                    assert_eq!(
                        t.winner(),
                        t.scan_winner(),
                        "n={n} {ranking:?} step={step}"
                    );
                    if n > 1 {
                        let skip = rng.below(n as u64) as usize;
                        let saved = t.key(skip);
                        let want = {
                            let mut probe = t.clone();
                            probe.disable(skip);
                            probe.scan_winner()
                        };
                        assert_eq!(t.winner_excluding(skip), want);
                        assert_eq!(t.key(skip), saved, "exclusion must restore the key");
                    }
                }
            }
        }
    }
}
