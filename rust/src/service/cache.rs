//! Plan memoization: skip the Optimize-phase (MI)LP solve for repeated
//! shapes.
//!
//! `perf_hotpath` shows the plan build (LP/MILP + adapt) is the hot path
//! of request admission; a serving workload repeats shapes constantly
//! (the paper profiles at installation time precisely because "real
//! matrix multiplication workloads arrive" later, §4.1.2). The cache
//! memoizes [`build_plan`] output keyed by `(GemmSize, model epoch)`:
//! the epoch is bumped whenever the dynamic scheduler refreshes the
//! performance model, so no plan computed against a stale model can ever
//! be returned — even stale entries that survived eviction would miss on
//! the epoch component of the key (they are additionally dropped
//! eagerly).

use crate::adapt::AdaptRules;
use crate::error::Result;
use crate::predict::PerfModel;
use crate::schedule::{build_plan, PlanOptions, SchedulePlan};
use crate::workload::GemmSize;
use std::collections::HashMap;
use std::hash::{BuildHasher, Hash, Hasher};

/// A fast, deterministic multiply-rotate hasher (the FxHash scheme) for
/// the small `Copy` keys the front-end memos use.
///
/// The default `HashMap` hasher (SipHash) is keyed for HashDoS
/// resistance, which the hot path does not need: memo keys are
/// scheduler-internal shape handles, not attacker-controlled strings.
/// Fx hashes a small fixed-size key in a few cycles and — unlike the
/// randomly seeded default — is deterministic across processes, which
/// keeps replay behaviour easy to reason about.
#[derive(Debug, Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

/// Odd multiplier from the FxHash scheme (a 64-bit truncation of pi).
const FX_SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(FX_SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for chunk in &mut chunks {
            let mut buf = [0u8; 8];
            buf.copy_from_slice(chunk);
            self.add(u64::from_le_bytes(buf));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rest.len()].copy_from_slice(rest);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

/// `BuildHasher` for [`FxHasher`]; plugs into `HashMap::default()`.
#[derive(Debug, Default, Clone, Copy)]
pub struct FxBuildHasher;

impl BuildHasher for FxBuildHasher {
    type Hasher = FxHasher;

    #[inline]
    fn build_hasher(&self) -> FxHasher {
        FxHasher::default()
    }
}

/// A `HashMap` hashed with [`FxHasher`] — the front-end's map type for
/// scheduler-internal keys.
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

/// A bounded map with touch-on-hit LRU eviction — the storage
/// primitive behind [`PlanCache`] and the [`super::Admission`] memos,
/// so the recency/eviction logic lives in exactly one place.
///
/// Recency is tracked by a monotonically increasing touch stamp per
/// entry, so the hit path ([`LruMap::get_touch`]) is O(1); the O(len)
/// scan for the least recently used entry happens only on an eviction.
/// Stamps are unique, so eviction order is deterministic even though
/// the underlying `HashMap` iteration order is not. Keys are hashed
/// with [`FxHasher`], so a hot-path memo lookup costs a few cycles of
/// hashing instead of a full SipHash round.
#[derive(Debug, Clone)]
pub struct LruMap<K, V> {
    /// Value plus the stamp of its most recent touch (hit or insert).
    map: FxHashMap<K, (V, u64)>,
    stamp: u64,
    capacity: usize,
}

impl<K: Hash + Eq + Copy, V> LruMap<K, V> {
    /// An empty map holding at most `capacity` entries (min 1).
    pub fn new(capacity: usize) -> Self {
        LruMap {
            map: FxHashMap::default(),
            stamp: 0,
            capacity: capacity.max(1),
        }
    }

    /// Number of live entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is stored.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Non-touching lookup (diagnostics/tests).
    pub fn peek(&self, key: &K) -> Option<&V> {
        self.map.get(key).map(|(v, _)| v)
    }

    /// Lookup that refreshes the entry's recency on a hit. O(1).
    pub fn get_touch(&mut self, key: &K) -> Option<&V> {
        self.stamp += 1;
        let stamp = self.stamp;
        match self.map.get_mut(key) {
            Some(entry) => {
                entry.1 = stamp;
                Some(&entry.0)
            }
            None => None,
        }
    }

    /// Insert an entry, evicting the least recently used past capacity.
    pub fn insert(&mut self, key: K, value: V) {
        self.stamp += 1;
        self.map.insert(key, (value, self.stamp));
        while self.map.len() > self.capacity {
            let oldest = self
                .map
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(k, _)| *k);
            match oldest {
                Some(k) => {
                    self.map.remove(&k);
                }
                None => break,
            }
        }
    }

    /// Drop every entry (capacity is retained).
    pub fn clear(&mut self) {
        self.map.clear();
    }
}

/// A bounded LRU memo of Optimize/Adapt output.
#[derive(Debug, Clone)]
pub struct PlanCache {
    store: LruMap<(GemmSize, u64), SchedulePlan>,
    epoch: u64,
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that had to solve.
    pub misses: u64,
    /// Epoch bumps performed (each dropped every cached plan).
    pub invalidations: u64,
}

impl PlanCache {
    /// New cache holding at most `capacity` plans (min 1).
    pub fn new(capacity: usize) -> Self {
        PlanCache {
            store: LruMap::new(capacity),
            epoch: 0,
            hits: 0,
            misses: 0,
            invalidations: 0,
        }
    }

    /// The current model epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.store.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    /// Fraction of lookups answered from the cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// The performance model changed (dynamic-scheduler refresh): any
    /// plan computed against the old model is wrong. Advances the epoch
    /// — which alone retires every existing key — and drops the entries.
    pub fn bump_epoch(&mut self) {
        self.epoch += 1;
        self.store.clear();
        self.invalidations += 1;
    }

    /// Non-counting lookup at the current epoch (diagnostics/tests).
    pub fn peek(&self, size: GemmSize) -> Option<&SchedulePlan> {
        self.store.peek(&(size, self.epoch))
    }

    /// Return the cached plan for `size` at the current epoch, or solve
    /// with [`build_plan`] and cache the result. The flag is `true` on a
    /// cache hit (the MILP solve was skipped).
    pub fn get_or_build(
        &mut self,
        model: &PerfModel,
        size: GemmSize,
        rules: &[AdaptRules],
        opts: &PlanOptions,
    ) -> Result<(SchedulePlan, bool)> {
        let key = (size, self.epoch);
        if let Some(plan) = self.store.get_touch(&key) {
            let plan = plan.clone();
            self.hits += 1;
            return Ok((plan, true));
        }
        self.misses += 1;
        let plan = build_plan(model, size, rules, opts)?;
        self.store.insert(key, plan.clone());
        Ok((plan, false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::predict::{profile, ProfileOptions};
    use crate::schedule::static_sched::rules_from_config;
    use crate::sim::SimMachine;

    fn fixture() -> (PerfModel, Vec<AdaptRules>, PlanOptions) {
        let cfg = presets::mach1();
        let mut sim = SimMachine::new(&cfg, 0);
        let model = profile(&mut sim, &ProfileOptions::default()).unwrap();
        (model, rules_from_config(&cfg), PlanOptions::default())
    }

    #[test]
    fn hit_returns_identical_plan() {
        let (model, rules, opts) = fixture();
        let size = GemmSize::square(20_000);
        let mut cache = PlanCache::new(8);
        let fresh = build_plan(&model, size, &rules, &opts).unwrap();
        let (first, hit1) = cache.get_or_build(&model, size, &rules, &opts).unwrap();
        let (second, hit2) = cache.get_or_build(&model, size, &rules, &opts).unwrap();
        assert!(!hit1);
        assert!(hit2);
        assert!(first.same_split(&fresh));
        assert!(second.same_split(&fresh));
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 1);
        assert!((cache.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn epoch_bump_invalidates_everything() {
        let (model, rules, opts) = fixture();
        let mut cache = PlanCache::new(8);
        for s in [10_000u64, 12_000, 14_000] {
            cache
                .get_or_build(&model, GemmSize::square(s), &rules, &opts)
                .unwrap();
        }
        assert_eq!(cache.len(), 3);
        cache.bump_epoch();
        assert!(cache.is_empty());
        assert_eq!(cache.epoch(), 1);
        assert_eq!(cache.invalidations, 1);
        assert!(cache.peek(GemmSize::square(10_000)).is_none());
        // The next lookup must re-solve.
        let (_, hit) = cache
            .get_or_build(&model, GemmSize::square(10_000), &rules, &opts)
            .unwrap();
        assert!(!hit);
    }

    #[test]
    fn capacity_evicts_oldest_first() {
        let (model, rules, opts) = fixture();
        let mut cache = PlanCache::new(2);
        let sizes = [
            GemmSize::square(10_000),
            GemmSize::square(12_000),
            GemmSize::square(14_000),
        ];
        for &s in &sizes {
            cache.get_or_build(&model, s, &rules, &opts).unwrap();
        }
        assert_eq!(cache.len(), 2);
        assert!(cache.peek(sizes[0]).is_none(), "oldest entry evicted");
        assert!(cache.peek(sizes[1]).is_some());
        assert!(cache.peek(sizes[2]).is_some());
    }

    #[test]
    fn lru_hit_refreshes_recency() {
        let (model, rules, opts) = fixture();
        let mut cache = PlanCache::new(2);
        let hot = GemmSize::square(10_000);
        cache.get_or_build(&model, hot, &rules, &opts).unwrap();
        for s in [12_000u64, 14_000, 16_000] {
            // Touch the hot shape between cold inserts: it must survive
            // the evictions that retire the cold entries.
            cache.get_or_build(&model, hot, &rules, &opts).unwrap();
            cache
                .get_or_build(&model, GemmSize::square(s), &rules, &opts)
                .unwrap();
        }
        assert!(cache.peek(hot).is_some(), "hot entry was evicted");
        assert_eq!(cache.misses, 4, "hot shape solved exactly once");
    }

    #[test]
    fn lru_map_touch_and_eviction() {
        let mut m: LruMap<u64, &'static str> = LruMap::new(2);
        assert!(m.is_empty());
        m.insert(1, "a");
        m.insert(2, "b");
        assert_eq!(m.get_touch(&1), Some(&"a")); // 1 is now most recent
        m.insert(3, "c"); // evicts 2, the least recently used
        assert_eq!(m.len(), 2);
        assert!(m.peek(&2).is_none());
        assert_eq!(m.peek(&1), Some(&"a"));
        assert_eq!(m.get_touch(&4), None);
        m.clear();
        assert!(m.is_empty());
        m.insert(5, "d");
        assert_eq!(m.peek(&5), Some(&"d"), "capacity survives clear");
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let (model, rules, opts) = fixture();
        let mut cache = PlanCache::new(0);
        let size = GemmSize::square(10_000);
        cache.get_or_build(&model, size, &rules, &opts).unwrap();
        assert_eq!(cache.len(), 1);
        let (_, hit) = cache.get_or_build(&model, size, &rules, &opts).unwrap();
        assert!(hit);
    }

    #[test]
    fn fx_hasher_is_deterministic_and_discriminating() {
        fn hash_of<T: std::hash::Hash>(v: &T) -> u64 {
            let mut h = FxBuildHasher.build_hasher();
            v.hash(&mut h);
            h.finish()
        }
        // Same value, same hash — across independently built hashers
        // (no per-process random seed, unlike the default hasher).
        assert_eq!(hash_of(&(7u32, 3u32, 1u32)), hash_of(&(7u32, 3u32, 1u32)));
        // Nearby keys separate.
        assert_ne!(hash_of(&(7u32, 3u32, 1u32)), hash_of(&(7u32, 3u32, 2u32)));
        assert_ne!(hash_of(&42u64), hash_of(&43u64));
        // The byte-slice path agrees with itself on uneven lengths.
        assert_eq!(hash_of(&[1u8, 2, 3]), hash_of(&[1u8, 2, 3]));
        assert_ne!(hash_of(&[1u8, 2, 3]), hash_of(&[1u8, 2, 4]));
    }
}
