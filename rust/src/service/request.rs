//! Service-level request and outcome types.
//!
//! A [`GemmRequest`] is the unit tenants submit: one GEMM shape plus a
//! repetition count. The server answers with a [`ServedRequest`] record
//! (virtual-time start/finish, execution mode, cache behaviour) and the
//! whole session aggregates into a [`ServiceReport`] with the latency /
//! throughput statistics the ROADMAP's production framing calls for.

use super::qos::QosClass;
use crate::metrics::{mean, percentile};
use crate::report::Table;
use crate::workload::GemmSize;
use std::fmt;

/// One tenant request: `C = A @ B` of `size`, repeated `reps` times,
/// submitted under a QoS tier and (optionally) a completion SLO.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GemmRequest {
    /// Caller-visible id (unique per server).
    pub id: u64,
    /// The GEMM shape.
    pub size: GemmSize,
    /// Repetitions (the paper's workloads repeat each input, §5.1.2).
    pub reps: u32,
    /// Service tier (weighted fairness between tenants).
    pub class: QosClass,
    /// Optional sojourn SLO, seconds from arrival to completion.
    /// Deadline-aware admission turns the request away (or demotes it,
    /// per [`super::DeadlinePolicy`]) when the predicted sojourn misses
    /// this budget.
    pub deadline_s: Option<f64>,
}

impl GemmRequest {
    /// A [`QosClass::Standard`] request with no SLO — the PR 2 shape.
    pub fn new(id: u64, size: GemmSize, reps: u32) -> Self {
        GemmRequest {
            id,
            size,
            reps,
            class: QosClass::Standard,
            deadline_s: None,
        }
    }

    /// Same request under `class`.
    pub fn with_class(mut self, class: QosClass) -> Self {
        self.class = class;
        self
    }

    /// Same request with a sojourn SLO of `deadline_s` seconds.
    pub fn with_deadline(mut self, deadline_s: f64) -> Self {
        self.deadline_s = Some(deadline_s);
        self
    }
}

/// Identity of one fused admission-time batch (unique per cluster;
/// see [`super::batch`]). Every member's completion record carries it
/// via [`ExecMode::Batched`], which is what ties the per-member fan-out
/// back together in a [`ServiceReport`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct BatchId(pub u64);

impl fmt::Display for BatchId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "b{}", self.0)
    }
}

/// How a request was executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Co-executed across the machine with a POAS plan.
    CoExec,
    /// Ran alone on one device (suitability gate said co-execution
    /// would not pay, §6).
    Standalone {
        /// The device it ran on.
        device: usize,
    },
    /// Standalone job co-scheduled on an idle device alongside another
    /// request's co-execution (the queue-level bypass).
    BypassStandalone {
        /// The device it ran on.
        device: usize,
    },
    /// Served as a member of a fused admission-time batch: the request
    /// was row-stacked with compatible small requests into one work
    /// unit the cluster gated, routed and executed as a whole (see
    /// [`super::batch`]).
    Batched {
        /// The batch this request was fused into.
        batch: BatchId,
    },
    /// Planning was infeasible: the request completes unserved (zero
    /// execution time, empty shares) instead of killing the shard.
    Rejected,
    /// Deadline-aware admission turned the request away at arrival: its
    /// SLO was predicted infeasible under
    /// [`super::DeadlinePolicy::Reject`]. Completes unserved with zero
    /// execution time, never reaching a shard.
    Denied,
}

impl ExecMode {
    /// True for either standalone variant.
    pub fn is_standalone(&self) -> bool {
        matches!(
            self,
            ExecMode::Standalone { .. } | ExecMode::BypassStandalone { .. }
        )
    }

    /// True when the request rode along via the bypass.
    pub fn is_bypass(&self) -> bool {
        matches!(self, ExecMode::BypassStandalone { .. })
    }

    /// True when the request was served inside a fused batch.
    pub fn is_batched(&self) -> bool {
        matches!(self, ExecMode::Batched { .. })
    }

    /// The fused batch this request was served in, if any.
    pub fn batch(&self) -> Option<BatchId> {
        match self {
            ExecMode::Batched { batch } => Some(*batch),
            _ => None,
        }
    }

    /// True when planning failed and the request was turned away.
    pub fn is_rejected(&self) -> bool {
        matches!(self, ExecMode::Rejected)
    }

    /// True when admission denied the request's SLO at arrival.
    pub fn is_denied(&self) -> bool {
        matches!(self, ExecMode::Denied)
    }

    /// True for any mode that consumed no machine time (planning
    /// rejection or admission denial) — excluded from the latency and
    /// throughput aggregates.
    pub fn is_unserved(&self) -> bool {
        self.is_rejected() || self.is_denied()
    }
}

impl fmt::Display for ExecMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExecMode::CoExec => write!(f, "co-exec"),
            ExecMode::Standalone { device } => write!(f, "standalone(d{device})"),
            ExecMode::BypassStandalone { device } => write!(f, "bypass(d{device})"),
            ExecMode::Batched { batch } => write!(f, "batched({batch})"),
            ExecMode::Rejected => write!(f, "rejected"),
            ExecMode::Denied => write!(f, "denied"),
        }
    }
}

/// The server's record of one completed request.
#[derive(Debug, Clone, PartialEq)]
pub struct ServedRequest {
    /// Request id.
    pub id: u64,
    /// The GEMM shape.
    pub size: GemmSize,
    /// Repetitions executed.
    pub reps: u32,
    /// Service tier the request was ultimately served under (differs
    /// from the submitted tier when admission down-classed it).
    pub class: QosClass,
    /// The sojourn SLO the request was served with (`None` once
    /// admission strips it under [`super::DeadlinePolicy::Downclass`]).
    pub deadline_s: Option<f64>,
    /// Execution mode chosen by the gate / bypass.
    pub mode: ExecMode,
    /// Shard that served it (`None` for requests denied at the
    /// front-end, which never reach a shard). On a heterogeneous
    /// cluster this is the routing decision itself.
    pub shard: Option<usize>,
    /// Virtual time the request entered the queue.
    pub arrival: f64,
    /// Virtual time its execution started.
    pub start: f64,
    /// Virtual time its own devices went idle (overlap-aware).
    pub finish: f64,
    /// Seconds its own devices were occupied (`finish - start`).
    pub exec_s: f64,
    /// Admission-time predicted service seconds (all reps).
    pub predicted_s: f64,
    /// True when planning was served from the [`super::PlanCache`].
    pub cache_hit: bool,
    /// Work share per device (machine order; sums to 1).
    pub shares: Vec<f64>,
}

impl ServedRequest {
    /// Queueing + service latency (sojourn time): arrival to completion.
    pub fn latency(&self) -> f64 {
        self.finish - self.arrival
    }

    /// Time spent waiting before execution started.
    pub fn queue_wait(&self) -> f64 {
        self.start - self.arrival
    }

    /// SLO verdict: `Some(true)` when a deadline-bound request finished
    /// within its budget, `Some(false)` when it missed (or was turned
    /// away), `None` when it carried no deadline.
    pub fn deadline_met(&self) -> Option<bool> {
        self.deadline_s
            .map(|d| !self.mode.is_unserved() && self.latency() <= d + 1e-9)
    }
}

/// Per-shard accounting inside a [`ServiceReport`] (one entry per
/// [`super::ExecutorShard`], shard order).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShardStats {
    /// Executions this shard dispatched (a bypass pairing counts once).
    pub dispatches: usize,
    /// Virtual seconds this shard spent executing.
    pub busy_s: f64,
    /// Virtual time the shard went idle for good.
    pub last_finish: f64,
    /// Requests this shard stole from a busier shard's queue.
    pub stolen: usize,
    /// Fused batches this shard dispatched (each counts once in
    /// `dispatches`; its members all appear in `served_by_class`).
    pub batches: usize,
    /// Requests this shard completed per QoS class
    /// ([`QosClass::index`] order; bypass riders count toward their own
    /// class, so the sum can exceed `dispatches`).
    pub served_by_class: [usize; super::qos::NUM_CLASSES],
    /// Requests this shard turned away at planning time (infeasible
    /// plans complete as [`ExecMode::Rejected`] with zero machine
    /// time). Admission denials have no per-shard entry — a denied
    /// request never reaches a shard (`shard: None`) — so `ShardStats`
    /// deliberately carries no `denied` counter.
    pub rejected: usize,
    /// Requests displaced off this shard by a crash and re-admitted
    /// elsewhere: queued entries plus aborted in-flight work, with the
    /// members of a disbanded fused batch counted individually.
    pub requeued: usize,
    /// Fingerprint of the [`crate::predict::PerfModel`] this shard
    /// currently predicts with (see
    /// [`crate::predict::PerfModel::fingerprint`]). Shards of a
    /// heterogeneous cluster — or a shard whose dynamic scheduler
    /// re-profiled after drift — disagree here.
    pub model_fp: u64,
    /// Sum of admission-time predicted service seconds over everything
    /// this shard executed.
    pub predicted_s: f64,
    /// Sum of realized execution seconds over the same requests.
    pub realized_s: f64,
    /// Machine-seconds this shard was provisioned for: from its
    /// provision instant (0 for construction-time shards, the join
    /// instant for scale-outs) to its drain-retirement instant, or the
    /// report clock while still live. The elasticity bill — what a
    /// statically-overprovisioned cluster pays for and an autoscaled
    /// one saves.
    pub provisioned_s: f64,
    /// Joules this shard's devices spent executing: each completion
    /// record is billed `exec_s` × the active watts of the devices it
    /// occupied (see `docs/energy.md`). Filled at report time by the
    /// cluster, which owns the completion records.
    pub joules_active: f64,
    /// Joules spent provisioned-but-idle: the machine's Σ idle watts
    /// over its provisioned span minus its busy seconds.
    pub joules_idle: f64,
    /// Joules spent parked after a graceful drain: idle watts scaled by
    /// the cluster's parked fraction over the retired span — what
    /// autoscaler scale-down actually saves.
    pub joules_parked: f64,
}

impl ShardStats {
    /// Placement quality of this shard: realized / predicted execution
    /// seconds over everything it served. `1.0` means routing's
    /// predictions matched the machine exactly; above `1.0` the shard
    /// ran slower than the model that attracted the work (stale or
    /// drifting profile); `None` before the first execution.
    pub fn placement_ratio(&self) -> Option<f64> {
        if self.predicted_s > 0.0 {
            Some(self.realized_s / self.predicted_s)
        } else {
            None
        }
    }

    /// Total joules this shard drew over the session: active + idle +
    /// parked.
    pub fn total_joules(&self) -> f64 {
        self.joules_active + self.joules_idle + self.joules_parked
    }
}

/// Per-class aggregate view of a session (see
/// [`ServiceReport::class_breakdown`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ClassBreakdown {
    /// The class described.
    pub class: QosClass,
    /// Requests of this class that actually executed.
    pub executed: usize,
    /// Median sojourn (arrival to completion) of the class.
    pub p50_sojourn: f64,
    /// Tail sojourn of the class.
    pub p99_sojourn: f64,
    /// Mean queueing delay of the class.
    pub mean_queue_wait: f64,
    /// Executed deadline-bound requests that met their SLO.
    pub deadline_hits: usize,
    /// Executed requests that carried an SLO.
    pub deadline_bound: usize,
    /// Requests of this class denied by admission.
    pub denied: usize,
    /// Requests of this class rejected at planning time.
    pub rejected: usize,
}

/// Aggregate outcome of a service session.
///
/// # Read surface
///
/// The report follows one convention throughout: **raw, digestable
/// accounting lives in public fields** (these are what
/// [`super::scenario::digest`] serializes and `PartialEq` compares —
/// byte-stable across replays), while **derived statistics live in
/// methods** (`throughput_rps`, `utilization`, `deadline_hit_rate`,
/// `total_joules`, the percentile helpers, …) computed on demand from
/// the fields. Rendering helpers (`table`, `class_table`,
/// `shard_table`, `summary`) sit on top of both and never feed back
/// into the accounting.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServiceReport {
    /// Every completed request, in dispatch order (per-shard dispatches
    /// interleave under a cluster; a bypass rider follows its carrier
    /// regardless of which finished first).
    pub served: Vec<ServedRequest>,
    /// Virtual session time: the instant the last event settled. Shards
    /// execute concurrently, so actual machine time consumed is the sum
    /// of [`ShardStats::busy_s`], up to `shards.len()` times larger.
    pub makespan: f64,
    /// Plan-cache hits across the session (all shards).
    pub cache_hits: u64,
    /// Plan-cache misses across the session (all shards).
    pub cache_misses: u64,
    /// Model-epoch bumps (each invalidated a shard's plan cache).
    pub epoch_bumps: u64,
    /// Dynamic-scheduler replans observed (0 without `dynamic`).
    pub replans: usize,
    /// Requests denied by deadline-aware admission
    /// ([`ExecMode::Denied`]); always equals the count of `Denied`
    /// records in `served`.
    pub denied: usize,
    /// Requests rejected at planning time ([`ExecMode::Rejected`]);
    /// always equals the count of `Rejected` records in `served`.
    pub rejected: usize,
    /// Requests re-admitted after a shard crash or graceful drain. Each
    /// displaced request counts once per fault that moved it, so this
    /// can exceed the number of distinct requests touched by faults; it
    /// is **not** derivable from `served`, which records only final
    /// outcomes.
    pub requeued: usize,
    /// Total machine-seconds provisioned across shards (the sum of
    /// [`ShardStats::provisioned_s`], precomputed at report time with
    /// every live span closed at `makespan`). Under elastic membership
    /// this is what the cluster *pays for*; [`ShardStats::busy_s`] is
    /// what it *uses* — see [`ServiceReport::utilization`].
    pub machine_seconds: f64,
    /// Joules spent executing across all shards (the sum of
    /// [`ShardStats::joules_active`]).
    pub joules_active: f64,
    /// Joules spent provisioned-but-idle across all shards (the sum of
    /// [`ShardStats::joules_idle`]).
    pub joules_idle: f64,
    /// Joules spent parked after graceful drains across all shards
    /// (the sum of [`ShardStats::joules_parked`]).
    pub joules_parked: f64,
    /// Active joules attributed per QoS class ([`QosClass::index`]
    /// order): each executed record bills its energy to the class it
    /// was served under. Sums to `joules_active` exactly — the
    /// conservation law the energy tests pin.
    pub joules_by_class: [f64; super::qos::NUM_CLASSES],
    /// Per-shard accounting (shard order; one entry for the classic
    /// single-machine [`super::Server`]).
    pub shards: Vec<ShardStats>,
}

impl ServiceReport {
    /// The requests that actually executed (everything but
    /// [`ExecMode::Rejected`] and [`ExecMode::Denied`]) — the
    /// population the latency/throughput aggregates describe, so
    /// zero-cost rejections and denials cannot inflate them.
    fn executed(&self) -> impl Iterator<Item = &ServedRequest> {
        self.served.iter().filter(|r| !r.mode.is_unserved())
    }

    /// Per-request latencies (arrival to completion) of executed
    /// requests, record order.
    pub fn latencies(&self) -> Vec<f64> {
        self.executed().map(|r| r.latency()).collect()
    }

    /// Mean completion latency — the metric SPJF optimizes.
    pub fn mean_completion(&self) -> f64 {
        mean(&self.latencies())
    }

    /// Latency (sojourn) percentile, `p` in [0, 100].
    pub fn latency_percentile(&self, p: f64) -> f64 {
        percentile(&self.latencies(), p)
    }

    /// Per-request queueing delays (arrival to execution start) of
    /// executed requests.
    pub fn queue_waits(&self) -> Vec<f64> {
        self.executed().map(|r| r.queue_wait()).collect()
    }

    /// Mean queueing delay — what the arrival process loads the queue
    /// with; ~0 when offered load is far below capacity.
    pub fn mean_queue_wait(&self) -> f64 {
        mean(&self.queue_waits())
    }

    /// Queueing-delay percentile, `p` in [0, 100].
    pub fn queue_wait_percentile(&self, p: f64) -> f64 {
        percentile(&self.queue_waits(), p)
    }

    /// Executed requests per virtual second over the session (rejected
    /// requests consumed no machine time and do not count).
    pub fn throughput_rps(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.executed().count() as f64 / self.makespan
        }
    }

    /// Fraction of provisioned machine-seconds actually spent
    /// executing: `Σ busy_s / machine_seconds`. The
    /// utilization-vs-SLO trade-off an autoscaler navigates — an
    /// overprovisioned cluster buys its deadline-hit rate with a low
    /// figure here; 0 before any machine time was provisioned.
    pub fn utilization(&self) -> f64 {
        if self.machine_seconds <= 0.0 {
            0.0
        } else {
            self.shards.iter().map(|s| s.busy_s).sum::<f64>() / self.machine_seconds
        }
    }

    /// Total joules the cluster drew over the session: active + idle +
    /// parked, across every shard.
    pub fn total_joules(&self) -> f64 {
        self.joules_active + self.joules_idle + self.joules_parked
    }

    /// Active joules billed to one QoS class.
    pub fn class_joules(&self, class: QosClass) -> f64 {
        self.joules_by_class[class.index()]
    }

    /// Fraction of co-exec plans answered from the cache.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Look up a request by id.
    pub fn request(&self, id: u64) -> Option<&ServedRequest> {
        self.served.iter().find(|r| r.id == id)
    }

    /// Count of requests served through the bypass.
    pub fn bypassed(&self) -> usize {
        self.served.iter().filter(|r| r.mode.is_bypass()).count()
    }

    /// Count of requests served inside a fused admission-time batch.
    pub fn fused(&self) -> usize {
        self.served.iter().filter(|r| r.mode.is_batched()).count()
    }

    /// Number of distinct fused batches dispatched over the session.
    pub fn num_batches(&self) -> usize {
        let mut ids: Vec<BatchId> = self.served.iter().filter_map(|r| r.mode.batch()).collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    }

    /// Mean members per fused batch (0 when nothing fused).
    pub fn mean_batch_members(&self) -> f64 {
        let batches = self.num_batches();
        if batches == 0 {
            0.0
        } else {
            self.fused() as f64 / batches as f64
        }
    }

    /// Fraction of executed requests that were served fused — the
    /// batching bench's headline figure next to throughput.
    pub fn fusion_rate(&self) -> f64 {
        let executed = self.executed().count();
        if executed == 0 {
            0.0
        } else {
            self.fused() as f64 / executed as f64
        }
    }

    /// Executed requests served under `class`, record order.
    pub fn class_latencies(&self, class: QosClass) -> Vec<f64> {
        self.executed()
            .filter(|r| r.class == class)
            .map(|r| r.latency())
            .collect()
    }

    /// Sojourn percentile of one class, `p` in [0, 100].
    pub fn class_latency_percentile(&self, class: QosClass, p: f64) -> f64 {
        percentile(&self.class_latencies(class), p)
    }

    /// Aggregate one class's view of the session — executed count,
    /// p50/p99 sojourn, mean queueing delay, deadline hits, denials and
    /// rejections (see [`ClassBreakdown`]).
    pub fn class_breakdown(&self, class: QosClass) -> ClassBreakdown {
        let lat = self.class_latencies(class);
        let mut hits = 0usize;
        let mut bound = 0usize;
        for r in self.executed().filter(|r| r.class == class) {
            if let Some(met) = r.deadline_met() {
                bound += 1;
                if met {
                    hits += 1;
                }
            }
        }
        ClassBreakdown {
            class,
            executed: lat.len(),
            p50_sojourn: percentile(&lat, 50.0),
            p99_sojourn: percentile(&lat, 99.0),
            mean_queue_wait: mean(
                &self
                    .executed()
                    .filter(|r| r.class == class)
                    .map(|r| r.queue_wait())
                    .collect::<Vec<_>>(),
            ),
            deadline_hits: hits,
            deadline_bound: bound,
            denied: self
                .served
                .iter()
                .filter(|r| r.class == class && r.mode.is_denied())
                .count(),
            rejected: self
                .served
                .iter()
                .filter(|r| r.class == class && r.mode.is_rejected())
                .count(),
        }
    }

    /// Fraction of **accepted** deadline-bound requests that finished
    /// within their SLO (1.0 when none were accepted: vacuously met).
    /// Denied requests never consumed capacity and are excluded — the
    /// point of deadline admission is that this rate stays high for
    /// everything it lets through.
    pub fn deadline_hit_rate(&self) -> f64 {
        let mut hits = 0usize;
        let mut bound = 0usize;
        for r in self.executed() {
            if let Some(met) = r.deadline_met() {
                bound += 1;
                if met {
                    hits += 1;
                }
            }
        }
        if bound == 0 {
            1.0
        } else {
            hits as f64 / bound as f64
        }
    }

    /// Render the per-class breakdown as a table.
    pub fn class_table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &[
                "class",
                "weight",
                "served",
                "p50",
                "p99",
                "mean wait",
                "deadline",
                "denied",
                "rejected",
            ],
        );
        for class in QosClass::ALL {
            let b = self.class_breakdown(class);
            t.row(&[
                class.to_string(),
                class.weight().to_string(),
                b.executed.to_string(),
                crate::report::secs(b.p50_sojourn),
                crate::report::secs(b.p99_sojourn),
                crate::report::secs(b.mean_queue_wait),
                if b.deadline_bound == 0 {
                    "-".to_string()
                } else {
                    format!("{}/{}", b.deadline_hits, b.deadline_bound)
                },
                b.denied.to_string(),
                b.rejected.to_string(),
            ]);
        }
        t
    }

    /// Cluster-wide placement quality: realized / predicted execution
    /// seconds summed over every shard (`1.0` when nothing executed).
    /// The benches gate on this — if it regresses far past 1, routing
    /// is steering work with predictions the machines do not honour.
    pub fn placement_quality(&self) -> f64 {
        let predicted: f64 = self.shards.iter().map(|s| s.predicted_s).sum();
        let realized: f64 = self.shards.iter().map(|s| s.realized_s).sum();
        if predicted > 0.0 {
            realized / predicted
        } else {
            1.0
        }
    }

    /// Render the per-shard accounting — model fingerprint, dispatch
    /// counts, utilization and placement quality — as a table.
    pub fn shard_table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &[
                "shard",
                "model",
                "dispatches",
                "busy",
                "provisioned",
                "stolen",
                "predicted",
                "realized",
                "quality",
            ],
        );
        for (i, s) in self.shards.iter().enumerate() {
            t.row(&[
                i.to_string(),
                format!("{:016x}", s.model_fp),
                s.dispatches.to_string(),
                crate::report::secs(s.busy_s),
                crate::report::secs(s.provisioned_s),
                s.stolen.to_string(),
                crate::report::secs(s.predicted_s),
                crate::report::secs(s.realized_s),
                match s.placement_ratio() {
                    Some(r) => format!("{r:.3}"),
                    None => "-".to_string(),
                },
            ]);
        }
        t
    }

    /// Render the per-request log as a table.
    pub fn table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &["req", "class", "size", "mode", "exec", "completion", "latency", "plan"],
        );
        for r in &self.served {
            t.row(&[
                format!("#{:03}", r.id),
                r.class.to_string(),
                r.size.to_string(),
                r.mode.to_string(),
                crate::report::secs(r.exec_s),
                crate::report::secs(r.finish),
                crate::report::secs(r.latency()),
                if r.mode == ExecMode::CoExec {
                    if r.cache_hit { "cached" } else { "solved" }.to_string()
                } else {
                    "-".to_string()
                },
            ]);
        }
        t
    }

    /// One-line summary of the session.
    pub fn summary(&self) -> String {
        format!(
            "{} requests in {} ({}) — mean completion {}, p95 {}, \
             cache {}/{} hits, {} epoch bumps",
            self.served.len(),
            crate::report::secs(self.makespan),
            crate::report::rate(self.throughput_rps()),
            crate::report::secs(self.mean_completion()),
            crate::report::secs(self.latency_percentile(95.0)),
            self.cache_hits,
            self.cache_hits + self.cache_misses,
            self.epoch_bumps,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn served(id: u64, arrival: f64, start: f64, finish: f64, mode: ExecMode) -> ServedRequest {
        ServedRequest {
            id,
            size: GemmSize::square(1000),
            reps: 1,
            class: QosClass::Standard,
            deadline_s: None,
            mode,
            shard: Some(0),
            arrival,
            start,
            finish,
            exec_s: finish - start,
            predicted_s: finish - start,
            cache_hit: false,
            shares: vec![1.0],
        }
    }

    fn report() -> ServiceReport {
        ServiceReport {
            served: vec![
                served(0, 0.0, 0.0, 2.0, ExecMode::CoExec),
                served(1, 0.0, 2.0, 3.0, ExecMode::Standalone { device: 2 }),
                served(2, 0.0, 0.0, 1.0, ExecMode::BypassStandalone { device: 0 }),
            ],
            makespan: 3.0,
            cache_hits: 1,
            cache_misses: 1,
            epoch_bumps: 0,
            replans: 0,
            denied: 0,
            rejected: 0,
            requeued: 0,
            machine_seconds: 3.0,
            joules_active: 90.0,
            joules_idle: 10.0,
            joules_parked: 2.0,
            joules_by_class: [0.0, 90.0, 0.0],
            shards: vec![ShardStats {
                dispatches: 2,
                busy_s: 3.0,
                last_finish: 3.0,
                stolen: 0,
                batches: 0,
                served_by_class: [0, 3, 0],
                rejected: 0,
                requeued: 0,
                model_fp: 0xDEAD_BEEF,
                predicted_s: 2.5,
                realized_s: 3.0,
                provisioned_s: 3.0,
                joules_active: 90.0,
                joules_idle: 10.0,
                joules_parked: 2.0,
            }],
        }
    }

    #[test]
    fn latency_and_throughput() {
        let r = report();
        assert_eq!(r.latencies(), vec![2.0, 3.0, 1.0]);
        assert!((r.mean_completion() - 2.0).abs() < 1e-12);
        assert!((r.throughput_rps() - 1.0).abs() < 1e-12);
        assert_eq!(r.bypassed(), 1);
        assert_eq!(r.request(1).unwrap().queue_wait(), 2.0);
        assert!(r.request(9).is_none());
        assert!((r.cache_hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_report_is_safe() {
        let r = ServiceReport::default();
        assert_eq!(r.mean_completion(), 0.0);
        assert_eq!(r.throughput_rps(), 0.0);
        assert_eq!(r.cache_hit_rate(), 0.0);
        assert_eq!(r.latency_percentile(99.0), 0.0);
        assert_eq!(r.utilization(), 0.0);
    }

    #[test]
    fn utilization_is_busy_over_provisioned() {
        let mut r = report();
        // One shard busy 3.0s of 3.0 provisioned machine-seconds.
        assert!((r.utilization() - 1.0).abs() < 1e-12);
        // An idle shard provisioned for the same span halves it.
        r.machine_seconds += 3.0;
        r.shards.push(ShardStats {
            provisioned_s: 3.0,
            ..ShardStats::default()
        });
        assert!((r.utilization() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn mode_display_and_predicates() {
        assert_eq!(ExecMode::CoExec.to_string(), "co-exec");
        assert_eq!(
            ExecMode::Standalone { device: 2 }.to_string(),
            "standalone(d2)"
        );
        assert_eq!(
            ExecMode::BypassStandalone { device: 0 }.to_string(),
            "bypass(d0)"
        );
        assert_eq!(
            ExecMode::Batched { batch: BatchId(3) }.to_string(),
            "batched(b3)"
        );
        assert_eq!(ExecMode::Rejected.to_string(), "rejected");
        assert_eq!(ExecMode::Denied.to_string(), "denied");
        assert!(ExecMode::Denied.is_denied());
        assert!(ExecMode::Denied.is_unserved());
        assert!(ExecMode::Rejected.is_unserved());
        assert!(!ExecMode::CoExec.is_unserved());
        assert!(!ExecMode::CoExec.is_standalone());
        assert!(ExecMode::Standalone { device: 1 }.is_standalone());
        assert!(ExecMode::BypassStandalone { device: 0 }.is_bypass());
        assert!(ExecMode::Rejected.is_rejected());
        assert!(!ExecMode::Rejected.is_standalone());
        assert!(!ExecMode::Rejected.is_bypass());
        assert!(!ExecMode::CoExec.is_rejected());
        let batched = ExecMode::Batched { batch: BatchId(7) };
        assert!(batched.is_batched());
        assert!(!batched.is_standalone());
        assert!(!batched.is_unserved());
        assert_eq!(batched.batch(), Some(BatchId(7)));
        assert_eq!(ExecMode::CoExec.batch(), None);
    }

    #[test]
    fn batch_metrics_aggregate_members_and_batches() {
        let mut r = report();
        // No batch served yet: everything is zero/empty.
        assert_eq!(r.fused(), 0);
        assert_eq!(r.num_batches(), 0);
        assert_eq!(r.mean_batch_members(), 0.0);
        assert_eq!(r.fusion_rate(), 0.0);
        // Two members of batch 0, one member of batch 1.
        r.served.push(served(3, 0.0, 1.0, 2.0, ExecMode::Batched { batch: BatchId(0) }));
        r.served.push(served(4, 0.0, 1.0, 2.0, ExecMode::Batched { batch: BatchId(0) }));
        r.served.push(served(5, 0.5, 1.0, 2.0, ExecMode::Batched { batch: BatchId(1) }));
        assert_eq!(r.fused(), 3);
        assert_eq!(r.num_batches(), 2);
        assert!((r.mean_batch_members() - 1.5).abs() < 1e-12);
        // 3 fused of 6 executed.
        assert!((r.fusion_rate() - 0.5).abs() < 1e-12);
        // Members render with their batch id in the request table.
        assert!(r.table("batches").render().contains("batched(b1)"));
    }

    #[test]
    fn queue_wait_metrics() {
        let r = report();
        assert_eq!(r.queue_waits(), vec![0.0, 2.0, 0.0]);
        assert!((r.mean_queue_wait() - 2.0 / 3.0).abs() < 1e-12);
        assert!((r.queue_wait_percentile(100.0) - 2.0).abs() < 1e-12);
        assert_eq!(r.rejected, 0);
        assert_eq!(ServiceReport::default().mean_queue_wait(), 0.0);
    }

    #[test]
    fn class_breakdown_and_deadline_accounting() {
        let mut r = report();
        // Re-class the three executed requests and add a deadline-bound
        // pair: one hit, one denied.
        r.served[0].class = QosClass::Interactive;
        r.served[0].deadline_s = Some(2.5); // latency 2.0: hit
        r.served[1].class = QosClass::Batch;
        let mut denied = served(3, 1.0, 1.0, 1.0, ExecMode::Denied);
        denied.class = QosClass::Interactive;
        denied.deadline_s = Some(0.1);
        denied.exec_s = 0.0;
        denied.shard = None;
        r.served.push(denied);
        r.denied += 1;

        assert_eq!(r.denied, 1);
        assert_eq!(r.rejected, 0);
        // The headline counters mirror the record modes exactly.
        assert_eq!(
            r.denied,
            r.served.iter().filter(|s| s.mode.is_denied()).count()
        );
        // Denied requests never enter the latency aggregates.
        assert_eq!(r.latencies().len(), 3);
        assert_eq!(r.class_latencies(QosClass::Interactive), vec![2.0]);
        assert_eq!(r.class_latencies(QosClass::Batch), vec![3.0]);

        let b = r.class_breakdown(QosClass::Interactive);
        assert_eq!(b.executed, 1);
        assert_eq!((b.deadline_hits, b.deadline_bound), (1, 1));
        assert_eq!(b.denied, 1);
        assert!((b.p50_sojourn - 2.0).abs() < 1e-12);
        // Accepted SLO requests all hit: rate 1.0; the denial is not a
        // miss, it is capacity the admission gate protected.
        assert!((r.deadline_hit_rate() - 1.0).abs() < 1e-12);

        assert_eq!(r.served[0].deadline_met(), Some(true));
        assert_eq!(r.served[3].deadline_met(), Some(false));
        assert_eq!(r.served[1].deadline_met(), None);

        let rendered = r.class_table("classes").render();
        assert!(rendered.contains("interactive"));
        assert!(rendered.contains("1/1"));
    }

    #[test]
    fn placement_quality_aggregates_per_shard_ratios() {
        let mut r = report();
        // One shard, predicted 2.5s, realized 3.0s.
        assert_eq!(r.shards[0].placement_ratio(), Some(1.2));
        assert!((r.placement_quality() - 1.2).abs() < 1e-12);
        // A second, idle shard contributes nothing (and has no ratio).
        r.shards.push(ShardStats::default());
        assert_eq!(r.shards[1].placement_ratio(), None);
        assert!((r.placement_quality() - 1.2).abs() < 1e-12);
        // No executions at all: vacuously perfect.
        assert_eq!(ServiceReport::default().placement_quality(), 1.0);
        // The shard table renders fingerprints and ratios.
        let rendered = r.shard_table("shards").render();
        assert!(rendered.contains("00000000deadbeef"));
        assert!(rendered.contains("1.200"));
        assert!(rendered.contains('-'));
    }

    #[test]
    fn joules_accessors_sum_the_components() {
        let r = report();
        assert!((r.total_joules() - 102.0).abs() < 1e-12);
        assert!((r.class_joules(QosClass::Standard) - 90.0).abs() < 1e-12);
        assert_eq!(r.class_joules(QosClass::Interactive), 0.0);
        assert!((r.shards[0].total_joules() - 102.0).abs() < 1e-12);
        // The conservation law the report-time accounting maintains.
        let by_class: f64 = r.joules_by_class.iter().sum();
        assert!((by_class - r.joules_active).abs() < 1e-12);
        assert_eq!(ServiceReport::default().total_joules(), 0.0);
    }

    #[test]
    fn empty_deadline_population_is_vacuously_met() {
        assert_eq!(report().deadline_hit_rate(), 1.0);
        assert_eq!(ServiceReport::default().deadline_hit_rate(), 1.0);
    }

    #[test]
    fn request_builders_default_to_standard() {
        let r = GemmRequest::new(7, GemmSize::square(100), 2);
        assert_eq!(r.class, QosClass::Standard);
        assert!(r.deadline_s.is_none());
        let r = r.with_class(QosClass::Interactive).with_deadline(1.5);
        assert_eq!(r.class, QosClass::Interactive);
        assert_eq!(r.deadline_s, Some(1.5));
    }

    #[test]
    fn table_and_summary_render() {
        let r = report();
        let s = r.table("demo").render();
        assert!(s.contains("co-exec"));
        assert!(s.contains("bypass(d0)"));
        let sum = r.summary();
        assert!(sum.contains("3 requests"));
        assert!(sum.contains("req/s"));
    }
}
