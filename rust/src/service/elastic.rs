//! Elastic membership policy: the autoscaler that provisions and
//! drains shards against offered load.
//!
//! PR 6's crash/restart faults cover *involuntary* churn; this module
//! is the voluntary kind — the cluster breathing with load the way the
//! co-scheduling literature frames as pack-and-resize (Aupy et al.)
//! and HTS's dynamic reallocation of resources between queued tasks
//! (Hegde et al.). The mechanism lives on the cluster event loop
//! ([`super::Cluster::inject_join`] / [`super::Cluster::inject_drain`],
//! or the recurring autoscaler-evaluation event a configured
//! [`AutoscalerPolicy`] arms); this module owns the *policy*:
//!
//! * **scale up** when the mean shard pressure (residual execution +
//!   queued backlog, in predicted seconds) crosses
//!   [`AutoscalerPolicy::scale_up_pressure_s`], or when admission
//!   denied a deadline since the last evaluation (deadline-risk: the
//!   gate is already turning away SLOs, so capacity is short *now*).
//!   One shard per evaluation, taken from the configured preset
//!   [`AutoscalerPolicy::pool`] — a never-provisioned entry joins
//!   fresh (profiled at provision time on its own seed, cold plan
//!   cache); a previously drained entry is revived instead, keeping
//!   its warmed cache and installation-time profile;
//! * **scale down** when mean pressure has stayed below
//!   [`AutoscalerPolicy::scale_down_pressure_s`] for
//!   [`AutoscalerPolicy::scale_down_evals`] consecutive evaluations
//!   with no new denials: the lowest-pressure live *pool* shard drains
//!   gracefully (never a static construction-time shard, so the
//!   configured floor capacity is untouchable).
//!
//! Every decision reads only deterministic cluster state at a
//! deterministic virtual instant, so autoscaled runs replay
//! byte-identically like everything else on the event loop. The bill
//! lands on [`super::ServiceReport`]: `machine_seconds` (what the
//! cluster paid for) against [`super::ServiceReport::utilization`] and
//! the deadline-hit rate (what it bought) — the trade-off
//! `ci/elasticity_floor.json` gates in CI (autoscaled must match the
//! statically-overprovisioned hit rate at materially fewer
//! machine-seconds on the diurnal trace).
//!
//! Membership changes are driver-transparent: a join, drain or revival
//! the policy triggers is mirrored through the cluster's tap under the
//! wall-clock driver ([`super::driver::WallClockDriver`]) — a join
//! spawns the new shard's worker thread, a drain winds it down to
//! idle, a revival reuses the still-running worker — so autoscaled
//! runs make identical decisions on both drivers.

use crate::config::MachineConfig;

/// Autoscaler configuration (see the module docs for the policy it
/// drives). Attach one via
/// [`super::ClusterOptions::autoscaler`]; `None` (the default)
/// reproduces the fixed-membership behaviour exactly — no evaluation
/// events are ever armed.
#[derive(Debug, Clone)]
pub struct AutoscalerPolicy {
    /// The preset machines the autoscaler may provision, in priority
    /// order. Each entry is at most one live shard at a time; a
    /// drained entry can be revived.
    pub pool: Vec<MachineConfig>,
    /// Virtual seconds between policy evaluations (must be finite and
    /// positive). The first evaluation fires one interval into the
    /// run.
    pub eval_interval_s: f64,
    /// Scale up when mean live-shard pressure (residual execution +
    /// queued backlog, predicted seconds) exceeds this.
    pub scale_up_pressure_s: f64,
    /// Arm scale-down only while mean pressure sits below this.
    pub scale_down_pressure_s: f64,
    /// Consecutive below-threshold evaluations required before one
    /// pool shard drains — the hysteresis that keeps a diurnal valley
    /// from flapping.
    pub scale_down_evals: u32,
    /// Base seed for profiling provisioned machines: pool entry `k`
    /// profiles on `profile_seed + k`, so autoscaled membership is as
    /// replayable as construction-time membership.
    pub profile_seed: u64,
}

impl AutoscalerPolicy {
    /// A policy over `pool` with neutral thresholds: evaluate every
    /// virtual second, scale up above 2 s of mean pressure, drain
    /// after 3 consecutive evaluations under 0.25 s. Callers tune the
    /// thresholds to their trace's service-time unit.
    pub fn new(pool: Vec<MachineConfig>) -> Self {
        AutoscalerPolicy {
            pool,
            eval_interval_s: 1.0,
            scale_up_pressure_s: 2.0,
            scale_down_pressure_s: 0.25,
            scale_down_evals: 3,
            profile_seed: 0x504f_4153_u64, // "POAS"
        }
    }
}

/// Runtime autoscaler state the cluster carries between evaluation
/// events. Constructed from the policy at cluster build time; all
/// mutation happens inside the cluster's evaluation handler.
#[derive(Debug, Clone)]
pub(crate) struct Autoscaler {
    pub(crate) policy: AutoscalerPolicy,
    /// Shard index each pool entry is provisioned as (`None` until its
    /// first join). An entry with a shard index may still be drained —
    /// the cluster's down flag is the live/retired truth.
    pub(crate) pool_shard: Vec<Option<usize>>,
    /// Consecutive evaluations below the scale-down threshold.
    pub(crate) low_streak: u32,
    /// Denial count at the previous evaluation (deadline-risk signal:
    /// any increase means admission is already refusing SLOs).
    pub(crate) last_denied: usize,
}

impl Autoscaler {
    pub(crate) fn new(policy: AutoscalerPolicy) -> Self {
        assert!(
            policy.eval_interval_s.is_finite() && policy.eval_interval_s > 0.0,
            "autoscaler eval_interval_s must be finite and positive, got {}",
            policy.eval_interval_s
        );
        let slots = policy.pool.len();
        Autoscaler {
            policy,
            pool_shard: vec![None; slots],
            low_streak: 0,
            last_denied: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn policy_defaults_are_sane() {
        let p = AutoscalerPolicy::new(vec![presets::mach1(), presets::gpu_node()]);
        assert_eq!(p.pool.len(), 2);
        assert!(p.eval_interval_s > 0.0);
        assert!(p.scale_up_pressure_s > p.scale_down_pressure_s);
        let a = Autoscaler::new(p);
        assert_eq!(a.pool_shard, vec![None, None]);
        assert_eq!(a.low_streak, 0);
    }

    #[test]
    #[should_panic]
    fn zero_interval_is_rejected() {
        let mut p = AutoscalerPolicy::new(vec![presets::mach1()]);
        p.eval_interval_s = 0.0;
        let _ = Autoscaler::new(p);
    }
}
