//! Admission-time batching: fuse small compatible GEMMs into one
//! co-execution at the cluster front-end.
//!
//! POAS's co-execution premise (paper §4–§6) is that one work unit
//! split across CPU/GPU/XPU beats any single accelerator — but the
//! suitability gate correctly sends *small* GEMMs standalone, one at a
//! time, onto a single device, leaving the other accelerators dark
//! exactly when accelerator-level parallelism would pay most. The
//! [`BatchFormer`] closes that gap the way aggregating schedulers do
//! (HTS amortizes per-task scheduling cost by batching work before
//! dispatch; Aupy et al.'s co-scheduling packs trade a bounded amount
//! of per-job latency for throughput): it holds small arrivals in a
//! short **batch window** and fuses compatible ones into a single
//! [`FusedBatch`] the §6 gate re-scores *as a batch*.
//!
//! ## The compatibility predicate
//!
//! Two requests may share a window iff **all** of the following hold
//! (see [`ShapeClass`]):
//!
//! * **same right-hand operand shape** — identical `n` and `k`. Fusing
//!   is row-stacking: `l` members of shapes `(m_i, n, k)` become one
//!   GEMM of `(Σ m_i, n, k)`, which is exactly the shared-weight
//!   serving case (many tenants multiplying against the same `B`, e.g.
//!   one model layer). Row-stacking is what lets the fused batch copy
//!   `B` once per accelerator instead of once per member — the
//!   amortization the throughput win comes from;
//! * **same `m` magnitude bucket** — `⌊log2 m⌋` must match, so one
//!   outsized member cannot dominate (and mis-attribute) the fused
//!   execution;
//! * **same repetition count** — the simulator runs one global
//!   repetition loop per work order ([`crate::sim::WorkOrder::merge`]
//!   enforces the same rule for bypass riders);
//! * **adjacent QoS classes** — the window's class span may not exceed
//!   one priority level (Interactive+Standard or Standard+Batch, never
//!   Interactive+Batch), and the fused batch is queued on the lane of
//!   its **strictest** member, so riding along never demotes anyone;
//! * **small enough** — member ops at most
//!   [`BatchWindow::max_member_ops`]; the cluster additionally requires
//!   that *no* shard's own gate would co-execute the member alone
//!   (requests worth splitting by themselves never wait for a window).
//!
//! ## Window and flush rules
//!
//! A window opens when the first compatible member arrives and flushes
//! — becoming a [`FusedBatch`] handed back to the cluster front-end —
//! at the earliest of:
//!
//! * **timer**: [`BatchWindow::window_s`] virtual seconds after it
//!   opened (the bounded latency cost of batching);
//! * **capacity**: the window reached [`BatchWindow::max_members`];
//! * **deadline pressure**: an SLO-bound member cannot afford to wait.
//!   For every member with deadline `d_i` the window must flush by
//!   `arrival_i + slack·d_i − service`, where `service` is the
//!   best-shard predicted service time of the fused batch (re-tightened
//!   on every join as the batch grows); when that bound reaches the
//!   present, [`BatchFormer::join`] answers
//!   [`JoinOutcome::FlushNow`] and the cluster flushes immediately —
//!   batch-window waiting can therefore never, by construction, push an
//!   admitted SLO request past its deadline.
//!
//! A flushed window of one member is not a batch: the cluster admits
//! the request solo, so `BatchPolicy::Windowed` degenerates gracefully
//! under light load. The former holds no machine state and iterates
//! plain vectors, so replays stay byte-identical.
//!
//! ## Carrier reuse
//!
//! Member vectors are the only per-window allocation. Callers that
//! consume a [`FusedBatch`] without shipping its members onward (solo
//! degenerate flushes, disbanded batches) hand the vector back via
//! [`BatchFormer::recycle`]; newly opened windows pop from that spare
//! pool, so the light-load steady state — windows opening and flushing
//! solo over and over — allocates no carriers at all.

use super::qos::QosClass;
use super::request::{BatchId, GemmRequest};
use crate::workload::GemmSize;

/// Whether (and how) the cluster front-end batches small arrivals.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum BatchPolicy {
    /// No batching: every arrival routes alone (the ablation baseline
    /// `benches/cluster_scaling.rs` and CI's batching gate compare
    /// against).
    #[default]
    Off,
    /// Windowed admission-time batching (see the module doc).
    Windowed(BatchWindow),
}

impl BatchPolicy {
    /// Windowed batching with the default window parameters.
    pub fn windowed() -> Self {
        BatchPolicy::Windowed(BatchWindow::default())
    }
}

/// Parameters of one batch window (see the module doc for the rules).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchWindow {
    /// Longest a window may stay open, virtual seconds from the first
    /// member's arrival.
    pub window_s: f64,
    /// Flush as soon as this many members have joined.
    pub max_members: usize,
    /// Largest member the former will hold (`m·n·k` multiply-adds);
    /// bigger requests route alone immediately.
    pub max_member_ops: f64,
}

impl Default for BatchWindow {
    fn default() -> Self {
        BatchWindow {
            window_s: 0.05,
            max_members: 8,
            // ~2520^3: well below the co-execution crossover of the
            // calibrated machines, comfortably above the shapes the
            // gate actually bypasses.
            max_member_ops: 16e9,
        }
    }
}

/// The shape class of the `GemmSize` bucketing: requests fuse only
/// within one class (see the module doc's compatibility predicate).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShapeClass {
    /// `⌊log2 m⌋` of the member's row count.
    pub m_pow2: u32,
    /// Exact column count (shared `B` operand).
    pub n: u64,
    /// Exact inner dimension (shared `B` operand).
    pub k: u64,
    /// Exact repetition count (one global rep loop per work order).
    pub reps: u32,
}

impl ShapeClass {
    /// The class `size` (at `reps` repetitions) buckets into.
    pub fn of(size: GemmSize, reps: u32) -> Self {
        ShapeClass {
            m_pow2: size.m.ilog2(),
            n: size.n,
            k: size.k,
            reps,
        }
    }
}

/// One member of a fused batch: the original request plus its true
/// arrival time (latency accounting runs from here, so time spent
/// waiting in the window is visible in the member's sojourn).
#[derive(Debug, Clone, Copy)]
pub struct BatchMember {
    /// The member request, untouched (its own class, SLO and id).
    pub req: GemmRequest,
    /// Virtual time the member reached the front-end.
    pub arrival: f64,
}

/// A flushed batch window: `members` row-stacked into one fused GEMM
/// the cluster admits, routes, steals and dispatches as a single unit.
#[derive(Debug, Clone)]
pub struct FusedBatch {
    /// Batch identity (carried by every member's
    /// [`super::ExecMode::Batched`] record).
    pub id: BatchId,
    /// The row-stacked shape: `(Σ member m, n, k)`.
    pub size: GemmSize,
    /// Shared repetition count.
    pub reps: u32,
    /// The strictest member class — the lane the batch queues on.
    pub class: QosClass,
    /// Tightest member completion deadline as an **absolute** virtual
    /// time (`min(arrival_i + d_i)`), `None` when no member carries an
    /// SLO.
    pub deadline_abs: Option<f64>,
    /// The members, join order (row-stack order: member `i` owns rows
    /// `[Σ_{j<i} m_j, Σ_{j<=i} m_j)` of the fused problem).
    pub members: Vec<BatchMember>,
}

impl FusedBatch {
    /// The synthetic request the front-end admits and routes for the
    /// whole batch at time `now`: fused shape, strictest class, and the
    /// tightest member deadline re-expressed relative to `now`.
    pub fn carrier(&self, now: f64) -> GemmRequest {
        GemmRequest {
            id: self.members[0].req.id,
            size: self.size,
            reps: self.reps,
            class: self.class,
            deadline_s: self.deadline_abs.map(|t| t - now),
        }
    }
}

/// What [`BatchFormer::join`] did with a candidate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum JoinOutcome {
    /// Joined an open window; the cluster should arm (or re-arm) the
    /// window's flush timer at `flush_at`.
    Pending {
        /// Window id ([`BatchFormer::flush`] takes it back).
        window: u64,
        /// Earliest of the timer / deadline-pressure flush bounds.
        flush_at: f64,
    },
    /// Joined a window that must flush immediately: it is full, or an
    /// SLO member cannot afford any further waiting.
    FlushNow {
        /// Window id to flush.
        window: u64,
    },
}

/// One open batch window.
#[derive(Debug, Clone)]
struct OpenWindow {
    id: u64,
    key: ShapeClass,
    opened: f64,
    flush_at: f64,
    members: Vec<BatchMember>,
}

/// True when adding `class` keeps the window's class span within one
/// priority level.
fn class_span_ok(members: &[BatchMember], class: QosClass) -> bool {
    let mut lo = class.index();
    let mut hi = class.index();
    for m in members {
        lo = lo.min(m.req.class.index());
        hi = hi.max(m.req.class.index());
    }
    hi - lo <= 1
}

/// The batch former: the cluster front-end's window bookkeeping (see
/// the module doc). Pure virtual-time state — no machine access — so it
/// replays byte-identically.
#[derive(Debug, Clone)]
pub struct BatchFormer {
    cfg: Option<BatchWindow>,
    /// The admission slack guard band (shared with deadline admission),
    /// applied to member SLOs when computing flush pressure.
    slack: f64,
    windows: Vec<OpenWindow>,
    next_window: u64,
    /// Retired member carriers awaiting reuse (see the module doc's
    /// carrier-reuse section). Bounded so a one-off burst of windows
    /// cannot pin memory forever.
    spare: Vec<Vec<BatchMember>>,
}

/// Most retired carrier vectors [`BatchFormer::recycle`] will hold.
const SPARE_CARRIERS: usize = 16;

impl BatchFormer {
    /// A former for `policy` (inert under [`BatchPolicy::Off`]), using
    /// `deadline_slack` for the SLO pressure bounds.
    pub fn new(policy: &BatchPolicy, deadline_slack: f64) -> Self {
        BatchFormer {
            cfg: match policy {
                BatchPolicy::Off => None,
                BatchPolicy::Windowed(cfg) => Some(*cfg),
            },
            slack: deadline_slack,
            windows: Vec::new(),
            next_window: 0,
            spare: Vec::new(),
        }
    }

    /// Hand a consumed batch's member vector back for reuse by the next
    /// window. Callers that forward members into served records skip
    /// this (the data outlives the former); callers that merely unpack
    /// them — solo flushes, disbanded batches — should not leak the
    /// capacity.
    pub fn recycle(&mut self, mut members: Vec<BatchMember>) {
        members.clear();
        if members.capacity() > 0 && self.spare.len() < SPARE_CARRIERS {
            self.spare.push(members);
        }
    }

    /// True when the former would hold `req` at all: batching is on and
    /// the request is small enough. (The cluster adds the second half
    /// of the candidacy test — no shard's own gate co-executes it
    /// alone.)
    pub fn candidate(&self, req: &GemmRequest) -> bool {
        match &self.cfg {
            Some(cfg) => req.size.ops() <= cfg.max_member_ops,
            None => false,
        }
    }

    /// Members currently waiting in open windows (the cluster counts
    /// them as pending).
    pub fn pending(&self) -> usize {
        self.windows.iter().map(|w| w.members.len()).sum()
    }

    /// Number of open windows (diagnostics/tests).
    pub fn open_windows(&self) -> usize {
        self.windows.len()
    }

    /// True while window `window` is still open. The cluster checks
    /// this before honouring a flush timer: a timer for a window that
    /// already flushed (early, on capacity or SLO pressure) is stale
    /// and must not even advance the virtual clock.
    pub fn has_window(&self, window: u64) -> bool {
        self.windows.iter().any(|w| w.id == window)
    }

    /// The window `req` would join right now, if any: first open window
    /// (open order) with the same [`ShapeClass`], spare capacity and a
    /// compatible class span.
    fn find(&self, key: &ShapeClass, class: QosClass) -> Option<usize> {
        let cfg = self.cfg.as_ref()?;
        self.windows.iter().position(|w| {
            w.key == *key && w.members.len() < cfg.max_members && class_span_ok(&w.members, class)
        })
    }

    /// The fused shape and member count [`BatchFormer::join`] would
    /// produce for `req` right now — the cluster uses this to compute
    /// the predicted batch service time it hands `join` as
    /// `service_hint_s`, without mutating any window.
    pub fn preview(&self, req: &GemmRequest) -> (GemmSize, u32) {
        let key = ShapeClass::of(req.size, req.reps);
        match self.find(&key, req.class) {
            Some(i) => {
                let w = &self.windows[i];
                let m: u64 = w.members.iter().map(|b| b.req.size.m).sum::<u64>() + req.size.m;
                (GemmSize::new(m, key.n, key.k), w.members.len() as u32 + 1)
            }
            None => (req.size, 1),
        }
    }

    /// Add `req` (arriving at `now`) to its compatible window, opening
    /// one if needed. `service_hint_s` is the best-shard predicted
    /// service time of the fused batch *including* `req` (see
    /// [`BatchFormer::preview`]); every member's deadline-pressure
    /// bound is re-tightened under it, so a growing batch can only
    /// flush earlier, never later.
    pub fn join(&mut self, req: GemmRequest, now: f64, service_hint_s: f64) -> JoinOutcome {
        let cfg = self.cfg.expect("join requires BatchPolicy::Windowed");
        let key = ShapeClass::of(req.size, req.reps);
        let idx = match self.find(&key, req.class) {
            Some(i) => i,
            None => {
                let id = self.next_window;
                self.next_window += 1;
                self.windows.push(OpenWindow {
                    id,
                    key,
                    opened: now,
                    flush_at: now + cfg.window_s,
                    // Reuse a retired carrier when one is pooled.
                    members: self.spare.pop().unwrap_or_default(),
                });
                self.windows.len() - 1
            }
        };
        let slack = self.slack;
        let w = &mut self.windows[idx];
        w.members.push(BatchMember { req, arrival: now });
        let mut flush_at = w.opened + cfg.window_s;
        for m in &w.members {
            if let Some(d) = m.req.deadline_s {
                flush_at = flush_at.min(m.arrival + slack * d - service_hint_s);
            }
        }
        w.flush_at = flush_at;
        let window = w.id;
        if w.members.len() >= cfg.max_members || flush_at <= now {
            JoinOutcome::FlushNow { window }
        } else {
            JoinOutcome::Pending { window, flush_at }
        }
    }

    /// Close window `window` and fuse its members. `None` when the
    /// window no longer exists (it already flushed — stale timers are
    /// harmless). A one-member result is the degenerate "batch" the
    /// cluster admits solo.
    pub fn flush(&mut self, window: u64) -> Option<FusedBatch> {
        let idx = self.windows.iter().position(|w| w.id == window)?;
        let w = self.windows.remove(idx);
        let m_total: u64 = w.members.iter().map(|b| b.req.size.m).sum();
        let class = w
            .members
            .iter()
            .map(|b| b.req.class)
            .min()
            .expect("a window always holds at least one member");
        let deadline_abs = w
            .members
            .iter()
            .filter_map(|b| b.req.deadline_s.map(|d| b.arrival + d))
            .reduce(f64::min);
        Some(FusedBatch {
            id: BatchId(w.id),
            size: GemmSize::new(m_total, w.key.n, w.key.k),
            reps: w.key.reps,
            class,
            deadline_abs,
            members: w.members,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BatchWindow {
        BatchWindow {
            window_s: 1.0,
            max_members: 4,
            max_member_ops: 16e9,
        }
    }

    fn former() -> BatchFormer {
        BatchFormer::new(&BatchPolicy::Windowed(cfg()), 0.9)
    }

    fn small(id: u64, m: u64) -> GemmRequest {
        GemmRequest::new(id, GemmSize::new(m, 1024, 1024), 2)
    }

    #[test]
    fn off_policy_is_inert() {
        let f = BatchFormer::new(&BatchPolicy::Off, 0.9);
        assert!(!f.candidate(&small(0, 1024)));
        assert_eq!(f.pending(), 0);
        assert_eq!(BatchPolicy::default(), BatchPolicy::Off);
        assert!(matches!(BatchPolicy::windowed(), BatchPolicy::Windowed(_)));
    }

    #[test]
    fn candidate_enforces_the_ops_ceiling() {
        let f = former();
        assert!(f.candidate(&small(0, 1024)));
        // 20000^3 is far past max_member_ops.
        assert!(!f.candidate(&GemmRequest::new(1, GemmSize::square(20_000), 2)));
    }

    #[test]
    fn same_shape_class_members_share_a_window() {
        let mut f = former();
        // 1024 and 1536 share ⌊log2⌋ = 10 and the exact (n, k, reps).
        let a = f.join(small(0, 1024), 0.0, 0.01);
        let b = f.join(small(1, 1536), 0.1, 0.01);
        assert!(matches!(a, JoinOutcome::Pending { window: 0, .. }));
        assert!(matches!(b, JoinOutcome::Pending { window: 0, .. }));
        assert_eq!(f.open_windows(), 1);
        assert_eq!(f.pending(), 2);
        let batch = f.flush(0).unwrap();
        assert_eq!(batch.size, GemmSize::new(2560, 1024, 1024));
        assert_eq!(batch.reps, 2);
        assert_eq!(batch.members.len(), 2);
        assert_eq!(batch.id, BatchId(0));
        assert_eq!(f.pending(), 0);
        // Stale timer: the window is gone.
        assert!(f.flush(0).is_none());
    }

    #[test]
    fn incompatible_shapes_open_separate_windows() {
        let mut f = former();
        f.join(small(0, 1024), 0.0, 0.01);
        // Different n.
        f.join(GemmRequest::new(1, GemmSize::new(1024, 512, 1024), 2), 0.0, 0.01);
        // Different k.
        f.join(GemmRequest::new(2, GemmSize::new(1024, 1024, 512), 2), 0.0, 0.01);
        // Different reps.
        f.join(GemmRequest::new(3, GemmSize::new(1024, 1024, 1024), 3), 0.0, 0.01);
        // Different m bucket (2048 -> ⌊log2⌋ = 11).
        f.join(small(4, 2048), 0.0, 0.01);
        assert_eq!(f.open_windows(), 5);
    }

    #[test]
    fn class_span_wider_than_one_level_does_not_mix() {
        let mut f = former();
        f.join(small(0, 1024).with_class(QosClass::Interactive), 0.0, 0.01);
        // Standard is adjacent: joins.
        f.join(small(1, 1024).with_class(QosClass::Standard), 0.0, 0.01);
        assert_eq!(f.open_windows(), 1);
        // Batch would stretch the span to 2: a second window opens.
        f.join(small(2, 1024).with_class(QosClass::Batch), 0.0, 0.01);
        assert_eq!(f.open_windows(), 2);
        // The fused lane is the strictest member's.
        let batch = f.flush(0).unwrap();
        assert_eq!(batch.class, QosClass::Interactive);
    }

    #[test]
    fn full_window_flushes_immediately() {
        let mut f = former();
        for i in 0..3u64 {
            assert!(matches!(
                f.join(small(i, 1024), 0.0, 0.01),
                JoinOutcome::Pending { .. }
            ));
        }
        assert_eq!(
            f.join(small(3, 1024), 0.0, 0.01),
            JoinOutcome::FlushNow { window: 0 }
        );
        let batch = f.flush(0).unwrap();
        assert_eq!(batch.members.len(), 4);
        assert_eq!(batch.size.m, 4096);
    }

    #[test]
    fn deadline_pressure_tightens_the_flush_bound() {
        let mut f = former();
        let relaxed = f.join(small(0, 1024), 0.0, 0.01);
        match relaxed {
            JoinOutcome::Pending { flush_at, .. } => assert_eq!(flush_at, 1.0),
            other => panic!("unexpected {other:?}"),
        }
        // An SLO member: must flush by arrival + 0.9*0.5 - hint = 0.40.
        let pressured = f.join(small(1, 1024).with_deadline(0.5), 0.05, 0.1);
        match pressured {
            JoinOutcome::Pending { window, flush_at } => {
                assert_eq!(window, 0);
                assert!((flush_at - (0.05 + 0.45 - 0.1)).abs() < 1e-12);
            }
            other => panic!("unexpected {other:?}"),
        }
        // A grown service hint re-tightens the *existing* member's
        // bound; here it collapses past `now`, forcing the flush.
        assert_eq!(
            f.join(small(2, 1024), 0.3, 0.25),
            JoinOutcome::FlushNow { window: 0 }
        );
    }

    #[test]
    fn untenable_slo_flushes_at_once() {
        let mut f = former();
        // Even an immediate flush is predicted to graze the SLO: the
        // former refuses to add any window wait.
        let out = f.join(small(0, 1024).with_deadline(0.05), 1.0, 0.2);
        assert_eq!(out, JoinOutcome::FlushNow { window: 0 });
        let batch = f.flush(0).unwrap();
        assert_eq!(batch.members.len(), 1);
        // The carrier re-expresses the absolute deadline.
        assert!((batch.deadline_abs.unwrap() - 1.05).abs() < 1e-12);
        let carrier = batch.carrier(1.0);
        assert!((carrier.deadline_s.unwrap() - 0.05).abs() < 1e-12);
        assert_eq!(carrier.id, 0);
    }

    #[test]
    fn flush_fuses_sums_and_takes_the_tightest_deadline() {
        let mut f = former();
        f.join(small(0, 1024).with_deadline(2.0), 0.0, 0.01);
        f.join(small(1, 1536), 0.1, 0.01);
        f.join(small(2, 1024).with_deadline(1.0), 0.2, 0.01);
        let batch = f.flush(0).unwrap();
        assert_eq!(batch.size.m, 1024 + 1536 + 1024);
        // min(0 + 2.0, 0.2 + 1.0) = 1.2.
        assert!((batch.deadline_abs.unwrap() - 1.2).abs() < 1e-12);
        let carrier = batch.carrier(0.5);
        assert!((carrier.deadline_s.unwrap() - 0.7).abs() < 1e-12);
        assert_eq!(carrier.size, batch.size);
        // Member records keep their own arrivals and deadlines.
        assert_eq!(batch.members[1].arrival, 0.1);
        assert_eq!(batch.members[2].req.deadline_s, Some(1.0));
    }

    #[test]
    fn preview_matches_what_join_would_fuse() {
        let mut f = former();
        let first = small(0, 1024);
        assert_eq!(f.preview(&first), (GemmSize::new(1024, 1024, 1024), 1));
        f.join(first, 0.0, 0.01);
        let second = small(1, 1536);
        assert_eq!(f.preview(&second), (GemmSize::new(2560, 1024, 1024), 2));
        // An incompatible request previews as a fresh window.
        let other = GemmRequest::new(2, GemmSize::new(1024, 512, 1024), 2);
        assert_eq!(f.preview(&other), (GemmSize::new(1024, 512, 1024), 1));
    }

    #[test]
    fn recycled_carriers_are_reused_by_new_windows() {
        let mut f = former();
        f.join(small(0, 1024), 0.0, 0.01);
        let batch = f.flush(0).unwrap();
        assert_eq!(batch.members.len(), 1);
        let ptr = batch.members.as_ptr();
        f.recycle(batch.members);
        // The next window pops the retired carrier instead of
        // allocating: same buffer, cleared.
        f.join(small(1, 1024), 1.0, 0.01);
        let again = f.flush(1).unwrap();
        assert_eq!(again.members.as_ptr(), ptr, "carrier buffer was reused");
        assert_eq!(again.members.len(), 1);
        assert_eq!(again.members[0].req.id, 1);
        // Recycling an empty (capacity-0) vector is a no-op.
        f.recycle(Vec::new());
    }

    #[test]
    fn shape_class_buckets_by_log2_m_only() {
        let a = ShapeClass::of(GemmSize::new(1024, 500, 600), 2);
        let b = ShapeClass::of(GemmSize::new(2047, 500, 600), 2);
        let c = ShapeClass::of(GemmSize::new(2048, 500, 600), 2);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, ShapeClass::of(GemmSize::new(1024, 501, 600), 2));
        assert_ne!(a, ShapeClass::of(GemmSize::new(1024, 500, 600), 3));
    }
}
