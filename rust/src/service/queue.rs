//! Pluggable request-queue policies.
//!
//! The server admits requests into a [`RequestQueue`] and drains it one
//! dispatch at a time. Two orderings are provided (queue-level
//! co-scheduling in the spirit of Aupy et al., "Co-Scheduling Algorithms
//! for High-Throughput Workload Execution"):
//!
//! * [`QueuePolicy::Fifo`] — arrival order (the baseline a naive
//!   service would use);
//! * [`QueuePolicy::Spjf`] — shortest-predicted-job-first: dispatch the
//!   request with the smallest admission-time predicted service time.
//!   Classic SPT scheduling minimizes mean completion time on a single
//!   shared machine, and POAS gives us the predictions for free.
//!
//! Requests are annotated once at admission ([`QueuedRequest`]) so
//! policy decisions never re-run the optimizer.

use super::request::GemmRequest;
use std::collections::VecDeque;

/// Dispatch-order policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueuePolicy {
    /// First in, first out.
    Fifo,
    /// Shortest predicted job first (ties: arrival order).
    Spjf,
}

/// A pending request plus the admission-time gate/prediction results.
#[derive(Debug, Clone)]
pub struct QueuedRequest {
    /// The request itself.
    pub req: GemmRequest,
    /// Virtual time it entered the queue.
    pub arrival: f64,
    /// Suitability-gate verdict: worth co-executing?
    pub co_execute: bool,
    /// Best single device if run standalone.
    pub best_device: usize,
    /// Predicted total service seconds (all reps) under the verdict.
    pub predicted_s: f64,
}

/// The pending-request queue.
#[derive(Debug, Clone)]
pub struct RequestQueue {
    policy: QueuePolicy,
    pending: VecDeque<QueuedRequest>,
}

impl RequestQueue {
    /// Empty queue under `policy`.
    pub fn new(policy: QueuePolicy) -> Self {
        RequestQueue {
            policy,
            pending: VecDeque::new(),
        }
    }

    /// The active policy.
    pub fn policy(&self) -> QueuePolicy {
        self.policy
    }

    /// Number of pending requests.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// True when nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Sum of the admission-time service predictions of everything
    /// pending — the backlog a routing front-end adds to a shard's
    /// predicted finish.
    pub fn predicted_backlog(&self) -> f64 {
        self.pending.iter().map(|q| q.predicted_s).sum()
    }

    /// Iterate the pending requests in queue order (diagnostics).
    pub fn iter(&self) -> impl Iterator<Item = &QueuedRequest> {
        self.pending.iter()
    }

    /// Admit a request at the tail.
    pub fn push(&mut self, q: QueuedRequest) {
        self.pending.push_back(q);
    }

    /// Put a request back at the head (used when a bypass pairing has to
    /// be undone).
    pub fn push_front(&mut self, q: QueuedRequest) {
        self.pending.push_front(q);
    }

    /// Remove and return the next request to dispatch under the policy.
    pub fn pop_next(&mut self) -> Option<QueuedRequest> {
        match self.policy {
            QueuePolicy::Fifo => self.pending.pop_front(),
            QueuePolicy::Spjf => {
                let idx = self
                    .pending
                    .iter()
                    .enumerate()
                    .min_by(|(ia, a), (ib, b)| {
                        a.predicted_s
                            .total_cmp(&b.predicted_s)
                            .then(ia.cmp(ib))
                    })
                    .map(|(i, _)| i)?;
                self.pending.remove(idx)
            }
        }
    }

    /// Remove and return the first pending request (queue order)
    /// matching `pred` — the bypass scan.
    pub fn take_first<F: FnMut(&QueuedRequest) -> bool>(
        &mut self,
        mut pred: F,
    ) -> Option<QueuedRequest> {
        let idx = self.pending.iter().position(|q| pred(q))?;
        self.pending.remove(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::GemmSize;

    fn q(id: u64, predicted_s: f64, co: bool) -> QueuedRequest {
        QueuedRequest {
            req: GemmRequest {
                id,
                size: GemmSize::square(1000),
                reps: 1,
            },
            arrival: id as f64,
            co_execute: co,
            best_device: 2,
            predicted_s,
        }
    }

    #[test]
    fn fifo_preserves_arrival_order() {
        let mut rq = RequestQueue::new(QueuePolicy::Fifo);
        for (id, t) in [(0, 5.0), (1, 1.0), (2, 3.0)] {
            rq.push(q(id, t, true));
        }
        let order: Vec<u64> = std::iter::from_fn(|| rq.pop_next().map(|x| x.req.id)).collect();
        assert_eq!(order, vec![0, 1, 2]);
        assert!(rq.is_empty());
    }

    #[test]
    fn spjf_dispatches_shortest_first() {
        let mut rq = RequestQueue::new(QueuePolicy::Spjf);
        for (id, t) in [(0, 5.0), (1, 1.0), (2, 3.0), (3, 1.0)] {
            rq.push(q(id, t, true));
        }
        let order: Vec<u64> = std::iter::from_fn(|| rq.pop_next().map(|x| x.req.id)).collect();
        // Ties (ids 1 and 3 at 1.0s) break by queue position.
        assert_eq!(order, vec![1, 3, 2, 0]);
    }

    #[test]
    fn take_first_scans_in_queue_order() {
        let mut rq = RequestQueue::new(QueuePolicy::Fifo);
        rq.push(q(0, 5.0, true));
        rq.push(q(1, 1.0, false));
        rq.push(q(2, 0.5, false));
        let got = rq.take_first(|c| !c.co_execute).unwrap();
        assert_eq!(got.req.id, 1, "first matching, not best matching");
        assert_eq!(rq.len(), 2);
        assert!(rq.take_first(|c| c.predicted_s > 100.0).is_none());
        assert_eq!(rq.len(), 2);
    }

    #[test]
    fn predicted_backlog_sums_pending() {
        let mut rq = RequestQueue::new(QueuePolicy::Fifo);
        assert_eq!(rq.predicted_backlog(), 0.0);
        rq.push(q(0, 5.0, true));
        rq.push(q(1, 1.5, false));
        assert!((rq.predicted_backlog() - 6.5).abs() < 1e-12);
        rq.pop_next();
        assert!((rq.predicted_backlog() - 1.5).abs() < 1e-12);
        assert_eq!(rq.iter().count(), 1);
    }

    #[test]
    fn push_front_restores_head() {
        let mut rq = RequestQueue::new(QueuePolicy::Fifo);
        rq.push(q(0, 1.0, true));
        let taken = rq.pop_next().unwrap();
        rq.push(q(1, 1.0, true));
        rq.push_front(taken);
        assert_eq!(rq.pop_next().unwrap().req.id, 0);
        assert_eq!(rq.pop_next().unwrap().req.id, 1);
    }
}
