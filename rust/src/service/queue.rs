//! Pluggable request-queue policies with per-class weighted fairness.
//!
//! The server admits requests into a [`RequestQueue`] and drains it one
//! dispatch at a time. Since the QoS tiers landed, the queue is really
//! **three queues** — one per [`QosClass`] — drained by a smooth
//! weighted round-robin pick (the classic deficit/credit scheme used by
//! fair packet schedulers): every pop credits each non-empty class with
//! its weight, serves the class holding the most credit, and debits the
//! winner by the total outstanding weight. Two invariants follow:
//!
//! * **weighted shares** — while several classes stay backlogged, class
//!   `c` receives `weight(c) / Σ weight` of the dispatches;
//! * **no starvation** — a non-empty class is served at least once
//!   every `Σ weight / weight(c)` pops (rounded up), no matter how
//!   heavy the other classes are.
//!
//! *Within* a class the original orderings still apply (queue-level
//! co-scheduling in the spirit of Aupy et al., "Co-Scheduling
//! Algorithms for High-Throughput Workload Execution"):
//!
//! * [`QueuePolicy::Fifo`] — arrival order (the baseline a naive
//!   service would use);
//! * [`QueuePolicy::Spjf`] — shortest-predicted-job-first: dispatch the
//!   request with the smallest admission-time predicted service time.
//!   Classic SPT scheduling minimizes mean completion time on a single
//!   shared machine, and POAS gives us the predictions for free.
//!
//! Requests are annotated once at admission ([`QueuedRequest`]) so
//! policy decisions never re-run the optimizer. Everything here is
//! integer-credit arithmetic over a fixed class order, so replays are
//! byte-identical.
//!
//! Backlog views ([`RequestQueue::class_backlog`] and friends) are
//! maintained **incrementally**: every push adds the entry's predicted
//! seconds to its lane's running total, every removal subtracts it, and
//! a lane that empties snaps back to exactly `0.0` so an idle lane is
//! bit-identical to a never-used one. That makes the per-arrival
//! routing probe ([`ExecutorShard::predicted_finish_for`]) O(1) in
//! queue depth instead of re-summing the lanes on every candidate —
//! the front-end hot path asks these questions once per candidate per
//! arrival.
//!
//! [`ExecutorShard::predicted_finish_for`]: super::shard::ExecutorShard::predicted_finish_for

use super::batch::FusedBatch;
use super::qos::{QosClass, NUM_CLASSES};
use super::request::GemmRequest;
use std::collections::VecDeque;

/// Dispatch-order policy within a class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueuePolicy {
    /// First in, first out.
    Fifo,
    /// Shortest predicted job first (ties: arrival order).
    Spjf,
}

/// A pending request plus the admission-time gate/prediction results.
#[derive(Debug, Clone)]
pub struct QueuedRequest {
    /// The request itself (carries its [`QosClass`] and optional SLO).
    pub req: GemmRequest,
    /// Virtual time it entered the queue.
    pub arrival: f64,
    /// Suitability-gate verdict: worth co-executing?
    pub co_execute: bool,
    /// Best single device if run standalone.
    pub best_device: usize,
    /// Predicted total service seconds (all reps) under the verdict.
    pub predicted_s: f64,
    /// The fused batch behind this entry, when `req` is a batch
    /// carrier: the batch occupies exactly **one queue slot** on the
    /// lane of its strictest member, is routed/stolen as one unit, and
    /// fans out into per-member completion records at dispatch.
    pub batch: Option<FusedBatch>,
}

/// The pending-request queue: one lane per [`QosClass`], drained by a
/// smooth weighted round-robin over the non-empty lanes.
#[derive(Debug, Clone)]
pub struct RequestQueue {
    policy: QueuePolicy,
    lanes: [VecDeque<QueuedRequest>; NUM_CLASSES],
    /// Weighted-deficit state: credit accumulated by each class. Only
    /// non-empty classes accrue; an emptied class resets to zero so a
    /// long-idle tier cannot bank an unbounded burst.
    credit: [i64; NUM_CLASSES],
    /// Running sum of `predicted_s` per lane, kept current on every
    /// push/pop/removal (snapped to exactly `0.0` when a lane empties)
    /// so the backlog views are O(1).
    lane_backlog: [f64; NUM_CLASSES],
}

impl RequestQueue {
    /// Empty queue under `policy`.
    pub fn new(policy: QueuePolicy) -> Self {
        RequestQueue {
            policy,
            lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            credit: [0; NUM_CLASSES],
            lane_backlog: [0.0; NUM_CLASSES],
        }
    }

    /// The active (within-class) policy.
    pub fn policy(&self) -> QueuePolicy {
        self.policy
    }

    /// Number of pending requests across all classes.
    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    /// True when nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.is_empty())
    }

    /// Pending requests of one class.
    pub fn class_len(&self, class: QosClass) -> usize {
        self.lanes[class.index()].len()
    }

    /// Sum of the admission-time service predictions of everything
    /// pending — the backlog a routing front-end adds to a shard's
    /// predicted finish. O(1): read from the incremental lane totals.
    pub fn predicted_backlog(&self) -> f64 {
        self.lane_backlog.iter().sum()
    }

    /// Predicted backlog of one class's lane. O(1).
    pub fn class_backlog(&self, class: QosClass) -> f64 {
        self.lane_backlog[class.index()]
    }

    /// Class-weighted backlog: each lane's predicted seconds scaled by
    /// its scheduling weight. The cluster's work stealing treats the
    /// shard with the largest value as the most urgent victim — a
    /// minute of queued interactive work outweighs a minute of batch.
    pub fn weighted_backlog(&self) -> f64 {
        QosClass::ALL
            .iter()
            .map(|&c| self.class_backlog(c) * c.weight() as f64)
            .sum()
    }

    /// Backlog a new arrival of `class` (with predicted service
    /// `service_s`) should expect to wait behind on this queue, under
    /// the weighted drain. Equal- and higher-priority lanes count at
    /// face value — they drain ahead of the arrival. A lower-priority
    /// lane `k` only interleaves while the arrival's own lane is
    /// draining, at most `weight(k)/weight(c)` seconds per second of
    /// that drain — so its contribution is capped by that ratio times
    /// the arrival's own-lane work (itself included), **not** its full
    /// backlog. Without the cap, a deep batch queue would spuriously
    /// fail deadline admission for interactive traffic it cannot
    /// actually delay.
    pub fn backlog_ahead_of(&self, class: QosClass, service_s: f64) -> f64 {
        let w_c = class.weight() as f64;
        // The arrival's own lane's work to drain, itself included.
        let own = self.class_backlog(class) + service_s;
        QosClass::ALL
            .iter()
            .map(|&k| {
                let lane = self.class_backlog(k);
                if k.weight() >= class.weight() {
                    lane
                } else {
                    lane.min(k.weight() as f64 / w_c * own)
                }
            })
            .sum()
    }

    /// Iterate the pending requests (class-major: interactive lane
    /// first, queue order within a lane) — diagnostics and the bypass
    /// scan.
    pub fn iter(&self) -> impl Iterator<Item = &QueuedRequest> {
        self.lanes.iter().flat_map(|l| l.iter())
    }

    /// Admit a request at the tail of its class lane.
    pub fn push(&mut self, q: QueuedRequest) {
        let lane = q.req.class.index();
        self.lane_backlog[lane] += q.predicted_s;
        self.lanes[lane].push_back(q);
    }

    /// Put a request back at the head of its class lane (used when a
    /// bypass pairing has to be undone).
    pub fn push_front(&mut self, q: QueuedRequest) {
        let lane = q.req.class.index();
        self.lane_backlog[lane] += q.predicted_s;
        self.lanes[lane].push_front(q);
    }

    /// Settle the incremental backlog after removing an entry with
    /// prediction `predicted_s` from `lane`: subtract it, and snap an
    /// emptied lane back to exactly `0.0` so float residue from the
    /// running sum can never distinguish an idle lane from a fresh one
    /// (symmetric shards must stay bit-identical for routing ties).
    fn settle_removal(&mut self, lane: usize, predicted_s: f64) {
        if self.lanes[lane].is_empty() {
            self.lane_backlog[lane] = 0.0;
        } else {
            self.lane_backlog[lane] -= predicted_s;
        }
    }

    /// The lane [`RequestQueue::pop_next`] would serve right now,
    /// computed **without mutating** the credit state: compare each
    /// non-empty lane's post-accrual credit (`credit + weight`).
    /// Strict `>` keeps ties on the earlier (higher-priority) class —
    /// exactly the pop's tie-break.
    fn winning_lane(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for c in QosClass::ALL {
            let i = c.index();
            if self.lanes[i].is_empty() {
                continue;
            }
            let credit = self.credit[i] + c.weight() as i64;
            let wins = match best {
                None => true,
                Some(b) => credit > self.credit[b] + QosClass::ALL[b].weight() as i64,
            };
            if wins {
                best = Some(i);
            }
        }
        best
    }

    /// The request [`RequestQueue::pop_next`] would return right now,
    /// without removing it or advancing the round-robin state. Steal
    /// offers consult this before committing to the pop: vetoing a
    /// steal *after* popping would burn one of the head class's
    /// weighted turns without any dispatch happening.
    pub fn peek_next(&self) -> Option<&QueuedRequest> {
        let lane = self.winning_lane()?;
        match self.policy {
            QueuePolicy::Fifo => self.lanes[lane].front(),
            QueuePolicy::Spjf => self.lanes[lane]
                .iter()
                .enumerate()
                .min_by(|(ia, a), (ib, b)| {
                    a.predicted_s.total_cmp(&b.predicted_s).then(ia.cmp(ib))
                })
                .map(|(_, q)| q),
        }
    }

    /// Remove and return the next request to dispatch: smooth weighted
    /// round-robin across non-empty classes, then the within-class
    /// policy. Deterministic — ties in credit break toward the
    /// higher-priority class (see [`RequestQueue::winning_lane`]).
    pub fn pop_next(&mut self) -> Option<QueuedRequest> {
        let lane = self.winning_lane()?;
        let mut total: i64 = 0;
        for c in QosClass::ALL {
            let i = c.index();
            if self.lanes[i].is_empty() {
                // An empty lane accrues nothing and banks nothing.
                self.credit[i] = 0;
                continue;
            }
            self.credit[i] += c.weight() as i64;
            total += c.weight() as i64;
        }
        self.credit[lane] -= total;
        self.pop_from_lane(lane)
    }

    fn pop_from_lane(&mut self, lane: usize) -> Option<QueuedRequest> {
        let popped = match self.policy {
            QueuePolicy::Fifo => self.lanes[lane].pop_front(),
            QueuePolicy::Spjf => {
                let idx = self.lanes[lane]
                    .iter()
                    .enumerate()
                    .min_by(|(ia, a), (ib, b)| {
                        a.predicted_s.total_cmp(&b.predicted_s).then(ia.cmp(ib))
                    })
                    .map(|(i, _)| i)?;
                self.lanes[lane].remove(idx)
            }
        };
        if let Some(q) = &popped {
            self.settle_removal(lane, q.predicted_s);
        }
        popped
    }

    /// Remove and return the first pending request (class-major scan
    /// order) matching `pred` — the bypass scan. Higher-priority riders
    /// are found first.
    pub fn take_first<F: FnMut(&QueuedRequest) -> bool>(
        &mut self,
        mut pred: F,
    ) -> Option<QueuedRequest> {
        for lane in 0..NUM_CLASSES {
            if let Some(idx) = self.lanes[lane].iter().position(|q| pred(q)) {
                let taken = self.lanes[lane].remove(idx);
                if let Some(q) = &taken {
                    self.settle_removal(lane, q.predicted_s);
                }
                return taken;
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::GemmSize;

    fn q(id: u64, predicted_s: f64, co: bool) -> QueuedRequest {
        q_class(id, predicted_s, co, QosClass::Standard)
    }

    fn q_class(id: u64, predicted_s: f64, co: bool, class: QosClass) -> QueuedRequest {
        QueuedRequest {
            req: GemmRequest::new(id, GemmSize::square(1000), 1).with_class(class),
            arrival: id as f64,
            co_execute: co,
            best_device: 2,
            predicted_s,
            batch: None,
        }
    }

    fn drain(rq: &mut RequestQueue) -> Vec<u64> {
        std::iter::from_fn(|| rq.pop_next().map(|x| x.req.id)).collect()
    }

    #[test]
    fn fifo_preserves_arrival_order() {
        let mut rq = RequestQueue::new(QueuePolicy::Fifo);
        for (id, t) in [(0, 5.0), (1, 1.0), (2, 3.0)] {
            rq.push(q(id, t, true));
        }
        assert_eq!(drain(&mut rq), vec![0, 1, 2]);
        assert!(rq.is_empty());
    }

    #[test]
    fn spjf_dispatches_shortest_first() {
        let mut rq = RequestQueue::new(QueuePolicy::Spjf);
        for (id, t) in [(0, 5.0), (1, 1.0), (2, 3.0), (3, 1.0)] {
            rq.push(q(id, t, true));
        }
        // Ties (ids 1 and 3 at 1.0s) break by queue position.
        assert_eq!(drain(&mut rq), vec![1, 3, 2, 0]);
    }

    #[test]
    fn take_first_scans_in_queue_order() {
        let mut rq = RequestQueue::new(QueuePolicy::Fifo);
        rq.push(q(0, 5.0, true));
        rq.push(q(1, 1.0, false));
        rq.push(q(2, 0.5, false));
        let got = rq.take_first(|c| !c.co_execute).unwrap();
        assert_eq!(got.req.id, 1, "first matching, not best matching");
        assert_eq!(rq.len(), 2);
        assert!(rq.take_first(|c| c.predicted_s > 100.0).is_none());
        assert_eq!(rq.len(), 2);
    }

    #[test]
    fn take_first_prefers_higher_priority_lanes() {
        let mut rq = RequestQueue::new(QueuePolicy::Fifo);
        rq.push(q_class(0, 1.0, false, QosClass::Batch));
        rq.push(q_class(1, 1.0, false, QosClass::Interactive));
        let got = rq.take_first(|c| !c.co_execute).unwrap();
        assert_eq!(got.req.id, 1, "interactive lane scanned first");
    }

    #[test]
    fn predicted_backlog_sums_pending() {
        let mut rq = RequestQueue::new(QueuePolicy::Fifo);
        assert_eq!(rq.predicted_backlog(), 0.0);
        rq.push(q(0, 5.0, true));
        rq.push(q(1, 1.5, false));
        assert!((rq.predicted_backlog() - 6.5).abs() < 1e-12);
        rq.pop_next();
        assert!((rq.predicted_backlog() - 1.5).abs() < 1e-12);
        assert_eq!(rq.iter().count(), 1);
    }

    #[test]
    fn push_front_restores_head() {
        let mut rq = RequestQueue::new(QueuePolicy::Fifo);
        rq.push(q(0, 1.0, true));
        let taken = rq.pop_next().unwrap();
        rq.push(q(1, 1.0, true));
        rq.push_front(taken);
        assert_eq!(rq.pop_next().unwrap().req.id, 0);
        assert_eq!(rq.pop_next().unwrap().req.id, 1);
    }

    #[test]
    fn peek_next_matches_pop_and_never_mutates() {
        for policy in [QueuePolicy::Fifo, QueuePolicy::Spjf] {
            let mut rq = RequestQueue::new(policy);
            for (id, t, class) in [
                (0, 5.0, QosClass::Batch),
                (1, 1.0, QosClass::Interactive),
                (2, 3.0, QosClass::Interactive),
                (3, 0.5, QosClass::Standard),
            ] {
                rq.push(q_class(id, t, true, class));
            }
            // Draining: every peek agrees with the pop that follows,
            // and peeking repeatedly (a vetoed steal, retried) never
            // advances the weighted round-robin.
            while !rq.is_empty() {
                let peeked = rq.peek_next().unwrap().req.id;
                assert_eq!(rq.peek_next().unwrap().req.id, peeked, "peek mutated state");
                let popped = rq.pop_next().unwrap().req.id;
                assert_eq!(peeked, popped, "peek and pop disagree under {policy:?}");
            }
            assert!(rq.peek_next().is_none());
        }
    }

    #[test]
    fn weighted_pick_shares_match_weights() {
        // 4:2:1 weights over 70 pops with every class kept non-empty:
        // exactly 40/20/10 dispatches.
        let mut rq = RequestQueue::new(QueuePolicy::Fifo);
        let mut counts = [0usize; NUM_CLASSES];
        let mut id = 0u64;
        for _ in 0..70 {
            for c in QosClass::ALL {
                // Keep every lane at depth >= 2 so none empties.
                while rq.class_len(c) < 2 {
                    rq.push(q_class(id, 1.0, true, c));
                    id += 1;
                }
            }
            let got = rq.pop_next().unwrap();
            counts[got.req.class.index()] += 1;
        }
        assert_eq!(counts, [40, 20, 10], "shares must match 4:2:1 weights");
    }

    #[test]
    fn heavy_class_cannot_starve_light_one() {
        // A deep interactive lane and a single batch request: the batch
        // request must dispatch within ceil(7/1) = 7 pops.
        let mut rq = RequestQueue::new(QueuePolicy::Fifo);
        for i in 0..40 {
            rq.push(q_class(i, 1.0, true, QosClass::Interactive));
        }
        rq.push(q_class(99, 1.0, true, QosClass::Batch));
        let order = drain(&mut rq);
        let pos = order.iter().position(|&id| id == 99).unwrap();
        assert!(pos < 7, "batch request starved: position {pos}");
    }

    #[test]
    fn single_class_degenerates_to_plain_policy() {
        // All-Standard input must behave exactly like the pre-QoS queue.
        let mut rq = RequestQueue::new(QueuePolicy::Spjf);
        for (id, t) in [(0, 5.0), (1, 1.0), (2, 3.0)] {
            rq.push(q(id, t, true));
        }
        assert_eq!(drain(&mut rq), vec![1, 2, 0]);
    }

    #[test]
    fn class_backlogs_and_weighted_views() {
        let mut rq = RequestQueue::new(QueuePolicy::Fifo);
        rq.push(q_class(0, 2.0, true, QosClass::Interactive));
        rq.push(q_class(1, 3.0, true, QosClass::Batch));
        assert!((rq.class_backlog(QosClass::Interactive) - 2.0).abs() < 1e-12);
        assert!((rq.class_backlog(QosClass::Batch) - 3.0).abs() < 1e-12);
        assert!((rq.class_backlog(QosClass::Standard)).abs() < 1e-12);
        // Weighted: 2*4 + 3*1 = 11.
        assert!((rq.weighted_backlog() - 11.0).abs() < 1e-12);
        // A 1s interactive arrival drains 2+1 = 3s of its own lane and
        // lets the batch lane interleave at most 3/4s of its 3s; a
        // batch arrival waits behind everything at face value.
        assert!((rq.backlog_ahead_of(QosClass::Interactive, 1.0) - (2.0 + 0.75)).abs() < 1e-12);
        assert!((rq.backlog_ahead_of(QosClass::Batch, 1.0) - 5.0).abs() < 1e-12);
        assert_eq!(rq.class_len(QosClass::Interactive), 1);
    }

    #[test]
    fn incremental_backlog_matches_recomputation_on_every_path() {
        // Exercise every mutation path (push, push_front, pop_next,
        // take_first) and check the O(1) lane totals against a
        // from-scratch re-sum; an emptied lane must read exactly 0.0.
        let mut rq = RequestQueue::new(QueuePolicy::Spjf);
        let recompute = |rq: &RequestQueue, c: QosClass| -> f64 {
            rq.iter()
                .filter(|q| q.req.class == c)
                .map(|q| q.predicted_s)
                .sum()
        };
        let check = |rq: &RequestQueue| {
            for c in QosClass::ALL {
                assert!(
                    (rq.class_backlog(c) - recompute(rq, c)).abs() < 1e-12,
                    "lane {c:?} drifted"
                );
            }
        };
        for (id, t, class) in [
            (0, 0.5, QosClass::Interactive),
            (1, 2.25, QosClass::Standard),
            (2, 1.75, QosClass::Standard),
            (3, 4.0, QosClass::Batch),
        ] {
            rq.push(q_class(id, t, id % 2 == 0, class));
            check(&rq);
        }
        let taken = rq.take_first(|q| !q.co_execute).unwrap();
        check(&rq);
        rq.push_front(taken);
        check(&rq);
        while let Some(_q) = rq.pop_next() {
            check(&rq);
        }
        for c in QosClass::ALL {
            assert_eq!(rq.class_backlog(c), 0.0, "emptied lane must be exact");
        }
        assert_eq!(rq.predicted_backlog(), 0.0);
    }

    #[test]
    fn deep_batch_backlog_cannot_stall_an_interactive_prediction() {
        // 100s of queued batch work: a 1s interactive arrival with an
        // empty own lane is only delayed by the interleave the weighted
        // drain actually allows (1/4 of its own 1s drain), not by the
        // whole batch queue.
        let mut rq = RequestQueue::new(QueuePolicy::Fifo);
        for i in 0..100 {
            rq.push(q_class(i, 1.0, true, QosClass::Batch));
        }
        assert!((rq.backlog_ahead_of(QosClass::Interactive, 1.0) - 0.25).abs() < 1e-12);
        // The same arrival submitted as batch waits behind the lane.
        assert!((rq.backlog_ahead_of(QosClass::Batch, 1.0) - 100.0).abs() < 1e-12);
    }
}
