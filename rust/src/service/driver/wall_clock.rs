//! The wall-clock driver: actor-per-shard execution mirrored off the
//! deterministic core.
//!
//! # Control plane vs data plane
//!
//! Every scheduling *decision* — admission verdict, batch membership,
//! routed shard, steal victim, fault handling, autoscaler move — is
//! still made by the deterministic core ([`Cluster`]) on its virtual
//! clock, which is why decisions are identical across drivers by
//! construction (and why the wall-clock driver's [`ServiceReport`]
//! digests equal the virtual driver's). What this driver adds is a
//! *data plane*: the core's dispatch/steal/fault stream (its tap,
//! [`TapAction`]) is mirrored in decision order to one worker thread
//! per shard, where an [`Executor`] really runs each unit — so shard
//! service, plan-cache hits, and completion fan-out genuinely overlap
//! across cores.
//!
//! # The actor protocol
//!
//! Each shard worker owns a **bounded** command channel
//! (`Dispatch` / `StealOffer` / `Drain` / `Crash` / `Shutdown`). The
//! bound is the backpressure: when a shard's mirror queue fills, the
//! core's forwarding loop blocks on `send` until the worker catches
//! up — the front-end cannot run unboundedly ahead of execution.
//! Workers report back on one unified unbounded MPSC event stream
//! ([`ShardEvent`]); the front-end folds that stream between core
//! steps and at shutdown, keyed by the unit ordinal the core assigned
//! at dispatch time — unit ordinals are allocated in decision order,
//! so the accounting is independent of thread interleaving.
//!
//! # Faults and exactly-once accounting
//!
//! A core crash displaces the shard's queued work *and* aborts its
//! in-flight record; the mirror matches that with a per-shard crash
//! **epoch** (an `Arc<AtomicU64>` the driver bumps *before* sending
//! `Crash`): any dispatch still sitting in the command channel from an
//! older epoch is acknowledged as [`ShardEvent::Dropped`], and a unit
//! already executing finishes as wasted work. Either way every
//! forwarded unit produces exactly one terminal event — `Completion`
//! or `Dropped` — which is the invariant
//! [`WallClockStats::lost`] / [`WallClockStats::duplicated`] count
//! violations of (both CI-gated at zero). Note the exactly-once
//! contract is per *unit*: a request displaced by a crash is
//! re-admitted by the core and may legitimately appear in a second
//! unit; the first execution was wasted work, exactly as in the
//! virtual model.
//!
//! Wall timings ([`WallClockStats`] sojourns, elapsed seconds) are
//! measurements, not replayable state: they vary run to run. The
//! core's report is the reproducible artifact.
//!
//! [`SimulatedExecutor`] sleeps each unit's virtual execution time
//! scaled by [`WallClockOptions::time_scale`]; a real PJRT-backed
//! executor plugs in through [`WallClockDriver::with_executors`]
//! without touching the core.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Sender, SyncSender};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use super::super::clock::{Clock, MonotonicClock};
use super::super::cluster::{Cluster, DispatchNote, TapAction};
use super::super::request::ServiceReport;
use super::Driver;

/// One mirrored dispatch: everything a worker needs to execute the
/// unit and everything the front-end needs to account for it.
#[derive(Debug, Clone)]
pub struct WorkUnit {
    /// Ordinal assigned by the core in decision order; the key the
    /// front-end tracks terminal events under.
    pub unit: u64,
    /// Shard the core dispatched this unit on.
    pub shard: usize,
    /// The shard's crash epoch at forwarding time; workers drop units
    /// from older epochs.
    pub epoch: u64,
    /// Virtual execution seconds the core charged for this unit.
    pub exec_s: f64,
    /// Core (virtual) start instant.
    pub virtual_start: f64,
    /// Core (virtual) finish instant.
    pub virtual_finish: f64,
    /// Request ids completed by this unit (a fused batch completes
    /// several).
    pub records: Vec<u64>,
    /// Wall instant the front-end forwarded the unit (queueing-delay
    /// baseline).
    pub forwarded_s: f64,
}

/// Commands on a shard worker's bounded channel.
enum Command {
    Dispatch(WorkUnit),
    StealOffer { victim: usize },
    Drain,
    Crash,
    Shutdown,
}

/// What shard workers report on the unified event stream.
#[derive(Debug, Clone)]
pub enum ShardEvent {
    /// A unit executed to completion.
    Completion {
        /// The unit's ordinal.
        unit: u64,
        /// Executing shard.
        shard: usize,
        /// Request ids the unit completed.
        records: Vec<u64>,
        /// Wall instant execution started.
        started_s: f64,
        /// Wall instant execution finished.
        finished_s: f64,
        /// Wall seconds the unit waited in the command channel.
        queued_s: f64,
    },
    /// A unit from a pre-crash epoch was discarded without executing.
    Dropped {
        /// The unit's ordinal.
        unit: u64,
        /// Discarding shard.
        shard: usize,
    },
    /// Acknowledgement of a mirrored steal decision.
    Stole {
        /// The thief shard.
        shard: usize,
        /// The shard the core stole from.
        victim: usize,
    },
    /// Acknowledgement of a mirrored graceful drain.
    Drained {
        /// The draining shard.
        shard: usize,
    },
    /// Acknowledgement of a mirrored crash.
    Crashed {
        /// The crashed shard.
        shard: usize,
        /// The epoch now current on that shard.
        epoch: u64,
    },
    /// The worker's last word before its thread exits.
    Stopped {
        /// The stopping shard.
        shard: usize,
        /// Units it executed over its lifetime.
        executed: u64,
    },
}

/// Executes one mirrored unit on a worker thread. Implement this to
/// plug real execution (e.g. the PJRT runtime) into the wall-clock
/// driver; the core's scheduling is untouched.
pub trait Executor: Send {
    /// Run the unit. Called on the shard's worker thread; blocking
    /// here is exactly what occupies the shard.
    fn execute(&mut self, unit: &WorkUnit);
}

/// The default executor: sleeps each unit's virtual execution time
/// scaled by a constant, so wall-clock runs are sleep-bound (shard
/// scaling tracks shard count, not host core count).
#[derive(Debug, Clone, Copy)]
pub struct SimulatedExecutor {
    /// Wall seconds slept per virtual second of execution; `0.0`
    /// executes instantly (pure protocol overhead).
    pub time_scale: f64,
}

impl Executor for SimulatedExecutor {
    fn execute(&mut self, unit: &WorkUnit) {
        let wall = unit.exec_s * self.time_scale;
        if wall > 0.0 {
            thread::sleep(Duration::from_secs_f64(wall));
        }
    }
}

/// Builds one [`Executor`] per shard index (shards may get
/// heterogeneous executors, mirroring heterogeneous machines).
pub type ExecutorFactory = Box<dyn Fn(usize) -> Box<dyn Executor>>;

/// Wall-clock driver knobs.
#[derive(Debug, Clone, Copy)]
pub struct WallClockOptions {
    /// Wall seconds per virtual execution second for the default
    /// [`SimulatedExecutor`] (ignored once a custom factory is
    /// installed).
    pub time_scale: f64,
    /// Bound of each shard's command channel (>= 1). Smaller bounds
    /// mean tighter backpressure on the front-end.
    pub channel_capacity: usize,
}

impl Default for WallClockOptions {
    fn default() -> Self {
        WallClockOptions {
            time_scale: 0.0,
            channel_capacity: 2,
        }
    }
}

/// Real measurements from one wall-clock run (the reproducible
/// decisions live in the core's [`ServiceReport`]).
#[derive(Debug, Clone, Default)]
pub struct WallClockStats {
    /// Wall seconds from driver start to shutdown.
    pub elapsed_s: f64,
    /// Units forwarded to workers.
    pub forwarded: u64,
    /// Units that reported `Completion`.
    pub completed: u64,
    /// Units discarded by the crash-epoch check.
    pub dropped: u64,
    /// Forwarded units with **no** terminal event — must be zero.
    pub lost: u64,
    /// Terminal events for already-settled units — must be zero.
    pub duplicated: u64,
    /// Steal acknowledgements observed.
    pub steals: u64,
    /// Per-record wall sojourn (command-channel wait + execution).
    pub sojourns_s: Vec<f64>,
}

impl WallClockStats {
    /// 99th-percentile wall sojourn, nearest-rank; `0.0` when empty.
    pub fn p99_sojourn_s(&self) -> f64 {
        if self.sojourns_s.is_empty() {
            return 0.0;
        }
        let mut v = self.sojourns_s.clone();
        v.sort_by(|a, b| a.total_cmp(b));
        let idx = ((v.len() - 1) as f64 * 0.99).round() as usize;
        v[idx]
    }
}

/// Handle to one shard's worker thread.
struct ShardWorker {
    tx: SyncSender<Command>,
    /// Shared with the worker; the driver is the sole bumper.
    epoch: Arc<AtomicU64>,
    /// The driver-side copy of the current epoch (stamped onto units).
    current_epoch: u64,
    handle: thread::JoinHandle<()>,
}

fn spawn_worker(
    shard: usize,
    clock: MonotonicClock,
    capacity: usize,
    event_tx: Sender<ShardEvent>,
    mut exec: Box<dyn Executor>,
) -> ShardWorker {
    let (tx, rx) = sync_channel::<Command>(capacity);
    let epoch = Arc::new(AtomicU64::new(0));
    let worker_epoch = Arc::clone(&epoch);
    let handle = thread::Builder::new()
        .name(format!("poas-shard-{shard}"))
        .spawn(move || {
            let mut executed = 0u64;
            while let Ok(cmd) = rx.recv() {
                match cmd {
                    Command::Dispatch(unit) => {
                        if unit.epoch < worker_epoch.load(Ordering::Acquire) {
                            let _ = event_tx.send(ShardEvent::Dropped {
                                unit: unit.unit,
                                shard,
                            });
                            continue;
                        }
                        let started_s = clock.now();
                        exec.execute(&unit);
                        let finished_s = clock.now();
                        executed += 1;
                        let WorkUnit {
                            unit: ordinal,
                            records,
                            forwarded_s,
                            ..
                        } = unit;
                        let _ = event_tx.send(ShardEvent::Completion {
                            unit: ordinal,
                            shard,
                            records,
                            started_s,
                            finished_s,
                            queued_s: (started_s - forwarded_s).max(0.0),
                        });
                    }
                    Command::StealOffer { victim } => {
                        let _ = event_tx.send(ShardEvent::Stole { shard, victim });
                    }
                    Command::Drain => {
                        let _ = event_tx.send(ShardEvent::Drained { shard });
                    }
                    Command::Crash => {
                        let _ = event_tx.send(ShardEvent::Crashed {
                            shard,
                            epoch: worker_epoch.load(Ordering::Acquire),
                        });
                    }
                    Command::Shutdown => break,
                }
            }
            let _ = event_tx.send(ShardEvent::Stopped { shard, executed });
        })
        .expect("spawn shard worker thread");
    ShardWorker {
        tx,
        epoch,
        current_epoch: 0,
        handle,
    }
}

/// The per-run thread fleet: one worker per shard plus the shared
/// clock origin and event-stream sender used to spawn late joiners.
struct Fleet {
    clock: MonotonicClock,
    capacity: usize,
    workers: Vec<ShardWorker>,
    event_tx: Sender<ShardEvent>,
}

impl Fleet {
    /// Mirror one core tap action onto the worker fleet.
    fn forward(
        &mut self,
        action: TapAction,
        make_executor: &ExecutorFactory,
        stats: &mut WallClockStats,
        terminal: &mut Vec<Option<bool>>,
    ) {
        match action {
            TapAction::Dispatch(note) => {
                let DispatchNote {
                    unit,
                    shard,
                    start,
                    finish,
                    exec_s,
                    records,
                } = note;
                debug_assert_eq!(unit as usize, terminal.len());
                terminal.push(None);
                let w = &self.workers[shard];
                let work = WorkUnit {
                    unit,
                    shard,
                    epoch: w.current_epoch,
                    exec_s,
                    virtual_start: start,
                    virtual_finish: finish,
                    records,
                    forwarded_s: self.clock.now(),
                };
                stats.forwarded += 1;
                // The blocking send on a bounded channel IS the
                // backpressure: a full mirror queue stalls the core's
                // loop here until the worker catches up.
                w.tx.send(Command::Dispatch(work)).expect("shard worker alive");
            }
            TapAction::Steal { thief, victim } => {
                self.workers[thief]
                    .tx
                    .send(Command::StealOffer { victim })
                    .expect("shard worker alive");
            }
            TapAction::Crash { shard } => {
                // Bump the epoch BEFORE the command so every stale unit
                // already in the channel fails the check.
                let w = &mut self.workers[shard];
                w.current_epoch += 1;
                w.epoch.store(w.current_epoch, Ordering::Release);
                w.tx.send(Command::Crash).expect("shard worker alive");
            }
            TapAction::Drain { shard } => {
                self.workers[shard]
                    .tx
                    .send(Command::Drain)
                    .expect("shard worker alive");
            }
            TapAction::Restart { .. } => {
                // The worker outlived the crash; displaced work comes
                // back as fresh units via the core's re-admission.
            }
            TapAction::Join { shard } => {
                // Tap order guarantees a fresh join precedes any
                // dispatch onto the new index; a revival reuses an
                // existing index whose worker never exited.
                debug_assert!(shard <= self.workers.len());
                if shard == self.workers.len() {
                    let exec = make_executor(shard);
                    self.workers.push(spawn_worker(
                        shard,
                        self.clock,
                        self.capacity,
                        self.event_tx.clone(),
                        exec,
                    ));
                }
            }
        }
    }
}

fn fold_event(ev: ShardEvent, stats: &mut WallClockStats, terminal: &mut [Option<bool>]) {
    match ev {
        ShardEvent::Completion {
            unit,
            records,
            started_s,
            finished_s,
            queued_s,
            ..
        } => match terminal.get_mut(unit as usize) {
            Some(slot) if slot.is_none() => {
                *slot = Some(true);
                stats.completed += 1;
                let service = (finished_s - started_s).max(0.0);
                for _ in &records {
                    stats.sojourns_s.push(queued_s + service);
                }
            }
            _ => stats.duplicated += 1,
        },
        ShardEvent::Dropped { unit, .. } => match terminal.get_mut(unit as usize) {
            Some(slot) if slot.is_none() => {
                *slot = Some(false);
                stats.dropped += 1;
            }
            _ => stats.duplicated += 1,
        },
        ShardEvent::Stole { .. } => stats.steals += 1,
        ShardEvent::Drained { .. } | ShardEvent::Crashed { .. } | ShardEvent::Stopped { .. } => {}
    }
}

/// Actor-per-shard driver over the deterministic core. See the module
/// docs for the control-plane / data-plane split.
pub struct WallClockDriver {
    cluster: Cluster,
    opts: WallClockOptions,
    make_executor: ExecutorFactory,
}

impl WallClockDriver {
    /// Wrap a cluster with default options ([`SimulatedExecutor`] at
    /// `time_scale = 0.0`).
    pub fn new(cluster: Cluster) -> Self {
        WallClockDriver::with_options(cluster, WallClockOptions::default())
    }

    /// Wrap a cluster with explicit options.
    pub fn with_options(cluster: Cluster, opts: WallClockOptions) -> Self {
        assert!(opts.channel_capacity >= 1, "channel_capacity must be >= 1");
        assert!(
            opts.time_scale.is_finite() && opts.time_scale >= 0.0,
            "time_scale must be finite and non-negative"
        );
        let time_scale = opts.time_scale;
        let make_executor: ExecutorFactory =
            Box::new(move |_shard| Box::new(SimulatedExecutor { time_scale }));
        WallClockDriver::with_executors(cluster, opts, make_executor)
    }

    /// Wrap a cluster with a custom per-shard executor factory — the
    /// seam where real (e.g. PJRT-backed) execution plugs in.
    pub fn with_executors(
        mut cluster: Cluster,
        opts: WallClockOptions,
        make_executor: ExecutorFactory,
    ) -> Self {
        assert!(opts.channel_capacity >= 1, "channel_capacity must be >= 1");
        cluster.set_tap(true);
        WallClockDriver {
            cluster,
            opts,
            make_executor,
        }
    }

    /// Recover the core (e.g. to inspect state after a run).
    pub fn into_cluster(self) -> Cluster {
        self.cluster
    }

    /// Run the submitted trace to completion, mirroring every dispatch
    /// onto the worker fleet; returns the core's deterministic report
    /// plus this run's wall measurements.
    pub fn run_measured(&mut self) -> (ServiceReport, WallClockStats) {
        let clock = MonotonicClock::new();
        let (event_tx, event_rx) = channel::<ShardEvent>();
        let mut fleet = Fleet {
            clock,
            capacity: self.opts.channel_capacity,
            workers: Vec::new(),
            event_tx,
        };
        for s in 0..self.cluster.num_shards() {
            let exec = (self.make_executor)(s);
            fleet.workers.push(spawn_worker(
                s,
                clock,
                self.opts.channel_capacity,
                fleet.event_tx.clone(),
                exec,
            ));
        }

        let mut stats = WallClockStats::default();
        // One slot per forwarded unit: None = pending, Some(true) =
        // completed, Some(false) = dropped.
        let mut terminal: Vec<Option<bool>> = Vec::new();
        let mut taps: Vec<TapAction> = Vec::new();

        loop {
            self.cluster.drain_tap(&mut taps);
            for action in taps.drain(..) {
                fleet.forward(action, &self.make_executor, &mut stats, &mut terminal);
            }
            while let Ok(ev) = event_rx.try_recv() {
                fold_event(ev, &mut stats, &mut terminal);
            }
            if !self.cluster.step_event() {
                break;
            }
        }
        // Flush taps from the final processed event, then shut down.
        self.cluster.drain_tap(&mut taps);
        for action in taps.drain(..) {
            fleet.forward(action, &self.make_executor, &mut stats, &mut terminal);
        }
        for w in &fleet.workers {
            let _ = w.tx.send(Command::Shutdown);
        }
        let Fleet {
            workers, event_tx, ..
        } = fleet;
        drop(event_tx);
        let mut stopped = 0usize;
        while stopped < workers.len() {
            match event_rx.recv() {
                Ok(ShardEvent::Stopped { .. }) => stopped += 1,
                Ok(ev) => fold_event(ev, &mut stats, &mut terminal),
                Err(_) => break,
            }
        }
        for w in workers {
            let _ = w.handle.join();
        }

        stats.lost = terminal.iter().filter(|t| t.is_none()).count() as u64;
        stats.elapsed_s = clock.now();
        // The heap is already drained; this just builds the core's
        // deterministic report.
        let report = self.cluster.run_to_completion();
        (report, stats)
    }

    /// [`Self::run_measured`], discarding the wall measurements.
    pub fn run_to_completion(&mut self) -> ServiceReport {
        self.run_measured().0
    }
}

impl Driver for WallClockDriver {
    fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    fn cluster_mut(&mut self) -> &mut Cluster {
        &mut self.cluster
    }

    fn run_to_completion(&mut self) -> ServiceReport {
        WallClockDriver::run_to_completion(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p99_is_nearest_rank() {
        let stats = WallClockStats {
            sojourns_s: (1..=100).map(|i| i as f64).collect(),
            ..WallClockStats::default()
        };
        assert_eq!(stats.p99_sojourn_s(), 99.0);
        assert_eq!(WallClockStats::default().p99_sojourn_s(), 0.0);
    }

    #[test]
    fn simulated_executor_zero_scale_is_instant() {
        let mut exec = SimulatedExecutor { time_scale: 0.0 };
        let unit = WorkUnit {
            unit: 0,
            shard: 0,
            epoch: 0,
            exec_s: 1e9, // would sleep ~32 years at scale 1.0
            virtual_start: 0.0,
            virtual_finish: 1e9,
            records: vec![1],
            forwarded_s: 0.0,
        };
        exec.execute(&unit); // returns immediately
    }
}
