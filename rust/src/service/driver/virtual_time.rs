//! The virtual-time driver: a thin name for the loop the cluster has
//! always run.
//!
//! [`VirtualDriver`] delegates straight to
//! [`Cluster::run_to_completion`] — no tap, no threads, no wall time.
//! It exists so call sites (scenarios, benches, tests) can select a
//! driver uniformly; driving the cluster directly remains supported
//! and byte-identical.

use super::super::cluster::Cluster;
use super::super::request::ServiceReport;
use super::Driver;

/// The deterministic binary-heap event loop, packaged as a driver.
#[derive(Debug, Clone)]
pub struct VirtualDriver {
    cluster: Cluster,
}

impl VirtualDriver {
    /// Wrap a cluster (typically with a trace already submitted).
    pub fn new(cluster: Cluster) -> Self {
        VirtualDriver { cluster }
    }

    /// Drain the event heap and build the report — exactly
    /// [`Cluster::run_to_completion`].
    pub fn run_to_completion(&mut self) -> ServiceReport {
        self.cluster.run_to_completion()
    }

    /// Recover the cluster (e.g. to inspect state after a run).
    pub fn into_cluster(self) -> Cluster {
        self.cluster
    }
}

impl Driver for VirtualDriver {
    fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    fn cluster_mut(&mut self) -> &mut Cluster {
        &mut self.cluster
    }

    fn run_to_completion(&mut self) -> ServiceReport {
        VirtualDriver::run_to_completion(self)
    }
}
