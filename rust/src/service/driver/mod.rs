//! Drivers: the two ways to advance a [`Cluster`] through its trace.
//!
//! The cluster is a driver-agnostic scheduling core — admission,
//! routing, QoS queueing, batching, stealing, faults, and the
//! autoscaler all read only deterministic cluster state at the
//! core's [`super::clock::VirtualClock`]. A *driver* owns the core
//! and decides how its event loop relates to real time:
//!
//! * [`VirtualDriver`] ([`virtual_time`]) — the classic in-process
//!   binary-heap loop, byte-identical to driving the cluster
//!   directly. Fast-forwards through idle time; the replay /
//!   determinism contract every existing test pins.
//! * [`WallClockDriver`] ([`wall_clock`]) — actor-per-shard real
//!   concurrency. The core still makes every decision (so decisions
//!   match the virtual driver exactly — property-tested); each
//!   dispatch is mirrored to a per-shard worker thread over a bounded
//!   command channel and executed against a real [`wall_clock::Executor`],
//!   with completions flowing back on one unified MPSC event stream.
//!
//! Scenarios pick a driver with the `driver = "virtual" | "wallclock"`
//! knob ([`super::scenario`]); both produce the same
//! [`ServiceReport`], because the report is the core's deterministic
//! accounting — the wall-clock driver *additionally* returns real
//! measurements ([`wall_clock::WallClockStats`]).

pub mod virtual_time;
pub mod wall_clock;

pub use virtual_time::VirtualDriver;
pub use wall_clock::{
    Executor, ExecutorFactory, ShardEvent, SimulatedExecutor, WallClockDriver, WallClockOptions,
    WallClockStats, WorkUnit,
};

use super::cluster::Cluster;
use super::request::ServiceReport;

/// Which driver a scenario (or caller) wants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DriverKind {
    /// The deterministic virtual-time heap loop (the default).
    #[default]
    Virtual,
    /// Actor-per-shard wall-clock execution with simulated executors.
    WallClock,
}

/// Something that can run a cluster's submitted trace to completion.
pub trait Driver {
    /// The core being driven.
    fn cluster(&self) -> &Cluster;
    /// Mutable access to the core (e.g. to submit more work before
    /// running).
    fn cluster_mut(&mut self) -> &mut Cluster;
    /// Drain every pending event and build the final report.
    fn run_to_completion(&mut self) -> ServiceReport;
}
