//! The multi-tenant GEMM server.
//!
//! A [`Server`] owns a simulated machine and its installation-time
//! profile (exactly like [`Pipeline`]) and serves a *stream* of
//! heterogeneous [`GemmRequest`]s — the regime ALP envisions (many
//! concurrent workloads, not one GEMM at a time):
//!
//! 1. **admission** — every request passes the §6 suitability gate once;
//!    the verdict and predicted service time are recorded so queue
//!    policies never re-run the optimizer;
//! 2. **dispatch** — a pluggable [`QueuePolicy`] picks the next request;
//! 3. **planning** — co-executed requests take their plan from the
//!    [`PlanCache`] (repeated shapes skip the MILP solve entirely);
//! 4. **bypass** — optionally, a standalone-bound small request is
//!    co-scheduled on a device the plan leaves idle, overlapping the
//!    co-execution instead of serializing behind it;
//! 5. **feedback** — optionally, the dynamic scheduler (§3.4.2) observes
//!    every co-execution; when the model drifts enough to re-plan, the
//!    cache epoch is bumped so stale plans are never reused.

use super::cache::PlanCache;
use super::queue::{QueuePolicy, QueuedRequest, RequestQueue};
use super::request::{ExecMode, GemmRequest, ServedRequest, ServiceReport};
use crate::adapt::AdaptRules;
use crate::baselines;
use crate::config::MachineConfig;
use crate::coordinator::Pipeline;
use crate::error::{Error, Result};
use crate::predict::PerfModel;
use crate::schedule::suitability::{predicted_standalone, recommend, Recommendation};
use crate::schedule::{build_plan_excluding, DynamicScheduler, PlanOptions, SchedulePlan};
use crate::sim::{SimMachine, WorkItem, WorkOrder};
use crate::workload::GemmSize;
use std::collections::HashMap;

/// Server construction options.
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Dispatch-order policy.
    pub policy: QueuePolicy,
    /// Co-schedule standalone-bound small requests on an idle device
    /// alongside a co-execution. Pairing happens at dispatch time, so
    /// this pays off under FIFO-like orders where small jobs sit queued
    /// behind heavy ones; under [`QueuePolicy::Spjf`] the small jobs
    /// usually dispatch first and no rider remains to pair.
    pub standalone_bypass: bool,
    /// Suitability-gate threshold (required predicted speedup, §6).
    pub min_gain: f64,
    /// Scheduling overhead charged to co-execution by the gate, seconds.
    pub overhead_s: f64,
    /// Plan-cache capacity (entries).
    pub cache_capacity: usize,
    /// Close the loop with the dynamic scheduler: refresh the model from
    /// observed executions and invalidate the plan cache on re-plan.
    pub dynamic: bool,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            policy: QueuePolicy::Fifo,
            standalone_bypass: false,
            min_gain: 1.05,
            overhead_s: 20e-6,
            cache_capacity: 64,
            dynamic: false,
        }
    }
}

/// A request-serving POAS deployment on one machine.
#[derive(Debug, Clone)]
pub struct Server {
    /// The machine being driven.
    pub sim: SimMachine,
    /// The live performance model (profiled at construction; refreshed
    /// by the dynamic scheduler when `dynamic` is on).
    pub model: PerfModel,
    /// The plan memo.
    pub cache: PlanCache,
    rules: Vec<AdaptRules>,
    plan_opts: PlanOptions,
    opts: ServerOptions,
    queue: RequestQueue,
    clock: f64,
    served: Vec<ServedRequest>,
    next_id: u64,
    dynsched: Option<DynamicScheduler>,
    /// Admission-gate memo: suitability verdict + per-rep prediction by
    /// `(shape, cache epoch)`, so repeated shapes skip the gate's LP
    /// solve just like they skip the plan solve.
    gate_memo: HashMap<(GemmSize, u64), (bool, usize, f64)>,
}

impl Server {
    /// Build a server for a simulated machine: profiles at installation
    /// time (like [`Pipeline::for_simulated_machine`]) and starts with an
    /// empty queue.
    pub fn new(cfg: &MachineConfig, seed: u64, opts: ServerOptions) -> Self {
        Self::from_pipeline(Pipeline::for_simulated_machine(cfg, seed), opts)
    }

    /// Promote an existing pipeline (machine + profile + plan options)
    /// into a server.
    pub fn from_pipeline(pipeline: Pipeline, opts: ServerOptions) -> Self {
        let Pipeline {
            sim,
            model,
            rules,
            opts: plan_opts,
        } = pipeline;
        let dynsched = if opts.dynamic {
            Some(DynamicScheduler::new(model.clone()))
        } else {
            None
        };
        Server {
            sim,
            cache: PlanCache::new(opts.cache_capacity),
            rules,
            plan_opts,
            queue: RequestQueue::new(opts.policy),
            clock: 0.0,
            served: Vec::new(),
            next_id: 0,
            dynsched,
            gate_memo: HashMap::new(),
            opts,
            model,
        }
    }

    /// Current virtual service time.
    pub fn now(&self) -> f64 {
        self.clock
    }

    /// Pending request count.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Requests completed so far.
    pub fn completed(&self) -> usize {
        self.served.len()
    }

    /// Submit a request; returns its id.
    pub fn submit(&mut self, size: GemmSize, reps: u32) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.submit_request(GemmRequest { id, size, reps });
        id
    }

    /// Submit a caller-identified request.
    pub fn submit_request(&mut self, req: GemmRequest) {
        self.next_id = self.next_id.max(req.id + 1);
        let (co_execute, best_device, predicted_s) = self.predict(req.size, req.reps);
        self.queue.push(QueuedRequest {
            req,
            arrival: self.clock,
            co_execute,
            best_device,
            predicted_s,
        });
    }

    /// Admission-time gate: (co-execute?, best single device, predicted
    /// total service seconds). Memoized by `(shape, epoch)` — the gate's
    /// own LP solve is as cacheable as the plan solve.
    fn predict(&mut self, size: GemmSize, reps: u32) -> (bool, usize, f64) {
        let reps = reps.max(1) as f64;
        let key = (size, self.cache.epoch());
        let (co_execute, device, t_rep) = match self.gate_memo.get(&key) {
            Some(&hit) => hit,
            None => {
                let fresh =
                    match recommend(&self.model, size, self.opts.min_gain, self.opts.overhead_s) {
                        Recommendation::CoExecute {
                            t_coexec,
                            best_device,
                            ..
                        } => (true, best_device, t_coexec),
                        Recommendation::Standalone {
                            device, t_single, ..
                        } => (false, device, t_single),
                    };
                if self.gate_memo.len() >= 1024 {
                    self.gate_memo.clear();
                }
                self.gate_memo.insert(key, fresh);
                fresh
            }
        };
        (co_execute, device, t_rep * reps)
    }

    /// The device the bypass frees for standalone riders: the slowest
    /// one (largest fitted slope), whose loss barely moves the co-exec
    /// optimum — on the paper's machines this is the CPU with its ~1%
    /// share.
    pub fn bypass_host(&self) -> usize {
        self.model
            .devices
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.a.total_cmp(&b.1.a))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Plan `size` with device `host` excluded from the split problem,
    /// so the resulting work order leaves it idle for a bypass rider.
    fn plan_excluding(&self, size: GemmSize, host: usize) -> Result<SchedulePlan> {
        let plan = build_plan_excluding(&self.model, size, &self.rules, &self.plan_opts, &[host])?;
        if plan.assignments[host].rows > 0 {
            // Defensive: alignment rebalancing handed leftover rows to
            // the host (possible only in degenerate configs).
            return Err(Error::Infeasible(format!(
                "bypass host {host} still assigned {} rows",
                plan.assignments[host].rows
            )));
        }
        Ok(plan)
    }

    /// Serve one dispatch (possibly two requests when the bypass pairs
    /// them). Returns `false` when the queue is empty.
    pub fn step(&mut self) -> bool {
        let Some(q) = self.queue.pop_next() else {
            return false;
        };
        if q.co_execute {
            self.step_coexec(q);
        } else {
            self.step_standalone(q);
        }
        true
    }

    fn step_coexec(&mut self, q: QueuedRequest) {
        let start = self.clock;

        // ---- Bypass pairing: a standalone-bound request that fits on
        // the host device within this request's predicted window rides
        // along instead of waiting for its own turn.
        let host = self.bypass_host();
        let mut rider: Option<QueuedRequest> = None;
        let mut rider_host_pred = 0.0_f64;
        if self.opts.standalone_bypass {
            let inputs = self.model.model_inputs();
            let budget = q.predicted_s;
            let reps = q.req.reps;
            rider = self.queue.take_first(|c| {
                !c.co_execute
                    && c.req.reps == reps
                    && predicted_standalone(&inputs[host], c.req.size) * reps.max(1) as f64
                        <= budget
            });
            if let Some(c) = &rider {
                // The rider runs on the host, so record the host-device
                // prediction (its admission-time one was for its best
                // standalone device).
                rider_host_pred =
                    predicted_standalone(&inputs[host], c.req.size) * reps.max(1) as f64;
            }
        }

        // ---- Plan: cached for the ordinary path; the bypass path plans
        // around the freed host (not cached — it is shape- and
        // pairing-specific).
        let (plan, cache_hit) = if rider.is_some() {
            match self.plan_excluding(q.req.size, host) {
                Ok(p) => (p, false),
                Err(_) => {
                    // Could not free the host: undo the pairing.
                    self.queue.push_front(rider.take().unwrap());
                    self.cached_plan(q.req.size)
                }
            }
        } else {
            self.cached_plan(q.req.size)
        };

        // ---- Build the (possibly merged) work order.
        let mut order = plan.to_work_order(q.req.reps);
        if let Some(c) = &rider {
            let priority = self.model.devices[host].priority;
            let small = WorkOrder {
                items: vec![WorkItem::whole(host, c.req.size, priority)],
                reps: c.req.reps,
            };
            // Guaranteed disjoint: plan_excluding left the host with zero
            // rows, and the rider predicate enforced equal reps.
            order = order
                .merge(&small)
                .expect("bypass invariant: host idle and reps equal");
        }

        // ---- Execute once; attribute completions per tenant.
        let outcome = self.sim.execute(&order);
        let finish_big = outcome.finish_of(&plan.active_device_indices());
        self.served.push(ServedRequest {
            id: q.req.id,
            size: q.req.size,
            reps: q.req.reps,
            mode: ExecMode::CoExec,
            arrival: q.arrival,
            start,
            finish: start + finish_big,
            exec_s: finish_big,
            predicted_s: q.predicted_s,
            cache_hit,
            shares: plan.shares(),
        });
        if let Some(c) = &rider {
            let finish_small = outcome.finish_of(&[host]);
            let mut shares = vec![0.0; self.sim.num_devices()];
            shares[host] = 1.0;
            self.served.push(ServedRequest {
                id: c.req.id,
                size: c.req.size,
                reps: c.req.reps,
                mode: ExecMode::BypassStandalone { device: host },
                arrival: c.arrival,
                start,
                finish: start + finish_small,
                exec_s: finish_small,
                predicted_s: rider_host_pred,
                cache_hit: false,
                shares,
            });
        }
        self.clock = start + outcome.makespan;

        // ---- Closed loop: observe, refresh, invalidate.
        if let Some(ds) = &mut self.dynsched {
            if ds.observe(&plan, &outcome, q.req.reps) {
                self.model = ds.model.clone();
                self.cache.bump_epoch();
                // Old-epoch gate entries can never be read again (the
                // key carries the epoch); drop them eagerly too.
                self.gate_memo.clear();
            }
        }
    }

    fn cached_plan(&mut self, size: GemmSize) -> (SchedulePlan, bool) {
        self.cache
            .get_or_build(&self.model, size, &self.rules, &self.plan_opts)
            .expect("planning failed")
    }

    fn step_standalone(&mut self, q: QueuedRequest) {
        let start = self.clock;
        let dev = q.best_device;
        let outcome = baselines::standalone(&mut self.sim, dev, q.req.size, q.req.reps);
        let mut shares = vec![0.0; self.sim.num_devices()];
        shares[dev] = 1.0;
        self.served.push(ServedRequest {
            id: q.req.id,
            size: q.req.size,
            reps: q.req.reps,
            mode: ExecMode::Standalone { device: dev },
            arrival: q.arrival,
            start,
            finish: start + outcome.makespan,
            exec_s: outcome.makespan,
            predicted_s: q.predicted_s,
            cache_hit: false,
            shares,
        });
        self.clock = start + outcome.makespan;
    }

    /// Drain the queue and return the session report.
    pub fn run_to_completion(&mut self) -> ServiceReport {
        while self.step() {}
        self.report()
    }

    /// Snapshot the session statistics.
    pub fn report(&self) -> ServiceReport {
        ServiceReport {
            served: self.served.clone(),
            makespan: self.clock,
            cache_hits: self.cache.hits,
            cache_misses: self.cache.misses,
            epoch_bumps: self.cache.invalidations,
            replans: self.dynsched.as_ref().map(|d| d.replans).unwrap_or(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn gate_routes_by_size_and_everything_completes() {
        let mut srv = Server::new(&presets::mach2(), 0, ServerOptions::default());
        let big = srv.submit(GemmSize::square(20_000), 3);
        let small = srv.submit(GemmSize::square(300), 3);
        let report = srv.run_to_completion();
        assert_eq!(report.served.len(), 2);
        assert_eq!(report.request(big).unwrap().mode, ExecMode::CoExec);
        assert!(matches!(
            report.request(small).unwrap().mode,
            ExecMode::Standalone { .. }
        ));
        // Virtual time advanced and completions are ordered sanely.
        assert!(report.makespan > 0.0);
        for r in &report.served {
            assert!(r.finish > r.start && r.start >= r.arrival);
            assert!((r.shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn repeated_shapes_hit_the_cache() {
        let mut srv = Server::new(&presets::mach2(), 1, ServerOptions::default());
        let size = GemmSize::square(18_000);
        for _ in 0..4 {
            srv.submit(size, 2);
        }
        let report = srv.run_to_completion();
        assert_eq!(report.cache_misses, 1);
        assert_eq!(report.cache_hits, 3);
        assert!(report.served.iter().filter(|r| r.cache_hit).count() == 3);
    }

    #[test]
    fn replay_is_deterministic() {
        let run = || {
            let mut srv = Server::new(&presets::mach1(), 7, ServerOptions::default());
            srv.submit(GemmSize::square(16_000), 2);
            srv.submit(GemmSize::square(400), 2);
            srv.submit(GemmSize::new(8_000, 12_000, 10_000), 2);
            srv.run_to_completion()
        };
        let a = run();
        let b = run();
        assert_eq!(a.makespan, b.makespan);
        for (x, y) in a.served.iter().zip(&b.served) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.finish, y.finish);
            assert_eq!(x.mode, y.mode);
        }
    }

    #[test]
    fn bypass_overlaps_small_request_with_coexecution() {
        let opts = ServerOptions {
            standalone_bypass: true,
            ..Default::default()
        };
        let mut srv = Server::new(&presets::mach2(), 2, opts);
        let host = srv.bypass_host();
        assert_eq!(host, 0, "slowest device on mach2 is the CPU");
        let big = srv.submit(GemmSize::square(20_000), 3);
        let small = srv.submit(GemmSize::square(400), 3);
        let report = srv.run_to_completion();
        assert_eq!(report.bypassed(), 1);
        let r_big = report.request(big).unwrap();
        let r_small = report.request(small).unwrap();
        assert_eq!(r_small.mode, ExecMode::BypassStandalone { device: host });
        // The rider started with the co-execution instead of after it.
        assert_eq!(r_small.start, r_big.start);
        assert!(r_small.finish <= r_big.finish + 1e-9);
    }

    #[test]
    fn dynamic_mode_bumps_cache_epoch_on_drift() {
        let opts = ServerOptions {
            dynamic: true,
            ..Default::default()
        };
        // mach1 throttles ~11% under sustained load — well past the 2%
        // replan threshold.
        let mut srv = Server::new(&presets::mach1(), 3, opts);
        let size = GemmSize::square(30_000);
        for _ in 0..3 {
            srv.submit(size, 50);
        }
        let report = srv.run_to_completion();
        assert!(report.replans >= 1);
        assert!(report.epoch_bumps >= 1);
        // The same shape had to re-plan after the invalidation.
        assert!(report.cache_misses >= 2, "misses {}", report.cache_misses);
    }
}
