//! The single-machine server: a 1-shard [`Cluster`] with the classic
//! API.
//!
//! Historically `Server` was a monolith owning admission, queueing,
//! plan caching, bypass pairing, execution and the virtual clock. That
//! state now lives in the layered components — [`Admission`],
//! [`super::ExecutorShard`], [`Cluster`] — and `Server` is a thin
//! wrapper over a one-shard cluster, kept because "one machine, batch
//! submissions, drain the queue" is the common case in tests, benches
//! and examples. The submit / run-to-completion / report surface is
//! unchanged; the old public `sim`/`model`/`cache` fields and `step()`
//! are gone — reach the owning components through [`Server::cluster`],
//! [`Server::shard`] and [`Server::admission`] instead. Anything the
//! wrapper does not expose (arrival traces, sharding, work stealing)
//! is a [`Cluster`] feature.

use super::admission::Admission;
use super::cluster::{Cluster, ClusterOptions};
use super::qos::DeadlinePolicy;
use super::queue::QueuePolicy;
use super::request::{GemmRequest, ServiceReport};
use super::shard::ExecutorShard;
use crate::config::MachineConfig;
use crate::coordinator::Pipeline;
use crate::workload::GemmSize;

/// Per-shard serving options (also the admission-gate knobs a cluster
/// front-end shares across its shards).
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Dispatch-order policy.
    pub policy: QueuePolicy,
    /// Co-schedule standalone-bound small requests on an idle device
    /// alongside a co-execution. Pairing happens at dispatch time, so
    /// this pays off under FIFO-like orders where small jobs sit queued
    /// behind heavy ones; under [`QueuePolicy::Spjf`] the small jobs
    /// usually dispatch first and no rider remains to pair.
    pub standalone_bypass: bool,
    /// Suitability-gate threshold (required predicted speedup, §6).
    pub min_gain: f64,
    /// Scheduling overhead charged to co-execution by the gate, seconds.
    pub overhead_s: f64,
    /// Plan-cache capacity (entries, per shard).
    pub cache_capacity: usize,
    /// Admission-memo capacity (entries; bounded LRU).
    pub gate_capacity: usize,
    /// Close the loop with the dynamic scheduler: refresh the model from
    /// observed executions and invalidate the plan cache on re-plan.
    pub dynamic: bool,
    /// What deadline-aware admission does with a request whose SLO is
    /// predicted infeasible at arrival (requests without a deadline are
    /// never affected).
    pub deadline_policy: DeadlinePolicy,
    /// Admission headroom for SLO requests, in (0, 1]: accept only when
    /// the predicted sojourn fits inside `deadline_slack * deadline_s`.
    /// The guard band absorbs prediction error (and the bounded
    /// interleaving the weighted drain allows), so what admission lets
    /// through actually lands inside the SLO instead of grazing it.
    pub deadline_slack: f64,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            policy: QueuePolicy::Fifo,
            standalone_bypass: false,
            min_gain: 1.05,
            overhead_s: 20e-6,
            cache_capacity: 64,
            gate_capacity: 1024,
            dynamic: false,
            deadline_policy: DeadlinePolicy::Reject,
            deadline_slack: 0.9,
        }
    }
}

/// A request-serving POAS deployment on one machine.
#[derive(Debug, Clone)]
pub struct Server {
    cluster: Cluster,
}

impl Server {
    /// Build a server for a simulated machine: profiles at installation
    /// time (like [`Pipeline::for_simulated_machine`]) and starts with an
    /// empty queue.
    pub fn new(cfg: &MachineConfig, seed: u64, opts: ServerOptions) -> Self {
        Self::from_pipeline(Pipeline::for_simulated_machine(cfg, seed), opts)
    }

    /// Promote an existing pipeline (machine + profile + plan options)
    /// into a server.
    pub fn from_pipeline(pipeline: Pipeline, opts: ServerOptions) -> Self {
        Server {
            cluster: Cluster::from_pipelines(
                vec![pipeline],
                ClusterOptions {
                    shards: 1,
                    shard: opts,
                    work_stealing: false,
                    ..Default::default()
                },
            ),
        }
    }

    /// The underlying one-shard cluster.
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// The machine-owning shard.
    pub fn shard(&self) -> &ExecutorShard {
        self.cluster.shard(0)
    }

    /// The admission gate.
    pub fn admission(&self) -> &Admission {
        self.cluster.admission()
    }

    /// Current virtual service time.
    pub fn now(&self) -> f64 {
        self.cluster.now()
    }

    /// Pending request count.
    pub fn pending(&self) -> usize {
        self.cluster.pending()
    }

    /// Requests completed so far.
    pub fn completed(&self) -> usize {
        self.cluster.completed()
    }

    /// Submit a request; returns its id.
    pub fn submit(&mut self, size: GemmSize, reps: u32) -> u64 {
        self.cluster.submit(size, reps)
    }

    /// Submit a caller-identified request.
    pub fn submit_request(&mut self, req: GemmRequest) {
        self.cluster.submit_request(req);
    }

    /// The device the bypass frees for standalone riders (see
    /// [`ExecutorShard::bypass_host`]).
    pub fn bypass_host(&self) -> usize {
        self.shard().bypass_host()
    }

    /// Drain the queue and return the session report.
    pub fn run_to_completion(&mut self) -> ServiceReport {
        self.cluster.run_to_completion()
    }

    /// Snapshot the session statistics.
    pub fn report(&self) -> ServiceReport {
        self.cluster.report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::service::request::ExecMode;

    #[test]
    fn gate_routes_by_size_and_everything_completes() {
        let mut srv = Server::new(&presets::mach2(), 0, ServerOptions::default());
        let big = srv.submit(GemmSize::square(20_000), 3);
        let small = srv.submit(GemmSize::square(300), 3);
        let report = srv.run_to_completion();
        assert_eq!(report.served.len(), 2);
        assert_eq!(report.request(big).unwrap().mode, ExecMode::CoExec);
        assert!(matches!(
            report.request(small).unwrap().mode,
            ExecMode::Standalone { .. }
        ));
        // Virtual time advanced and completions are ordered sanely.
        assert!(report.makespan > 0.0);
        for r in &report.served {
            assert!(r.finish > r.start && r.start >= r.arrival);
            assert!((r.shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn repeated_shapes_hit_the_cache() {
        let mut srv = Server::new(&presets::mach2(), 1, ServerOptions::default());
        let size = GemmSize::square(18_000);
        for _ in 0..4 {
            srv.submit(size, 2);
        }
        let report = srv.run_to_completion();
        assert_eq!(report.cache_misses, 1);
        assert_eq!(report.cache_hits, 3);
        assert!(report.served.iter().filter(|r| r.cache_hit).count() == 3);
    }

    #[test]
    fn replay_is_deterministic() {
        let run = || {
            let mut srv = Server::new(&presets::mach1(), 7, ServerOptions::default());
            srv.submit(GemmSize::square(16_000), 2);
            srv.submit(GemmSize::square(400), 2);
            srv.submit(GemmSize::new(8_000, 12_000, 10_000), 2);
            srv.run_to_completion()
        };
        let a = run();
        let b = run();
        assert_eq!(a.makespan, b.makespan);
        for (x, y) in a.served.iter().zip(&b.served) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.finish, y.finish);
            assert_eq!(x.mode, y.mode);
        }
    }

    #[test]
    fn bypass_overlaps_small_request_with_coexecution() {
        let opts = ServerOptions {
            standalone_bypass: true,
            ..Default::default()
        };
        let mut srv = Server::new(&presets::mach2(), 2, opts);
        let host = srv.bypass_host();
        assert_eq!(host, 0, "slowest device on mach2 is the CPU");
        let big = srv.submit(GemmSize::square(20_000), 3);
        let small = srv.submit(GemmSize::square(400), 3);
        let report = srv.run_to_completion();
        assert_eq!(report.bypassed(), 1);
        let r_big = report.request(big).unwrap();
        let r_small = report.request(small).unwrap();
        assert_eq!(r_small.mode, ExecMode::BypassStandalone { device: host });
        // The rider started with the co-execution instead of after it.
        assert_eq!(r_small.start, r_big.start);
        assert!(r_small.finish <= r_big.finish + 1e-9);
    }

    #[test]
    fn dynamic_mode_bumps_cache_epoch_on_drift() {
        let opts = ServerOptions {
            dynamic: true,
            ..Default::default()
        };
        // mach1 throttles ~11% under sustained load — well past the 2%
        // replan threshold.
        let mut srv = Server::new(&presets::mach1(), 3, opts);
        let size = GemmSize::square(30_000);
        for _ in 0..3 {
            srv.submit(size, 50);
        }
        let report = srv.run_to_completion();
        assert!(report.replans >= 1);
        assert!(report.epoch_bumps >= 1);
        // The same shape had to re-plan after the invalidation.
        assert!(report.cache_misses >= 2, "misses {}", report.cache_misses);
        // The replan refreshed the front-end gate too.
        assert!(srv.admission().epoch() >= 1);
    }

    #[test]
    fn wrapper_exposes_the_layered_components() {
        let mut srv = Server::new(&presets::mach2(), 4, ServerOptions::default());
        assert_eq!(srv.cluster().num_shards(), 1);
        assert_eq!(srv.shard().id, 0);
        assert_eq!(srv.completed(), 0);
        let id = srv.submit(GemmSize::square(16_000), 1);
        assert_eq!(srv.pending(), 1);
        let report = srv.run_to_completion();
        assert_eq!(srv.pending(), 0);
        assert_eq!(srv.completed(), 1);
        assert!(report.request(id).is_some());
        assert!(srv.now() > 0.0);
        assert_eq!(report.shards.len(), 1);
        assert_eq!(report.shards[0].stolen, 0);
    }
}
