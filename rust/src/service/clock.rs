//! Time, abstracted: the seam between the deterministic scheduling
//! core and the driver that advances it.
//!
//! The cluster core never asks the operating system what time it is.
//! It reads a [`Clock`], and the *driver* decides what that clock
//! means:
//!
//! * [`VirtualClock`] — simulated service time. The virtual driver
//!   (and the classic in-process event loop) advances it monotonically
//!   to each popped event's timestamp, so identical construction and
//!   trace replay byte-identically.
//! * [`MonotonicClock`] — real elapsed seconds since an origin
//!   `Instant`. The wall-clock driver hands one shared origin to every
//!   shard worker so their timestamps are mutually comparable.
//!
//! Both clocks report `f64` seconds, the unit every queue-depth,
//! deadline, and sojourn computation in the serving layer already
//! uses.

use std::time::Instant;

/// A monotonically non-decreasing source of seconds.
pub trait Clock {
    /// The current time, in seconds. Successive calls never go
    /// backwards.
    fn now(&self) -> f64;
}

/// Simulated service time: advances only when the event loop says so.
///
/// This is the clock the deterministic core owns. `advance_to` is
/// monotonic by construction (a stale timestamp is ignored), which is
/// exactly the `clock = clock.max(event.time)` idiom the event loop
/// used before the seam existed.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    /// A virtual clock at time zero.
    pub fn new() -> Self {
        VirtualClock { now: 0.0 }
    }

    /// Advance to `t` if `t` is later than the current reading;
    /// otherwise leave the clock untouched.
    pub fn advance_to(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.now
    }
}

/// Real elapsed seconds since a fixed origin.
///
/// `Copy`, deliberately: the wall-clock driver creates *one* origin
/// and copies it into every shard worker, so `now()` readings taken
/// on different threads share a timeline and can be subtracted
/// meaningfully.
#[derive(Debug, Clone, Copy)]
pub struct MonotonicClock {
    origin: Instant,
}

impl MonotonicClock {
    /// A wall clock whose zero is "now".
    pub fn new() -> Self {
        MonotonicClock {
            origin: Instant::now(),
        }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        MonotonicClock::new()
    }
}

impl Clock for MonotonicClock {
    fn now(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_is_monotonic() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_to(1.5);
        assert_eq!(c.now(), 1.5);
        c.advance_to(0.5); // stale timestamps are ignored
        assert_eq!(c.now(), 1.5);
        c.advance_to(2.0);
        assert_eq!(c.now(), 2.0);
    }

    #[test]
    fn monotonic_clock_moves_forward() {
        let c = MonotonicClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
        assert!(a >= 0.0);
    }
}
