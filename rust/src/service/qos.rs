//! QoS tiers: priority classes, weights, and deadline-admission policy.
//!
//! The sharded [`super::Cluster`] of PR 2 treated every tenant
//! identically; this module introduces the vocabulary for SLO-bound
//! serving — the deployment shape co-scheduling frameworks (HTS, Aupy
//! et al.) target:
//!
//! * [`QosClass`] — three service tiers attached to every
//!   [`super::GemmRequest`]. Each class carries a scheduling **weight**;
//!   per-class queues inside [`super::ExecutorShard`] are drained by a
//!   smooth weighted round-robin pick (see
//!   [`super::RequestQueue::pop_next`]), so a heavy class can consume at
//!   most its weight share while a non-empty light class is never
//!   starved;
//! * [`DeadlinePolicy`] — what the front-end does with a request whose
//!   per-request SLO ([`super::GemmRequest::deadline_s`]) is predicted
//!   infeasible at arrival: turn it away ([`DeadlinePolicy::Reject`],
//!   recorded as [`super::ExecMode::Denied`]) or strip the SLO and
//!   demote it to [`QosClass::Batch`] ([`DeadlinePolicy::Downclass`]).
//!
//! The weights are deliberately small integers: the weighted pick and
//! the class-aware routing estimate both stay exactly replayable.

use std::fmt;

/// Service tier of a request. Order encodes priority: lower discriminant
/// = more latency-sensitive = larger scheduling weight.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum QosClass {
    /// Latency-sensitive traffic (weight 4): user-facing requests, the
    /// tier SLO deadlines usually ride on.
    Interactive,
    /// The default tier (weight 2): everything that is neither
    /// interactive nor throughput filler.
    #[default]
    Standard,
    /// Throughput traffic (weight 1): background jobs that tolerate
    /// queueing and absorb leftover capacity.
    Batch,
}

/// Number of QoS classes (array dimension for per-class state).
pub const NUM_CLASSES: usize = 3;

impl QosClass {
    /// All classes, priority order (index = [`QosClass::index`]).
    pub const ALL: [QosClass; NUM_CLASSES] =
        [QosClass::Interactive, QosClass::Standard, QosClass::Batch];

    /// Dense index for per-class arrays (0 = most latency-sensitive).
    pub fn index(self) -> usize {
        match self {
            QosClass::Interactive => 0,
            QosClass::Standard => 1,
            QosClass::Batch => 2,
        }
    }

    /// Scheduling weight: the share ratio the weighted-deficit pick
    /// enforces between backlogged classes (4 : 2 : 1).
    pub fn weight(self) -> u64 {
        match self {
            QosClass::Interactive => 4,
            QosClass::Standard => 2,
            QosClass::Batch => 1,
        }
    }

    /// Short label for tables and summaries.
    pub fn label(self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Standard => "standard",
            QosClass::Batch => "batch",
        }
    }
}

impl fmt::Display for QosClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// What deadline-aware admission does with a request whose SLO is
/// predicted infeasible at arrival time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DeadlinePolicy {
    /// Turn the request away: it completes immediately as
    /// [`super::ExecMode::Denied`], consuming no machine time. The
    /// tenant gets a fast, honest "no" instead of a guaranteed miss.
    #[default]
    Reject,
    /// Keep the request but strip its SLO and demote it to
    /// [`QosClass::Batch`]: it is served on a best-effort basis behind
    /// the tiers that still have guarantees.
    Downclass,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_are_dense_and_match_all_order() {
        for (i, c) in QosClass::ALL.iter().enumerate() {
            assert_eq!(c.index(), i);
        }
    }

    #[test]
    fn weights_encode_priority_order() {
        assert!(QosClass::Interactive.weight() > QosClass::Standard.weight());
        assert!(QosClass::Standard.weight() > QosClass::Batch.weight());
        assert_eq!(QosClass::default(), QosClass::Standard);
    }

    #[test]
    fn labels_render() {
        assert_eq!(QosClass::Interactive.to_string(), "interactive");
        assert_eq!(QosClass::Batch.to_string(), "batch");
        assert_eq!(DeadlinePolicy::default(), DeadlinePolicy::Reject);
    }
}
