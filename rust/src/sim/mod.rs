//! Virtual-time simulator of the paper's heterogeneous testbeds.
//!
//! The paper evaluated on two physical HPC servers we do not own
//! (repro band 0 — hardware gate), so this module plays their role: it is
//! the *ground truth* the POAS pipeline profiles, predicts, and schedules
//! against, exactly as the paper's pipeline treated MKL/cuBLAS/PCIe.
//!
//! Structure:
//! * [`device`] — per-device GEMM timing: effective rate curves, launch
//!   overhead, run-to-run noise, thermal throttling state, memory
//!   oversubscription and tensor-core alignment penalties.
//! * [`bus`] — the shared PCIe bus: serialized DMA transfers under a
//!   pluggable arbitration policy (priority / FIFO / round-robin), with a
//!   recorded trace (Fig. 2 regenerator).
//! * [`machine`] — a complete testbed: devices + bus + virtual clock, with
//!   the two entry points the rest of the stack uses: profiling
//!   microbenchmarks and full work-order execution.
//! * [`energy`] — joule accounting from the execution timeline.
//!
//! Everything is deterministic given a seed; the paper's "3 independent
//! runs" become 3 seeds.

pub mod bus;
pub mod device;
pub mod energy;
pub mod machine;

pub use bus::{BusPolicy, BusSegment, BusTrace, Direction};
pub use device::SimDevice;
pub use energy::EnergyReport;
pub use machine::{DeviceTimeline, ExecOutcome, SimMachine, WorkItem, WorkOrder};
