//! Simulated compute device: GEMM timing with realistic imperfections.
//!
//! A [`SimDevice`] answers one question — *how long does this device take
//! to multiply these matrices, starting at this virtual time?* — while
//! maintaining the hidden state that makes the answer realistic:
//!
//! * **effective rate curve**: the sustained library throughput, with the
//!   big-GEMM bonus curve (many-core CPUs are threading-bound on small
//!   tiles) and penalties for memory oversubscription (working set
//!   exceeding device memory) and tensor-core misalignment (`m % 8 != 0`);
//! * **thermal throttling**: heat builds exponentially under sustained
//!   load and decays when idle. Profiling (short bursts) therefore sees a
//!   faster device than a 50-rep production workload — the exact effect
//!   the paper blames for mach1's Table 4 outliers (§5.2);
//! * **run-to-run noise**: multiplicative jitter on every call.

use crate::config::{DeviceKind, DeviceSpec};
use crate::rng::Rng;
use crate::workload::GemmSize;

/// Number of integration sub-steps for the thermal ODE per compute call.
/// 16 keeps the integration error well under the noise floor.
const THERMAL_STEPS: usize = 16;

/// A device instance inside a [`super::SimMachine`].
#[derive(Debug, Clone)]
pub struct SimDevice {
    /// Static description (ground truth).
    pub spec: DeviceSpec,
    /// Private noise stream.
    rng: Rng,
    /// Thermal state in [0, 1]: 0 = cold, 1 = fully throttled.
    heat: f64,
    /// Virtual time when the thermal state was last updated.
    heat_t: f64,
    /// Accumulated busy seconds (for energy accounting).
    busy_s: f64,
}

impl SimDevice {
    /// Create a device from its spec with a forked RNG stream.
    pub fn new(spec: DeviceSpec, rng: Rng) -> Self {
        SimDevice {
            spec,
            rng,
            heat: 0.0,
            heat_t: 0.0,
            busy_s: 0.0,
        }
    }

    /// Current heat in [0,1] (test/diagnostic hook).
    pub fn heat(&self) -> f64 {
        self.heat
    }

    /// Total busy time so far (for energy accounting).
    pub fn busy_seconds(&self) -> f64 {
        self.busy_s
    }

    /// Reset thermal + accounting state (fresh run), keeping the RNG
    /// stream rolling so repeated runs see different noise.
    pub fn reset(&mut self) {
        self.heat = 0.0;
        self.heat_t = 0.0;
        self.busy_s = 0.0;
    }

    /// Let the device cool from `heat_t` to `now` (idle period).
    fn cool_to(&mut self, now: f64) {
        if now > self.heat_t {
            let dt = now - self.heat_t;
            self.heat *= (-dt / self.spec.thermal.cool_tau_s).exp();
            self.heat_t = now;
        }
    }

    /// Instantaneous rate multiplier from the thermal state.
    fn thermal_mult(&self) -> f64 {
        1.0 - self.spec.thermal.throttle_frac * self.heat
    }

    /// Rate multiplier from the big-GEMM curve for a call of `ops` ops.
    fn size_mult(&self, ops: f64) -> f64 {
        if self.spec.big_gemm_bonus == 0.0 {
            1.0
        } else {
            1.0 + self.spec.big_gemm_bonus * ops / (ops + self.spec.big_gemm_knee_ops)
        }
    }

    /// Rate multiplier from memory pressure for a resident working set of
    /// `ws_bytes`. Continuous: throughput degrades as the working set
    /// pushes past ~85% of device memory (driver reservations) and the
    /// library falls back to chunked streaming through host memory.
    fn oversub_mult(&self, ws_bytes: f64) -> f64 {
        if self.spec.mem_gib <= 0.0 {
            return 1.0; // host memory, effectively unbounded
        }
        let cap = self.spec.mem_gib * 1024.0 * 1024.0 * 1024.0 * 0.85;
        if ws_bytes <= cap {
            1.0
        } else {
            // Degrades linearly with oversubscription, hitting the
            // penalty floor at 1.5x capacity.
            let excess = ws_bytes / cap - 1.0;
            let floor = self.spec.oversub_penalty;
            (1.0 - (1.0 - floor) * (excess / 0.5).min(1.0)).max(floor)
        }
    }

    /// Rate multiplier from tensor-core alignment (paper footnote 1).
    fn align_mult(&self, size: GemmSize) -> f64 {
        if self.spec.kind == DeviceKind::Xpu
            && (size.m % self.spec.align != 0 || size.k % self.spec.align != 0)
        {
            self.spec.misalign_penalty
        } else {
            1.0
        }
    }

    /// The device's *cold, noise-free* rate for a call — used by tests
    /// and by the calibration tooling, never by the POAS pipeline.
    pub fn ideal_rate_ops(&self, size: GemmSize, ws_bytes: f64) -> f64 {
        self.spec.eff_rate_tops
            * 1e12
            * self.size_mult(size.ops())
            * self.oversub_mult(ws_bytes)
            * self.align_mult(size)
    }

    /// Simulate one GEMM call of `size` starting at virtual time `start`,
    /// with a device-resident working set of `ws_bytes`. Returns the call
    /// duration in seconds and advances the thermal state.
    pub fn compute(&mut self, size: GemmSize, ws_bytes: f64, start: f64) -> f64 {
        self.cool_to(start);

        let ops = size.ops();
        let base_rate = self.ideal_rate_ops(size, ws_bytes);
        let noise = self.rng.noise_factor(self.spec.noise_sigma);

        // Integrate the thermal ODE over the call: heat rises toward 1
        // with time constant heat_tau while busy, and the instantaneous
        // rate is base * (1 - throttle_frac * heat).
        let tau = self.spec.thermal.heat_tau_s;
        let mut remaining = ops;
        let mut t = 0.0f64;
        let step_ops = ops / THERMAL_STEPS as f64;
        for _ in 0..THERMAL_STEPS {
            let rate = (base_rate * self.thermal_mult() * noise).max(1.0);
            let dt = step_ops / rate;
            // Exact relaxation of h' = (1 - h)/tau over dt.
            let decay = (-dt / tau).exp();
            self.heat = 1.0 - (1.0 - self.heat) * decay;
            t += dt;
            remaining -= step_ops;
        }
        debug_assert!(remaining.abs() < ops * 1e-9 + 1.0);

        let total = t + self.spec.launch_overhead_s;
        self.heat_t = start + total;
        self.busy_s += total;
        total
    }

    /// Simulated duration of a host<->device DMA of `bytes` at the link's
    /// ground-truth bandwidth, with per-transfer latency and jitter.
    /// The *bus* decides when the transfer may start; this is only the
    /// occupancy duration.
    pub fn transfer_time(&mut self, bytes: f64) -> f64 {
        debug_assert!(
            self.spec.bus_bw_gbs > 0.0,
            "transfer_time on a device without a bus link"
        );
        let bw = self.spec.bus_bw_gbs * 1e9;
        let noise = self.rng.noise_factor(self.spec.noise_sigma * 0.5);
        self.spec.bus_latency_s + bytes / (bw * noise)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn gpu() -> SimDevice {
        let m = presets::mach1();
        SimDevice::new(m.devices[1].clone(), Rng::new(42))
    }

    fn xpu() -> SimDevice {
        let m = presets::mach1();
        SimDevice::new(m.devices[2].clone(), Rng::new(42))
    }

    fn cold_quiet(mut d: SimDevice) -> SimDevice {
        d.spec.noise_sigma = 0.0;
        d.spec.thermal.throttle_frac = 0.0;
        d
    }

    #[test]
    fn time_scales_linearly_with_ops() {
        let mut d = cold_quiet(gpu());
        let oh = d.spec.launch_overhead_s;
        let t1 = d.compute(GemmSize::square(1000), 0.0, 0.0) - oh;
        d.reset();
        let t2 = d.compute(GemmSize::new(2000, 1000, 1000), 0.0, 0.0) - oh;
        // 2x the ops = 2x the time once the launch overhead is removed.
        assert!((t2 / t1 - 2.0).abs() < 0.01, "t1={t1} t2={t2}");
    }

    #[test]
    fn rate_matches_spec_when_cold() {
        let mut d = cold_quiet(gpu());
        let s = GemmSize::square(4000);
        let t = d.compute(s, 0.0, 0.0);
        let rate = s.ops() / t / 1e12;
        assert!(
            (rate - d.spec.eff_rate_tops).abs() / d.spec.eff_rate_tops < 0.01,
            "rate={rate}"
        );
    }

    #[test]
    fn sustained_load_heats_and_slows() {
        let mut d = gpu();
        d.spec.noise_sigma = 0.0;
        let s = GemmSize::square(8000);
        let first = d.compute(s, 0.0, 0.0);
        // Run ~90 seconds of sustained work (heat_tau = 18 s).
        let mut t = first;
        let mut last = first;
        for _ in 0..1000 {
            last = d.compute(s, 0.0, t);
            t += last;
        }
        assert!(d.heat() > 0.9, "heat={}", d.heat());
        let slowdown = last / first;
        // throttle_frac = 0.11 -> sustained calls ~11% slower than cold.
        assert!(slowdown > 1.08 && slowdown < 1.14, "slowdown={slowdown}");
    }

    #[test]
    fn idle_cools_down() {
        let mut d = gpu();
        d.spec.noise_sigma = 0.0;
        let s = GemmSize::square(4000);
        let mut t = 0.0;
        for _ in 0..100 {
            t += d.compute(s, 0.0, t);
        }
        let hot = d.heat();
        // 5 cool-down time constants of idleness.
        let _ = d.compute(s, 0.0, t + 5.0 * d.spec.thermal.cool_tau_s);
        assert!(d.heat() < hot * 0.3, "heat {} -> {}", hot, d.heat());
    }

    #[test]
    fn misaligned_xpu_is_slower() {
        let mut d = cold_quiet(xpu());
        let aligned = d.compute(GemmSize::new(4096, 4096, 4096), 0.0, 0.0);
        d.reset();
        let misaligned = d.compute(GemmSize::new(4097, 4096, 4097), 0.0, 0.0);
        let ratio = misaligned / aligned;
        assert!(
            (ratio - 1.0 / d.spec.misalign_penalty).abs() < 0.02,
            "ratio={ratio}"
        );
    }

    #[test]
    fn gpu_alignment_irrelevant() {
        let mut d = cold_quiet(gpu());
        let a = d.compute(GemmSize::new(4096, 4096, 4096), 0.0, 0.0);
        d.reset();
        let b = d.compute(GemmSize::new(4097, 4096, 4097), 0.0, 0.0);
        assert!((b / a - 1.0).abs() < 0.01);
    }

    #[test]
    fn oversubscription_slows_down() {
        let mut d = cold_quiet(gpu());
        let s = GemmSize::square(4000);
        let fits = d.compute(s, 1e9, 0.0);
        d.reset();
        let oversub = d.compute(s, 25e9, 0.0); // 25 GB on an 11 GiB card
        assert!(
            oversub / fits > 1.3,
            "oversub={oversub} fits={fits}"
        );
        // Bounded by the penalty floor.
        d.reset();
        let extreme = d.compute(s, 500e9, 0.0);
        let floor_ratio = extreme / fits;
        assert!(
            (floor_ratio - 1.0 / d.spec.oversub_penalty).abs() < 0.05,
            "floor_ratio={floor_ratio}"
        );
    }

    #[test]
    fn big_gemm_bonus_curve() {
        let m = presets::mach2();
        let mut d = SimDevice::new(m.devices[0].clone(), Rng::new(1));
        d = cold_quiet(d);
        assert!(d.spec.big_gemm_bonus > 0.0);
        let small = GemmSize::square(1500); // profiling-sized
        let huge = GemmSize::square(30_000);
        let r_small = small.ops() / d.compute(small, 0.0, 0.0);
        d.reset();
        let r_huge = huge.ops() / d.compute(huge, 0.0, 0.0);
        let gain = r_huge / r_small;
        // Negligible bonus inside the profiling range, most of it at
        // standalone-workload sizes.
        assert!(gain > 1.0 + 0.8 * d.spec.big_gemm_bonus, "gain={gain}");
        assert!(gain < 1.0 + d.spec.big_gemm_bonus + 0.01);
    }

    #[test]
    fn noise_is_bounded_and_centered() {
        let mut d = gpu();
        d.spec.thermal.throttle_frac = 0.0;
        let s = GemmSize::square(3000);
        let base = s.ops() / d.spec.eff_rate_tops / 1e12;
        let n = 300;
        let mean: f64 = (0..n)
            .map(|i| d.compute(s, 0.0, (i as f64) * 1e6)) // long gaps: stays cold
            .sum::<f64>()
            / n as f64;
        assert!((mean / base - 1.0).abs() < 0.02, "mean={mean} base={base}");
    }

    #[test]
    fn transfer_time_matches_bandwidth() {
        let mut d = gpu();
        d.spec.noise_sigma = 0.0;
        let t = d.transfer_time(15.75e9);
        assert!((t - 1.0).abs() < 0.001, "t={t}"); // 15.75 GB at 15.75 GB/s
    }

    #[test]
    fn determinism_same_seed() {
        let m = presets::mach1();
        let mut a = SimDevice::new(m.devices[1].clone(), Rng::new(7));
        let mut b = SimDevice::new(m.devices[1].clone(), Rng::new(7));
        for i in 0..20 {
            let s = GemmSize::square(3000 + i * 10);
            assert_eq!(a.compute(s, 0.0, 0.0), b.compute(s, 0.0, 0.0));
        }
    }
}
