//! Shared PCIe bus with serialized, policy-arbitrated transfers.
//!
//! The paper's communication model (§3.4.3, §4.4, Fig. 2): accelerators
//! share the host bus, copies are serialized, and the order is decided by
//! a policy — the paper proposes *priority scheduling* (faster device
//! first). FIFO and round-robin arbitration are implemented as ablation
//! baselines (`benches/ablation_bus_policy.rs`).
//!
//! The bus itself is bandwidth-agnostic: each transfer carries its own
//! occupancy duration (computed by the owning device's link model), and
//! the bus decides *when* each transfer runs, recording a trace that the
//! Fig. 2 regenerator renders.

/// Transfer direction relative to the host.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Host to device (matrices A and B).
    H2D,
    /// Device to host (matrix C).
    D2H,
}

impl std::fmt::Display for Direction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Direction::H2D => write!(f, "H2D"),
            Direction::D2H => write!(f, "D2H"),
        }
    }
}

/// Bus arbitration policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BusPolicy {
    /// The paper's scheme: transfers start in descending device priority
    /// (faster device = higher priority).
    Priority,
    /// First-come first-served on request (ready-time) order.
    Fifo,
    /// Interleave pending transfers in fixed-size chunks.
    RoundRobin,
}

/// One completed bus occupancy interval.
#[derive(Debug, Clone, PartialEq)]
pub struct BusSegment {
    /// Device index within the machine.
    pub device: usize,
    /// Transfer direction.
    pub dir: Direction,
    /// Label for traces ("A", "B", "C", "bench"...).
    pub label: &'static str,
    /// Start time (virtual seconds).
    pub start: f64,
    /// End time (virtual seconds).
    pub end: f64,
    /// Bytes moved.
    pub bytes: f64,
}

/// A transfer request queued on the bus.
#[derive(Debug, Clone)]
pub struct TransferReq {
    /// Device index.
    pub device: usize,
    /// Direction.
    pub dir: Direction,
    /// Trace label.
    pub label: &'static str,
    /// Earliest virtual time the transfer may start.
    pub ready: f64,
    /// Bus occupancy duration (from the device's link model).
    pub duration: f64,
    /// Bytes moved (trace/energy accounting only).
    pub bytes: f64,
    /// Device priority — higher runs first under `BusPolicy::Priority`.
    pub priority: u32,
}

/// Recorded bus activity for one simulated execution.
#[derive(Debug, Clone, Default)]
pub struct BusTrace {
    /// Completed segments in start-time order.
    pub segments: Vec<BusSegment>,
}

impl BusTrace {
    /// Total bus busy time.
    pub fn busy_time(&self) -> f64 {
        self.segments.iter().map(|s| s.end - s.start).sum()
    }

    /// Last completion time (0 if no traffic).
    pub fn end_time(&self) -> f64 {
        self.segments.iter().map(|s| s.end).fold(0.0, f64::max)
    }

    /// True if no two segments overlap (the serialization invariant).
    pub fn is_serialized(&self) -> bool {
        let mut sorted: Vec<_> = self.segments.iter().collect();
        sorted.sort_by(|a, b| a.start.total_cmp(&b.start));
        sorted
            .windows(2)
            .all(|w| w[0].end <= w[1].start + 1e-12)
    }
}

/// The shared bus scheduler.
///
/// `schedule` takes a batch of transfer requests that become ready at
/// known times and returns each request's (start, end), advancing the
/// internal busy-until cursor. Batches model the paper's copy phases:
/// all H2D copies of one repetition are requested together, then later
/// the D2H copies as devices finish.
#[derive(Debug, Clone)]
pub struct Bus {
    policy: BusPolicy,
    busy_until: f64,
    trace: BusTrace,
    /// Chunk duration for round-robin interleaving (seconds of occupancy).
    rr_chunk_s: f64,
}

impl Bus {
    /// New idle bus with the given arbitration policy.
    pub fn new(policy: BusPolicy) -> Self {
        Bus {
            policy,
            busy_until: 0.0,
            trace: BusTrace::default(),
            rr_chunk_s: 0.01,
        }
    }

    /// The arbitration policy.
    pub fn policy(&self) -> BusPolicy {
        self.policy
    }

    /// Accumulated trace.
    pub fn trace(&self) -> &BusTrace {
        &self.trace
    }

    /// Drop the recorded trace (keep the clock state).
    pub fn clear_trace(&mut self) {
        self.trace.segments.clear();
    }

    /// Reset to an idle bus at t=0.
    pub fn reset(&mut self) {
        self.busy_until = 0.0;
        self.trace.segments.clear();
    }

    /// Schedule a batch of transfers; returns (start, end) per request in
    /// the input order.
    pub fn schedule(&mut self, mut reqs: Vec<TransferReq>) -> Vec<(f64, f64)> {
        let n = reqs.len();
        let mut out = vec![(0.0, 0.0); n];
        if n == 0 {
            return out;
        }
        // Remember input order.
        let order: Vec<usize> = (0..n).collect();
        let mut tagged: Vec<(usize, TransferReq)> =
            order.into_iter().zip(reqs.drain(..)).collect();

        match self.policy {
            BusPolicy::Priority => {
                // Descending priority, ties broken by ready time then index
                // (deterministic).
                tagged.sort_by(|(ia, a), (ib, b)| {
                    b.priority
                        .cmp(&a.priority)
                        .then(a.ready.total_cmp(&b.ready))
                        .then(ia.cmp(ib))
                });
                self.run_serial(&tagged, &mut out);
            }
            BusPolicy::Fifo => {
                tagged.sort_by(|(ia, a), (ib, b)| {
                    a.ready.total_cmp(&b.ready).then(ia.cmp(ib))
                });
                self.run_serial(&tagged, &mut out);
            }
            BusPolicy::RoundRobin => {
                self.run_round_robin(&tagged, &mut out);
            }
        }
        out
    }

    /// Run transfers one-by-one in the given order.
    fn run_serial(&mut self, tagged: &[(usize, TransferReq)], out: &mut [(f64, f64)]) {
        for (idx, r) in tagged {
            let start = r.ready.max(self.busy_until);
            let end = start + r.duration;
            self.busy_until = end;
            self.trace.segments.push(BusSegment {
                device: r.device,
                dir: r.dir,
                label: r.label,
                start,
                end,
                bytes: r.bytes,
            });
            out[*idx] = (start, end);
        }
    }

    /// Interleave transfers in chunks (round-robin ablation). Each chunk
    /// is a separate trace segment; a request's span is first-chunk start
    /// to last-chunk end.
    fn run_round_robin(&mut self, tagged: &[(usize, TransferReq)], out: &mut [(f64, f64)]) {
        let mut remaining: Vec<(usize, &TransferReq, f64)> = tagged
            .iter()
            .map(|(i, r)| (*i, r, r.duration))
            .collect();
        let mut started: Vec<Option<f64>> = vec![None; out.len()];
        while !remaining.is_empty() {
            let mut still: Vec<(usize, &TransferReq, f64)> = Vec::new();
            for (idx, r, left) in remaining.drain(..) {
                let start = r.ready.max(self.busy_until);
                let chunk = left.min(self.rr_chunk_s);
                let end = start + chunk;
                self.busy_until = end;
                let frac = chunk / r.duration.max(1e-30);
                self.trace.segments.push(BusSegment {
                    device: r.device,
                    dir: r.dir,
                    label: r.label,
                    start,
                    end,
                    bytes: r.bytes * frac,
                });
                if started[idx].is_none() {
                    started[idx] = Some(start);
                }
                if left - chunk > 1e-15 {
                    still.push((idx, r, left - chunk));
                } else {
                    out[idx] = (started[idx].unwrap(), end);
                }
            }
            remaining = still;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(device: usize, ready: f64, duration: f64, priority: u32) -> TransferReq {
        TransferReq {
            device,
            dir: Direction::H2D,
            label: "t",
            ready,
            duration,
            bytes: duration * 1e9,
            priority,
        }
    }

    #[test]
    fn priority_orders_by_priority() {
        let mut bus = Bus::new(BusPolicy::Priority);
        // Device 0 asks first but has lower priority.
        let spans = bus.schedule(vec![req(0, 0.0, 1.0, 1), req(1, 0.0, 1.0, 9)]);
        assert_eq!(spans[1], (0.0, 1.0)); // high priority runs first
        assert_eq!(spans[0], (1.0, 2.0));
        assert!(bus.trace().is_serialized());
    }

    #[test]
    fn fifo_orders_by_ready_time() {
        let mut bus = Bus::new(BusPolicy::Fifo);
        let spans = bus.schedule(vec![req(0, 0.5, 1.0, 1), req(1, 0.0, 1.0, 9)]);
        assert_eq!(spans[1], (0.0, 1.0));
        assert_eq!(spans[0], (1.0, 2.0));
    }

    #[test]
    fn serialization_invariant_holds() {
        let mut bus = Bus::new(BusPolicy::Priority);
        let reqs: Vec<_> = (0..10)
            .map(|i| req(i, (i as f64) * 0.1, 0.3, (10 - i) as u32))
            .collect();
        bus.schedule(reqs);
        assert!(bus.trace().is_serialized());
    }

    #[test]
    fn ready_time_respected() {
        let mut bus = Bus::new(BusPolicy::Priority);
        let spans = bus.schedule(vec![req(0, 5.0, 1.0, 1)]);
        assert_eq!(spans[0], (5.0, 6.0));
    }

    #[test]
    fn bus_state_persists_across_batches() {
        let mut bus = Bus::new(BusPolicy::Fifo);
        bus.schedule(vec![req(0, 0.0, 2.0, 1)]);
        let spans = bus.schedule(vec![req(1, 0.0, 1.0, 1)]);
        assert_eq!(spans[0], (2.0, 3.0));
    }

    #[test]
    fn round_robin_interleaves() {
        let mut bus = Bus::new(BusPolicy::RoundRobin);
        let spans = bus.schedule(vec![req(0, 0.0, 0.05, 1), req(1, 0.0, 0.05, 1)]);
        // Both finish within 0.1s total, and neither monopolizes: device 0
        // ends after device 1 starts.
        assert!(spans[0].1 > 0.05 && spans[1].1 > 0.05);
        assert!((spans[0].1.max(spans[1].1) - 0.1).abs() < 1e-9);
        assert!(bus.trace().is_serialized());
        assert!(bus.trace().segments.len() > 2, "chunked into segments");
    }

    #[test]
    fn round_robin_total_time_equals_serial() {
        // Work-conserving: same total occupancy as serial policies.
        let mut rr = Bus::new(BusPolicy::RoundRobin);
        let mut pr = Bus::new(BusPolicy::Priority);
        let reqs = vec![req(0, 0.0, 0.5, 1), req(1, 0.0, 0.25, 2)];
        rr.schedule(reqs.clone());
        pr.schedule(reqs);
        assert!((rr.trace().busy_time() - pr.trace().busy_time()).abs() < 1e-9);
        assert!((rr.trace().end_time() - pr.trace().end_time()).abs() < 1e-9);
    }

    #[test]
    fn trace_accounting() {
        let mut bus = Bus::new(BusPolicy::Priority);
        bus.schedule(vec![req(0, 0.0, 1.0, 1), req(1, 0.0, 2.0, 2)]);
        assert!((bus.trace().busy_time() - 3.0).abs() < 1e-12);
        assert!((bus.trace().end_time() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_batch_is_noop() {
        let mut bus = Bus::new(BusPolicy::Priority);
        assert!(bus.schedule(vec![]).is_empty());
        assert_eq!(bus.trace().segments.len(), 0);
    }

    #[test]
    fn deterministic_tie_break() {
        let mk = || {
            let mut bus = Bus::new(BusPolicy::Priority);
            bus.schedule(vec![req(0, 0.0, 1.0, 5), req(1, 0.0, 1.0, 5)])
        };
        assert_eq!(mk(), mk());
    }
}
