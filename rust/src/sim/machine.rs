//! A complete simulated testbed: devices + shared bus + virtual clock.
//!
//! Two entry points, mirroring how the paper's software touches real
//! hardware:
//!
//! * **profiling microbenchmarks** ([`SimMachine::profile_compute`],
//!   [`SimMachine::profile_bandwidth`]) — what the Predict phase runs at
//!   installation time (§4.1.2);
//! * **work-order execution** ([`SimMachine::execute`]) — a scheduled
//!   co-execution: per repetition, each accelerator's A/B copies go
//!   through the shared bus (arbitrated by the configured policy), the
//!   device computes its list of sub-products, and C returns over the
//!   bus (Fig. 2). The CPU computes host-side without copies.
//!
//! The returned [`ExecOutcome`] carries per-device timelines (compute
//! versus copy seconds — what Table 4's prediction errors are measured
//! against), the makespan (Tables 6–7, Figs. 3–4), the energy report and
//! the bus trace (Fig. 2).

use super::bus::{Bus, BusPolicy, BusTrace, Direction, TransferReq};
use super::device::SimDevice;
use super::energy::EnergyReport;
use crate::config::{DeviceKind, MachineConfig};
use crate::rng::Rng;
use crate::workload::GemmSize;

/// The work assigned to one device for one co-executed GEMM.
#[derive(Debug, Clone)]
pub struct WorkItem {
    /// Device index in the machine.
    pub device: usize,
    /// The device's overall slice (m_i, n, k) — sizes the A/B/C copies.
    pub slice: GemmSize,
    /// The slice decomposed into sub-products executed sequentially per
    /// repetition (the Adapt phase's square decomposition). May be just
    /// `[slice]` when no decomposition is applied.
    pub subproducts: Vec<GemmSize>,
    /// Bus priority (higher = earlier copies; paper: faster device first).
    pub priority: u32,
}

impl WorkItem {
    /// Undecomposed work item.
    pub fn whole(device: usize, slice: GemmSize, priority: u32) -> Self {
        WorkItem {
            device,
            slice,
            subproducts: vec![slice],
            priority,
        }
    }
}

/// A complete co-execution request: per-device work plus repetitions
/// (the paper repeats each input 50 times, §5.1.2).
#[derive(Debug, Clone)]
pub struct WorkOrder {
    pub items: Vec<WorkItem>,
    pub reps: u32,
}

impl WorkOrder {
    /// Merge two work orders that target disjoint device sets and share a
    /// repetition count. The service layer's standalone bypass uses this
    /// to co-schedule an independent job on a device the plan leaves
    /// idle. Returns `None` when the orders conflict (a shared device) or
    /// their repetition counts differ (the simulator runs one global
    /// repetition loop, so mixed counts cannot share an execution).
    pub fn merge(&self, other: &WorkOrder) -> Option<WorkOrder> {
        if self.reps != other.reps {
            return None;
        }
        let mine: std::collections::HashSet<usize> =
            self.items.iter().map(|i| i.device).collect();
        if other.items.iter().any(|i| mine.contains(&i.device)) {
            return None;
        }
        let mut items = self.items.clone();
        items.extend(other.items.iter().cloned());
        Some(WorkOrder {
            items,
            reps: self.reps,
        })
    }

    /// The devices this order occupies.
    pub fn devices(&self) -> Vec<usize> {
        self.items.iter().map(|i| i.device).collect()
    }
}

/// Per-device timing of one execution.
#[derive(Debug, Clone, Default)]
pub struct DeviceTimeline {
    /// Seconds spent computing (all reps).
    pub compute_s: f64,
    /// Seconds of H2D occupancy attributed to this device.
    pub h2d_s: f64,
    /// Seconds of D2H occupancy attributed to this device.
    pub d2h_s: f64,
    /// Seconds spent waiting on the bus (ready but not transferring).
    pub bus_wait_s: f64,
    /// Virtual time the device finished its last repetition.
    pub finish: f64,
}

impl DeviceTimeline {
    /// Total copy seconds (both directions).
    pub fn copy_s(&self) -> f64 {
        self.h2d_s + self.d2h_s
    }
}

/// Result of executing a [`WorkOrder`].
#[derive(Debug, Clone)]
pub struct ExecOutcome {
    /// Wall-clock of the whole co-execution (max device finish).
    pub makespan: f64,
    /// Per-device timelines (machine order; devices with no work get
    /// default zeros).
    pub timelines: Vec<DeviceTimeline>,
    /// Energy over the makespan window.
    pub energy: EnergyReport,
    /// Bus activity.
    pub bus_trace: BusTrace,
}

impl ExecOutcome {
    /// Overlap-aware completion time of a subset of devices: the virtual
    /// time (relative to the execution's start) when the last of
    /// `devices` went idle. A multi-tenant caller needs this to
    /// attribute per-request completion inside a merged co-execution —
    /// `makespan` covers *all* tenants of the order.
    pub fn finish_of(&self, devices: &[usize]) -> f64 {
        devices
            .iter()
            .map(|&d| self.timelines[d].finish)
            .fold(0.0, f64::max)
    }
}

/// A simulated machine instance.
#[derive(Debug, Clone)]
pub struct SimMachine {
    cfg: MachineConfig,
    devices: Vec<SimDevice>,
    bus: Bus,
    /// Session clock: profiling and executions advance it so thermal
    /// state carries realistically between activities.
    now: f64,
    /// Session-clock time the last work order finished (before the
    /// inter-run rest); profiling does not move it.
    busy_until: f64,
}

impl SimMachine {
    /// Build a machine with the paper's priority bus policy.
    pub fn new(cfg: &MachineConfig, seed: u64) -> Self {
        Self::with_policy(cfg, seed, BusPolicy::Priority)
    }

    /// Build a machine with an explicit bus arbitration policy.
    pub fn with_policy(cfg: &MachineConfig, seed: u64, policy: BusPolicy) -> Self {
        let mut root = Rng::new(seed ^ 0x9E3779B97F4A7C15);
        let devices = cfg
            .devices
            .iter()
            .map(|d| SimDevice::new(d.clone(), root.fork()))
            .collect();
        SimMachine {
            cfg: cfg.clone(),
            devices,
            bus: Bus::new(policy),
            now: 0.0,
            busy_until: 0.0,
        }
    }

    /// The machine's configuration.
    pub fn config(&self) -> &MachineConfig {
        &self.cfg
    }

    /// Number of devices.
    pub fn num_devices(&self) -> usize {
        self.devices.len()
    }

    /// Scale every device's effective compute rate by `factor` — the
    /// fault-injection hook behind stragglers (`factor < 1`: realized
    /// times drift slower than any model fitted before the change) and
    /// their recovery (`factor > 1` restores the original rate, since
    /// scales compose multiplicatively). Takes effect on the next
    /// `compute` call; in-flight work orders are not revisited. The
    /// machine's fitted [`crate::predict::PerfModel`] knows nothing of
    /// this — closing that gap is the dynamic scheduler's job.
    pub fn scale_rates(&mut self, factor: f64) {
        assert!(
            factor.is_finite() && factor > 0.0,
            "rate factor must be finite and positive, got {factor}"
        );
        for d in &mut self.devices {
            d.spec.eff_rate_tops *= factor;
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Session-clock time at which the machine last finished executing a
    /// work order (the instant its slowest device went idle, before the
    /// inter-run rest is charged). `0.0` until the first execution.
    /// The serving layer's shards difference this against the
    /// pre-execution clock to account machine-busy seconds without
    /// re-deriving them from per-device timelines.
    pub fn busy_until(&self) -> f64 {
        self.busy_until
    }

    /// Direct (test/calibration) access to a device.
    pub fn device(&self, i: usize) -> &SimDevice {
        &self.devices[i]
    }

    // ------------------------------------------------------------------
    // Profiling microbenchmarks (the Predict phase's view of hardware)
    // ------------------------------------------------------------------

    /// Run one square `s x s x s` GEMM on device `dev` and return the
    /// measured (virtual) seconds — including the launch overhead, like a
    /// wall-clock benchmark would. Advances the session clock with a
    /// small inter-run gap.
    pub fn profile_compute_once(&mut self, dev: usize, s: u64) -> f64 {
        let size = GemmSize::square(s);
        let ws = size.working_set_bytes(self.cfg.devices[dev].kind.dtype_bytes());
        let t = self.devices[dev].compute(size, ws, self.now);
        self.now += t + 0.05; // benchmark harness gap between runs
        t
    }

    /// Measure the host<->device bandwidth of device `dev` by timing a
    /// transfer of `bytes` (exclusive bus — profiling runs alone).
    /// Returns measured bytes/second.
    pub fn profile_bandwidth_once(&mut self, dev: usize, bytes: f64) -> f64 {
        let t = self.devices[dev].transfer_time(bytes);
        self.now += t + 0.05;
        bytes / t
    }

    /// Let every device cool down (idle gap between experiments).
    pub fn rest(&mut self, seconds: f64) {
        self.now += seconds;
    }

    // ------------------------------------------------------------------
    // Work-order execution (the Schedule phase's view of hardware)
    // ------------------------------------------------------------------

    /// Execute a co-scheduled GEMM. Devices start cold (a fresh program
    /// run after the inter-experiment gap) but heat up across the
    /// repetitions — which is exactly how the paper's testbed behaved.
    pub fn execute(&mut self, order: &WorkOrder) -> ExecOutcome {
        for d in &mut self.devices {
            d.reset();
        }
        self.bus.reset();
        let t0 = 0.0;

        let mut timelines: Vec<DeviceTimeline> = (0..self.devices.len())
            .map(|_| DeviceTimeline::default())
            .collect();

        // Per-device time cursor within this execution.
        let mut cursor = vec![t0; self.devices.len()];

        for _rep in 0..order.reps.max(1) {
            // ---- Phase 1: H2D copies of A_i and B (accelerators only).
            let mut reqs = Vec::new();
            let mut req_owner = Vec::new();
            for item in &order.items {
                let spec = &self.cfg.devices[item.device];
                if spec.kind == DeviceKind::Cpu {
                    continue;
                }
                let dt = spec.kind.dtype_bytes();
                let a = item.slice.a_bytes(dt);
                let b = item.slice.b_bytes(dt);
                for (bytes, label) in [(a, "A"), (b, "B")] {
                    let duration = self.devices[item.device].transfer_time(bytes);
                    reqs.push(TransferReq {
                        device: item.device,
                        dir: Direction::H2D,
                        label,
                        ready: cursor[item.device],
                        duration,
                        bytes,
                        priority: item.priority,
                    });
                    req_owner.push(item.device);
                }
            }
            let spans = self.bus.schedule(reqs);
            // Advance each accelerator's cursor to its last H2D end.
            for (owner, (start, end)) in req_owner.iter().zip(&spans) {
                let tl = &mut timelines[*owner];
                tl.h2d_s += end - start;
                tl.bus_wait_s += (start - cursor[*owner]).max(0.0);
                cursor[*owner] = cursor[*owner].max(*end);
            }

            // ---- Phase 2: compute (all devices, including CPU).
            for item in &order.items {
                let spec = &self.cfg.devices[item.device];
                let dt = spec.kind.dtype_bytes();
                let ws = item.slice.working_set_bytes(dt);
                let mut t = cursor[item.device];
                for sub in &item.subproducts {
                    let dur = self.devices[item.device].compute(*sub, ws, t);
                    timelines[item.device].compute_s += dur;
                    t += dur;
                }
                cursor[item.device] = t;
            }

            // ---- Phase 3: D2H copy of C_i (accelerators only).
            let mut reqs = Vec::new();
            let mut req_owner = Vec::new();
            for item in &order.items {
                let spec = &self.cfg.devices[item.device];
                if spec.kind == DeviceKind::Cpu {
                    continue;
                }
                let dt = spec.kind.dtype_bytes();
                let c = item.slice.c_bytes(dt);
                let duration = self.devices[item.device].transfer_time(c);
                reqs.push(TransferReq {
                    device: item.device,
                    dir: Direction::D2H,
                    label: "C",
                    ready: cursor[item.device],
                    duration,
                    bytes: c,
                    priority: item.priority,
                });
                req_owner.push(item.device);
            }
            let spans = self.bus.schedule(reqs);
            for (owner, (start, end)) in req_owner.iter().zip(&spans) {
                let tl = &mut timelines[*owner];
                tl.d2h_s += end - start;
                tl.bus_wait_s += (start - cursor[*owner]).max(0.0);
                cursor[*owner] = cursor[*owner].max(*end);
            }
        }

        for (i, tl) in timelines.iter_mut().enumerate() {
            tl.finish = cursor[i];
        }
        let makespan = cursor
            .iter()
            .cloned()
            .fold(0.0, f64::max);

        let busy: Vec<f64> = timelines
            .iter()
            .map(|t| t.compute_s + t.h2d_s + t.d2h_s)
            .collect();
        let energy = EnergyReport::from_busy(&self.cfg, &busy, makespan);
        let bus_trace = self.bus.trace().clone();

        // The experiment occupied the session: advance the clock and give
        // the machine the paper's inter-run rest.
        self.busy_until = self.now + makespan;
        self.now += makespan + 30.0;

        ExecOutcome {
            makespan,
            timelines,
            energy,
            bus_trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn mach1() -> SimMachine {
        SimMachine::new(&presets::mach1(), 0)
    }

    fn simple_order(_m: &SimMachine) -> WorkOrder {
        // Rough thirds of a 9000-row GEMM across cpu/gpu/xpu.
        let n = 9000;
        let slice = |rows| GemmSize::new(rows, n, n);
        WorkOrder {
            items: vec![
                WorkItem::whole(0, slice(40), 0),
                WorkItem::whole(1, slice(1960), 1),
                WorkItem::whole(2, slice(7000), 2),
            ],
            reps: 2,
        }
    }

    #[test]
    fn execute_produces_consistent_outcome() {
        let mut m = mach1();
        let o = m.execute(&simple_order(&m));
        assert!(o.makespan > 0.0);
        // makespan is the max finish.
        let max_fin = o.timelines.iter().map(|t| t.finish).fold(0.0, f64::max);
        assert_eq!(o.makespan, max_fin);
        // accelerators moved bytes, CPU did not.
        assert_eq!(o.timelines[0].copy_s(), 0.0);
        assert!(o.timelines[1].copy_s() > 0.0);
        assert!(o.timelines[2].copy_s() > 0.0);
        // bus never overlaps.
        assert!(o.bus_trace.is_serialized());
        assert!(o.energy.total_j > 0.0);
    }

    #[test]
    fn priority_device_copies_first() {
        let mut m = mach1();
        let o = m.execute(&simple_order(&m));
        // First bus segment belongs to the XPU (priority 2).
        assert_eq!(o.bus_trace.segments[0].device, 2);
        assert_eq!(o.bus_trace.segments[0].dir, Direction::H2D);
    }

    #[test]
    fn reps_scale_compute_time() {
        let mut m1 = mach1();
        let mut o1 = simple_order(&m1);
        o1.reps = 1;
        let r1 = m1.execute(&o1);
        let mut m2 = mach1();
        let mut o2 = simple_order(&m2);
        o2.reps = 4;
        let r4 = m2.execute(&o2);
        let ratio = r4.timelines[2].compute_s / r1.timelines[2].compute_s;
        assert!(ratio > 3.7 && ratio < 4.3, "ratio={ratio}");
    }

    #[test]
    fn standalone_cpu_has_no_bus_traffic() {
        let mut m = mach1();
        let o = m.execute(&WorkOrder {
            items: vec![WorkItem::whole(0, GemmSize::square(3000), 0)],
            reps: 1,
        });
        assert!(o.bus_trace.segments.is_empty());
        assert!(o.makespan > 0.0);
    }

    #[test]
    fn profiling_returns_sane_rates() {
        let mut m = mach1();
        let t = m.profile_compute_once(1, 4000);
        let rate_tops = GemmSize::square(4000).ops() / t / 1e12;
        let spec_rate = m.config().devices[1].eff_rate_tops;
        assert!((rate_tops / spec_rate - 1.0).abs() < 0.15, "rate={rate_tops}");
    }

    #[test]
    fn bandwidth_profiling_near_spec() {
        let mut m = mach1();
        let measured = m.profile_bandwidth_once(1, 1e9);
        let spec = m.config().devices[1].bus_bw_gbs * 1e9;
        assert!((measured / spec - 1.0).abs() < 0.2, "bw={measured}");
    }

    #[test]
    fn subproduct_decomposition_equivalent_ops() {
        // Decomposed work takes roughly as long as whole work (same total
        // ops, more launch overheads).
        let mut m1 = mach1();
        let whole = m1.execute(&WorkOrder {
            items: vec![WorkItem::whole(1, GemmSize::square(8000), 1)],
            reps: 1,
        });
        let mut m2 = mach1();
        let subs: Vec<GemmSize> = (0..8).map(|_| GemmSize::new(1000, 8000, 8000)).collect();
        let split = m2.execute(&WorkOrder {
            items: vec![WorkItem {
                device: 1,
                slice: GemmSize::square(8000),
                subproducts: subs,
                priority: 1,
            }],
            reps: 1,
        });
        let ratio = split.timelines[1].compute_s / whole.timelines[1].compute_s;
        assert!((ratio - 1.0).abs() < 0.05, "ratio={ratio}");
    }

    #[test]
    fn determinism_per_seed() {
        let cfg = presets::mach2();
        let run = |seed| {
            let mut m = SimMachine::new(&cfg, seed);
            let o = m.execute(&WorkOrder {
                items: vec![
                    WorkItem::whole(1, GemmSize::new(2000, 8000, 8000), 1),
                    WorkItem::whole(2, GemmSize::new(6000, 8000, 8000), 2),
                ],
                reps: 3,
            });
            o.makespan
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    #[test]
    fn merge_rejects_conflicts_and_mixed_reps() {
        let a = WorkOrder {
            items: vec![WorkItem::whole(1, GemmSize::square(1000), 1)],
            reps: 2,
        };
        let b = WorkOrder {
            items: vec![WorkItem::whole(0, GemmSize::square(500), 0)],
            reps: 2,
        };
        let merged = a.merge(&b).unwrap();
        assert_eq!(merged.items.len(), 2);
        assert_eq!(merged.devices(), vec![1, 0]);
        // Same device on both sides -> conflict.
        let c = WorkOrder {
            items: vec![WorkItem::whole(1, GemmSize::square(500), 1)],
            reps: 2,
        };
        assert!(a.merge(&c).is_none());
        // Mismatched reps -> no merge.
        let d = WorkOrder {
            items: vec![WorkItem::whole(0, GemmSize::square(500), 0)],
            reps: 3,
        };
        assert!(a.merge(&d).is_none());
    }

    #[test]
    fn finish_of_attributes_per_tenant_completion() {
        // Big job on the XPU, small independent job on the CPU, merged.
        let mut m = mach1();
        let big = WorkOrder {
            items: vec![WorkItem::whole(2, GemmSize::new(7000, 9000, 9000), 2)],
            reps: 2,
        };
        let small = WorkOrder {
            items: vec![WorkItem::whole(0, GemmSize::square(1200), 0)],
            reps: 2,
        };
        let merged = big.merge(&small).unwrap();
        let o = m.execute(&merged);
        let f_big = o.finish_of(&[2]);
        let f_small = o.finish_of(&[0]);
        // Each tenant finishes no later than the whole order, and the
        // makespan is exactly the slowest tenant.
        assert!(f_big <= o.makespan && f_small <= o.makespan);
        assert!((o.finish_of(&[0, 2]) - o.makespan).abs() < 1e-12);
        // The small CPU job overlaps the big one instead of following it.
        assert!(f_small < f_big, "small {f_small} vs big {f_big}");
        // Devices without work report finish 0.
        assert_eq!(o.finish_of(&[1]), 0.0);
        assert_eq!(o.finish_of(&[]), 0.0);
    }

    #[test]
    fn busy_until_tracks_execution_end_not_rest() {
        let mut m = mach1();
        assert_eq!(m.busy_until(), 0.0);
        m.profile_compute_once(1, 2000);
        assert_eq!(m.busy_until(), 0.0, "profiling is not serving work");
        let before = m.now();
        let o = m.execute(&simple_order(&m));
        assert!((m.busy_until() - (before + o.makespan)).abs() < 1e-9);
        // The inter-run rest is charged to the session clock only.
        assert!(m.now() > m.busy_until());
        m.rest(100.0);
        assert!((m.busy_until() - (before + o.makespan)).abs() < 1e-9);
    }

    #[test]
    fn thermal_state_resets_per_execution() {
        let mut m = mach1();
        let o1 = m.execute(&simple_order(&m));
        let o2 = m.execute(&simple_order(&m));
        // Same order, fresh thermal state: makespans within noise of each
        // other (not monotonically increasing from carried-over heat).
        let rel = (o1.makespan - o2.makespan).abs() / o1.makespan;
        assert!(rel < 0.1, "rel={rel}");
    }
}
