//! Energy accounting over a simulated execution.
//!
//! POAS can optimize for energy instead of time (§3: "minimizing the
//! energy used"); this module supplies the joule numbers for both the
//! energy-objective pipeline and the `ablation_energy` bench. The model
//! is the standard two-level one: each device draws `idle_w` for the
//! whole wall-clock window plus `active_w` while it is computing or
//! driving its PCIe link.

use crate::config::MachineConfig;

/// Per-device and total energy for one execution window.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyReport {
    /// Joules per device (machine order).
    pub per_device_j: Vec<f64>,
    /// Total joules including idle floor.
    pub total_j: f64,
    /// Wall-clock window the report covers (seconds).
    pub window_s: f64,
}

impl EnergyReport {
    /// Compute a report from per-device busy seconds over a window.
    ///
    /// `busy_s[i]` = seconds device `i` spent computing or transferring;
    /// the idle draw applies for the full window (the machine is on).
    pub fn from_busy(cfg: &MachineConfig, busy_s: &[f64], window_s: f64) -> Self {
        assert_eq!(busy_s.len(), cfg.devices.len());
        let per_device_j: Vec<f64> = cfg
            .devices
            .iter()
            .zip(busy_s)
            .map(|(d, &b)| d.idle_w * window_s + d.active_w * b.min(window_s))
            .collect();
        let total_j = per_device_j.iter().sum();
        EnergyReport {
            per_device_j,
            total_j,
            window_s,
        }
    }

    /// Average power over the window (watts).
    pub fn avg_power_w(&self) -> f64 {
        if self.window_s > 0.0 {
            self.total_j / self.window_s
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn idle_machine_draws_idle_power() {
        let m = presets::mach1();
        let r = EnergyReport::from_busy(&m, &[0.0, 0.0, 0.0], 10.0);
        let idle_sum: f64 = m.devices.iter().map(|d| d.idle_w).sum();
        assert!((r.total_j - idle_sum * 10.0).abs() < 1e-9);
    }

    #[test]
    fn busy_device_adds_active_power() {
        let m = presets::mach1();
        let r = EnergyReport::from_busy(&m, &[0.0, 4.0, 0.0], 10.0);
        let expect = m.devices.iter().map(|d| d.idle_w * 10.0).sum::<f64>()
            + m.devices[1].active_w * 4.0;
        assert!((r.total_j - expect).abs() < 1e-9);
    }

    #[test]
    fn busy_clamped_to_window() {
        let m = presets::mach1();
        let a = EnergyReport::from_busy(&m, &[20.0, 0.0, 0.0], 10.0);
        let b = EnergyReport::from_busy(&m, &[10.0, 0.0, 0.0], 10.0);
        assert_eq!(a.total_j, b.total_j);
    }

    #[test]
    fn avg_power() {
        let m = presets::mach1();
        let r = EnergyReport::from_busy(&m, &[0.0, 0.0, 0.0], 5.0);
        assert!((r.avg_power_w() - r.total_j / 5.0).abs() < 1e-12);
    }
}
