//! Baked testbed descriptions.
//!
//! `mach1` / `mach2` reproduce the paper's two HPC servers (Tables 1–2).
//! The *spec-sheet* numbers (core counts, peak TFLOP/s, bus generation)
//! come straight from Table 1; the *effective* throughputs are calibrated
//! so that the simulated testbed reproduces the relative device speeds the
//! paper measured (Tables 6–7) — see `EXPERIMENTS.md` §Calibration for the
//! derivation. The POAS pipeline itself never reads these values: it
//! re-discovers them by profiling.

use super::{DeviceKind, DeviceSpec, MachineConfig, ThermalSpec};

/// Convenience constructor with the fields every preset shares.
#[allow(clippy::too_many_arguments)]
fn dev(
    name: &str,
    kind: DeviceKind,
    model: &str,
    eff_rate_tops: f64,
    bus_bw_gbs: f64,
    mem_gib: f64,
    thermal: ThermalSpec,
    noise_sigma: f64,
) -> DeviceSpec {
    let is_cpu = kind == DeviceKind::Cpu;
    DeviceSpec {
        name: name.to_string(),
        kind,
        model: model.to_string(),
        eff_rate_tops,
        launch_overhead_s: if is_cpu { 20e-6 } else { 60e-6 },
        noise_sigma,
        thermal,
        mem_gib,
        // Working sets past ~80% of device memory force chunked streaming
        // through host memory; throughput drops to ~60%.
        oversub_penalty: 0.62,
        // cuBLAS without tensor-core eligibility (footnote 1).
        misalign_penalty: if kind == DeviceKind::Xpu { 0.55 } else { 1.0 },
        big_gemm_bonus: 0.0,
        big_gemm_knee_ops: 64.0e9, // ~4000^3

        bus_bw_gbs,
        bus_latency_s: 12e-6,
        idle_w: if is_cpu { 25.0 } else { 18.0 },
        active_w: match kind {
            DeviceKind::Cpu => 70.0,
            DeviceKind::Gpu => 240.0,
            DeviceKind::Xpu => 255.0,
        },
        align: if kind == DeviceKind::Xpu { 8 } else { 1 },
        cache_fit_ops: if is_cpu { 8.0e9 } else { 0.0 }, // 2000^3, §5.1.3
        profile_lo: if is_cpu { 1000 } else { 3000 },
        profile_hi: if is_cpu { 2000 } else { 6000 },
    }
}

/// `mach1`: Intel Xeon E5-2603 v3 (6C Haswell) + RTX 2080 Ti as GPU +
/// RTX 2080 Ti as XPU, PCIe 3.0 x16 (15.75 GB/s). Poor chassis cooling:
/// the paper attributes mach1's larger prediction errors to clock
/// down-scaling under sustained load (§5.2), modelled here as thermal
/// throttling on both accelerators.
pub fn mach1() -> MachineConfig {
    let hot = ThermalSpec {
        throttle_frac: 0.11,
        heat_tau_s: 18.0,
        cool_tau_s: 45.0,
    };
    MachineConfig {
        name: "mach1".to_string(),
        devices: vec![
            // 0.307 TFLOP/s FP32 peak, 5 of 6 cores usable (one reserved
            // to drive the accelerators, §5.1.1), MKL ~85% efficiency:
            // 0.307/2 * 5/6 * 0.85 ≈ 0.109 Tera-madd/s.
            dev(
                "xeon",
                DeviceKind::Cpu,
                "Intel Xeon E5-2603 v3",
                0.109,
                0.0,
                0.0,
                ThermalSpec::NONE,
                0.020,
            ),
            // 13.45 TFLOP/s FP32 peak; cuBLAS SGEMM ~83% -> 5.6 T-madd/s.
            dev(
                "2080ti-gpu",
                DeviceKind::Gpu,
                "NVIDIA RTX 2080 Ti (CUDA cores)",
                5.6,
                15.75,
                11.0,
                hot,
                0.025,
            ),
            // 107.5 TFLOP/s FP16 tensor peak; achieved HGEMM throughput on
            // Turing is far below peak (~40%) -> 21.5 T-madd/s.
            dev(
                "2080ti-xpu",
                DeviceKind::Xpu,
                "NVIDIA RTX 2080 Ti (tensor cores)",
                21.5,
                15.75,
                11.0,
                hot,
                0.030,
            ),
        ],
    }
}

/// `mach2`: AMD EPYC 7413 (24C Zen 3) + RTX 3090 as GPU + RTX 2080 Ti as
/// XPU. GPU on PCIe 4.0 x16 (31.75 GB/s); the 2080 Ti only links at 3.0
/// speed (15.75 GB/s) even in the 4.0 slot (§5.1.1). Well-cooled chassis.
pub fn mach2() -> MachineConfig {
    MachineConfig {
        name: "mach2".to_string(),
        devices: vec![
            // 2.76 TFLOP/s FP32 peak on 24C; 23 usable. BLIS on small
            // cache-fit tiles sustains ~0.60 T-madd/s (the profiled rate);
            // monolithic huge GEMMs stream better (big_gemm curve in the
            // simulator) which is why the paper's standalone-CPU speedup
            // (~36x) is below the inverse CPU share (~1/1.1%).
            {
                let mut d = dev(
                    "epyc",
                    DeviceKind::Cpu,
                    "AMD EPYC 7413",
                    0.60,
                    0.0,
                    0.0,
                    ThermalSpec::NONE,
                    0.012,
                );
                // 24C Zen3 BLIS is threading-bound on cache-fit tiles;
                // monolithic huge GEMMs (the standalone baseline's single
                // library call) reach ~1.4x the profiled rate. The knee
                // sits far above the profiling range so the Predict
                // phase's linear model stays valid on scheduled tiles.
                d.big_gemm_bonus = 0.4;
                d.big_gemm_knee_ops = 1.0e12;
                d
            },
            // 35.58 TFLOP/s FP32 peak; cuBLAS SGEMM on Ampere sustains
            // ~92% on large tiles -> 16.4 T-madd/s.
            dev(
                "3090-gpu",
                DeviceKind::Gpu,
                "NVIDIA RTX 3090 (CUDA cores)",
                16.4,
                31.75,
                24.0,
                ThermalSpec {
                    throttle_frac: 0.045,
                    heat_tau_s: 25.0,
                    cool_tau_s: 40.0,
                },
                0.018,
            ),
            // Same silicon as mach1's XPU but properly cooled: sustains
            // ~38 T-madd/s (71% of FP16 tensor peak).
            dev(
                "2080ti-xpu",
                DeviceKind::Xpu,
                "NVIDIA RTX 2080 Ti (tensor cores)",
                38.0,
                15.75,
                11.0,
                ThermalSpec {
                    throttle_frac: 0.075,
                    heat_tau_s: 22.0,
                    cool_tau_s: 40.0,
                },
                0.022,
            ),
        ],
    }
}

// ---------------------------------------------------------------------
// Heterogeneous-cluster node presets
// ---------------------------------------------------------------------
//
// ALP environments are not fleets of clones (Hill & Reddi): a serving
// cluster mixes accelerator-dense boxes with CPU-only and
// single-accelerator nodes. These presets describe such *shards* — each
// is a complete `MachineConfig` a `Cluster` profiles independently at
// install time, so routing can exploit the asymmetry from per-shard
// predictions. Device parameters are reused from the calibrated
// mach1/mach2 tables; only the *composition* differs.

/// `gpu_node`: an accelerator-dense shard — mach1's weak Xeon driving
/// mach2's well-cooled RTX 3090 + tensor-core 2080 Ti. Large GEMMs
/// predict ~50x faster here than on [`cpu_node`].
pub fn gpu_node() -> MachineConfig {
    let mut m = mach2();
    m.name = "gpu-node".to_string();
    // Swap the strong EPYC for mach1's small Xeon: the node's value is
    // its accelerators, and the weak host makes tiny GEMMs predict
    // *slower* here than on the CPU node — the asymmetry the routing
    // tests exercise.
    m.devices[0] = mach1().devices[0].clone();
    m
}

/// `cpu_node`: a CPU-only shard — a single well-fed AMD EPYC 7413, no
/// accelerators at all. The suitability gate always recommends
/// standalone here (co-execution needs co-executors), and tiny GEMMs
/// predict faster than on [`gpu_node`] (no PCIe copies, lower launch
/// overhead, stronger host cores).
pub fn cpu_node() -> MachineConfig {
    let mut m = mach2();
    m.name = "cpu-node".to_string();
    m.devices.truncate(1); // keep only the EPYC
    m
}

/// `xpu_node`: a single-accelerator shard — mach1's Xeon plus one
/// properly cooled tensor-core 2080 Ti (mach2's XPU). Sits between
/// [`gpu_node`] and [`cpu_node`] on heavy shapes.
pub fn xpu_node() -> MachineConfig {
    let gpu = gpu_node();
    MachineConfig {
        name: "xpu-node".to_string(),
        devices: vec![gpu.devices[0].clone(), gpu.devices[2].clone()],
    }
}

/// The baked heterogeneous mix: one GPU-heavy shard, one CPU-only
/// shard, one XPU shard — the smallest cluster where per-shard
/// performance models disagree on *everything* (device count, best
/// standalone device, co-execution feasibility).
pub fn hetero_mix() -> Vec<MachineConfig> {
    vec![gpu_node(), cpu_node(), xpu_node()]
}

/// A local PJRT testbed for the real-execution path: three "devices"
/// backed by the host CPU running the AOT artifacts (f32 artifacts for
/// cpu/gpu, bf16 for xpu). Rates are placeholders — the e2e examples
/// profile the PJRT executables live, exactly like the simulated flow.
pub fn pjrt_local() -> MachineConfig {
    let mk = |name: &str, kind, model: &str| DeviceSpec {
        // PJRT-interpret GEMM on this host is in the GFLOP/s range.
        eff_rate_tops: 0.001,
        launch_overhead_s: 1e-4,
        noise_sigma: 0.05,
        thermal: ThermalSpec::NONE,
        mem_gib: 0.0,
        oversub_penalty: 1.0,
        misalign_penalty: 1.0,
        big_gemm_bonus: 0.0,
        big_gemm_knee_ops: 64.0e9,
        // "Copies" are host memcpys; treat as a fast virtual link.
        bus_bw_gbs: if kind == DeviceKind::Cpu { 0.0 } else { 8.0 },
        bus_latency_s: 5e-6,
        idle_w: 5.0,
        active_w: 30.0,
        align: if kind == DeviceKind::Xpu { 8 } else { 1 },
        cache_fit_ops: 0.0,
        // Tile menu sizes are the profiling menu on the real path.
        profile_lo: 64,
        profile_hi: 256,
        name: name.to_string(),
        kind,
        model: model.to_string(),
    };
    MachineConfig {
        name: "pjrt-local".to_string(),
        devices: vec![
            mk("pjrt-cpu", DeviceKind::Cpu, "PJRT CPU (f32 artifacts)"),
            mk("pjrt-gpu", DeviceKind::Gpu, "PJRT CPU (f32 artifacts)"),
            mk("pjrt-xpu", DeviceKind::Xpu, "PJRT CPU (bf16 artifacts)"),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mach1_matches_table1_structure() {
        let m = mach1();
        assert_eq!(m.devices.len(), 3);
        assert_eq!(m.devices[0].kind, DeviceKind::Cpu);
        assert_eq!(m.devices[1].kind, DeviceKind::Gpu);
        assert_eq!(m.devices[2].kind, DeviceKind::Xpu);
        // PCIe 3.0 on both accelerators.
        assert_eq!(m.devices[1].bus_bw_gbs, 15.75);
        assert_eq!(m.devices[2].bus_bw_gbs, 15.75);
    }

    #[test]
    fn mach2_bus_generations() {
        let m = mach2();
        assert_eq!(m.devices[1].bus_bw_gbs, 31.75); // 3090 on PCIe 4.0
        assert_eq!(m.devices[2].bus_bw_gbs, 15.75); // 2080 Ti capped at 3.0
    }

    #[test]
    fn device_speed_ordering_xpu_gt_gpu_gt_cpu() {
        for m in [mach1(), mach2()] {
            let r = |k| m.devices[m.device_of_kind(k).unwrap()].eff_rate_tops;
            assert!(r(DeviceKind::Xpu) > r(DeviceKind::Gpu));
            assert!(r(DeviceKind::Gpu) > r(DeviceKind::Cpu));
        }
    }

    #[test]
    fn mach1_is_thermally_worse_than_mach2() {
        let t1 = mach1().devices[2].thermal.throttle_frac;
        let t2 = mach2().devices[2].thermal.throttle_frac;
        assert!(t1 > t2);
    }

    #[test]
    fn xpu_alignment_rule() {
        for m in [mach1(), mach2(), pjrt_local()] {
            for d in &m.devices {
                if d.kind == DeviceKind::Xpu {
                    assert_eq!(d.align, 8);
                } else {
                    assert_eq!(d.align, 1);
                }
            }
        }
    }

    #[test]
    fn hetero_nodes_are_valid_and_asymmetric() {
        for m in hetero_mix() {
            m.validate().expect("hetero preset must validate");
        }
        let gpu = gpu_node();
        let cpu = cpu_node();
        let xpu = xpu_node();
        assert_eq!(gpu.devices.len(), 3);
        assert_eq!(cpu.devices.len(), 1);
        assert_eq!(xpu.devices.len(), 2);
        // The CPU node's host is strictly stronger than the GPU node's.
        assert!(cpu.devices[0].eff_rate_tops > gpu.devices[0].eff_rate_tops);
        // The CPU node has no accelerators; the others do.
        assert!(cpu.device_of_kind(DeviceKind::Gpu).is_none());
        assert!(cpu.device_of_kind(DeviceKind::Xpu).is_none());
        assert!(gpu.device_of_kind(DeviceKind::Gpu).is_some());
        assert!(xpu.device_of_kind(DeviceKind::Xpu).is_some());
    }

    #[test]
    fn cpu_profiling_range_is_cache_fit() {
        for m in [mach1(), mach2()] {
            let cpu = &m.devices[m.device_of_kind(DeviceKind::Cpu).unwrap()];
            assert_eq!((cpu.profile_lo, cpu.profile_hi), (1000, 2000));
            let (_, hi) = cpu.submatrix_ops_range();
            assert!(hi <= cpu.cache_fit_ops);
        }
    }
}
