//! Machine and device configuration.
//!
//! The paper evaluates on two HPC servers (`mach1`, `mach2` — Tables 1–2).
//! A [`MachineConfig`] describes such a testbed: one entry per device with
//! the *ground-truth* parameters of the simulator (effective GEMM
//! throughput, bus link bandwidth, noise, thermal behaviour, power) plus
//! the adapt-phase constraints the paper attaches to each device class
//! (tensor-core alignment, CPU cache-fit, profiling size ranges).
//!
//! Ground truth is only visible to the simulator. The POAS pipeline never
//! reads these numbers: it *profiles* the simulated machine exactly as the
//! paper profiled MKL/cuBLAS (§4.1.2) and works from the fitted model.
//!
//! Configs can be written in a small TOML subset (see [`parser`]) or taken
//! from [`presets`] which bake the calibrated mach1/mach2 descriptions.

pub mod parser;
pub mod presets;

use crate::error::{Error, Result};

/// Device class — drives precision, alignment rules and artifact choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// Host CPU running MKL/BLIS (FP32, no PCIe copies).
    Cpu,
    /// GPU using ordinary CUDA cores / cuBLAS SGEMM (FP32).
    Gpu,
    /// GPU using tensor cores / cuBLAS HGEMM — the paper's "XPU"
    /// (low-precision multiply, wide accumulate).
    Xpu,
}

impl DeviceKind {
    /// Parse from the config-file token.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "cpu" => Ok(DeviceKind::Cpu),
            "gpu" => Ok(DeviceKind::Gpu),
            "xpu" => Ok(DeviceKind::Xpu),
            other => Err(Error::Config(format!("unknown device kind `{other}`"))),
        }
    }

    /// Canonical config-file token.
    pub fn as_str(&self) -> &'static str {
        match self {
            DeviceKind::Cpu => "cpu",
            DeviceKind::Gpu => "gpu",
            DeviceKind::Xpu => "xpu",
        }
    }

    /// Bytes per element of the device's native GEMM input dtype
    /// (paper §4.5: CPU/GPU work in FP32, XPU in FP16 — our XPU artifact
    /// uses bf16 which is also 2 bytes).
    pub fn dtype_bytes(&self) -> u64 {
        match self {
            DeviceKind::Cpu | DeviceKind::Gpu => 4,
            DeviceKind::Xpu => 2,
        }
    }

    /// AOT artifact family executed for this device class.
    pub fn artifact_kind(&self) -> &'static str {
        match self {
            DeviceKind::Cpu | DeviceKind::Gpu => "f32",
            DeviceKind::Xpu => "bf16",
        }
    }
}

/// Thermal throttling model of a simulated device.
///
/// While a device is busy its clock multiplier decays exponentially from
/// 1.0 toward `1.0 - throttle_frac` with time constant `heat_tau_s`; while
/// idle it recovers toward 1.0 with `cool_tau_s`. This reproduces the
/// paper's §5.2 observation that mach1's poor heat dissipation made
/// profiled frequencies overestimate real-workload frequencies (the
/// "outlier" prediction errors of Table 4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ThermalSpec {
    /// Fraction of throughput lost at full throttle (0 = no throttling).
    pub throttle_frac: f64,
    /// Seconds of sustained load to reach ~63% of full throttle.
    pub heat_tau_s: f64,
    /// Seconds of idle to recover ~63% of the lost clock.
    pub cool_tau_s: f64,
}

impl ThermalSpec {
    /// A device that never throttles (well-cooled server part).
    pub const NONE: ThermalSpec = ThermalSpec {
        throttle_frac: 0.0,
        heat_tau_s: 1.0,
        cool_tau_s: 1.0,
    };
}

/// Full description of one device in a testbed.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    /// Short unique id, e.g. `"xeon"`, `"2080ti-xpu"`.
    pub name: String,
    /// Device class.
    pub kind: DeviceKind,
    /// Marketing / spec-sheet model name (Table 1 row).
    pub model: String,

    // ---- simulator ground truth (hidden from the POAS pipeline) ----
    /// Effective sustained GEMM throughput in Tera-ops/s, where one op is
    /// one multiply-add (the paper's `ops = m*n*k` unit). This is the
    /// *library-achieved* rate, not the spec-sheet peak.
    pub eff_rate_tops: f64,
    /// Fixed per-call overhead (library dispatch, kernel launch) seconds.
    pub launch_overhead_s: f64,
    /// Run-to-run multiplicative throughput noise (std-dev).
    pub noise_sigma: f64,
    /// Thermal throttling behaviour.
    pub thermal: ThermalSpec,
    /// Device memory capacity in GiB (0 = host memory, effectively inf).
    pub mem_gib: f64,
    /// Throughput multiplier applied when a workload's working set
    /// exceeds `mem_gib` and the device must stream/chunk through host
    /// memory (models the paper's standalone-GPU degradation on 30K-sized
    /// inputs that barely fit an 11 GiB card).
    pub oversub_penalty: f64,
    /// Throughput multiplier when XPU inputs violate the tensor-core
    /// alignment restriction (m % 8, k % 8) — cuBLAS falls back to the
    /// non-tensor path (footnote 1 in the paper).
    pub misalign_penalty: f64,
    /// Asymptotic throughput *gain* for very large single GEMM calls
    /// relative to the small cache-fit tiles the profiler measures
    /// (`rate *= 1 + bonus * ops/(ops + knee)`). Models many-core CPUs
    /// whose BLAS is launch/threading-bound on small tiles — this is why
    /// the paper's standalone-EPYC speedup (~36x) is much smaller than
    /// the inverse of its co-execution share (~1/1.1%). 0 = flat curve.
    pub big_gemm_bonus: f64,
    /// Half-saturation point of the bonus curve, in ops.
    pub big_gemm_knee_ops: f64,

    // ---- PCIe link (simulator ground truth; CPU has none) ----
    /// Link bandwidth in GB/s (0 for the CPU — no copies needed).
    pub bus_bw_gbs: f64,
    /// Per-transfer latency in seconds.
    pub bus_latency_s: f64,

    // ---- energy model ----
    /// Idle power draw in watts.
    pub idle_w: f64,
    /// Additional power draw while computing, watts.
    pub active_w: f64,

    // ---- adapt-phase constraints (paper §4.3.2) ----
    /// Required alignment of m and k for full-rate operation (8 for
    /// tensor cores, 1 otherwise).
    pub align: u64,
    /// Largest sub-matrix operation count that stays cache-resident on a
    /// CPU (0 = unconstrained). The profiling menu and the adapt phase
    /// both respect this bound.
    pub cache_fit_ops: f64,

    // ---- profiling menu (paper §5.1.3) ----
    /// Smallest square profiling size.
    pub profile_lo: u64,
    /// Largest square profiling size.
    pub profile_hi: u64,
}

impl DeviceSpec {
    /// Sub-matrix decomposition bounds implied by the profiling menu: the
    /// paper restricts sub-products to the op range covered by profiling.
    pub fn submatrix_ops_range(&self) -> (f64, f64) {
        let lo = self.profile_lo as f64;
        let hi = self.profile_hi as f64;
        (lo * lo * lo, hi * hi * hi)
    }

    /// Basic sanity validation.
    pub fn validate(&self) -> Result<()> {
        if self.eff_rate_tops <= 0.0 {
            return Err(Error::Config(format!(
                "device {}: eff_rate_tops must be > 0",
                self.name
            )));
        }
        if self.kind != DeviceKind::Cpu && self.bus_bw_gbs <= 0.0 {
            return Err(Error::Config(format!(
                "device {}: accelerators need bus_bw_gbs > 0",
                self.name
            )));
        }
        if self.profile_lo == 0 || self.profile_hi < self.profile_lo {
            return Err(Error::Config(format!(
                "device {}: bad profiling range [{}, {}]",
                self.name, self.profile_lo, self.profile_hi
            )));
        }
        if self.align == 0 {
            return Err(Error::Config(format!(
                "device {}: align must be >= 1",
                self.name
            )));
        }
        if !(0.0..=1.0).contains(&self.thermal.throttle_frac) {
            return Err(Error::Config(format!(
                "device {}: throttle_frac must be in [0,1]",
                self.name
            )));
        }
        Ok(())
    }
}

/// A testbed: a named set of devices sharing one host.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineConfig {
    /// Machine id, e.g. `"mach1"`.
    pub name: String,
    /// Devices, CPU first by convention (not required).
    pub devices: Vec<DeviceSpec>,
}

impl MachineConfig {
    /// Validate the whole config.
    pub fn validate(&self) -> Result<()> {
        if self.devices.is_empty() {
            return Err(Error::Config("machine has no devices".into()));
        }
        let mut names = std::collections::HashSet::new();
        for d in &self.devices {
            d.validate()?;
            if !names.insert(d.name.clone()) {
                return Err(Error::Config(format!("duplicate device name {}", d.name)));
            }
        }
        Ok(())
    }

    /// Index of the first device of the given kind.
    pub fn device_of_kind(&self, kind: DeviceKind) -> Option<usize> {
        self.devices.iter().position(|d| d.kind == kind)
    }

    /// Load from a config file in the supported TOML subset.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        parser::parse_machine(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in [DeviceKind::Cpu, DeviceKind::Gpu, DeviceKind::Xpu] {
            assert_eq!(DeviceKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(DeviceKind::parse("tpu").is_err());
    }

    #[test]
    fn dtype_bytes_match_paper() {
        assert_eq!(DeviceKind::Cpu.dtype_bytes(), 4);
        assert_eq!(DeviceKind::Gpu.dtype_bytes(), 4);
        assert_eq!(DeviceKind::Xpu.dtype_bytes(), 2);
    }

    #[test]
    fn artifact_kind_mapping() {
        assert_eq!(DeviceKind::Gpu.artifact_kind(), "f32");
        assert_eq!(DeviceKind::Xpu.artifact_kind(), "bf16");
    }

    #[test]
    fn presets_validate() {
        presets::mach1().validate().unwrap();
        presets::mach2().validate().unwrap();
        presets::pjrt_local().validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_rate() {
        let mut m = presets::mach1();
        m.devices[0].eff_rate_tops = 0.0;
        assert!(m.validate().is_err());
    }

    #[test]
    fn validation_catches_duplicate_names() {
        let mut m = presets::mach1();
        let dup = m.devices[0].clone();
        m.devices.push(dup);
        assert!(m.validate().is_err());
    }

    #[test]
    fn validation_catches_missing_bus() {
        let mut m = presets::mach1();
        let gpu = m.device_of_kind(DeviceKind::Gpu).unwrap();
        m.devices[gpu].bus_bw_gbs = 0.0;
        assert!(m.validate().is_err());
    }

    #[test]
    fn submatrix_ops_range_is_cubic() {
        let m = presets::mach1();
        let cpu = &m.devices[m.device_of_kind(DeviceKind::Cpu).unwrap()];
        let (lo, hi) = cpu.submatrix_ops_range();
        assert_eq!(lo, (cpu.profile_lo as f64).powi(3));
        assert_eq!(hi, (cpu.profile_hi as f64).powi(3));
    }
}
