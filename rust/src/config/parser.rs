//! Minimal TOML-subset parser for machine config files.
//!
//! Supported grammar (enough for testbed descriptions, nothing more):
//!
//! ```toml
//! name = "mach1"
//!
//! [[device]]
//! name = "xeon"
//! kind = "cpu"
//! model = "Intel Xeon E5-2603 v3"
//! eff_rate_tops = 0.109
//! thermal.throttle_frac = 0.0
//! ...
//! ```
//!
//! * top-level `key = value` pairs before the first table header;
//! * `[[device]]` array-of-tables headers;
//! * values: double-quoted strings, integers, floats;
//! * `#` comments and blank lines.
//!
//! A matching [`serialize_machine`] writes configs back out, and the
//! round-trip is property-tested.

use super::{DeviceKind, DeviceSpec, MachineConfig, ThermalSpec};
use crate::error::{Error, Result};

/// One parsed `key = value` with the raw value token. Shared with the
/// scenario parser ([`crate::service::scenario`]), which reads the same
/// TOML subset with its own section headers.
#[derive(Debug, Clone)]
pub(crate) enum Value {
    Str(String),
    Num(f64),
}

impl Value {
    pub(crate) fn as_str(&self, key: &str) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            Value::Num(_) => Err(Error::Config(format!("key `{key}` must be a string"))),
        }
    }

    pub(crate) fn as_f64(&self, key: &str) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            Value::Str(_) => Err(Error::Config(format!("key `{key}` must be a number"))),
        }
    }

    pub(crate) fn as_u64(&self, key: &str) -> Result<u64> {
        let n = self.as_f64(key)?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::Config(format!(
                "key `{key}` must be a non-negative integer, got {n}"
            )));
        }
        Ok(n as u64)
    }
}

fn parse_value(raw: &str, line_no: usize) -> Result<Value> {
    let raw = raw.trim();
    if let Some(stripped) = raw.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| Error::Config(format!("line {line_no}: unterminated string")))?;
        return Ok(Value::Str(inner.to_string()));
    }
    raw.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| Error::Config(format!("line {line_no}: cannot parse value `{raw}`")))
}

/// Key-value map for one section, preserving dotted keys verbatim.
pub(crate) type Section = Vec<(String, Value)>;

pub(crate) fn get<'a>(sec: &'a Section, key: &str) -> Option<&'a Value> {
    sec.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v)
}

pub(crate) fn req<'a>(sec: &'a Section, key: &str, what: &str) -> Result<&'a Value> {
    get(sec, key).ok_or_else(|| Error::Config(format!("{what}: missing key `{key}`")))
}

pub(crate) fn num_or(sec: &Section, key: &str, default: f64) -> Result<f64> {
    match get(sec, key) {
        Some(v) => v.as_f64(key),
        None => Ok(default),
    }
}

/// Split TOML-subset text into its top-level section plus one `(header
/// name, section)` entry per `[[header]]` table, in document order.
/// `headers` names the accepted tables (without brackets); anything
/// else errors. The machine parser below and the scenario parser
/// ([`crate::service::scenario`]) share this splitter, so both dialects
/// get identical comment, string and number handling.
pub(crate) fn split_sections(
    text: &str,
    headers: &[&str],
) -> Result<(Section, Vec<(String, Section)>)> {
    let mut top: Section = Vec::new();
    let mut tables: Vec<(String, Section)> = Vec::new();
    for (i, raw_line) in text.lines().enumerate() {
        let line_no = i + 1;
        let line = match raw_line.find('#') {
            // Only strip comments outside of strings — our values never
            // contain `#`, so a simple check suffices: keep the `#` if
            // it appears inside quotes.
            Some(pos) if raw_line[..pos].matches('"').count() % 2 == 0 => &raw_line[..pos],
            _ => raw_line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line
            .strip_prefix("[[")
            .and_then(|rest| rest.strip_suffix("]]"))
        {
            if !headers.contains(&name) {
                return Err(Error::Config(format!(
                    "line {line_no}: unsupported table header `{line}`"
                )));
            }
            tables.push((name.to_string(), Vec::new()));
            continue;
        }
        if line.starts_with('[') {
            return Err(Error::Config(format!(
                "line {line_no}: unsupported table header `{line}`"
            )));
        }
        let eq = line
            .find('=')
            .ok_or_else(|| Error::Config(format!("line {line_no}: expected `key = value`")))?;
        let key = line[..eq].trim().to_string();
        let value = parse_value(&line[eq + 1..], line_no)?;
        match tables.last_mut() {
            Some((_, sec)) => sec.push((key, value)),
            None => top.push((key, value)),
        }
    }
    Ok((top, tables))
}

fn build_device(sec: &Section) -> Result<DeviceSpec> {
    let name = req(sec, "name", "device")?.as_str("name")?.to_string();
    let what = format!("device {name}");
    let kind = DeviceKind::parse(req(sec, "kind", &what)?.as_str("kind")?)?;
    let is_xpu = kind == DeviceKind::Xpu;
    let is_cpu = kind == DeviceKind::Cpu;
    Ok(DeviceSpec {
        model: match get(sec, "model") {
            Some(v) => v.as_str("model")?.to_string(),
            None => name.clone(),
        },
        eff_rate_tops: req(sec, "eff_rate_tops", &what)?.as_f64("eff_rate_tops")?,
        launch_overhead_s: num_or(sec, "launch_overhead_s", 50e-6)?,
        noise_sigma: num_or(sec, "noise_sigma", 0.02)?,
        thermal: ThermalSpec {
            throttle_frac: num_or(sec, "thermal.throttle_frac", 0.0)?,
            heat_tau_s: num_or(sec, "thermal.heat_tau_s", 20.0)?,
            cool_tau_s: num_or(sec, "thermal.cool_tau_s", 40.0)?,
        },
        mem_gib: num_or(sec, "mem_gib", 0.0)?,
        oversub_penalty: num_or(sec, "oversub_penalty", 1.0)?,
        misalign_penalty: num_or(sec, "misalign_penalty", if is_xpu { 0.55 } else { 1.0 })?,
        big_gemm_bonus: num_or(sec, "big_gemm_bonus", 0.0)?,
        big_gemm_knee_ops: num_or(sec, "big_gemm_knee_ops", 64.0e9)?,
        bus_bw_gbs: num_or(sec, "bus_bw_gbs", 0.0)?,
        bus_latency_s: num_or(sec, "bus_latency_s", 12e-6)?,
        idle_w: num_or(sec, "idle_w", 20.0)?,
        active_w: num_or(sec, "active_w", 150.0)?,
        align: match get(sec, "align") {
            Some(v) => v.as_u64("align")?,
            None => {
                if is_xpu {
                    8
                } else {
                    1
                }
            }
        },
        cache_fit_ops: num_or(sec, "cache_fit_ops", 0.0)?,
        profile_lo: match get(sec, "profile_lo") {
            Some(v) => v.as_u64("profile_lo")?,
            None => {
                if is_cpu {
                    1000
                } else {
                    3000
                }
            }
        },
        profile_hi: match get(sec, "profile_hi") {
            Some(v) => v.as_u64("profile_hi")?,
            None => {
                if is_cpu {
                    2000
                } else {
                    6000
                }
            }
        },
        name,
        kind,
    })
}

/// Parse a machine config from TOML-subset text.
pub fn parse_machine(text: &str) -> Result<MachineConfig> {
    // Two passes: first split the text into sections (top level plus
    // one per `[[device]]` header), then build the structs.
    let (top, tables) = split_sections(text, &["device"])?;
    let name = req(&top, "name", "machine")?.as_str("name")?.to_string();
    let mut devs = Vec::new();
    for (_, sec) in &tables {
        devs.push(build_device(sec)?);
    }
    let machine = MachineConfig {
        name,
        devices: devs,
    };
    machine.validate()?;
    Ok(machine)
}

/// Serialize a machine config in the same TOML subset.
pub fn serialize_machine(m: &MachineConfig) -> String {
    let mut out = String::new();
    out.push_str(&format!("name = \"{}\"\n", m.name));
    for d in &m.devices {
        out.push_str("\n[[device]]\n");
        out.push_str(&format!("name = \"{}\"\n", d.name));
        out.push_str(&format!("kind = \"{}\"\n", d.kind.as_str()));
        out.push_str(&format!("model = \"{}\"\n", d.model));
        out.push_str(&format!("eff_rate_tops = {}\n", d.eff_rate_tops));
        out.push_str(&format!("launch_overhead_s = {}\n", d.launch_overhead_s));
        out.push_str(&format!("noise_sigma = {}\n", d.noise_sigma));
        out.push_str(&format!(
            "thermal.throttle_frac = {}\n",
            d.thermal.throttle_frac
        ));
        out.push_str(&format!("thermal.heat_tau_s = {}\n", d.thermal.heat_tau_s));
        out.push_str(&format!("thermal.cool_tau_s = {}\n", d.thermal.cool_tau_s));
        out.push_str(&format!("mem_gib = {}\n", d.mem_gib));
        out.push_str(&format!("oversub_penalty = {}\n", d.oversub_penalty));
        out.push_str(&format!("misalign_penalty = {}\n", d.misalign_penalty));
        out.push_str(&format!("big_gemm_bonus = {}\n", d.big_gemm_bonus));
        out.push_str(&format!("big_gemm_knee_ops = {}\n", d.big_gemm_knee_ops));
        out.push_str(&format!("bus_bw_gbs = {}\n", d.bus_bw_gbs));
        out.push_str(&format!("bus_latency_s = {}\n", d.bus_latency_s));
        out.push_str(&format!("idle_w = {}\n", d.idle_w));
        out.push_str(&format!("active_w = {}\n", d.active_w));
        out.push_str(&format!("align = {}\n", d.align));
        out.push_str(&format!("cache_fit_ops = {}\n", d.cache_fit_ops));
        out.push_str(&format!("profile_lo = {}\n", d.profile_lo));
        out.push_str(&format!("profile_hi = {}\n", d.profile_hi));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn parse_minimal() {
        let text = r#"
            name = "tiny"
            [[device]]
            name = "c"
            kind = "cpu"
            eff_rate_tops = 0.1
        "#;
        let m = parse_machine(text).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.devices.len(), 1);
        assert_eq!(m.devices[0].kind, DeviceKind::Cpu);
        // defaults applied
        assert_eq!(m.devices[0].profile_lo, 1000);
        assert_eq!(m.devices[0].align, 1);
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let text = "# header\nname = \"t\"  # trailing\n\n[[device]]\nname = \"c\"\nkind = \"cpu\"\neff_rate_tops = 1\n";
        assert!(parse_machine(text).is_ok());
    }

    #[test]
    fn roundtrip_presets() {
        for m in [presets::mach1(), presets::mach2(), presets::pjrt_local()] {
            let text = serialize_machine(&m);
            let parsed = parse_machine(&text).unwrap();
            assert_eq!(parsed, m, "round-trip mismatch for {}", m.name);
        }
    }

    #[test]
    fn missing_required_key_errors() {
        let text = "name = \"t\"\n[[device]]\nname = \"c\"\nkind = \"cpu\"\n";
        let err = parse_machine(text).unwrap_err();
        assert!(err.to_string().contains("eff_rate_tops"));
    }

    #[test]
    fn bad_kind_errors() {
        let text = "name = \"t\"\n[[device]]\nname = \"c\"\nkind = \"dsp\"\neff_rate_tops = 1\n";
        assert!(parse_machine(text).is_err());
    }

    #[test]
    fn type_errors_reported() {
        let text = "name = 5\n";
        assert!(parse_machine(text).is_err());
        let text = "name = \"t\"\n[[device]]\nname = \"c\"\nkind = \"cpu\"\neff_rate_tops = \"fast\"\n";
        assert!(parse_machine(text).is_err());
    }

    #[test]
    fn unsupported_header_errors() {
        let text = "name = \"t\"\n[device]\n";
        assert!(parse_machine(text).is_err());
    }

    #[test]
    fn unterminated_string_errors() {
        assert!(parse_machine("name = \"t\n").is_err());
    }

    #[test]
    fn last_duplicate_key_wins() {
        let text = "name = \"a\"\nname = \"b\"\n[[device]]\nname = \"c\"\nkind = \"cpu\"\neff_rate_tops = 1\n";
        assert_eq!(parse_machine(text).unwrap().name, "b");
    }

    #[test]
    fn integer_fields_reject_fractions() {
        let text = "name = \"t\"\n[[device]]\nname = \"c\"\nkind = \"cpu\"\neff_rate_tops = 1\nalign = 1.5\n";
        assert!(parse_machine(text).is_err());
    }
}
