//! The Fig. 2 communication scheme: predicted bus/compute timeline.
//!
//! Given a plan and the fitted model, reconstruct the schedule the
//! priority bus produces: A and B copies in descending priority, compute
//! per device, C copies back in the order devices finish (priority order
//! by construction). Used by the `fig2_bus_trace` regenerator and by
//! diagnostics that compare predicted against simulated timelines.

use super::plan::SchedulePlan;
use crate::config::DeviceKind;
use crate::predict::PerfModel;

/// What a timeline entry describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhaseKind {
    /// A+B host-to-device copy.
    CopyIn,
    /// Device compute.
    Compute,
    /// C device-to-host copy.
    CopyOut,
}

impl std::fmt::Display for PhaseKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PhaseKind::CopyIn => write!(f, "copy A,B"),
            PhaseKind::Compute => write!(f, "compute"),
            PhaseKind::CopyOut => write!(f, "copy C"),
        }
    }
}

/// One predicted interval.
#[derive(Debug, Clone)]
pub struct TimelineEntry {
    pub device: usize,
    pub phase: PhaseKind,
    pub start: f64,
    pub end: f64,
}

/// Predict the per-repetition timeline of a plan under the Fig. 2
/// priority scheme. Pure model arithmetic — no simulator access.
pub fn predicted_timeline(plan: &SchedulePlan, model: &PerfModel) -> Vec<TimelineEntry> {
    let mut entries = Vec::new();
    // Active accelerators in descending priority; CPU computes alongside.
    let mut accels: Vec<usize> = plan
        .assignments
        .iter()
        .filter(|a| a.rows > 0 && model.devices[a.device].kind != DeviceKind::Cpu)
        .map(|a| a.device)
        .collect();
    accels.sort_by_key(|&d| std::cmp::Reverse(plan.priorities[d]));

    let input = model.model_inputs();

    // Phase 1: serialized H2D in priority order.
    let mut bus_t = 0.0f64;
    let mut compute_start = vec![0.0f64; plan.assignments.len()];
    for &d in &accels {
        let a = &plan.assignments[d];
        let ops = a.slice.ops();
        let h2d = input[d].h2d_time(ops, plan.size);
        entries.push(TimelineEntry {
            device: d,
            phase: PhaseKind::CopyIn,
            start: bus_t,
            end: bus_t + h2d,
        });
        bus_t += h2d;
        compute_start[d] = bus_t;
    }

    // Phase 2: compute (CPU from t=0, accelerators after their copy).
    let mut compute_end = vec![0.0f64; plan.assignments.len()];
    for a in &plan.assignments {
        if a.rows == 0 {
            continue;
        }
        let d = a.device;
        let start = compute_start[d];
        let dur = model.devices[d].predict_compute(a.slice);
        entries.push(TimelineEntry {
            device: d,
            phase: PhaseKind::Compute,
            start,
            end: start + dur,
        });
        compute_end[d] = start + dur;
    }

    // Phase 3: serialized D2H, priority order, each after its compute.
    let mut bus_t = 0.0f64;
    for &d in &accels {
        let a = &plan.assignments[d];
        let ops = a.slice.ops();
        let d2h = input[d].d2h_time(ops, plan.size);
        let start = compute_end[d].max(bus_t);
        entries.push(TimelineEntry {
            device: d,
            phase: PhaseKind::CopyOut,
            start,
            end: start + d2h,
        });
        bus_t = start + d2h;
    }

    entries
}

/// Render a timeline as an ASCII Gantt chart (Fig. 2 style).
pub fn render_ascii(
    entries: &[TimelineEntry],
    device_names: &[String],
    width: usize,
) -> String {
    let t_max = entries.iter().map(|e| e.end).fold(0.0, f64::max);
    if t_max <= 0.0 {
        return String::new();
    }
    let mut out = String::new();
    let col = |t: f64| ((t / t_max) * (width as f64 - 1.0)).round() as usize;
    for (d, name) in device_names.iter().enumerate() {
        let mut row = vec![' '; width];
        for e in entries.iter().filter(|e| e.device == d) {
            let (s, en) = (col(e.start), col(e.end).max(col(e.start) + 1));
            let ch = match e.phase {
                PhaseKind::CopyIn => '<',
                PhaseKind::Compute => '#',
                PhaseKind::CopyOut => '>',
            };
            for c in row.iter_mut().take(en.min(width)).skip(s) {
                *c = ch;
            }
        }
        out.push_str(&format!("{name:>12} |{}|\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!(
        "{:>12}  0{:>w$.3}s   (< copy-in, # compute, > copy-out)\n",
        "t",
        t_max,
        w = width - 1
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::predict::{profile, ProfileOptions};
    use crate::schedule::static_sched::{build_plan, rules_from_config, PlanOptions};
    use crate::sim::SimMachine;
    use crate::workload::GemmSize;

    fn plan_and_model() -> (SchedulePlan, PerfModel) {
        let cfg = presets::mach1();
        let mut sim = SimMachine::new(&cfg, 0);
        let model = profile(&mut sim, &ProfileOptions::default()).unwrap();
        let plan = build_plan(
            &model,
            GemmSize::square(30_000),
            &rules_from_config(&cfg),
            &PlanOptions::default(),
        )
        .unwrap();
        (plan, model)
    }

    #[test]
    fn copyins_serialized_priority_first() {
        let (plan, model) = plan_and_model();
        let tl = predicted_timeline(&plan, &model);
        let copyins: Vec<_> = tl
            .iter()
            .filter(|e| e.phase == PhaseKind::CopyIn)
            .collect();
        assert_eq!(copyins.len(), 2);
        // XPU (higher priority) first.
        assert_eq!(copyins[0].device, 2);
        assert!(copyins[0].end <= copyins[1].start + 1e-12);
    }

    #[test]
    fn cpu_computes_from_time_zero() {
        let (plan, model) = plan_and_model();
        let tl = predicted_timeline(&plan, &model);
        let cpu = tl
            .iter()
            .find(|e| e.device == 0 && e.phase == PhaseKind::Compute)
            .unwrap();
        assert_eq!(cpu.start, 0.0);
    }

    #[test]
    fn compute_follows_copyin() {
        let (plan, model) = plan_and_model();
        let tl = predicted_timeline(&plan, &model);
        for d in [1usize, 2] {
            let ci = tl
                .iter()
                .find(|e| e.device == d && e.phase == PhaseKind::CopyIn)
                .unwrap();
            let co = tl
                .iter()
                .find(|e| e.device == d && e.phase == PhaseKind::Compute)
                .unwrap();
            assert!(co.start >= ci.end - 1e-12);
        }
    }

    #[test]
    fn copyouts_do_not_overlap() {
        let (plan, model) = plan_and_model();
        let tl = predicted_timeline(&plan, &model);
        let outs: Vec<_> = tl
            .iter()
            .filter(|e| e.phase == PhaseKind::CopyOut)
            .collect();
        for w in outs.windows(2) {
            assert!(w[0].end <= w[1].start + 1e-12);
        }
    }

    #[test]
    fn ascii_render_contains_all_devices() {
        let (plan, model) = plan_and_model();
        let tl = predicted_timeline(&plan, &model);
        let names: Vec<String> = model.devices.iter().map(|d| d.name.clone()).collect();
        let art = render_ascii(&tl, &names, 60);
        for n in &names {
            assert!(art.contains(n.as_str()));
        }
        assert!(art.contains('#'));
        assert!(art.contains('<'));
        assert!(art.contains('>'));
    }
}
