//! The schedule plan: what each device runs and in what bus order.

use crate::adapt::DeviceAssignment;
use crate::optimize::SplitSolution;
use crate::sim::{WorkItem, WorkOrder};
use crate::workload::GemmSize;

/// A complete, executable schedule for one GEMM workload.
#[derive(Debug, Clone)]
pub struct SchedulePlan {
    /// The global problem.
    pub size: GemmSize,
    /// Per-device assignments from the Adapt phase (machine order).
    pub assignments: Vec<DeviceAssignment>,
    /// Bus priority per device (machine order; paper: fastest first).
    pub priorities: Vec<u32>,
    /// The optimizer's predictions behind this plan.
    pub predicted: SplitSolution,
}

impl SchedulePlan {
    /// Convert into the simulator's work order for `reps` repetitions.
    /// Devices with zero rows are omitted.
    pub fn to_work_order(&self, reps: u32) -> WorkOrder {
        let items = self
            .assignments
            .iter()
            .filter(|a| a.rows > 0)
            .map(|a| WorkItem {
                device: a.device,
                slice: a.slice,
                subproducts: a.subproducts.clone(),
                priority: self.priorities[a.device],
            })
            .collect();
        WorkOrder { items, reps }
    }

    /// Work share per device (fraction of ops), machine order.
    pub fn shares(&self) -> Vec<f64> {
        let total: f64 = self
            .assignments
            .iter()
            .map(|a| a.rows as f64)
            .sum::<f64>()
            .max(1.0);
        self.assignments
            .iter()
            .map(|a| a.rows as f64 / total)
            .collect()
    }

    /// Predicted makespan per repetition, seconds.
    pub fn predicted_makespan(&self) -> f64 {
        self.predicted.t_pred
    }

    /// Number of devices actually used.
    pub fn active_devices(&self) -> usize {
        self.assignments.iter().filter(|a| a.rows > 0).count()
    }

    /// Devices with a non-empty assignment (machine order).
    pub fn active_device_indices(&self) -> Vec<usize> {
        self.assignments
            .iter()
            .filter(|a| a.rows > 0)
            .map(|a| a.device)
            .collect()
    }

    /// True when two plans describe the same executable schedule: same
    /// problem, per-device rows/offsets/sub-products and bus priorities,
    /// and bit-identical predictions. Plan construction is deterministic,
    /// so a cached plan must satisfy this against a fresh solve — the
    /// `PlanCache` property tests assert exactly that.
    pub fn same_split(&self, other: &SchedulePlan) -> bool {
        self.size == other.size
            && self.priorities == other.priorities
            && self.assignments.len() == other.assignments.len()
            && self
                .assignments
                .iter()
                .zip(&other.assignments)
                .all(|(a, b)| {
                    a.device == b.device
                        && a.rows == b.rows
                        && a.row_offset == b.row_offset
                        && a.subproducts == b.subproducts
                })
            && self.predicted.t_pred == other.predicted.t_pred
            && self.predicted.ops == other.predicted.ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapt::DeviceAssignment;

    fn plan() -> SchedulePlan {
        let size = GemmSize::new(100, 50, 40);
        let mk = |device, rows, row_offset| DeviceAssignment {
            device,
            rows,
            row_offset,
            slice: GemmSize::new(rows.max(1), 50, 40),
            subproducts: if rows > 0 {
                vec![GemmSize::new(rows, 50, 40)]
            } else {
                vec![]
            },
            squareness: 1.0,
        };
        SchedulePlan {
            size,
            assignments: vec![mk(0, 10, 0), mk(1, 0, 10), mk(2, 90, 10)],
            priorities: vec![0, 1, 2],
            predicted: SplitSolution {
                ops: vec![10.0 * 50.0 * 40.0, 0.0, 90.0 * 50.0 * 40.0],
                t_pred: 0.5,
                compute_pred: vec![0.5, 0.0, 0.5],
                copy_pred: vec![0.0, 0.0, 0.1],
            },
        }
    }

    #[test]
    fn work_order_skips_empty_devices() {
        let wo = plan().to_work_order(3);
        assert_eq!(wo.items.len(), 2);
        assert_eq!(wo.reps, 3);
        assert_eq!(wo.items[1].priority, 2);
    }

    #[test]
    fn shares_sum_to_one() {
        let s = plan().shares();
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(s[1], 0.0);
    }

    #[test]
    fn active_devices_counted() {
        assert_eq!(plan().active_devices(), 2);
        assert_eq!(plan().active_device_indices(), vec![0, 2]);
    }

    #[test]
    fn same_split_detects_differences() {
        let a = plan();
        let mut b = plan();
        assert!(a.same_split(&b));
        b.predicted.t_pred += 1e-9;
        assert!(!a.same_split(&b));
        let mut c = plan();
        c.assignments[0].rows += 1;
        assert!(!a.same_split(&c));
    }
}
