//! Co-execution suitability detection (paper §6, future work).
//!
//! "The POAS framework can detect when running a certain workload is
//! beneficial for co-execution or not depending on the amount of work to
//! do ... when the workload size is known (after the DS-POAS was
//! designed)." This module implements exactly that hook: with the fitted
//! model in hand, compare the *predicted* co-execution makespan against
//! the *predicted* best standalone device, fold in the scheduling
//! overhead, and recommend a mode. Small GEMMs (where B's copy time or
//! launch overheads dominate) correctly fall back to a single device.

use crate::optimize::problem::{BusModel, DeviceModelInput, SplitProblem};
use crate::predict::PerfModel;
use crate::workload::GemmSize;

/// The detector's recommendation.
#[derive(Debug, Clone, PartialEq)]
pub enum Recommendation {
    /// Co-execute with the predicted split (expected gain stated).
    CoExecute {
        /// Predicted makespan of the co-execution (s/rep).
        t_coexec: f64,
        /// Predicted makespan of the best single device (s/rep).
        t_best_single: f64,
        /// Which device would be the best single runner.
        best_device: usize,
        /// Predicted speedup (>= the threshold).
        gain: f64,
    },
    /// Run on one device; co-execution would not pay.
    Standalone {
        /// The device to use.
        device: usize,
        /// Predicted makespan on it (s/rep).
        t_single: f64,
        /// Predicted co-execution makespan that lost.
        t_coexec: f64,
    },
}

impl Recommendation {
    /// True if co-execution is advised.
    pub fn co_execute(&self) -> bool {
        matches!(self, Recommendation::CoExecute { .. })
    }
}

/// Predicted standalone time of the full workload on one device
/// (compute + its own copies — no bus contention when running alone).
pub fn predicted_standalone(dev: &DeviceModelInput, size: GemmSize) -> f64 {
    dev.compute_time(size.ops()) + dev.copy_time(size.ops(), size)
}

/// Decide whether `size` is worth co-executing under `model`.
///
/// `min_gain` is the required predicted speedup over the best single
/// device (e.g. 1.05 = demand at least 5%); the comparison also charges
/// the co-execution side `overhead_s` (planning + extra orchestration,
/// measured at ~15 µs by `perf_hotpath` — essentially free, but the
/// parameter keeps the trade-off explicit).
pub fn recommend(
    model: &PerfModel,
    size: GemmSize,
    min_gain: f64,
    overhead_s: f64,
) -> Recommendation {
    let inputs = model.model_inputs();
    let (best_device, t_best_single) = inputs
        .iter()
        .enumerate()
        .map(|(i, d)| (i, predicted_standalone(d, size)))
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .expect("model has devices");

    let t_coexec = SplitProblem {
        devices: inputs,
        size,
        bus: BusModel::SharedPriority,
        row_integral: false,
    }
    .solve()
    .map(|s| s.t_pred)
    .unwrap_or(f64::INFINITY)
        + overhead_s;

    let gain = t_best_single / t_coexec;
    if gain >= min_gain {
        Recommendation::CoExecute {
            t_coexec,
            t_best_single,
            best_device,
            gain,
        }
    } else {
        Recommendation::Standalone {
            device: best_device,
            t_single: t_best_single,
            t_coexec,
        }
    }
}

/// Binary-search the smallest square size (to `tol` relative precision)
/// for which co-execution is recommended — the "crossover point" a
/// DS-POAS designer would document for their domain.
pub fn coexec_crossover(model: &PerfModel, min_gain: f64, overhead_s: f64) -> u64 {
    let worth = |s: u64| recommend(model, GemmSize::square(s), min_gain, overhead_s).co_execute();
    // Bracket.
    let mut hi = 64u64;
    while !worth(hi) {
        hi *= 2;
        if hi > 1 << 22 {
            return hi; // never worth it at sane sizes
        }
    }
    let mut lo = hi / 2;
    while hi - lo > (lo / 64).max(1) {
        let mid = lo + (hi - lo) / 2;
        if worth(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    hi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::predict::{profile, ProfileOptions};
    use crate::sim::SimMachine;

    fn model() -> PerfModel {
        let mut sim = SimMachine::new(&presets::mach1(), 0);
        profile(&mut sim, &ProfileOptions::default()).unwrap()
    }

    #[test]
    fn big_gemm_is_worth_coexecuting() {
        let m = model();
        let rec = recommend(&m, GemmSize::square(30_000), 1.05, 20e-6);
        assert!(rec.co_execute(), "{rec:?}");
        if let Recommendation::CoExecute {
            gain, best_device, ..
        } = rec
        {
            assert!(gain > 1.05 && gain < 2.0, "gain {gain}");
            assert_eq!(best_device, 2, "XPU is the best single device");
        }
    }

    #[test]
    fn tiny_gemm_stays_standalone() {
        let m = model();
        // 256^3: B copy + launch overheads dwarf any parallel gain.
        let rec = recommend(&m, GemmSize::square(256), 1.05, 20e-6);
        assert!(!rec.co_execute(), "{rec:?}");
    }

    #[test]
    fn crossover_is_between_tiny_and_huge() {
        let m = model();
        let s = coexec_crossover(&m, 1.05, 20e-6);
        assert!(s > 256, "crossover {s} suspiciously small");
        assert!(s < 30_000, "crossover {s} suspiciously large");
        // Consistency: below says no, above says yes.
        assert!(!recommend(&m, GemmSize::square(s / 2), 1.05, 20e-6).co_execute());
        assert!(recommend(&m, GemmSize::square(s * 2), 1.05, 20e-6).co_execute());
    }

    #[test]
    fn higher_threshold_raises_crossover() {
        let m = model();
        let low = coexec_crossover(&m, 1.02, 20e-6);
        let high = coexec_crossover(&m, 1.15, 20e-6);
        assert!(high >= low, "low {low} high {high}");
    }

    #[test]
    fn best_single_device_is_fastest_overall() {
        let m = model();
        let size = GemmSize::square(10_000);
        let inputs = m.model_inputs();
        let t_xpu = predicted_standalone(&inputs[2], size);
        let t_gpu = predicted_standalone(&inputs[1], size);
        assert!(t_xpu < t_gpu);
    }
}
