//! The Schedule phase (paper §3.4, §4.4).
//!
//! * [`plan`] — the [`SchedulePlan`]: per-device work items with bus
//!   priorities, plus the predictions they were derived from;
//! * [`static_sched`] — the paper's static scheduler: predict → optimize
//!   → adapt once, then execute unchanged (chosen for hgemms, §4.4);
//! * [`dynamic`] — the dynamic scheduler of §3.4.2: keeps measuring
//!   real executions and refreshes the performance model (EWMA on the
//!   observed rates), re-running the pipeline when the model drifts;
//! * [`comm`] — the Fig. 2 communication scheme: the predicted
//!   priority-ordered bus timeline for a plan;
//! * [`suitability`] — the §6 future-work hook: decide whether a
//!   workload is worth co-executing at all, and find the crossover size.

pub mod comm;
pub mod dynamic;
pub mod plan;
pub mod static_sched;
pub mod suitability;

pub use comm::{predicted_timeline, PhaseKind, TimelineEntry};
pub use dynamic::DynamicScheduler;
pub use plan::SchedulePlan;
pub use static_sched::{build_plan, build_plan_excluding, PlanOptions};
pub use suitability::{coexec_crossover, recommend, Recommendation};
