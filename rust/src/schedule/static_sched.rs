//! Static scheduling: run the POAS pipeline once per workload (§3.4.1).
//!
//! `build_plan` is the complete Predict→Optimize→Adapt composition: it
//! takes the fitted [`PerfModel`], formulates and solves the split
//! MILP, maps ops to matrix rows, and returns an executable
//! [`SchedulePlan`]. The paper uses exactly this mode for hgemms ("we
//! used a static scheduling, as we found that gives excellent results
//! for our case study", §4.4).

use super::plan::SchedulePlan;
use crate::adapt::{ops_to_mnk, AdaptOptions, AdaptRules};
use crate::error::{Error, Result};
use crate::optimize::problem::{BusModel, SplitProblem};
use crate::optimize::SplitSolution;
use crate::predict::PerfModel;
use crate::workload::GemmSize;

/// Options controlling plan construction (defaults = the paper's setup).
#[derive(Debug, Clone)]
pub struct PlanOptions {
    /// Bus model in the optimizer formulation.
    pub bus: BusModel,
    /// Constrain the split to whole C rows (MILP). The relaxation is
    /// near-integral, so this mainly matters for small/skewed problems.
    pub row_integral: bool,
    /// Adapt-phase switches (square decomposition, alignment).
    pub adapt: AdaptOptions,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions {
            bus: BusModel::SharedPriority,
            row_integral: false,
            adapt: AdaptOptions::default(),
        }
    }
}

/// Build a static schedule for `size` from a fitted model.
///
/// `rules` carries the per-device adapt constraints (alignment, profiled
/// op range) in machine order.
pub fn build_plan(
    model: &PerfModel,
    size: GemmSize,
    rules: &[AdaptRules],
    opts: &PlanOptions,
) -> Result<SchedulePlan> {
    build_plan_excluding(model, size, rules, opts, &[])
}

/// [`build_plan`], but with `excluded` devices left out of the split
/// problem entirely: they are guaranteed zero ops (and zero rows), so
/// the resulting work order leaves them idle. The service layer's
/// standalone bypass plans around its host device this way.
///
/// Assignments and predictions come back in full machine order;
/// excluded devices carry empty assignments and zeroed predictions.
pub fn build_plan_excluding(
    model: &PerfModel,
    size: GemmSize,
    rules: &[AdaptRules],
    opts: &PlanOptions,
    excluded: &[usize],
) -> Result<SchedulePlan> {
    let n = model.devices.len();
    let keep: Vec<usize> = (0..n).filter(|i| !excluded.contains(i)).collect();
    if keep.is_empty() {
        return Err(Error::Infeasible(
            "every device excluded from the split problem".into(),
        ));
    }
    let inputs = model.model_inputs();

    // ---- Optimize: split ops across the kept devices (Eq. 1-4).
    let problem = SplitProblem {
        devices: keep.iter().map(|&i| inputs[i].clone()).collect(),
        size,
        bus: opts.bus,
        row_integral: opts.row_integral,
    };
    let sub = problem.solve()?;

    // Re-expand the solution to machine order (zeros for excluded).
    let mut ops = vec![0.0; n];
    let mut compute_pred = vec![0.0; n];
    let mut copy_pred = vec![0.0; n];
    for (j, &i) in keep.iter().enumerate() {
        ops[i] = sub.ops[j];
        compute_pred[i] = sub.compute_pred[j];
        copy_pred[i] = sub.copy_pred[j];
    }
    let split = SplitSolution {
        ops,
        t_pred: sub.t_pred,
        compute_pred,
        copy_pred,
    };

    // ---- Adapt: ops -> rows -> square sub-products.
    let priorities: Vec<u32> = model.devices.iter().map(|d| d.priority).collect();
    let assignments = ops_to_mnk(&split, size, rules, &priorities, &opts.adapt)?;

    Ok(SchedulePlan {
        size,
        assignments,
        priorities,
        predicted: split,
    })
}

/// Derive the adapt rules from a machine config (datasheet constraints:
/// alignment and profiled ranges — public information, not hidden
/// simulator state).
pub fn rules_from_config(cfg: &crate::config::MachineConfig) -> Vec<AdaptRules> {
    cfg.devices
        .iter()
        .map(|d| {
            let (lo, hi) = d.submatrix_ops_range();
            AdaptRules {
                align: d.align,
                ops_lo: lo,
                ops_hi: hi,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapt::assignments_cover;
    use crate::config::presets;
    use crate::predict::{profile, ProfileOptions};
    use crate::sim::SimMachine;

    fn mach1_plan(size: GemmSize) -> SchedulePlan {
        let cfg = presets::mach1();
        let mut sim = SimMachine::new(&cfg, 0);
        let model = profile(&mut sim, &ProfileOptions::default()).unwrap();
        build_plan(
            &model,
            size,
            &rules_from_config(&cfg),
            &PlanOptions::default(),
        )
        .unwrap()
    }

    #[test]
    fn plan_covers_problem() {
        let size = GemmSize::square(30_000);
        let plan = mach1_plan(size);
        assert!(assignments_cover(&plan.assignments, size));
    }

    #[test]
    fn plan_shares_match_paper_shape() {
        // Table 6 mach1 i1: CPU ~0.3%, GPU ~21%, XPU ~78%.
        let plan = mach1_plan(GemmSize::square(30_000));
        let s = plan.shares();
        assert!(s[0] < 0.02, "cpu {}", s[0]);
        assert!(s[1] > 0.10 && s[1] < 0.35, "gpu {}", s[1]);
        assert!(s[2] > 0.60 && s[2] < 0.90, "xpu {}", s[2]);
    }

    #[test]
    fn xpu_rows_aligned() {
        let plan = mach1_plan(GemmSize::square(30_000));
        assert_eq!(plan.assignments[2].rows % 8, 0);
    }

    #[test]
    fn predicted_makespan_positive_and_sane() {
        let size = GemmSize::square(30_000);
        let plan = mach1_plan(size);
        // All-XPU lower bound: N / rate_xpu.
        let lower = size.ops() / (21.5e12 * 1.2);
        assert!(plan.predicted_makespan() > lower);
        assert!(plan.predicted_makespan() < 10.0 * lower);
    }

    #[test]
    fn row_integral_plans_also_cover() {
        let cfg = presets::mach1();
        let mut sim = SimMachine::new(&cfg, 1);
        let model = profile(&mut sim, &ProfileOptions::default()).unwrap();
        let size = GemmSize::new(4000, 2000, 1600);
        let plan = build_plan(
            &model,
            size,
            &rules_from_config(&cfg),
            &PlanOptions {
                row_integral: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(assignments_cover(&plan.assignments, size));
    }

    #[test]
    fn excluding_a_device_zeroes_it_and_still_covers() {
        let cfg = presets::mach1();
        let mut sim = SimMachine::new(&cfg, 2);
        let model = profile(&mut sim, &ProfileOptions::default()).unwrap();
        let size = GemmSize::square(30_000);
        let plan = build_plan_excluding(
            &model,
            size,
            &rules_from_config(&cfg),
            &PlanOptions::default(),
            &[0], // exclude the CPU
        )
        .unwrap();
        assert_eq!(plan.assignments[0].rows, 0);
        assert_eq!(plan.predicted.ops[0], 0.0);
        assert!(assignments_cover(&plan.assignments, size));
        assert_eq!(plan.active_devices(), 2);
        // Excluding everything is infeasible.
        assert!(build_plan_excluding(
            &model,
            size,
            &rules_from_config(&cfg),
            &PlanOptions::default(),
            &[0, 1, 2],
        )
        .is_err());
    }

    #[test]
    fn rules_from_config_respects_spec() {
        let cfg = presets::mach1();
        let rules = rules_from_config(&cfg);
        assert_eq!(rules[2].align, 8);
        assert_eq!(rules[0].ops_hi, 8e9);
    }
}
