//! Dynamic scheduling (paper §3.4.2).
//!
//! The static scheduler assumes profiled performance holds during real
//! workloads. When it does not (thermal throttling, contention,
//! hot-plugged devices), the dynamic scheduler closes the loop: after
//! every execution it compares *observed* per-device rates against the
//! model, blends them in with an EWMA ("constantly measuring the
//! execution time of the application and adapting the performance model
//! over certain periods"), and rebuilds the plan when the drift exceeds
//! a threshold.

use super::plan::SchedulePlan;
use super::static_sched::{build_plan, PlanOptions};
use crate::adapt::AdaptRules;
use crate::error::Result;
use crate::predict::PerfModel;
use crate::sim::ExecOutcome;
use crate::workload::GemmSize;

/// Closed-loop scheduler state.
#[derive(Debug, Clone)]
pub struct DynamicScheduler {
    /// The live performance model (starts as the profiled one).
    pub model: PerfModel,
    /// EWMA blend factor for observed rates (0 = ignore observations,
    /// 1 = replace model each step). Paper leaves the granularity open;
    /// 0.5 converges in a few iterations without oscillating.
    pub alpha: f64,
    /// Relative rate drift that triggers a re-plan.
    pub replan_threshold: f64,
    /// Count of re-plans performed (diagnostics).
    pub replans: usize,
}

impl DynamicScheduler {
    /// Start from a profiled model.
    pub fn new(model: PerfModel) -> Self {
        DynamicScheduler {
            model,
            alpha: 0.5,
            replan_threshold: 0.02,
            replans: 0,
        }
    }

    /// Build the initial (or refreshed) plan.
    pub fn plan(
        &self,
        size: GemmSize,
        rules: &[AdaptRules],
        opts: &PlanOptions,
    ) -> Result<SchedulePlan> {
        build_plan(&self.model, size, rules, opts)
    }

    /// Feed back one execution. Returns `true` if the model drifted
    /// enough that the caller should re-plan.
    ///
    /// Observation model: device `i` computed `ops_i` ops in
    /// `compute_s_i` measured seconds, so its observed slope is
    /// `compute_s_i / ops_i` (the intercept is negligible at workload
    /// sizes). The EWMA blends slopes, not rates, because the LP
    /// consumes slopes.
    pub fn observe(&mut self, plan: &SchedulePlan, outcome: &ExecOutcome, reps: u32) -> bool {
        let mut max_drift: f64 = 0.0;
        for a in &plan.assignments {
            if a.rows == 0 {
                continue;
            }
            let ops = a.slice.ops() * reps.max(1) as f64;
            let tl = &outcome.timelines[a.device];
            if tl.compute_s <= 0.0 || ops <= 0.0 {
                continue;
            }
            let observed_a = tl.compute_s / ops;
            let dev = &mut self.model.devices[a.device];
            let drift = (observed_a - dev.a).abs() / dev.a;
            max_drift = max_drift.max(drift);
            dev.a = (1.0 - self.alpha) * dev.a + self.alpha * observed_a;
        }
        // Speeds may have reordered: refresh priorities.
        self.model.assign_priorities();
        let replan = max_drift > self.replan_threshold;
        if replan {
            self.replans += 1;
        }
        replan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::predict::{profile, ProfileOptions};
    use crate::schedule::static_sched::rules_from_config;
    use crate::sim::SimMachine;
    use crate::workload::GemmSize;

    fn setup() -> (SimMachine, DynamicScheduler, Vec<AdaptRules>) {
        let cfg = presets::mach1();
        let mut sim = SimMachine::new(&cfg, 0);
        let model = profile(&mut sim, &ProfileOptions::default()).unwrap();
        let rules = rules_from_config(&cfg);
        (sim, DynamicScheduler::new(model), rules)
    }

    #[test]
    fn observe_converges_toward_truth() {
        let (mut sim, mut dyn_sched, rules) = setup();
        let size = GemmSize::square(30_000);
        let opts = PlanOptions::default();
        // Thermal throttling makes sustained rates ~10% below profiled on
        // mach1; after a few observe/replan cycles the model's XPU slope
        // should have moved toward the sustained (slower) truth.
        let a0 = dyn_sched.model.devices[2].a;
        for _ in 0..4 {
            let plan = dyn_sched.plan(size, &rules, &opts).unwrap();
            let outcome = sim.execute(&plan.to_work_order(50));
            dyn_sched.observe(&plan, &outcome, 50);
        }
        let a1 = dyn_sched.model.devices[2].a;
        assert!(a1 > a0, "slope should grow (device slower when hot)");
        let slowdown = a1 / a0;
        assert!(slowdown < 1.25, "unreasonable drift {slowdown}");
    }

    #[test]
    fn drift_triggers_replan_flag() {
        let (mut sim, mut dyn_sched, rules) = setup();
        let size = GemmSize::square(30_000);
        let plan = dyn_sched.plan(size, &rules, &PlanOptions::default()).unwrap();
        let outcome = sim.execute(&plan.to_work_order(50));
        let replan = dyn_sched.observe(&plan, &outcome, 50);
        // mach1's throttling (11%) is well past the 2% threshold.
        assert!(replan);
        assert_eq!(dyn_sched.replans, 1);
    }

    #[test]
    fn dynamic_beats_static_under_drift() {
        // Run 5 consecutive 50-rep workloads. The static plan keeps the
        // cold-profile split; the dynamic scheduler rebalances toward the
        // observed hot rates. Dynamic must not be slower overall.
        let size = GemmSize::square(30_000);

        let (mut sim_s, dyn0, rules) = setup();
        let static_plan = dyn0.plan(size, &rules, &PlanOptions::default()).unwrap();
        let mut static_total = 0.0;
        for _ in 0..5 {
            static_total += sim_s.execute(&static_plan.to_work_order(50)).makespan;
        }

        let (mut sim_d, mut dyn_sched, rules) = setup();
        let mut dynamic_total = 0.0;
        let mut plan = dyn_sched.plan(size, &rules, &PlanOptions::default()).unwrap();
        for _ in 0..5 {
            let outcome = sim_d.execute(&plan.to_work_order(50));
            dynamic_total += outcome.makespan;
            if dyn_sched.observe(&plan, &outcome, 50) {
                plan = dyn_sched.plan(size, &rules, &PlanOptions::default()).unwrap();
            }
        }
        assert!(
            dynamic_total <= static_total * 1.02,
            "dynamic {dynamic_total} vs static {static_total}"
        );
    }

    #[test]
    fn zero_work_devices_ignored() {
        let (mut sim, mut dyn_sched, rules) = setup();
        let size = GemmSize::square(30_000);
        let plan = dyn_sched.plan(size, &rules, &PlanOptions::default()).unwrap();
        let outcome = sim.execute(&plan.to_work_order(10));
        let cpu_a_before = dyn_sched.model.devices[0].a;
        dyn_sched.observe(&plan, &outcome, 10);
        // CPU had (tiny but nonzero) work — its slope may move; devices
        // with zero compute time must not corrupt the model with NaNs.
        for d in &dyn_sched.model.devices {
            assert!(d.a.is_finite() && d.a > 0.0);
        }
        let _ = cpu_a_before;
    }
}
