//! Dense two-phase primal simplex.
//!
//! Solves `min c·x  s.t.  A x {<=,>=,=} b,  x >= 0` — the LP relaxations
//! behind the POAS split problem. Problems here are tiny (a handful of
//! devices + one epigraph variable), so a dense tableau with Bland's
//! anti-cycling rule is the right tool: simple, exact enough, and easy
//! to verify.
//!
//! Phase 1 minimizes the sum of artificial variables to find a basic
//! feasible point; phase 2 re-optimizes the true objective from there.

use crate::error::{Error, Result};

/// Constraint relation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Relation {
    /// `coeffs . x <= rhs`
    Le,
    /// `coeffs . x >= rhs`
    Ge,
    /// `coeffs . x == rhs`
    Eq,
}

/// One linear constraint.
#[derive(Debug, Clone)]
pub struct Constraint {
    pub coeffs: Vec<f64>,
    pub op: Relation,
    pub rhs: f64,
}

impl Constraint {
    pub fn le(coeffs: Vec<f64>, rhs: f64) -> Self {
        Constraint {
            coeffs,
            op: Relation::Le,
            rhs,
        }
    }

    pub fn ge(coeffs: Vec<f64>, rhs: f64) -> Self {
        Constraint {
            coeffs,
            op: Relation::Ge,
            rhs,
        }
    }

    pub fn eq(coeffs: Vec<f64>, rhs: f64) -> Self {
        Constraint {
            coeffs,
            op: Relation::Eq,
            rhs,
        }
    }
}

/// A linear program: minimize `objective . x` over the constraints,
/// with implicit `x >= 0`.
#[derive(Debug, Clone)]
pub struct Lp {
    pub objective: Vec<f64>,
    pub constraints: Vec<Constraint>,
}

/// An optimal LP solution.
#[derive(Debug, Clone)]
pub struct LpSolution {
    /// Optimal point (structural variables only).
    pub x: Vec<f64>,
    /// Objective value at `x`.
    pub objective: f64,
}

const EPS: f64 = 1e-9;

impl Lp {
    /// Minimize; returns the optimum or `Infeasible` / `Unbounded`.
    ///
    /// The POAS problems mix coefficients spanning ~26 orders of magnitude
    /// (ops ~1e13 against slopes ~1e-13), which would swamp any fixed
    /// pivot tolerance — so the problem is equilibrated first: every
    /// column is scaled to unit max magnitude (substituting
    /// `x_j = x'_j / s_j`), rows likewise, and the solution is mapped
    /// back afterwards.
    pub fn solve(&self) -> Result<LpSolution> {
        let n = self.objective.len();
        for (i, c) in self.constraints.iter().enumerate() {
            if c.coeffs.len() != n {
                return Err(Error::Config(format!(
                    "constraint {i} has {} coefficients, expected {n}",
                    c.coeffs.len()
                )));
            }
        }

        // ---- Alternating geometric-mean equilibration (Curtis–Reid
        // style): find row scales r_i and column scales c_j such that the
        // nonzeros of r_i * a_ij * c_j all sit near 1. A few alternating
        // passes shrink the dynamic range from ~1e26 to ~1e1.
        let m = self.constraints.len();
        let mut row_scale = vec![1.0f64; m];
        let mut col_scale = vec![1.0f64; n];
        for _ in 0..15 {
            for (i, c) in self.constraints.iter().enumerate() {
                let mut log_sum = 0.0;
                let mut cnt = 0usize;
                for (j, &v) in c.coeffs.iter().enumerate() {
                    let s = (v * row_scale[i] * col_scale[j]).abs();
                    if s > 0.0 {
                        log_sum += s.ln();
                        cnt += 1;
                    }
                }
                if cnt > 0 {
                    row_scale[i] /= (log_sum / cnt as f64).exp();
                }
            }
            for j in 0..n {
                let mut log_sum = 0.0;
                let mut cnt = 0usize;
                for (i, c) in self.constraints.iter().enumerate() {
                    let s = (c.coeffs[j] * row_scale[i] * col_scale[j]).abs();
                    if s > 0.0 {
                        log_sum += s.ln();
                        cnt += 1;
                    }
                }
                if cnt > 0 {
                    col_scale[j] /= (log_sum / cnt as f64).exp();
                }
            }
        }

        // Substitution x_j = c_j * x'_j: scaled problem has coefficients
        // r_i a_ij c_j, rhs r_i b_i, objective obj_j c_j.
        let scaled = Lp {
            objective: self
                .objective
                .iter()
                .zip(&col_scale)
                .map(|(o, s)| o * s)
                .collect(),
            constraints: self
                .constraints
                .iter()
                .zip(&row_scale)
                .map(|(c, &r)| Constraint {
                    coeffs: c
                        .coeffs
                        .iter()
                        .zip(&col_scale)
                        .map(|(v, s)| v * r * s)
                        .collect(),
                    op: c.op,
                    rhs: c.rhs * r,
                })
                .collect(),
        };
        let mut sol = scaled.solve_scaled()?;
        for (x, s) in sol.x.iter_mut().zip(&col_scale) {
            *x *= s;
        }
        // Recompute the objective in original units (more accurate than
        // unscaling the tableau value).
        sol.objective = self
            .objective
            .iter()
            .zip(&sol.x)
            .map(|(o, x)| o * x)
            .sum();
        Ok(sol)
    }

    /// Core two-phase simplex on an (already equilibrated) problem.
    fn solve_scaled(&self) -> Result<LpSolution> {
        let n = self.objective.len();
        let m = self.constraints.len();

        // ---- Build the standard-form tableau.
        // Columns: [structural n | slack/surplus s | artificial a | rhs]
        // Every row is normalized to rhs >= 0 first.
        let mut rows: Vec<(Vec<f64>, Relation, f64)> = self
            .constraints
            .iter()
            .map(|c| {
                if c.rhs < 0.0 {
                    let coeffs: Vec<f64> = c.coeffs.iter().map(|v| -v).collect();
                    let op = match c.op {
                        Relation::Le => Relation::Ge,
                        Relation::Ge => Relation::Le,
                        Relation::Eq => Relation::Eq,
                    };
                    (coeffs, op, -c.rhs)
                } else {
                    (c.coeffs.clone(), c.op, c.rhs)
                }
            })
            .collect();

        let n_slack = rows
            .iter()
            .filter(|(_, op, _)| *op != Relation::Eq)
            .count();
        let n_art = rows
            .iter()
            .filter(|(_, op, _)| *op != Relation::Le)
            .count();
        let total = n + n_slack + n_art;

        // tableau[r] = row of length total+1 (last = rhs)
        let mut t = vec![vec![0.0f64; total + 1]; m];
        let mut basis = vec![usize::MAX; m];
        let mut s_idx = n;
        let mut a_idx = n + n_slack;
        for (r, (coeffs, op, rhs)) in rows.drain(..).enumerate() {
            t[r][..n].copy_from_slice(&coeffs);
            t[r][total] = rhs;
            match op {
                Relation::Le => {
                    t[r][s_idx] = 1.0;
                    basis[r] = s_idx;
                    s_idx += 1;
                }
                Relation::Ge => {
                    t[r][s_idx] = -1.0;
                    s_idx += 1;
                    t[r][a_idx] = 1.0;
                    basis[r] = a_idx;
                    a_idx += 1;
                }
                Relation::Eq => {
                    t[r][a_idx] = 1.0;
                    basis[r] = a_idx;
                    a_idx += 1;
                }
            }
        }

        // ---- Phase 1: minimize sum of artificials.
        if n_art > 0 {
            let mut cost = vec![0.0f64; total];
            for c in cost.iter_mut().take(n + n_slack + n_art).skip(n + n_slack) {
                *c = 1.0;
            }
            let obj = Self::optimize(&mut t, &mut basis, &cost, total)?;
            if obj > 1e-7 {
                return Err(Error::Infeasible(format!(
                    "phase-1 objective {obj:.3e} > 0"
                )));
            }
            // Drive any artificial still in the basis out (degenerate).
            for r in 0..m {
                if basis[r] >= n + n_slack {
                    // Pivot on any non-artificial column with a nonzero
                    // entry; if none, the row is redundant — zero it.
                    if let Some(col) = (0..n + n_slack).find(|&c| t[r][c].abs() > EPS) {
                        Self::pivot(&mut t, &mut basis, r, col, total);
                    }
                }
            }
        }

        // ---- Phase 2: the real objective (artificials forbidden).
        let mut cost = vec![0.0f64; total];
        cost[..n].copy_from_slice(&self.objective);
        // Forbid re-entry of artificials by giving them a huge cost and
        // masking them out of pivoting (handled in `optimize` via the
        // `max_col` argument).
        let obj = Self::optimize(&mut t, &mut basis, &cost, n + n_slack)?;

        let mut x = vec![0.0f64; n];
        for (r, &b) in basis.iter().enumerate() {
            if b < n {
                x[b] = t[r][total];
            }
        }
        Ok(LpSolution { x, objective: obj })
    }

    /// Run simplex iterations on the tableau, minimizing `cost` over
    /// columns `[0, max_col)`. Returns the objective value.
    fn optimize(
        t: &mut [Vec<f64>],
        basis: &mut [usize],
        cost: &[f64],
        max_col: usize,
    ) -> Result<f64> {
        let m = t.len();
        let total = cost.len();
        let rhs_col = t.first().map(|r| r.len() - 1).unwrap_or(0);

        // Iteration cap: Bland's rule guarantees termination, the cap is
        // a defensive backstop against numerical pathologies.
        let max_iters = 200 * (m + total) + 1000;
        for _ in 0..max_iters {
            // Reduced costs: cj - cB . B^-1 Aj  (computed directly from
            // the tableau: rc_j = cost_j - sum_r cost[basis[r]] * t[r][j])
            let mut entering = None;
            for j in 0..max_col {
                let mut rc = cost[j];
                for r in 0..m {
                    let cb = cost[basis[r]];
                    if cb != 0.0 {
                        rc -= cb * t[r][j];
                    }
                }
                if rc < -EPS {
                    entering = Some(j); // Bland: first improving column
                    break;
                }
            }
            let Some(col) = entering else {
                // Optimal: objective = cB . xB
                let mut obj = 0.0;
                for r in 0..m {
                    obj += cost[basis[r]] * t[r][rhs_col];
                }
                return Ok(obj);
            };

            // Ratio test (Bland: smallest basis index breaks ties).
            let mut leave: Option<usize> = None;
            let mut best = f64::INFINITY;
            for r in 0..m {
                if t[r][col] > EPS {
                    let ratio = t[r][rhs_col] / t[r][col];
                    let better = ratio < best - EPS
                        || (ratio < best + EPS
                            && leave.map(|l| basis[r] < basis[l]).unwrap_or(false));
                    if better {
                        best = ratio;
                        leave = Some(r);
                    }
                }
            }
            let Some(row) = leave else {
                return Err(Error::Unbounded(
                    "no leaving row: objective unbounded below".into(),
                ));
            };
            Self::pivot(t, basis, row, col, rhs_col);
        }
        Err(Error::Infeasible(
            "simplex iteration cap exceeded (numerical cycling?)".into(),
        ))
    }

    /// Gauss pivot on (row, col).
    fn pivot(t: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize, rhs_col: usize) {
        let m = t.len();
        let piv = t[row][col];
        debug_assert!(piv.abs() > EPS);
        for v in t[row].iter_mut() {
            *v /= piv;
        }
        for r in 0..m {
            if r != row {
                let f = t[r][col];
                if f != 0.0 {
                    for j in 0..=rhs_col {
                        t[r][j] -= f * t[row][j];
                    }
                }
            }
        }
        basis[row] = col;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6, "{a} != {b}");
    }

    #[test]
    fn simple_2d_max_as_min() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18  (classic Dantzig)
        // -> min -3x -5y; optimum x=2, y=6, obj=-36.
        let lp = Lp {
            objective: vec![-3.0, -5.0],
            constraints: vec![
                Constraint::le(vec![1.0, 0.0], 4.0),
                Constraint::le(vec![0.0, 2.0], 12.0),
                Constraint::le(vec![3.0, 2.0], 18.0),
            ],
        };
        let s = lp.solve().unwrap();
        assert_close(s.objective, -36.0);
        assert_close(s.x[0], 2.0);
        assert_close(s.x[1], 6.0);
    }

    #[test]
    fn equality_constraints() {
        // min x + y  s.t. x + y = 10, x >= 3 -> x=10,y=0 ... any point on
        // the segment has obj 10; check objective only.
        let lp = Lp {
            objective: vec![1.0, 1.0],
            constraints: vec![
                Constraint::eq(vec![1.0, 1.0], 10.0),
                Constraint::ge(vec![1.0, 0.0], 3.0),
            ],
        };
        let s = lp.solve().unwrap();
        assert_close(s.objective, 10.0);
        assert!(s.x[0] >= 3.0 - 1e-9);
        assert_close(s.x[0] + s.x[1], 10.0);
    }

    #[test]
    fn infeasible_detected() {
        // x <= 1 and x >= 2
        let lp = Lp {
            objective: vec![1.0],
            constraints: vec![
                Constraint::le(vec![1.0], 1.0),
                Constraint::ge(vec![1.0], 2.0),
            ],
        };
        assert!(matches!(lp.solve(), Err(Error::Infeasible(_))));
    }

    #[test]
    fn unbounded_detected() {
        // min -x with x >= 0 only
        let lp = Lp {
            objective: vec![-1.0],
            constraints: vec![Constraint::ge(vec![1.0], 0.0)],
        };
        assert!(matches!(lp.solve(), Err(Error::Unbounded(_))));
    }

    #[test]
    fn negative_rhs_normalized() {
        // -x <= -5  <=>  x >= 5
        let lp = Lp {
            objective: vec![1.0],
            constraints: vec![Constraint::le(vec![-1.0], -5.0)],
        };
        let s = lp.solve().unwrap();
        assert_close(s.x[0], 5.0);
    }

    #[test]
    fn epigraph_minimax() {
        // min T s.t. T >= 2a, T >= 3b, a + b = 10  (the POAS pattern)
        // vars: [a, b, T]; optimum: 2a = 3b -> a=6, b=4, T=12.
        let lp = Lp {
            objective: vec![0.0, 0.0, 1.0],
            constraints: vec![
                Constraint::le(vec![2.0, 0.0, -1.0], 0.0),
                Constraint::le(vec![0.0, 3.0, -1.0], 0.0),
                Constraint::eq(vec![1.0, 1.0, 0.0], 10.0),
            ],
        };
        let s = lp.solve().unwrap();
        assert_close(s.objective, 12.0);
        assert_close(s.x[0], 6.0);
        assert_close(s.x[1], 4.0);
    }

    #[test]
    fn degenerate_redundant_rows() {
        // Duplicate equality rows must not break phase 1.
        let lp = Lp {
            objective: vec![1.0, 2.0],
            constraints: vec![
                Constraint::eq(vec![1.0, 1.0], 4.0),
                Constraint::eq(vec![2.0, 2.0], 8.0),
            ],
        };
        let s = lp.solve().unwrap();
        assert_close(s.objective, 4.0); // all weight on x0
    }

    #[test]
    fn zero_rhs_feasible() {
        let lp = Lp {
            objective: vec![1.0],
            constraints: vec![Constraint::eq(vec![1.0], 0.0)],
        };
        let s = lp.solve().unwrap();
        assert_close(s.x[0], 0.0);
    }

    #[test]
    fn mismatched_arity_is_config_error() {
        let lp = Lp {
            objective: vec![1.0, 1.0],
            constraints: vec![Constraint::le(vec![1.0], 1.0)],
        };
        assert!(matches!(lp.solve(), Err(Error::Config(_))));
    }

    #[test]
    fn scale_invariance_large_numbers() {
        // POAS works in ops (1e13+) and seconds — coefficients span many
        // orders of magnitude; the pivoting must stay stable.
        let n_ops = 2.7e13f64;
        let lp = Lp {
            // vars: [c1, c2, T]
            objective: vec![0.0, 0.0, 1.0],
            constraints: vec![
                // T >= c1 / 5.6e12, T >= c2 / 21.5e12
                Constraint::le(vec![1.0 / 5.6e12, 0.0, -1.0], 0.0),
                Constraint::le(vec![0.0, 1.0 / 21.5e12, -1.0], 0.0),
                Constraint::eq(vec![1.0, 1.0, 0.0], n_ops),
            ],
        };
        let s = lp.solve().unwrap();
        let expect_t = n_ops / (5.6e12 + 21.5e12);
        assert!((s.objective - expect_t).abs() / expect_t < 1e-6);
        assert!((s.x[0] + s.x[1] - n_ops).abs() / n_ops < 1e-6);
    }
}
