//! Branch & bound MILP on top of the simplex relaxation.
//!
//! The paper formulates the split as a *mixed-integer* program (§4.2.1).
//! In hgemms the integral quantities are whole C rows (`m_i`): a device
//! cannot compute a fractional row. [`solve_milp`] therefore accepts a
//! list of integer-constrained variables with a per-variable unit (ops
//! per row), solves the LP relaxation, and branches on the most
//! fractional variable until all integrality gaps close.
//!
//! Best-first search with bound pruning; depth is tiny in practice
//! because the relaxation is almost integral (unit ≪ N).

use super::simplex::{Constraint, Lp, LpSolution};
use crate::error::{Error, Result};

/// Options for the branch & bound search.
#[derive(Debug, Clone)]
pub struct MilpOptions {
    /// Variables constrained to integer multiples of `units[i]`
    /// (variable index, unit size). Empty = plain LP.
    pub integer_units: Vec<(usize, f64)>,
    /// Maximum branch & bound nodes before giving up and returning the
    /// best incumbent (or the relaxation if none).
    pub max_nodes: usize,
    /// Integrality tolerance in *units*.
    pub tol: f64,
}

impl Default for MilpOptions {
    fn default() -> Self {
        MilpOptions {
            integer_units: Vec::new(),
            max_nodes: 10_000,
            tol: 1e-6,
        }
    }
}

/// Solve `lp` with the integrality side-constraints of `opts`.
pub fn solve_milp(lp: &Lp, opts: &MilpOptions) -> Result<LpSolution> {
    let relax = lp.solve()?;
    if opts.integer_units.is_empty() {
        return Ok(relax);
    }

    // Node = additional bound constraints (var, unit-multiple lower, upper).
    #[derive(Clone)]
    struct Node {
        extra: Vec<Constraint>,
        bound: f64, // LP relaxation objective (lower bound for min)
        sol: LpSolution,
    }

    let mut best: Option<LpSolution> = None;
    let mut stack = vec![Node {
        extra: Vec::new(),
        bound: relax.objective,
        sol: relax,
    }];
    let mut nodes = 0usize;

    while let Some(node) = stack.pop() {
        nodes += 1;
        if nodes > opts.max_nodes {
            break;
        }
        // Prune against incumbent.
        if let Some(b) = &best {
            if node.bound >= b.objective - 1e-12 {
                continue;
            }
        }

        // Find the most fractional integer variable.
        let mut branch: Option<(usize, f64, f64)> = None; // (var, unit, value_units)
        let mut worst_frac = opts.tol;
        for &(var, unit) in &opts.integer_units {
            let units = node.sol.x[var] / unit;
            let frac = (units - units.round()).abs();
            if frac > worst_frac {
                worst_frac = frac;
                branch = Some((var, unit, units));
            }
        }

        let Some((var, unit, units)) = branch else {
            // Integral: candidate incumbent.
            match &best {
                Some(b) if b.objective <= node.sol.objective => {}
                _ => best = Some(node.sol.clone()),
            }
            continue;
        };

        // Branch: x_var <= floor(units)*unit  |  x_var >= ceil(units)*unit
        let lo = units.floor() * unit;
        let hi = units.ceil() * unit;
        let nvars = lp.objective.len();
        let mut unitvec = vec![0.0; nvars];
        unitvec[var] = 1.0;

        for bound_con in [
            Constraint::le(unitvec.clone(), lo),
            Constraint::ge(unitvec.clone(), hi),
        ] {
            let mut extra = node.extra.clone();
            extra.push(bound_con);
            let mut sub = lp.clone();
            sub.constraints.extend(extra.iter().cloned());
            match sub.solve() {
                Ok(sol) => {
                    let bound = sol.objective;
                    // Prune immediately if dominated.
                    if best
                        .as_ref()
                        .map(|b| bound >= b.objective - 1e-12)
                        .unwrap_or(false)
                    {
                        continue;
                    }
                    stack.push(Node { extra, bound, sol });
                }
                Err(Error::Infeasible(_)) => {} // dead branch
                Err(e) => return Err(e),
            }
        }
        // Best-first: keep the most promising node on top.
        stack.sort_by(|a, b| b.bound.total_cmp(&a.bound));
    }

    best.ok_or_else(|| {
        Error::Infeasible("no integral solution found within node budget".into())
    })
}

/// Round an LP point onto the integer grid (fallback / warm start):
/// floors every integer variable and reports the leftover per variable.
pub fn floor_to_units(x: &[f64], integer_units: &[(usize, f64)]) -> (Vec<f64>, f64) {
    let mut out = x.to_vec();
    let mut leftover = 0.0;
    for &(var, unit) in integer_units {
        let floored = (x[var] / unit).floor() * unit;
        leftover += x[var] - floored;
        out[var] = floored;
    }
    (out, leftover)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimize::simplex::{Constraint, Lp};

    #[test]
    fn plain_lp_passthrough() {
        let lp = Lp {
            objective: vec![1.0],
            constraints: vec![Constraint::ge(vec![1.0], 2.5)],
        };
        let s = solve_milp(&lp, &MilpOptions::default()).unwrap();
        assert!((s.x[0] - 2.5).abs() < 1e-9);
    }

    #[test]
    fn integer_rounding_up() {
        // min x s.t. x >= 2.5, x integer -> 3
        let lp = Lp {
            objective: vec![1.0],
            constraints: vec![Constraint::ge(vec![1.0], 2.5)],
        };
        let opts = MilpOptions {
            integer_units: vec![(0, 1.0)],
            ..Default::default()
        };
        let s = solve_milp(&lp, &opts).unwrap();
        assert!((s.x[0] - 3.0).abs() < 1e-7, "x={}", s.x[0]);
    }

    #[test]
    fn knapsack_like() {
        // max 5a + 4b s.t. 6a + 5b <= 14, a,b integer >= 0
        // LP opt: a=14/6; MILP opt: a=1,b=1 (9) vs a=2,b=0 (10) -> 10.
        let lp = Lp {
            objective: vec![-5.0, -4.0],
            constraints: vec![Constraint::le(vec![6.0, 5.0], 14.0)],
        };
        let opts = MilpOptions {
            integer_units: vec![(0, 1.0), (1, 1.0)],
            ..Default::default()
        };
        let s = solve_milp(&lp, &opts).unwrap();
        assert!((s.objective + 10.0).abs() < 1e-7, "obj={}", s.objective);
        assert!((s.x[0] - 2.0).abs() < 1e-7);
        assert!(s.x[1].abs() < 1e-7);
    }

    #[test]
    fn custom_units() {
        // min x s.t. x >= 10, x multiple of 4 -> 12.
        let lp = Lp {
            objective: vec![1.0],
            constraints: vec![Constraint::ge(vec![1.0], 10.0)],
        };
        let opts = MilpOptions {
            integer_units: vec![(0, 4.0)],
            ..Default::default()
        };
        let s = solve_milp(&lp, &opts).unwrap();
        assert!((s.x[0] - 12.0).abs() < 1e-7);
    }

    #[test]
    fn mixed_integer_split() {
        // The POAS shape: c1 + c2 = 100, T >= c1/1, T >= c2/3, c1 rows of 7.
        // Relaxation: c1=25, c2=75, T=25. With c1 restricted to multiples
        // of 7: c1=21 -> T=max(21, 79/3=26.33)=26.33; c1=28 -> T=28.
        // Optimum c1=21.
        let lp = Lp {
            objective: vec![0.0, 0.0, 1.0],
            constraints: vec![
                Constraint::le(vec![1.0, 0.0, -1.0], 0.0),
                Constraint::le(vec![0.0, 1.0 / 3.0, -1.0], 0.0),
                Constraint::eq(vec![1.0, 1.0, 0.0], 100.0),
            ],
        };
        let opts = MilpOptions {
            integer_units: vec![(0, 7.0)],
            ..Default::default()
        };
        let s = solve_milp(&lp, &opts).unwrap();
        assert!((s.x[0] - 21.0).abs() < 1e-6, "c1={}", s.x[0]);
    }

    #[test]
    fn infeasible_integrality() {
        // x = 2.5 exactly, x integer — infeasible.
        let lp = Lp {
            objective: vec![1.0],
            constraints: vec![Constraint::eq(vec![1.0], 2.5)],
        };
        let opts = MilpOptions {
            integer_units: vec![(0, 1.0)],
            ..Default::default()
        };
        assert!(solve_milp(&lp, &opts).is_err());
    }

    #[test]
    fn floor_to_units_accounting() {
        let (x, leftover) = floor_to_units(&[10.7, 5.0], &[(0, 1.0)]);
        assert_eq!(x[0], 10.0);
        assert!((leftover - 0.7).abs() < 1e-12);
        assert_eq!(x[1], 5.0);
    }
}
