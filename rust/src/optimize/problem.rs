//! The hgemms split formulation (paper §4.2.1, Eq. 1–4).
//!
//! Decision variables: `c_x` = ops assigned to device `x`, plus the
//! epigraph variable `T` that linearizes Eq. 1's minimax:
//!
//! ```text
//!   minimize T
//!   s.t.     finish_x(c) <= T        for every device x
//!            sum_x c_x    = N        (Eq. 3)
//!            c_x         >= 0        (Eq. 2)
//! ```
//!
//! `finish_x` composes the predicted compute time `t_cx = a_x c_x + b_x`
//! with the copy model of Eq. 4. Two bus modes:
//!
//! * **exclusive** — Eq. 4 as printed: each accelerator owns its link,
//!   `finish_x = y_h2d(x) + t_cx + y_d2h(x)`;
//! * **shared (serialized)** — the paper's actual testbed (§4.2.1 "we
//!   modified the equation ... the time to copy the data of previous
//!   devices"): under priority arbitration, device `x` waits for the H2D
//!   copies of every device with priority >= its own, then computes and
//!   returns its own C: `finish_x = Σ_{p(j)>=p(x)} y_h2d(j) + t_cx +
//!   y_d2h(x)` (C returns rarely contend — devices finish at different
//!   times). All terms stay linear in `c`, so the problem remains a
//!   (MI)LP.
//!
//! With `row_integral`, each `c_x` is constrained to whole C rows
//! (multiples of `n*k` ops) — the mixed-integer part the paper solves
//! with CPLEX; we solve it with the in-tree branch & bound.

use super::milp::{solve_milp, MilpOptions};
use super::simplex::{Constraint, Lp};
use crate::error::{Error, Result};
use crate::workload::GemmSize;

/// Per-device inputs produced by the Predict phase.
#[derive(Debug, Clone)]
pub struct DeviceModelInput {
    /// Device name (diagnostics only).
    pub name: String,
    /// CPUs compute from host memory: no copy terms.
    pub is_cpu: bool,
    /// Compute-time slope: seconds per op (1/effective rate).
    pub a: f64,
    /// Compute-time intercept: seconds (launch overhead etc.).
    pub b: f64,
    /// Element size on this device's link (4 for f32, 2 for f16/bf16).
    pub dtype_bytes: f64,
    /// Measured link bandwidth, bytes/second (ignored for CPUs).
    pub bw: f64,
    /// Per-transfer latency, seconds.
    pub lat: f64,
    /// Bus priority — higher copies first (paper: fastest device first).
    pub priority: u32,
}

impl DeviceModelInput {
    /// Predicted compute seconds for `c` ops.
    pub fn compute_time(&self, c: f64) -> f64 {
        self.a * c + self.b
    }

    /// Predicted H2D seconds for `c` ops of an (m, n, k)-shaped GEMM:
    /// A is `c/n` elements (m_x * k = c/n), B is `k*n` elements.
    pub fn h2d_time(&self, c: f64, size: GemmSize) -> f64 {
        if self.is_cpu {
            return 0.0;
        }
        if c <= 0.0 {
            return 0.0;
        }
        let elems = c / size.n as f64 + (size.k * size.n) as f64;
        self.dtype_bytes * elems / self.bw + 2.0 * self.lat
    }

    /// Predicted D2H seconds: C is `c/k` elements (m_x * n = c/k).
    pub fn d2h_time(&self, c: f64, size: GemmSize) -> f64 {
        if self.is_cpu || c <= 0.0 {
            return 0.0;
        }
        self.dtype_bytes * (c / size.k as f64) / self.bw + self.lat
    }

    /// Full Eq. 4 copy time (both directions).
    pub fn copy_time(&self, c: f64, size: GemmSize) -> f64 {
        self.h2d_time(c, size) + self.d2h_time(c, size)
    }
}

/// Bus modelling mode for the formulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BusModel {
    /// Eq. 4 as printed: each device owns its link.
    Exclusive,
    /// Serialized shared bus under priority order (the paper's testbed).
    SharedPriority,
}

/// The assembled optimization problem.
#[derive(Debug, Clone)]
pub struct SplitProblem {
    pub devices: Vec<DeviceModelInput>,
    pub size: GemmSize,
    pub bus: BusModel,
    /// Constrain each `c_x` to whole C rows (multiples of `n*k` ops).
    pub row_integral: bool,
}

/// The optimizer's answer.
#[derive(Debug, Clone)]
pub struct SplitSolution {
    /// Ops per device (machine order of `SplitProblem::devices`).
    pub ops: Vec<f64>,
    /// Predicted makespan (the epigraph optimum), seconds per repetition.
    pub t_pred: f64,
    /// Predicted per-device compute seconds at the optimum.
    pub compute_pred: Vec<f64>,
    /// Predicted per-device copy seconds (own transfers, both directions).
    pub copy_pred: Vec<f64>,
}

impl SplitSolution {
    /// Work shares in [0,1] per device.
    pub fn shares(&self) -> Vec<f64> {
        let total: f64 = self.ops.iter().sum();
        self.ops.iter().map(|o| o / total.max(1.0)).collect()
    }
}

impl SplitProblem {
    /// Build the (MI)LP and solve it.
    pub fn solve(&self) -> Result<SplitSolution> {
        let d = self.devices.len();
        if d == 0 {
            return Err(Error::Config("split problem with zero devices".into()));
        }
        let n_ops = self.size.ops();
        let nvars = d + 1; // c_0..c_{d-1}, T
        let t_var = d;

        let mut constraints = Vec::with_capacity(d + 1);

        // Eq. 3: sum c = N.
        let mut sum_row = vec![1.0; d];
        sum_row.push(0.0);
        constraints.push(Constraint::eq(sum_row, n_ops));

        // finish_x <= T for each x.
        for (i, dev) in self.devices.iter().enumerate() {
            let mut row = vec![0.0; nvars];
            let mut rhs = -dev.b; // move intercept to RHS
            row[i] += dev.a;
            row[t_var] = -1.0;

            if !dev.is_cpu {
                // H2D: under the Fig. 2 priority scheme, device x's A/B
                // arrive only after every higher-priority device's A/B
                // went over the bus — the "time to copy the data of
                // previous devices" the paper adds to Eq. 4.
                let h2d_waits: Vec<usize> = match self.bus {
                    BusModel::Exclusive => vec![i],
                    BusModel::SharedPriority => (0..d)
                        .filter(|&j| {
                            !self.devices[j].is_cpu
                                && self.devices[j].priority >= dev.priority
                        })
                        .collect(),
                };
                for &j in &h2d_waits {
                    let dj = &self.devices[j];
                    // A term linear in c_j, B term constant.
                    row[j] += dj.dtype_bytes / (self.size.n as f64 * dj.bw);
                    rhs -= dj.dtype_bytes * (self.size.k * self.size.n) as f64 / dj.bw
                        + 2.0 * dj.lat;
                }
                // D2H: each device's C return rarely contends (devices
                // finish computing at different times and the returns
                // interleave with compute), so only the device's own
                // copy-back is charged.
                row[i] += dev.dtype_bytes / (self.size.k as f64 * dev.bw);
                rhs -= dev.lat;
            }
            constraints.push(Constraint::le(row, rhs));
        }

        let mut objective = vec![0.0; nvars];
        objective[t_var] = 1.0;
        let lp = Lp {
            objective,
            constraints,
        };

        let sol = if self.row_integral {
            let unit = (self.size.n * self.size.k) as f64;
            let opts = MilpOptions {
                integer_units: (0..d).map(|i| (i, unit)).collect(),
                ..Default::default()
            };
            solve_milp(&lp, &opts)?
        } else {
            lp.solve()?
        };

        let ops: Vec<f64> = sol.x[..d].iter().map(|&c| c.max(0.0)).collect();
        let compute_pred: Vec<f64> = self
            .devices
            .iter()
            .zip(&ops)
            .map(|(dev, &c)| dev.compute_time(c))
            .collect();
        let copy_pred: Vec<f64> = self
            .devices
            .iter()
            .zip(&ops)
            .map(|(dev, &c)| dev.copy_time(c, self.size))
            .collect();

        Ok(SplitSolution {
            ops,
            t_pred: sol.objective,
            compute_pred,
            copy_pred,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three devices shaped like mach1 (CPU / GPU / XPU rates).
    fn mach1_like(size: GemmSize) -> SplitProblem {
        let mk = |name: &str, is_cpu: bool, rate_tops: f64, dt: f64, prio: u32| {
            DeviceModelInput {
                name: name.into(),
                is_cpu,
                a: 1.0 / (rate_tops * 1e12),
                b: 50e-6,
                dtype_bytes: dt,
                bw: 15.75e9,
                lat: 12e-6,
                priority: prio,
            }
        };
        SplitProblem {
            devices: vec![
                mk("cpu", true, 0.109, 4.0, 0),
                mk("gpu", false, 5.6, 4.0, 1),
                mk("xpu", false, 21.5, 2.0, 2),
            ],
            size,
            bus: BusModel::SharedPriority,
            row_integral: false,
        }
    }

    #[test]
    fn shares_follow_rates() {
        let p = mach1_like(GemmSize::square(30_000));
        let s = p.solve().unwrap();
        let shares = s.shares();
        // XPU fastest -> biggest share; CPU tiny.
        assert!(shares[2] > 0.6, "xpu share {}", shares[2]);
        assert!(shares[1] > 0.1 && shares[1] < 0.35, "gpu share {}", shares[1]);
        assert!(shares[0] < 0.02, "cpu share {}", shares[0]);
        // Conservation.
        let total: f64 = s.ops.iter().sum();
        assert!((total / p.size.ops() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn epigraph_is_max_finish() {
        let p = mach1_like(GemmSize::square(20_000));
        let s = p.solve().unwrap();
        // Every device's standalone finish estimate (exclusive copies +
        // shared-bus waits) must be <= T; the binding ones equal it.
        // Recompute finishes the same way the LP does.
        let mut max_finish = 0.0f64;
        for (i, dev) in p.devices.iter().enumerate() {
            let mut fin = dev.compute_time(s.ops[i]);
            if !dev.is_cpu {
                for (j, dj) in p.devices.iter().enumerate() {
                    if !dj.is_cpu && dj.priority >= dev.priority {
                        fin += dj.h2d_time(s.ops[j].max(1.0), p.size);
                    }
                }
                fin += dev.d2h_time(s.ops[i].max(1.0), p.size);
            }
            max_finish = max_finish.max(fin);
        }
        assert!(
            (max_finish - s.t_pred).abs() / s.t_pred < 0.02,
            "max_finish={max_finish} T={}",
            s.t_pred
        );
    }

    #[test]
    fn single_device_gets_everything() {
        let mut p = mach1_like(GemmSize::square(10_000));
        p.devices.truncate(1); // CPU only
        let s = p.solve().unwrap();
        assert!((s.ops[0] - p.size.ops()).abs() < 1.0);
        // T ≈ N / rate.
        let expect = p.devices[0].compute_time(p.size.ops());
        assert!((s.t_pred - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn exclusive_bus_is_no_slower_shared_no_faster() {
        // Shared serialized bus can only increase the optimum.
        let base = mach1_like(GemmSize::square(30_000));
        let mut excl = base.clone();
        excl.bus = BusModel::Exclusive;
        let t_shared = base.solve().unwrap().t_pred;
        let t_excl = excl.solve().unwrap().t_pred;
        assert!(t_excl <= t_shared + 1e-9, "excl={t_excl} shared={t_shared}");
    }

    #[test]
    fn row_integral_respects_units() {
        let size = GemmSize::new(1000, 500, 400);
        let mut p = mach1_like(size);
        p.row_integral = true;
        let s = p.solve().unwrap();
        let unit = (size.n * size.k) as f64;
        for (i, &c) in s.ops.iter().enumerate() {
            let units = c / unit;
            assert!(
                (units - units.round()).abs() < 1e-4,
                "device {i}: {c} ops is not whole rows ({units} rows)"
            );
        }
        let total: f64 = s.ops.iter().sum();
        assert!((total - size.ops()).abs() < 1.0);
    }

    #[test]
    fn integral_solution_close_to_relaxation() {
        let size = GemmSize::new(2000, 1000, 800);
        let relaxed = mach1_like(size).solve().unwrap();
        let mut p = mach1_like(size);
        p.row_integral = true;
        let integral = p.solve().unwrap();
        assert!(integral.t_pred >= relaxed.t_pred - 1e-9);
        assert!(
            (integral.t_pred - relaxed.t_pred) / relaxed.t_pred < 0.01,
            "integrality gap too large: {} vs {}",
            integral.t_pred,
            relaxed.t_pred
        );
    }

    #[test]
    fn faster_memory_shifts_work_to_accelerators() {
        let size = GemmSize::square(10_000);
        let slow = mach1_like(size);
        let mut fast = mach1_like(size);
        for d in &mut fast.devices {
            d.bw *= 4.0;
        }
        let s_slow = slow.solve().unwrap();
        let s_fast = fast.solve().unwrap();
        // Cheaper copies -> accelerators can absorb more work.
        let acc_slow = s_slow.shares()[1] + s_slow.shares()[2];
        let acc_fast = s_fast.shares()[1] + s_fast.shares()[2];
        assert!(acc_fast >= acc_slow - 1e-9);
        assert!(s_fast.t_pred <= s_slow.t_pred);
    }

    #[test]
    fn empty_problem_errors() {
        let p = SplitProblem {
            devices: vec![],
            size: GemmSize::square(10),
            bus: BusModel::Exclusive,
            row_integral: false,
        };
        assert!(p.solve().is_err());
    }

    #[test]
    fn copy_time_matches_eq4_shape() {
        let dev = DeviceModelInput {
            name: "gpu".into(),
            is_cpu: false,
            a: 1e-12,
            b: 0.0,
            dtype_bytes: 4.0,
            bw: 1e9,
            lat: 0.0,
            priority: 1,
        };
        let size = GemmSize::new(100, 50, 200);
        let c = size.ops(); // whole matrix
        // A = m*k elems, B = k*n, C = m*n.
        let expect = 4.0
            * ((100 * 200) as f64 + (200 * 50) as f64 + (100 * 50) as f64)
            / 1e9;
        let got = dev.copy_time(c, size);
        assert!((got - expect).abs() < 1e-12, "{got} vs {expect}");
    }
}
