//! Energy-objective variant of the split problem (§3: POAS "can be
//! focused on ... minimizing the energy consumption").
//!
//! Same decision variables and constraints as [`super::problem`], but the
//! objective becomes total energy:
//!
//! ```text
//!   minimize  Σ_x  p_active(x) * (t_cx + t_yx)  +  P_idle * T
//!   s.t.      finish_x(c) <= T   (same as the time formulation)
//!             T <= deadline      (optional time budget)
//!             Σ c_x = N, c_x >= 0
//! ```
//!
//! Active energy is linear in `c` (compute and copy times are), and the
//! idle floor is linear in `T`, so the problem stays an LP. Without a
//! deadline the optimum degenerates to "put everything on the most
//! efficient device"; the deadline constraint exposes the energy/time
//! trade-off curve (`ablation_energy` bench).

use super::problem::{BusModel, DeviceModelInput, SplitSolution};
use super::simplex::{Constraint, Lp};
use crate::error::{Error, Result};
use crate::workload::GemmSize;

/// Per-device power figures for the energy objective.
#[derive(Debug, Clone, Copy)]
pub struct DevicePower {
    /// Extra watts while computing or copying.
    pub active_w: f64,
    /// Idle watts (summed machine-wide into the `T` coefficient).
    pub idle_w: f64,
}

/// Energy-minimizing split problem.
#[derive(Debug, Clone)]
pub struct EnergyProblem {
    pub devices: Vec<DeviceModelInput>,
    pub power: Vec<DevicePower>,
    pub size: GemmSize,
    pub bus: BusModel,
    /// Optional cap on the makespan (seconds per repetition).
    pub deadline_s: Option<f64>,
}

impl EnergyProblem {
    /// Solve; returns the split plus the predicted energy (J/repetition).
    pub fn solve(&self) -> Result<(SplitSolution, f64)> {
        let d = self.devices.len();
        if d == 0 || self.power.len() != d {
            return Err(Error::Config(
                "energy problem needs matching devices and power entries".into(),
            ));
        }
        let n_ops = self.size.ops();
        let nvars = d + 1;
        let t_var = d;

        let mut constraints = Vec::new();
        let mut sum_row = vec![1.0; d];
        sum_row.push(0.0);
        constraints.push(Constraint::eq(sum_row, n_ops));

        // finish_x <= T (identical construction to the time problem).
        for (i, dev) in self.devices.iter().enumerate() {
            let mut row = vec![0.0; nvars];
            let mut rhs = -dev.b;
            row[i] += dev.a;
            row[t_var] = -1.0;
            if !dev.is_cpu {
                // Same structure as the time formulation: serialized H2D
                // waits, own D2H (see problem.rs).
                let h2d_waits: Vec<usize> = match self.bus {
                    BusModel::Exclusive => vec![i],
                    BusModel::SharedPriority => (0..d)
                        .filter(|&j| {
                            !self.devices[j].is_cpu
                                && self.devices[j].priority >= dev.priority
                        })
                        .collect(),
                };
                for &j in &h2d_waits {
                    let dj = &self.devices[j];
                    row[j] += dj.dtype_bytes / (self.size.n as f64 * dj.bw);
                    rhs -= dj.dtype_bytes * (self.size.k * self.size.n) as f64 / dj.bw
                        + 2.0 * dj.lat;
                }
                row[i] += dev.dtype_bytes / (self.size.k as f64 * dev.bw);
                rhs -= dev.lat;
            }
            constraints.push(Constraint::le(row, rhs));
        }

        if let Some(dl) = self.deadline_s {
            let mut row = vec![0.0; nvars];
            row[t_var] = 1.0;
            constraints.push(Constraint::le(row, dl));
        }

        // Objective: active energy (linear in c) + idle power * T.
        let mut objective = vec![0.0; nvars];
        let mut fixed_energy = 0.0;
        for (i, (dev, pw)) in self.devices.iter().zip(&self.power).enumerate() {
            // compute: a*c + b seconds.
            objective[i] += pw.active_w * dev.a;
            fixed_energy += pw.active_w * dev.b;
            if !dev.is_cpu {
                // copy: (dt/(n bw) + dt/(k bw)) * c + constants.
                objective[i] += pw.active_w
                    * (dev.dtype_bytes / (self.size.n as f64 * dev.bw)
                        + dev.dtype_bytes / (self.size.k as f64 * dev.bw));
                fixed_energy += pw.active_w
                    * (dev.dtype_bytes * (self.size.k * self.size.n) as f64 / dev.bw
                        + 3.0 * dev.lat);
            }
        }
        objective[t_var] = self.power.iter().map(|p| p.idle_w).sum();

        let lp = Lp {
            objective,
            constraints,
        };
        let sol = lp.solve()?;
        let ops: Vec<f64> = sol.x[..d].iter().map(|&c| c.max(0.0)).collect();
        let t_pred = sol.x[t_var];
        let compute_pred: Vec<f64> = self
            .devices
            .iter()
            .zip(&ops)
            .map(|(dev, &c)| dev.compute_time(c))
            .collect();
        let copy_pred: Vec<f64> = self
            .devices
            .iter()
            .zip(&ops)
            .map(|(dev, &c)| dev.copy_time(c, self.size))
            .collect();
        let energy = sol.objective + fixed_energy;
        Ok((
            SplitSolution {
                ops,
                t_pred,
                compute_pred,
                copy_pred,
            },
            energy,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn devices() -> (Vec<DeviceModelInput>, Vec<DevicePower>) {
        let mk = |name: &str, is_cpu: bool, rate_tops: f64, dt: f64, prio: u32| {
            DeviceModelInput {
                name: name.into(),
                is_cpu,
                a: 1.0 / (rate_tops * 1e12),
                b: 0.0,
                dtype_bytes: dt,
                bw: 15.75e9,
                lat: 0.0,
                priority: prio,
            }
        };
        (
            vec![
                mk("cpu", true, 0.109, 4.0, 0),
                mk("gpu", false, 5.6, 4.0, 1),
                mk("xpu", false, 21.5, 2.0, 2),
            ],
            vec![
                DevicePower {
                    active_w: 70.0,
                    idle_w: 25.0,
                },
                DevicePower {
                    active_w: 240.0,
                    idle_w: 18.0,
                },
                DevicePower {
                    active_w: 255.0,
                    idle_w: 18.0,
                },
            ],
        )
    }

    #[test]
    fn without_deadline_prefers_efficient_device() {
        let (devices, power) = devices();
        let p = EnergyProblem {
            devices,
            power,
            size: GemmSize::square(20_000),
            bus: BusModel::SharedPriority,
            deadline_s: None,
        };
        let (sol, energy) = p.solve().unwrap();
        assert!(energy > 0.0);
        // XPU: 255 W / 21.5 Tops = 11.9 J/Top — by far the most
        // energy-efficient; it should take (almost) everything.
        let shares = sol.shares();
        assert!(shares[2] > 0.95, "xpu share {}", shares[2]);
    }

    #[test]
    fn tight_deadline_forces_coexecution() {
        let (devices, power) = devices();
        // Time-optimal T for this size is ~0.29s/rep; force close to it.
        let size = GemmSize::square(20_000);
        let time_opt = crate::optimize::problem::SplitProblem {
            devices: devices.clone(),
            size,
            bus: BusModel::SharedPriority,
            row_integral: false,
        }
        .solve()
        .unwrap();
        let p = EnergyProblem {
            devices,
            power,
            size,
            bus: BusModel::SharedPriority,
            deadline_s: Some(time_opt.t_pred * 1.02),
        };
        let (sol, _) = p.solve().unwrap();
        let shares = sol.shares();
        // Meeting a near-optimal deadline requires the GPU too.
        assert!(shares[1] > 0.05, "gpu share {}", shares[1]);
    }

    #[test]
    fn energy_increases_as_deadline_tightens() {
        let (devices, power) = devices();
        let size = GemmSize::square(20_000);
        let solve_dl = |dl: Option<f64>| {
            EnergyProblem {
                devices: devices.clone(),
                power: power.clone(),
                size,
                bus: BusModel::SharedPriority,
                deadline_s: dl,
            }
            .solve()
            .unwrap()
            .1
        };
        let t_opt = crate::optimize::problem::SplitProblem {
            devices: devices.clone(),
            size,
            bus: BusModel::SharedPriority,
            row_integral: false,
        }
        .solve()
        .unwrap()
        .t_pred;
        let loose = solve_dl(None);
        let tight = solve_dl(Some(t_opt * 1.05));
        assert!(
            tight >= loose - 1e-6,
            "tight deadline must cost energy: {tight} vs {loose}"
        );
    }

    #[test]
    fn infeasible_deadline_detected() {
        let (devices, power) = devices();
        let p = EnergyProblem {
            devices,
            power,
            size: GemmSize::square(20_000),
            bus: BusModel::SharedPriority,
            deadline_s: Some(1e-6),
        };
        assert!(p.solve().is_err());
    }

    #[test]
    fn mismatched_power_entries_error() {
        let (devices, mut power) = devices();
        power.pop();
        let p = EnergyProblem {
            devices,
            power,
            size: GemmSize::square(100),
            bus: BusModel::Exclusive,
            deadline_s: None,
        };
        assert!(p.solve().is_err());
    }
}
