//! The Optimize phase: a from-scratch LP/MILP solver and the POAS
//! work-split formulation.
//!
//! The paper expresses the split of `ops` across devices as a
//! mixed-integer linear program (Eq. 1–4) and solves it with CPLEX 12.10
//! (§4.2.1). CPLEX is proprietary, so this module implements the solver
//! substrate from scratch:
//!
//! * [`simplex`] — a dense two-phase primal simplex with Bland's rule;
//! * [`milp`] — branch & bound on top of the LP relaxation;
//! * [`problem`] — the hgemms formulation: the minimax objective of Eq. 1
//!   linearized with an epigraph variable, the copy-time model of Eq. 4,
//!   and the serialized shared-bus extension the paper describes
//!   ("the function must take into account the time to copy the data of
//!   previous devices");
//! * [`energy`] — the energy-objective variant (§3: POAS can minimize
//!   energy instead of time).

pub mod energy;
pub mod milp;
pub mod problem;
pub mod simplex;

pub use energy::EnergyProblem;
pub use milp::{solve_milp, MilpOptions};
pub use problem::{DeviceModelInput, SplitProblem, SplitSolution};
pub use simplex::{Constraint, Lp, LpSolution, Relation};
