//! Deterministic pseudo-random numbers for the simulator.
//!
//! The whole evaluation must be reproducible run-to-run (the paper averages
//! 3 independent runs; we seed them 0, 1, 2), so the simulator uses its own
//! small PRNG instead of a system source: `SplitMix64` for seeding and
//! `xoshiro256**` for the stream — both public-domain algorithms with good
//! statistical quality and trivial state.

/// `xoshiro256**` PRNG seeded via `SplitMix64`.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 significant bits -> exact dyadic rationals in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire-style rejection-free-enough reduction; bias is < 2^-53 for
        // the n values used here (simulation jitter, not cryptography).
        ((self.uniform() * n as f64) as u64).min(n - 1)
    }

    /// Standard normal via Box–Muller (one value per call; the twin is
    /// discarded to keep the state machine trivial).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300); // avoid log(0)
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean `mu` and standard deviation `sigma`.
    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Multiplicative noise factor `~ N(1, sigma)`, clamped to stay
    /// positive — models run-to-run variance of a device's throughput.
    pub fn noise_factor(&mut self, sigma: f64) -> f64 {
        self.normal_with(1.0, sigma).max(0.01)
    }

    /// Fork an independent stream (for per-device generators).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(6);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
        // all residues hit
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn noise_factor_positive_and_centered() {
        let mut r = Rng::new(8);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.noise_factor(0.02)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01);
        for _ in 0..1000 {
            assert!(r.noise_factor(0.5) > 0.0);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut f1 = root.fork();
        let mut f2 = root.fork();
        let a: Vec<u64> = (0..10).map(|_| f1.next_u64()).collect();
        let b: Vec<u64> = (0..10).map(|_| f2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
