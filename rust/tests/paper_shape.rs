//! The acceptance criteria of DESIGN.md, as executable assertions.
//!
//! These pin the *shape* of the paper's evaluation — who wins, by
//! roughly what factor, and how the trends move — on the simulated
//! testbeds. Absolute numbers are not asserted (our substrate is a
//! simulator, not the authors' servers).

use poas::baselines;
use poas::config::presets;
use poas::coordinator::Pipeline;
use poas::workload::{paper_inputs, GemmSize};

/// Table 6 shape: XPU supermajority, GPU minority, CPU sliver; CPU share
/// larger on mach2 (24-core EPYC) than mach1 (6-core Xeon).
#[test]
fn table6_share_shape() {
    let mut cpu_shares = Vec::new();
    for cfg in [presets::mach1(), presets::mach2()] {
        let p = Pipeline::for_simulated_machine(&cfg, 0);
        for inp in paper_inputs() {
            let plan = p.plan(inp.size).unwrap();
            let s = plan.shares();
            assert!(
                s[2] > 0.60 && s[2] < 0.90,
                "{} {}: xpu share {}",
                cfg.name,
                inp.id,
                s[2]
            );
            assert!(
                s[1] > 0.10 && s[1] < 0.35,
                "{} {}: gpu share {}",
                cfg.name,
                inp.id,
                s[1]
            );
            assert!(s[0] < 0.03, "{} {}: cpu share {}", cfg.name, inp.id, s[0]);
        }
        let plan = p.plan(paper_inputs()[0].size).unwrap();
        cpu_shares.push(plan.shares()[0]);
    }
    assert!(
        cpu_shares[1] > cpu_shares[0],
        "mach2's EPYC must take a larger share than mach1's Xeon: {cpu_shares:?}"
    );
}

/// Table 7 shape: speedup orderings and rough factors on i1.
#[test]
fn table7_speedup_shape() {
    let size = GemmSize::square(30_000);
    let reps = 10;

    // mach1: CPU huge, GPU mid, XPU just above 1.
    let cfg = presets::mach1();
    let mut p = Pipeline::for_simulated_machine(&cfg, 0);
    let co = p.run_sim(size, reps).makespan;
    let s_cpu = baselines::standalone(&mut p.sim, 0, size, reps).makespan / co;
    let s_gpu = baselines::standalone(&mut p.sim, 1, size, reps).makespan / co;
    let s_xpu = baselines::standalone(&mut p.sim, 2, size, reps).makespan / co;
    assert!(s_cpu > 100.0, "mach1 cpu speedup {s_cpu}");
    assert!((4.0..12.0).contains(&s_gpu), "mach1 gpu speedup {s_gpu}");
    assert!((1.05..1.5).contains(&s_xpu), "mach1 xpu speedup {s_xpu}");

    // mach2: CPU tens, GPU ~2-3, XPU 1.1-1.6.
    let cfg = presets::mach2();
    let mut p = Pipeline::for_simulated_machine(&cfg, 0);
    let co = p.run_sim(size, reps).makespan;
    let s_cpu = baselines::standalone(&mut p.sim, 0, size, reps).makespan / co;
    let s_gpu = baselines::standalone(&mut p.sim, 1, size, reps).makespan / co;
    let s_xpu = baselines::standalone(&mut p.sim, 2, size, reps).makespan / co;
    assert!((15.0..80.0).contains(&s_cpu), "mach2 cpu speedup {s_cpu}");
    assert!((1.7..4.0).contains(&s_gpu), "mach2 gpu speedup {s_gpu}");
    assert!((1.1..1.7).contains(&s_xpu), "mach2 xpu speedup {s_xpu}");
}

/// Figs. 3/4 shape: the hgemms bar is the lowest for every input.
#[test]
fn fig3_fig4_hgemms_always_lowest() {
    for cfg in [presets::mach1(), presets::mach2()] {
        let mut p = Pipeline::for_simulated_machine(&cfg, 1);
        for inp in paper_inputs() {
            let co = p.run_sim(inp.size, 3).makespan;
            for dev in 0..3 {
                let alone = baselines::standalone(&mut p.sim, dev, inp.size, 3).makespan;
                assert!(
                    co < alone,
                    "{} {}: hgemms {co:.2}s not below device {dev} ({alone:.2}s)",
                    cfg.name,
                    inp.id
                );
            }
        }
    }
}

/// Table 4 shape: mach1 (bad cooling) predicts no better than mach2.
#[test]
fn table4_mach1_noisier_than_mach2() {
    let size = GemmSize::square(30_000);
    let mut errs = Vec::new();
    for cfg in [presets::mach1(), presets::mach2()] {
        let mut p = Pipeline::for_simulated_machine(&cfg, 0);
        let r = p.run_sim(size, 50);
        // XPU global error (the paper's dominant term).
        let pred = (r.plan.predicted.compute_pred[2] + r.plan.predicted.copy_pred[2]) * 50.0;
        let meas = r.exec.timelines[2].compute_s + r.exec.timelines[2].copy_s();
        errs.push(100.0 * (meas - pred).abs() / meas);
    }
    assert!(
        errs[0] > errs[1] * 0.8,
        "mach1 ({:.1}%) should not predict dramatically better than mach2 ({:.1}%)",
        errs[0],
        errs[1]
    );
    assert!(errs[0] < 20.0 && errs[1] < 15.0, "errors sane: {errs:?}");
}

/// §5.3 trend: the CPU's share does not grow as inputs grow (mach1 row
/// of Table 6: 0.32% at i1 down to 0.28% at i6).
#[test]
fn cpu_share_trend_with_size() {
    let cfg = presets::mach1();
    let p = Pipeline::for_simulated_machine(&cfg, 0);
    let inputs = paper_inputs();
    let first = p.plan(inputs[0].size).unwrap().shares()[0];
    let last = p.plan(inputs[5].size).unwrap().shares()[0];
    assert!(
        last <= first * 1.05,
        "cpu share should not grow with input size: i1 {first} vs i6 {last}"
    );
}
