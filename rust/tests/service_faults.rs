//! Fault-injection accounting on the serving cluster: crash requeue
//! invariants (PR 6 satellite) and straggler-drift behaviour under the
//! dynamic loop.
//!
//! Everything here runs on the event-driven virtual-time loop with
//! injected [`Cluster::inject_crash`] / [`Cluster::inject_restart`] /
//! [`Cluster::inject_slowdown`] events, so each test is exactly
//! replayable. The companion scenario-level invariants (fault-free
//! equivalence, digest determinism) live in `prop_invariants.rs`.

use poas::config::presets;
use poas::service::batch::{BatchPolicy, BatchWindow};
use poas::service::{Cluster, ClusterOptions, DeadlinePolicy, QosClass, ServerOptions};
use poas::workload::GemmSize;

fn heavy() -> GemmSize {
    GemmSize::square(16_000)
}

// ---------------------------------------------------------------------
// Crash requeue accounting
// ---------------------------------------------------------------------

#[test]
fn crash_requeues_onto_surviving_shard_with_original_arrival() {
    // Two identical shards, six heavy requests in one burst at t = 0 —
    // routing splits them — then shard 1 dies long before anything
    // heavy can finish. Every displaced request (its in-flight job and
    // its queue) must re-enter admission and complete on shard 0.
    let mut c = Cluster::builder().replicas(&presets::mach1(), 2).seed(9).build();
    let slo = 1e6;
    let ids: Vec<u64> = (0..5).map(|_| c.submit(heavy(), 2)).collect();
    let bound = c.submit_qos(heavy(), 2, QosClass::Interactive, Some(slo));
    c.inject_crash(0.01, 1);
    let report = c.run_to_completion();

    // Exactly once each: no request is lost or duplicated by the crash.
    assert_eq!(report.served.len(), 6);
    let mut seen: Vec<u64> = report.served.iter().map(|r| r.id).collect();
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len(), 6);

    // Shard 1 had work at t = 0.01 (the burst split), and every
    // executed record landed on the survivor.
    assert!(report.requeued >= 1, "shard 1 must have been displaced");
    assert_eq!(report.shards[1].requeued, report.requeued);
    assert_eq!(report.shards[0].requeued, 0);
    for r in &report.served {
        assert!(!r.mode.is_unserved(), "nothing should be denied here");
        assert_eq!(r.shard, Some(0), "request {} served on the dead shard", r.id);
        assert_eq!(r.arrival, 0.0, "requeue must keep the original arrival");
    }
    // Shard 1's per-class lanes were rolled back with its aborted work.
    assert_eq!(report.shards[1].served_by_class, [0, 0, 0]);

    // The SLO request was re-gated with its budget still charged from
    // the original arrival: deadline and class survive the requeue.
    let r = report.request(bound).unwrap();
    assert_eq!(r.class, QosClass::Interactive);
    assert_eq!(r.deadline_s, Some(slo));
    assert_eq!(r.deadline_met(), Some(r.finish - r.arrival <= slo));
    for id in ids {
        assert_eq!(report.request(id).unwrap().deadline_s, None);
    }
}

#[test]
fn total_outage_parks_arrivals_and_restart_readmits_once() {
    // One shard: three requests at t = 0 (one dispatches, two queue),
    // the shard crashes at 0.01, a fourth request arrives while the
    // whole cluster is down, and the shard returns at 0.5.
    let mut c = Cluster::builder().machine(&presets::mach1()).seed(12).build();
    for _ in 0..3 {
        c.submit(heavy(), 2);
    }
    c.inject_crash(0.01, 0);
    c.submit_request_at(
        0.02,
        poas::service::GemmRequest::new(100, heavy(), 2),
    );
    c.inject_restart(0.5, 0);
    let report = c.run_to_completion();

    assert_eq!(report.served.len(), 4);
    // Displacement counts the crash victims only: the t = 0.02 arrival
    // was parked at the front door, never displaced off a shard.
    assert_eq!(report.requeued, 3);
    assert_eq!(report.shards[0].requeued, 3);
    for r in &report.served {
        assert!(!r.mode.is_unserved());
        // Nothing can start before the restart — the pre-crash
        // dispatch was aborted and re-done.
        assert!(
            r.start >= 0.5,
            "request {} started at {} while the shard was down",
            r.id,
            r.start
        );
    }
    // Original arrivals survive the park/requeue round-trip.
    assert_eq!(report.request(100).unwrap().arrival, 0.02);
    assert!(report
        .served
        .iter()
        .filter(|r| r.id != 100)
        .all(|r| r.arrival == 0.0));
}

#[test]
fn crash_mid_flight_disbands_batch_and_members_readmit_solo() {
    // Two gpu_nodes with windowed batching: four simultaneous small
    // standalone-bound requests fuse into one batch (see
    // `windowed_batching_fuses_a_simultaneous_small_burst`) and
    // dispatch on one shard. A probe run discovers the batch's shard
    // and flight window; the real run crashes that shard mid-flight,
    // so the in-flight `ExecMode::Batched` records must be aborted and
    // every member re-admitted *solo* on the survivor.
    let build = || {
        let mut c = Cluster::builder()
            .replicas(&presets::gpu_node(), 2)
            .seed(21)
            .options(ClusterOptions {
                batching: BatchPolicy::Windowed(BatchWindow {
                    window_s: 0.05,
                    max_members: 4,
                    ..Default::default()
                }),
                ..Default::default()
            })
            .build();
        for _ in 0..4 {
            c.submit(GemmSize::square(1024), 2);
        }
        c
    };

    // Probe: where did the batch fly, and when?
    let probe = build().run_to_completion();
    assert_eq!(probe.fused(), 4, "probe burst must fuse into one batch");
    assert_eq!(probe.num_batches(), 1);
    let members: Vec<_> = probe.served.iter().filter(|r| r.mode.is_batched()).collect();
    let shard = members[0].shard.expect("batched members carry their shard");
    let start = members[0].start;
    let min_finish = members
        .iter()
        .map(|r| r.finish)
        .fold(f64::INFINITY, f64::min);
    assert!(min_finish > start);
    let mid = 0.5 * (start + min_finish);

    // Real run: same construction, crash at mid-flight.
    let mut c = build();
    c.inject_crash(mid, shard);
    let report = c.run_to_completion();

    assert_eq!(report.served.len(), 4);
    assert_eq!(report.requeued, 4, "all four members displaced at once");
    assert_eq!(report.shards[shard].requeued, 4);
    let survivor = 1 - shard;
    for r in &report.served {
        assert!(
            !r.mode.is_batched(),
            "member {} re-fused after the crash; re-admission must route solo",
            r.id
        );
        assert!(!r.mode.is_unserved());
        assert_eq!(r.shard, Some(survivor));
        assert_eq!(r.arrival, 0.0, "members keep their original arrival");
        assert!(r.start >= mid, "nothing re-dispatches before the crash");
    }
    assert_eq!(report.fused(), 0, "the aborted batch leaves no fused records");
    // The dead shard's lanes rolled back with the aborted members.
    assert_eq!(report.shards[shard].served_by_class, [0, 0, 0]);
    assert_eq!(
        report.shards[survivor].served_by_class.iter().sum::<usize>(),
        4
    );
}

// ---------------------------------------------------------------------
// Straggler drift under the dynamic loop
// ---------------------------------------------------------------------

#[test]
fn slowdown_drift_triggers_replan_and_gate_epoch_bump() {
    // The machine runs at 40% of its fitted model from t = 0 — a 2.5x
    // drift, far past the 2% replan threshold. With the dynamic loop
    // closed the first observed execution forces a replan, the shard's
    // admission gate adopts the refreshed model (epoch bump), and
    // placement quality recovers toward 1. The static ablation keeps
    // predicting with the stale model and stays near 2.5.
    let run = |dynamic: bool| {
        let mut c = Cluster::builder()
            .machine(&presets::mach1())
            .seed(31)
            .options(ClusterOptions {
                shard: ServerOptions {
                    dynamic,
                    ..Default::default()
                },
                ..Default::default()
            })
            .build();
        let epoch_before = c.admission_for(0).epoch();
        c.inject_slowdown(0.0, 0, 0.4);
        for _ in 0..8 {
            c.submit(heavy(), 3);
        }
        let report = c.run_to_completion();
        let epoch_after = c.admission_for(0).epoch();
        (report, epoch_after - epoch_before)
    };

    let (dyn_report, dyn_epochs) = run(true);
    let (static_report, static_epochs) = run(false);

    assert!(dyn_report.replans > 0, "2.5x drift must force a replan");
    assert!(dyn_report.epoch_bumps > 0, "replans invalidate the plan cache");
    assert!(
        dyn_epochs > 0,
        "the shard's admission gate must adopt the refreshed model"
    );
    assert_eq!(static_report.replans, 0);
    assert_eq!(static_epochs, 0);

    let dyn_q = dyn_report.placement_quality();
    let static_q = static_report.placement_quality();
    assert!(
        static_q > 1.5,
        "stale model must mispredict the slowed machine: quality {static_q}"
    );
    assert!(
        (dyn_q - 1.0).abs() < (static_q - 1.0).abs(),
        "dynamic loop must recover placement quality: {dyn_q} vs static {static_q}"
    );
    // Both runs serve everything exactly once either way.
    assert_eq!(dyn_report.served.len(), 8);
    assert_eq!(static_report.served.len(), 8);
}

#[test]
fn deadline_policy_is_honored_under_drift() {
    // A machine slowed to 30% and a request whose SLO was never
    // feasible: Reject must deny it (no shard, no machine time);
    // Downclass must demote it to best-effort Batch instead — denial
    // is impossible under Downclass, drift or not.
    let run = |policy: DeadlinePolicy| {
        let mut c = Cluster::builder()
            .machine(&presets::mach2())
            .seed(41)
            .options(ClusterOptions {
                shard: ServerOptions {
                    deadline_policy: policy,
                    ..Default::default()
                },
                ..Default::default()
            })
            .build();
        c.inject_slowdown(0.0, 0, 0.3);
        let ok = c.submit(heavy(), 2);
        let tight = c.submit_qos(heavy(), 2, QosClass::Interactive, Some(1e-3));
        let report = c.run_to_completion();
        (report, ok, tight)
    };

    let (rej, ok_r, tight_r) = run(DeadlinePolicy::Reject);
    assert_eq!(rej.denied, 1);
    let denied = rej.request(tight_r).unwrap();
    assert!(denied.mode.is_denied());
    assert_eq!(denied.shard, None, "denials never reach a shard");
    assert_eq!(denied.class, QosClass::Interactive, "denial keeps the tier");
    assert!(!rej.request(ok_r).unwrap().mode.is_unserved());

    let (down, ok_d, tight_d) = run(DeadlinePolicy::Downclass);
    assert_eq!(down.denied, 0, "Downclass never denies");
    let demoted = down.request(tight_d).unwrap();
    assert!(!demoted.mode.is_unserved(), "demoted work still executes");
    assert_eq!(demoted.class, QosClass::Batch, "demotion lands in Batch");
    assert_eq!(demoted.deadline_s, None, "the SLO is given up, not missed");
    assert!(!down.request(ok_d).unwrap().mode.is_unserved());
    // The explicit counters mirror the records in both runs.
    for r in [&rej, &down] {
        assert_eq!(
            r.denied,
            r.served.iter().filter(|s| s.mode.is_denied()).count()
        );
        assert_eq!(
            r.rejected,
            r.served.iter().filter(|s| s.mode.is_rejected()).count()
        );
    }
}
