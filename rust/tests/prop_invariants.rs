//! Property-based tests on the coordinator's invariants.
//!
//! No external property-testing crate is available offline, so this file
//! carries a small in-tree harness: `prop!` runs a closure over N
//! deterministic random cases from the crate's own PRNG and reports the
//! first failing case's seed for reproduction.

use poas::adapt::{align_rows, assignments_cover, decompose, ops_to_mnk, ops_to_rows, AdaptOptions, AdaptRules};
use poas::optimize::milp::{solve_milp, MilpOptions};
use poas::optimize::simplex::{Constraint, Lp};
use poas::optimize::problem::{BusModel, DeviceModelInput, SplitProblem};
use poas::optimize::SplitSolution;
use poas::rng::Rng;
use poas::sim::bus::{Bus, BusPolicy, Direction, TransferReq};
use poas::workload::GemmSize;

/// Run `cases` deterministic random property checks.
fn prop<F: FnMut(&mut Rng, u64)>(name: &str, cases: u64, mut f: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        // A panic inside f carries `name` and `case` via the message of
        // the assert; wrap to add context.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng, case)
        }));
        if let Err(e) = result {
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {e:?}");
        }
    }
}

// ---------------------------------------------------------------------
// Adapt invariants
// ---------------------------------------------------------------------

#[test]
fn prop_ops_to_rows_conserves_and_bounds() {
    prop("ops_to_rows conservation", 500, |rng, _| {
        let d = 1 + (rng.below(5) as usize);
        let total = 1 + rng.below(100_000);
        let ops: Vec<f64> = (0..d).map(|_| rng.uniform() * 1e12).collect();
        let rows = ops_to_rows(&ops, total);
        assert_eq!(rows.iter().sum::<u64>(), total);
        // Each device's rows within 1 of the exact proportional value.
        let sum: f64 = ops.iter().sum();
        if sum > 0.0 {
            for (r, o) in rows.iter().zip(&ops) {
                let exact = o / sum * total as f64;
                assert!(
                    (*r as f64 - exact).abs() <= 1.0 + 1e-9,
                    "rows {r} vs exact {exact}"
                );
            }
        }
    });
}

#[test]
fn prop_align_rows_conserves_and_aligns() {
    prop("align_rows", 500, |rng, _| {
        let d = 1 + (rng.below(5) as usize);
        let rows: Vec<u64> = (0..d).map(|_| rng.below(50_000)).collect();
        let aligns: Vec<u64> = (0..d)
            .map(|_| *[1u64, 1, 8, 16].get(rng.below(4) as usize).unwrap())
            .collect();
        let rules: Vec<AdaptRules> = aligns
            .iter()
            .map(|&a| AdaptRules {
                align: a,
                ops_lo: 0.0,
                ops_hi: f64::INFINITY,
            })
            .collect();
        let ranks: Vec<u32> = (0..d as u32).collect();
        let out = align_rows(&rows, &rules, &ranks);
        assert_eq!(out.iter().sum::<u64>(), rows.iter().sum::<u64>());
        // Any device that was shaved is aligned; the absorber may not be.
        let absorber = (0..d)
            .filter(|&i| aligns[i] <= 1)
            .max_by_key(|&i| ranks[i])
            .unwrap_or_else(|| (0..d).max_by_key(|&i| ranks[i]).unwrap());
        for i in 0..d {
            if i != absorber && aligns[i] > 1 {
                assert_eq!(out[i] % aligns[i], 0, "device {i} misaligned");
            }
        }
    });
}

#[test]
fn prop_decompose_conserves_ops_and_alignment() {
    prop("decompose", 300, |rng, _| {
        let align = *[1u64, 8].get(rng.below(2) as usize).unwrap();
        let rows = align * (1 + rng.below(4000));
        let n = 8 * (1 + rng.below(3000));
        let k = align * (1 + rng.below(3000));
        let lo = 1e9;
        let hi = 216e9;
        let d = decompose(rows, n, k, lo, hi, align);
        let total: f64 = d.tiles.iter().map(|t| t.ops()).sum();
        let want = GemmSize::new(rows, n, k).ops();
        assert!(
            (total - want).abs() < want * 1e-9 + 1.0,
            "ops {total} != {want}"
        );
        assert_eq!(k % d.k_prime, 0);
        if align > 1 && d.tiles.len() > 1 {
            for t in &d.tiles {
                assert_eq!(t.m % align, 0, "tile m misaligned");
                assert_eq!(t.k % align, 0, "tile k misaligned");
            }
        }
    });
}

#[test]
fn prop_ops_to_mnk_covers_problem() {
    prop("ops_to_mnk coverage", 200, |rng, _| {
        let size = GemmSize::new(
            8 * (1 + rng.below(4000)),
            8 * (1 + rng.below(3000)),
            8 * (1 + rng.below(3000)),
        );
        let total = size.ops();
        let w = [rng.uniform(), rng.uniform(), rng.uniform()];
        let wsum: f64 = w.iter().sum();
        let split = SplitSolution {
            ops: w.iter().map(|x| x / wsum * total).collect(),
            t_pred: 1.0,
            compute_pred: vec![],
            copy_pred: vec![],
        };
        let rules = vec![
            AdaptRules {
                align: 1,
                ops_lo: 1e9,
                ops_hi: 8e9,
            },
            AdaptRules {
                align: 1,
                ops_lo: 27e9,
                ops_hi: 216e9,
            },
            AdaptRules {
                align: 8,
                ops_lo: 27e9,
                ops_hi: 216e9,
            },
        ];
        let asg =
            ops_to_mnk(&split, size, &rules, &[0, 1, 2], &AdaptOptions::default()).unwrap();
        assert!(assignments_cover(&asg, size));
        // Offsets are a partition.
        let mut cursor = 0;
        for a in &asg {
            assert_eq!(a.row_offset, cursor);
            cursor += a.rows;
        }
        assert_eq!(cursor, size.m);
    });
}

// ---------------------------------------------------------------------
// Optimizer invariants
// ---------------------------------------------------------------------

#[test]
fn prop_lp_solution_is_feasible() {
    prop("simplex feasibility", 300, |rng, _| {
        // Random small LP: 2-4 vars, 2-5 constraints, mixed relations.
        let n = 2 + rng.below(3) as usize;
        let m = 2 + rng.below(4) as usize;
        let objective: Vec<f64> = (0..n).map(|_| rng.range(-5.0, 5.0)).collect();
        let constraints: Vec<Constraint> = (0..m)
            .map(|_| {
                let coeffs: Vec<f64> = (0..n).map(|_| rng.range(-3.0, 3.0)).collect();
                // Keep rhs >= small positive so x=0 feasible for Le; mix
                // in some Ge with rhs <= 0 (also feasible at 0).
                match rng.below(3) {
                    0 => Constraint::le(coeffs, rng.range(0.1, 10.0)),
                    1 => Constraint::ge(coeffs, rng.range(-10.0, -0.1)),
                    _ => Constraint::le(coeffs, rng.range(0.1, 10.0)),
                }
            })
            .collect();
        let lp = Lp {
            objective,
            constraints,
        };
        match lp.solve() {
            Ok(sol) => {
                // Check feasibility of the returned point.
                for (ci, c) in lp.constraints.iter().enumerate() {
                    let lhs: f64 = c.coeffs.iter().zip(&sol.x).map(|(a, x)| a * x).sum();
                    let ok = match c.op {
                        poas::optimize::simplex::Relation::Le => lhs <= c.rhs + 1e-6,
                        poas::optimize::simplex::Relation::Ge => lhs >= c.rhs - 1e-6,
                        poas::optimize::simplex::Relation::Eq => (lhs - c.rhs).abs() < 1e-6,
                    };
                    assert!(ok, "constraint {ci} violated: {lhs} vs {}", c.rhs);
                }
                for &x in &sol.x {
                    assert!(x >= -1e-7, "negative variable {x}");
                }
            }
            Err(_) => {} // unbounded is legitimate for random objectives
        }
    });
}

#[test]
fn prop_split_problem_conserves_and_bounds() {
    prop("split conservation", 200, |rng, _| {
        let size = GemmSize::new(
            1000 + rng.below(100_000),
            1000 + rng.below(50_000),
            1000 + rng.below(50_000),
        );
        let d = 2 + rng.below(3) as usize;
        let devices: Vec<DeviceModelInput> = (0..d)
            .map(|i| DeviceModelInput {
                name: format!("d{i}"),
                is_cpu: i == 0,
                a: 1.0 / (rng.range(0.1, 50.0) * 1e12),
                b: rng.range(0.0, 1e-4),
                dtype_bytes: if rng.below(2) == 0 { 4.0 } else { 2.0 },
                bw: rng.range(5.0, 40.0) * 1e9,
                lat: 1e-5,
                priority: i as u32,
            })
            .collect();
        let p = SplitProblem {
            devices,
            size,
            bus: if rng.below(2) == 0 {
                BusModel::Exclusive
            } else {
                BusModel::SharedPriority
            },
            row_integral: false,
        };
        let sol = p.solve().unwrap();
        let total: f64 = sol.ops.iter().sum();
        assert!(
            (total - size.ops()).abs() < size.ops() * 1e-6,
            "ops not conserved: {total} vs {}",
            size.ops()
        );
        for &c in &sol.ops {
            assert!(c >= -1e-6);
        }
        assert!(sol.t_pred > 0.0);
        // T must be at least the best single device's pure compute bound.
        let best_rate = p
            .devices
            .iter()
            .map(|dv| 1.0 / dv.a)
            .fold(0.0f64, f64::max);
        let all_rate: f64 = p.devices.iter().map(|dv| 1.0 / dv.a).sum();
        assert!(sol.t_pred >= size.ops() / all_rate - 1e-9);
        assert!(sol.t_pred <= size.ops() / best_rate * 2.0 + 1.0);
    });
}

#[test]
fn prop_milp_respects_units_and_dominates_relaxation() {
    prop("milp units", 100, |rng, _| {
        let unit = 1.0 + rng.below(20) as f64;
        let total = unit * (10.0 + rng.below(500) as f64);
        let r1 = rng.range(1.0, 10.0);
        let r2 = rng.range(1.0, 10.0);
        // min T st c1/r1 <= T, c2/r2 <= T, c1+c2 = total, c1 unit-integral.
        let lp = Lp {
            objective: vec![0.0, 0.0, 1.0],
            constraints: vec![
                Constraint::le(vec![1.0 / r1, 0.0, -1.0], 0.0),
                Constraint::le(vec![0.0, 1.0 / r2, -1.0], 0.0),
                Constraint::eq(vec![1.0, 1.0, 0.0], total),
            ],
        };
        let relax = lp.solve().unwrap();
        let milp = solve_milp(
            &lp,
            &MilpOptions {
                integer_units: vec![(0, unit)],
                ..Default::default()
            },
        )
        .unwrap();
        let units = milp.x[0] / unit;
        assert!(
            (units - units.round()).abs() < 1e-5,
            "not integral: {}",
            milp.x[0]
        );
        assert!(milp.objective >= relax.objective - 1e-9);
        // Within one unit's worth of the relaxation.
        let unit_time = unit / r1.min(r2);
        assert!(milp.objective <= relax.objective + unit_time + 1e-6);
    });
}

// ---------------------------------------------------------------------
// Bus invariants
// ---------------------------------------------------------------------

#[test]
fn prop_bus_serialization_and_work_conservation() {
    prop("bus serialization", 300, |rng, _| {
        let policy = match rng.below(3) {
            0 => BusPolicy::Priority,
            1 => BusPolicy::Fifo,
            _ => BusPolicy::RoundRobin,
        };
        let mut bus = Bus::new(policy);
        let nreq = 1 + rng.below(12) as usize;
        let reqs: Vec<TransferReq> = (0..nreq)
            .map(|i| TransferReq {
                device: i % 3,
                dir: if rng.below(2) == 0 {
                    Direction::H2D
                } else {
                    Direction::D2H
                },
                label: "p",
                ready: rng.range(0.0, 1.0),
                duration: rng.range(0.001, 0.5),
                bytes: 1e6,
                priority: rng.below(4) as u32,
            })
            .collect();
        let total_dur: f64 = reqs.iter().map(|r| r.duration).sum();
        let spans = bus.schedule(reqs.clone());
        // Serialized.
        assert!(bus.trace().is_serialized());
        // Work conserving: busy time equals sum of durations.
        assert!((bus.trace().busy_time() - total_dur).abs() < 1e-9);
        // Each request's span >= its duration and starts after ready.
        for (r, (s, e)) in reqs.iter().zip(&spans) {
            assert!(*e - *s >= r.duration - 1e-9);
            assert!(*s >= r.ready - 1e-9);
        }
    });
}

// ---------------------------------------------------------------------
// PlanCache invariants (service layer)
// ---------------------------------------------------------------------

#[test]
fn prop_plan_cache_hit_identical_to_fresh_solve() {
    use poas::config::presets;
    use poas::predict::{profile, ProfileOptions};
    use poas::schedule::{build_plan, static_sched::rules_from_config, PlanOptions};
    use poas::service::PlanCache;
    use poas::sim::SimMachine;

    let cfg = presets::mach1();
    let mut sim = SimMachine::new(&cfg, 42);
    let model = profile(&mut sim, &ProfileOptions::default()).unwrap();
    let rules = rules_from_config(&cfg);
    let opts = PlanOptions::default();
    let mut cache = PlanCache::new(256);

    prop("plan cache hit == fresh solve", 60, |rng, _| {
        // Draw from a small menu of sizes so repeated shapes (and
        // therefore organic hits) occur across cases.
        let size = GemmSize::new(
            2_000 + 1_000 * rng.below(12),
            2_000 + 1_000 * rng.below(8),
            2_000 + 1_000 * rng.below(8),
        );
        let fresh = build_plan(&model, size, &rules, &opts).unwrap();
        let (cached, _first_hit) = cache.get_or_build(&model, size, &rules, &opts).unwrap();
        assert!(cached.same_split(&fresh), "cached plan diverged for {size}");
        // A second lookup is a guaranteed hit and still identical to the
        // fresh solve (plan construction is deterministic).
        let (again, hit) = cache.get_or_build(&model, size, &rules, &opts).unwrap();
        assert!(hit, "second lookup of {size} missed");
        assert!(again.same_split(&fresh));
    });
    assert!(cache.hits >= 60, "expected at least one hit per case");
}

#[test]
fn prop_plan_cache_epoch_bump_invalidates_all_entries() {
    use poas::config::presets;
    use poas::predict::{profile, ProfileOptions};
    use poas::schedule::{build_plan, static_sched::rules_from_config, PlanOptions};
    use poas::service::PlanCache;
    use poas::sim::SimMachine;

    let cfg = presets::mach2();
    let mut sim = SimMachine::new(&cfg, 43);
    let model = profile(&mut sim, &ProfileOptions::default()).unwrap();
    let rules = rules_from_config(&cfg);
    let opts = PlanOptions::default();

    prop("plan cache epoch invalidation", 20, |rng, _| {
        let mut cache = PlanCache::new(64);
        let mut sizes = Vec::new();
        for _ in 0..(1 + rng.below(6)) {
            let size = GemmSize::new(
                2_000 + 1_000 * rng.below(10),
                2_000 + 1_000 * rng.below(6),
                2_000 + 1_000 * rng.below(6),
            );
            cache.get_or_build(&model, size, &rules, &opts).unwrap();
            sizes.push(size);
        }
        let epoch0 = cache.epoch();
        cache.bump_epoch();
        assert_eq!(cache.epoch(), epoch0 + 1);
        assert!(cache.is_empty(), "entries survived the epoch bump");
        for &size in &sizes {
            assert!(cache.peek(size).is_none(), "stale entry for {size}");
        }
        // Re-resolving after the bump must miss, re-solve, and agree
        // with a fresh build against the current model.
        let misses_before = cache.misses;
        let (rebuilt, hit) = cache.get_or_build(&model, sizes[0], &rules, &opts).unwrap();
        assert!(!hit, "lookup after bump must not hit");
        assert_eq!(cache.misses, misses_before + 1);
        let fresh = build_plan(&model, sizes[0], &rules, &opts).unwrap();
        assert!(rebuilt.same_split(&fresh));
    });
}

// ---------------------------------------------------------------------
// QoS invariants (service layer)
// ---------------------------------------------------------------------

#[test]
fn prop_weighted_queue_never_starves_a_nonempty_class() {
    use poas::service::{GemmRequest, QosClass, QueuePolicy, QueuedRequest, RequestQueue};

    prop("weighted queue no starvation", 300, |rng, _| {
        let policy = if rng.below(2) == 0 {
            QueuePolicy::Fifo
        } else {
            QueuePolicy::Spjf
        };
        let mut rq = RequestQueue::new(policy);
        let mut id = 0u64;
        for class in QosClass::ALL {
            for _ in 0..(1 + rng.below(12)) {
                rq.push(QueuedRequest {
                    req: GemmRequest::new(id, GemmSize::square(1000), 1).with_class(class),
                    arrival: id as f64,
                    co_execute: true,
                    best_device: 0,
                    predicted_s: rng.range(0.1, 5.0),
                    batch: None,
                });
                id += 1;
            }
        }
        let total_w: u64 = QosClass::ALL.iter().map(|c| c.weight()).sum();
        // Pops a non-empty class can be passed over before it *must* be
        // served: the smooth weighted round-robin serves class c within
        // ~total/weight pops; assert a 2x-slack bound, which still
        // disproves starvation.
        let bound = |c: QosClass| -> u64 { (2 * total_w).div_ceil(c.weight()) };
        let mut waited = [0u64; 3];
        while let Some(got) = rq.pop_next() {
            waited[got.req.class.index()] = 0;
            for c in QosClass::ALL {
                if rq.class_len(c) > 0 && c != got.req.class {
                    waited[c.index()] += 1;
                    assert!(
                        waited[c.index()] <= bound(c),
                        "{c} waited {} pops (bound {})",
                        waited[c.index()],
                        bound(c)
                    );
                }
            }
        }
        assert!(rq.is_empty());
    });
}

#[test]
fn prop_deadline_admission_verdicts_replay_deterministically() {
    use poas::config::presets;
    use poas::coordinator::Pipeline;
    use poas::service::{
        ClassLoad, Cluster, ClusterOptions, DeadlinePolicy, MixedArrivals, QosClass, ServerOptions,
    };

    // Profile once; each case clones the pipelines so both runs of a
    // case start from the identical installation state.
    let p0 = Pipeline::for_simulated_machine(&presets::mach2(), 0);
    let p1 = Pipeline::for_simulated_machine(&presets::mach2(), 1);

    prop("deadline admission replay", 6, |rng, _| {
        let rate = rng.range(0.5, 4.0);
        let deadline = rng.range(0.5, 8.0);
        let seed = rng.below(1 << 20);
        let policy = if rng.below(2) == 0 {
            DeadlinePolicy::Reject
        } else {
            DeadlinePolicy::Downclass
        };
        let mix = MixedArrivals::new(
            vec![
                ClassLoad {
                    class: QosClass::Interactive,
                    rate_rps: rate,
                    menu: vec![(GemmSize::square(16_000), 2), (GemmSize::square(20_000), 2)],
                    deadline_s: Some(deadline),
                },
                ClassLoad {
                    class: QosClass::Batch,
                    rate_rps: rate * 2.0,
                    menu: vec![(GemmSize::square(18_000), 2)],
                    deadline_s: None,
                },
            ],
            seed,
        );
        let run = || {
            let mut cluster = Cluster::from_pipelines(
                vec![p0.clone(), p1.clone()],
                ClusterOptions {
                    shards: 2,
                    shard: ServerOptions {
                        deadline_policy: policy,
                        ..Default::default()
                    },
                    work_stealing: true,
                    ..Default::default()
                },
            );
            cluster.submit_trace(&mix.trace(6));
            cluster.run_to_completion()
        };
        let a = run();
        let b = run();
        // The whole report — including every accept/deny/downclass
        // verdict — must replay byte-identically.
        assert_eq!(a, b);
        let denied: Vec<u64> = a
            .served
            .iter()
            .filter(|r| r.mode.is_denied())
            .map(|r| r.id)
            .collect();
        let denied_b: Vec<u64> = b
            .served
            .iter()
            .filter(|r| r.mode.is_denied())
            .map(|r| r.id)
            .collect();
        assert_eq!(denied, denied_b, "denial verdicts drifted across replays");
        if policy == DeadlinePolicy::Downclass {
            assert!(denied.is_empty(), "downclass policy must never deny");
        }
        // Every arrival is accounted for exactly once.
        assert_eq!(a.served.len(), 12);
    });
}

#[test]
fn prop_hetero_cluster_replay_is_byte_identical() {
    use poas::config::presets;
    use poas::coordinator::Pipeline;
    use poas::service::{Cluster, ClusterOptions, PoissonArrivals};

    // Profile the three distinct machines once; each case clones the
    // pipelines so both runs of a case start from identical
    // installation state.
    let pipes: Vec<Pipeline> = presets::hetero_mix()
        .iter()
        .enumerate()
        .map(|(i, cfg)| Pipeline::for_simulated_machine(cfg, 60 + i as u64))
        .collect();
    let menu = vec![
        (GemmSize::square(16_000), 2),
        (GemmSize::square(20_000), 2),
        (GemmSize::square(400), 2),
    ];

    prop("hetero cluster replay", 5, |rng, _| {
        let rate = rng.range(0.2, 3.0);
        let seed = rng.below(1 << 20);
        let stealing = rng.below(2) == 0;
        let trace = PoissonArrivals::new(rate, menu.clone(), seed).trace(8);
        let run = || {
            let mut cluster = Cluster::from_pipelines(
                pipes.clone(),
                ClusterOptions {
                    work_stealing: stealing,
                    ..Default::default()
                },
            );
            cluster.submit_trace(&trace);
            cluster.run_to_completion()
        };
        let a = run();
        let b = run();
        // The whole report — routing decisions, per-shard stats, model
        // fingerprints, placement accounting — must replay
        // byte-identically on a heterogeneous cluster.
        assert_eq!(a, b);
        assert_eq!(
            format!("{a:?}"),
            format!("{b:?}"),
            "hetero replay must be byte-identical"
        );
        assert_eq!(a.served.len(), 8);
        // Per-shard models stay distinct across the replay.
        let fps: std::collections::HashSet<u64> =
            a.shards.iter().map(|s| s.model_fp).collect();
        assert_eq!(fps.len(), 3);
    });
}

#[test]
fn prop_batched_cluster_replay_is_byte_identical() {
    use poas::config::presets;
    use poas::coordinator::Pipeline;
    use poas::service::{BatchPolicy, BatchWindow, Cluster, ClusterOptions, PoissonArrivals};

    // Profile the three distinct machines once; each case clones the
    // pipelines so both runs of a case start from identical
    // installation state.
    let pipes: Vec<Pipeline> = presets::hetero_mix()
        .iter()
        .enumerate()
        .map(|(i, cfg)| Pipeline::for_simulated_machine(cfg, 80 + i as u64))
        .collect();
    // Small-GEMM-heavy menu: most arrivals are batching candidates
    // (one shared (n, k) shape class), with a heavy co-exec shape and a
    // shape-class outlier mixed in.
    let menu = vec![
        (GemmSize::new(1600, 2000, 2000), 2),
        (GemmSize::new(2000, 2000, 2000), 2),
        (GemmSize::new(1792, 1024, 1024), 2),
        (GemmSize::square(16_000), 2),
    ];

    prop("batched cluster replay", 4, |rng, _| {
        let rate = rng.range(20.0, 400.0);
        let seed = rng.below(1 << 20);
        let window_s = rng.range(0.002, 0.1);
        let n = 14;
        let trace = PoissonArrivals::new(rate, menu.clone(), seed).trace(n);
        let run = || {
            let mut cluster = Cluster::from_pipelines(
                pipes.clone(),
                ClusterOptions {
                    batching: BatchPolicy::Windowed(BatchWindow {
                        window_s,
                        max_members: 4,
                        ..Default::default()
                    }),
                    ..Default::default()
                },
            );
            cluster.submit_trace(&trace);
            cluster.run_to_completion()
        };
        let a = run();
        let b = run();
        // The whole report — window formation, flush timing, batch
        // routing, member fan-out — must replay byte-identically.
        assert_eq!(a, b);
        assert_eq!(
            format!("{a:?}"),
            format!("{b:?}"),
            "batched replay must be byte-identical"
        );
        // Every member is served exactly once, whatever it fused into.
        assert_eq!(a.served.len(), n);
        let mut ids: Vec<u64> = a.served.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n as u64).collect::<Vec<u64>>());
        // Fused members and their batches agree across the replay.
        assert_eq!(a.fused(), b.fused());
        assert_eq!(a.num_batches(), b.num_batches());
    });
}

// ---------------------------------------------------------------------
// End-to-end plan invariant on random workloads
// ---------------------------------------------------------------------

#[test]
fn prop_random_workloads_always_covered() {
    use poas::config::presets;
    use poas::predict::{profile, ProfileOptions};
    use poas::schedule::{build_plan, static_sched::rules_from_config, PlanOptions};
    use poas::sim::SimMachine;

    let cfg = presets::mach1();
    let mut sim = SimMachine::new(&cfg, 99);
    let model = profile(&mut sim, &ProfileOptions::default()).unwrap();
    let rules = rules_from_config(&cfg);

    prop("random workload coverage", 100, |rng, _| {
        let size = GemmSize::new(
            1000 + rng.below(120_000),
            1000 + rng.below(60_000),
            1000 + rng.below(60_000),
        );
        let plan = build_plan(&model, size, &rules, &PlanOptions::default()).unwrap();
        assert!(assignments_cover(&plan.assignments, size), "size {size}");
        let shares = plan.shares();
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    });
}

// ---------------------------------------------------------------------
// Scenario-engine invariants
// ---------------------------------------------------------------------

#[test]
fn prop_faultfree_scenario_equals_direct_cluster() {
    use poas::service::scenario::Scenario;
    use poas::service::Cluster;

    // A scenario with no [[fault]] tables must be indistinguishable —
    // field for field, `PartialEq` on the whole `ServiceReport` — from
    // building the equivalent cluster by hand and submitting the same
    // realized trace. The fault machinery must be a strict no-op when
    // no fault fires.
    prop("fault-free scenario == direct cluster", 3, |rng, _| {
        let seed = rng.below(1 << 16);
        let rate = rng.range(0.5, 3.0);
        let stealing = rng.below(2);
        let text = format!(
            r#"
            name = "equiv"
            seed = {seed}
            work_stealing = {stealing}

            [[shard]]
            preset = "mach1"
            count = 2

            [[arrivals]]
            process = "poisson"
            class = "standard"
            rate_rps = {rate}
            count = 6
            menu = "16000*2, 12000x18000x14000*2"

            [[arrivals]]
            process = "poisson"
            class = "interactive"
            rate_rps = 1.0
            count = 3
            deadline_s = 60.0
            menu = "10000*2"
            "#
        );
        let sc: Scenario = text.parse().expect("scenario parses");
        assert!(sc.faults.is_empty());

        let via_scenario = sc.run();
        let mut direct = Cluster::builder()
            .machines(&sc.machines)
            .seed(sc.seed)
            .options(sc.opts.clone())
            .build();
        direct.submit_trace(&sc.trace());
        let via_cluster = direct.run_to_completion();

        assert_eq!(via_scenario, via_cluster);
        assert_eq!(
            format!("{via_scenario:?}"),
            format!("{via_cluster:?}"),
            "fault-free scenario must be byte-identical to the direct cluster"
        );
        assert_eq!(via_scenario.requeued, 0);
    });
}

#[test]
fn prop_fault_scenario_replay_is_deterministic() {
    use poas::service::scenario::{digest, Scenario};

    // Crash + restart + straggler drift, replayed: same file, same
    // seed, same digest — the determinism promise the CI corpus gate
    // (two back-to-back runner executions) enforces on every commit.
    prop("fault scenario replay determinism", 3, |rng, _| {
        let seed = rng.below(1 << 16);
        let rate = rng.range(1.0, 3.0);
        let text = format!(
            r#"
            name = "faulted"
            seed = {seed}
            dynamic = 1

            [[shard]]
            preset = "mach1"
            count = 2

            [[arrivals]]
            process = "poisson"
            class = "standard"
            rate_rps = {rate}
            count = 8
            menu = "16000*2, 20000*2"

            [[fault]]
            kind = "slow"
            at = 0.5
            shard = 0
            factor = 0.5

            [[fault]]
            kind = "crash"
            at = 1.0
            shard = 1

            [[fault]]
            kind = "restart"
            at = 4.0
            shard = 1
            "#
        );
        let sc: Scenario = text.parse().expect("scenario parses");
        let a = sc.run();
        let b = sc.run();
        assert_eq!(a, b, "fault-laden replay must produce identical reports");
        assert_eq!(digest(&a), digest(&b), "and identical digests");
        // Every arrival is still accounted for exactly once.
        assert_eq!(a.served.len(), 8);
        let mut ids: Vec<u64> = a.served.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 8, "no request may be duplicated by a crash");
    });
}

#[test]
fn prop_wallclock_driver_matches_virtual_decisions() {
    use poas::service::driver::DriverKind;
    use poas::service::scenario::Scenario;

    // The wall-clock driver mirrors the deterministic core onto real
    // worker threads; it must not perturb a single scheduling decision.
    // Replay random scenarios — fault-free and faulted — through both
    // drivers and demand identical admission verdicts, routed shards
    // and execution modes for every request.
    prop("wallclock matches virtual decisions", 4, |rng, case| {
        let seed = rng.below(1 << 16);
        let rate = rng.range(20.0, 80.0);
        let count = 8 + rng.below(17);
        let shards = 1 + rng.below(3);
        let faults = if case % 2 == 1 && shards > 1 {
            r#"
            [[fault]]
            kind = "crash"
            at = 0.05
            shard = 0

            [[fault]]
            kind = "restart"
            at = 0.4
            shard = 0

            [[fault]]
            kind = "join"
            at = 0.1
            preset = "mach2"

            [[fault]]
            kind = "drain"
            at = 0.3
            shard = 1
            "#
        } else {
            ""
        };
        let text = format!(
            r#"
            name = "driver_equiv"
            seed = {seed}
            work_stealing = 1

            [[shard]]
            preset = "mach1"
            count = {shards}

            [[arrivals]]
            process = "poisson"
            class = "standard"
            rate_rps = {rate}
            count = {count}
            menu = "128, 256*2, 512x256x128"

            [[arrivals]]
            process = "poisson"
            class = "interactive"
            rate_rps = 10.0
            count = 4
            deadline_s = 30.0
            menu = "256*2"
            {faults}
            "#
        );
        let mut sc: Scenario = text.parse().expect("scenario parses");
        assert_eq!(sc.driver, DriverKind::Virtual);
        let virt = sc.run();
        sc.driver = DriverKind::WallClock;
        let wall = sc.run();

        assert_eq!(
            virt.served.len(),
            wall.served.len(),
            "drivers disagree on how many requests completed"
        );
        let key = |r: &poas::service::ServedRequest| (r.id, r.mode, r.shard);
        let mut a: Vec<_> = virt.served.iter().map(key).collect();
        let mut b: Vec<_> = wall.served.iter().map(key).collect();
        a.sort_by_key(|t| t.0);
        b.sort_by_key(|t| t.0);
        assert_eq!(a, b, "per-request decisions drifted across drivers");
    });
}

// ---------------------------------------------------------------------
// Elastic membership: drain conservation, replay byte-identity
// ---------------------------------------------------------------------

#[test]
fn prop_graceful_drain_conserves_and_displaces_no_inflight() {
    use poas::config::presets;
    use poas::coordinator::Pipeline;
    use poas::service::{Cluster, ClusterOptions, PoissonArrivals};

    // Profile once; each case clones the pipelines so both runs of a
    // case start from identical installation state.
    let pipes: Vec<Pipeline> = (0..3u64)
        .map(|i| Pipeline::for_simulated_machine(&presets::mach2(), 130 + i))
        .collect();
    let menu = vec![(GemmSize::square(16_000), 2), (GemmSize::square(12_000), 2)];

    prop("graceful drain conservation", 5, |rng, _| {
        let rate = rng.range(0.5, 3.0);
        let seed = rng.below(1 << 20);
        let victim = rng.below(3) as usize;
        let drain_at = rng.range(0.1, 2.0);
        let n = 10;
        let trace = PoissonArrivals::new(rate, menu.clone(), seed).trace(n);
        let run = || {
            let mut cluster = Cluster::from_pipelines(
                pipes.clone(),
                ClusterOptions {
                    work_stealing: true,
                    ..Default::default()
                },
            );
            cluster.inject_drain(drain_at, victim);
            cluster.submit_trace(&trace);
            cluster.run_to_completion()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "drain replay must be identical");
        // Conservation: one record per arrival — served, denied and
        // rejected together, nothing lost, nothing duplicated.
        assert_eq!(a.served.len(), n);
        let mut ids: Vec<u64> = a.served.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "a drain may not lose or duplicate requests");
        assert_eq!(
            a.denied,
            a.served.iter().filter(|r| r.mode.is_denied()).count()
        );
        assert_eq!(
            a.rejected,
            a.served.iter().filter(|r| r.mode.is_rejected()).count()
        );
        // Zero in-flight displacement: anything that executed on the
        // drained shard was dispatched strictly before the drain fired
        // (the drain is injected first, so it wins same-instant ties).
        for r in &a.served {
            if r.shard == Some(victim) {
                assert!(
                    r.start < drain_at,
                    "request {} dispatched on the drained shard at {}",
                    r.id,
                    r.start
                );
            }
        }
        // Billing reconciles: the drained shard's span is closed, every
        // span fits the session, and the report sums them.
        let sum: f64 = a.shards.iter().map(|s| s.provisioned_s).sum();
        assert!((a.machine_seconds - sum).abs() < 1e-9);
        assert!(a.machine_seconds <= 3.0 * a.makespan + 1e-9);
    });
}

#[test]
fn prop_elastic_membership_replay_is_byte_identical() {
    use poas::config::presets;
    use poas::coordinator::Pipeline;
    use poas::service::scenario::digest;
    use poas::service::{
        AutoscalerPolicy, Cluster, ClusterOptions, PoissonArrivals, RoutePolicy,
    };

    // Two static shards under sampled routing (the rejection-sampling
    // path stays live as the membership grows), plus a scheduled join,
    // a scheduled drain and an autoscaler over a one-entry pool: the
    // full elastic machinery must replay to byte-identical reports.
    let pipes: Vec<Pipeline> = (0..2u64)
        .map(|i| Pipeline::for_simulated_machine(&presets::mach2(), 150 + i))
        .collect();
    let menu = vec![(GemmSize::square(16_000), 2), (GemmSize::square(12_000), 2)];

    prop("elastic membership replay", 3, |rng, _| {
        let rate = rng.range(1.0, 4.0);
        let seed = rng.below(1 << 20);
        let join_at = rng.range(0.1, 1.5);
        let drain_at = join_at + rng.range(0.5, 2.0);
        let n = 10;
        let trace = PoissonArrivals::new(rate, menu.clone(), seed).trace(n);
        let mut policy = AutoscalerPolicy::new(vec![presets::mach2()]);
        policy.eval_interval_s = rng.range(0.5, 1.5);
        let run = || {
            let mut cluster = Cluster::from_pipelines(
                pipes.clone(),
                ClusterOptions {
                    route: RoutePolicy::Sampled { d: 2 },
                    work_stealing: true,
                    autoscaler: Some(policy.clone()),
                    ..Default::default()
                },
            );
            cluster.inject_join(join_at, presets::mach1(), 160);
            cluster.inject_drain(drain_at, 0);
            cluster.submit_trace(&trace);
            cluster.run_to_completion()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "membership replay must be identical");
        assert_eq!(
            format!("{a:?}"),
            format!("{b:?}"),
            "membership replay must be byte-identical"
        );
        assert_eq!(digest(&a), digest(&b), "and digest-deterministic");
        // Conservation across join + drain + autoscaler.
        assert_eq!(a.served.len(), n);
        let mut ids: Vec<u64> = a.served.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n);
        // The scheduled join materialized as shard 2, billed only from
        // its provision time.
        assert!(a.shards.len() >= 3, "the join must add a shard");
        assert!(a.shards[2].provisioned_s <= a.shards[1].provisioned_s + 1e-9);
        let sum: f64 = a.shards.iter().map(|s| s.provisioned_s).sum();
        assert!((a.machine_seconds - sum).abs() < 1e-9);
    });
}

// ---------------------------------------------------------------------
// Sampled routing: exactness at full coverage, determinism under faults
// ---------------------------------------------------------------------

#[test]
fn prop_sampled_router_with_full_coverage_equals_full_scan() {
    use poas::config::presets;
    use poas::coordinator::Pipeline;
    use poas::service::{Cluster, ClusterOptions, PoissonArrivals, RoutePolicy};

    // Profile the three distinct machines once; each case clones the
    // pipelines so every run starts from identical installation state.
    let pipes: Vec<Pipeline> = presets::hetero_mix()
        .iter()
        .enumerate()
        .map(|(i, cfg)| Pipeline::for_simulated_machine(cfg, 90 + i as u64))
        .collect();
    let menu = vec![
        (GemmSize::square(16_000), 2),
        (GemmSize::square(20_000), 2),
        (GemmSize::square(400), 2),
    ];

    prop("sampled d >= shards == full scan", 5, |rng, _| {
        let rate = rng.range(0.2, 3.0);
        let seed = rng.below(1 << 20);
        let stealing = rng.below(2) == 0;
        // d at or above the live shard count: the sampled router's
        // exact path must reproduce the full scan bit for bit — same
        // routing, same stealing, same report — on a heterogeneous
        // cluster where a wrong pick would be visible.
        let d = 3 + rng.below(4) as usize;
        let trace = PoissonArrivals::new(rate, menu.clone(), seed).trace(8);
        let run = |route: RoutePolicy| {
            let mut cluster = Cluster::from_pipelines(
                pipes.clone(),
                ClusterOptions {
                    route,
                    work_stealing: stealing,
                    ..Default::default()
                },
            );
            cluster.submit_trace(&trace);
            cluster.run_to_completion()
        };
        let full = run(RoutePolicy::Full);
        let sampled = run(RoutePolicy::Sampled { d });
        assert_eq!(full, sampled);
        assert_eq!(
            format!("{full:?}"),
            format!("{sampled:?}"),
            "d >= shards must be byte-identical to the full scan"
        );
    });
}

#[test]
fn prop_sampled_router_replay_is_deterministic_under_faults() {
    use poas::config::presets;
    use poas::coordinator::Pipeline;
    use poas::service::scenario::digest;
    use poas::service::{Cluster, ClusterOptions, PoissonArrivals, RoutePolicy};

    // Four same-machine shards with independent profiling seeds; the
    // sampled router (d below the shard count, so the rejection-sampling
    // path is live) plus a crash and a restart must still replay to an
    // identical report and digest.
    let pipes: Vec<Pipeline> = (0..4u64)
        .map(|i| Pipeline::for_simulated_machine(&presets::mach2(), 110 + i))
        .collect();
    let menu = vec![(GemmSize::square(16_000), 2), (GemmSize::square(400), 2)];

    prop("sampled replay under faults", 4, |rng, _| {
        let rate = rng.range(0.5, 3.0);
        let seed = rng.below(1 << 20);
        let victim = rng.below(4) as usize;
        let crash_at = rng.range(0.2, 2.0);
        let restart_at = crash_at + rng.range(0.5, 3.0);
        let trace = PoissonArrivals::new(rate, menu.clone(), seed).trace(10);
        let run = || {
            let mut cluster = Cluster::from_pipelines(
                pipes.clone(),
                ClusterOptions {
                    route: RoutePolicy::Sampled { d: 2 },
                    work_stealing: true,
                    ..Default::default()
                },
            );
            cluster.inject_crash(crash_at, victim);
            cluster.inject_restart(restart_at, victim);
            cluster.submit_trace(&trace);
            cluster.run_to_completion()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "sampled replay with crash/restart must be identical");
        assert_eq!(digest(&a), digest(&b), "and digest-deterministic");
        // Every arrival is accounted for exactly once despite the fault.
        assert_eq!(a.served.len(), 10);
        let mut ids: Vec<u64> = a.served.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 10, "no request may be duplicated by the crash");
    });
}
