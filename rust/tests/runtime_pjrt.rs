//! PJRT runtime integration tests — require `make artifacts` first.
//!
//! These close the three-layer loop from the Rust side: load the
//! HLO-text artifacts lowered from the Pallas kernels, execute them
//! through the PJRT CPU client, and check numerics against the host
//! reference. The full co-execution (threads + assembly + verification)
//! is covered at the end.
//!
//! The artifacts are an environment-provided build product, not part of
//! the checkout, so every test here *skips* (with a message on stderr)
//! when they are absent instead of failing the tier-1 gate. Set
//! `POAS_REQUIRE_ARTIFACTS=1` to turn a missing environment into a hard
//! failure (e.g. on a CI runner that just built them).

use poas::coordinator::PjrtCoordinator;
use poas::rng::Rng;
use poas::runtime::{ArtifactManifest, Runtime};
use poas::workload::Matrix;
use std::path::PathBuf;

fn artifact_dir() -> Option<PathBuf> {
    let required = std::env::var_os("POAS_REQUIRE_ARTIFACTS").is_some();
    if cfg!(not(feature = "pjrt")) {
        // The offline build links the in-tree PJRT stub: Runtime::new
        // can never succeed, artifacts or not.
        if required {
            panic!(
                "POAS_REQUIRE_ARTIFACTS is set but this build has no PJRT \
                 backend — enable the `pjrt` feature (and the `xla` \
                 dependency; see rust/src/runtime/pjrt_stub.rs)"
            );
        }
        eprintln!(
            "skipping PJRT test: built without the `pjrt` feature (stub runtime; \
             see rust/src/runtime/pjrt_stub.rs)"
        );
        return None;
    }
    let dir = ArtifactManifest::default_dir();
    if dir.join("manifest.txt").exists() {
        return Some(dir);
    }
    if required {
        panic!(
            "artifacts missing in {} — run `make artifacts` \
             (POAS_REQUIRE_ARTIFACTS is set, so this is fatal)",
            dir.display()
        );
    }
    eprintln!(
        "skipping PJRT test: artifacts missing in {} — run `make artifacts` to enable",
        dir.display()
    );
    None
}

#[test]
fn manifest_has_full_menu() {
    let Some(dir) = artifact_dir() else { return };
    let m = ArtifactManifest::load(&dir).unwrap();
    for kind in ["f32", "bf16", "acc_f32", "acc_bf16"] {
        let menu = m.tile_menu(kind);
        assert!(
            menu.contains(&64) && menu.contains(&128) && menu.contains(&256),
            "{kind}: menu {menu:?}"
        );
    }
}

#[test]
fn f32_tile_matches_host_reference() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(1);
    let a = Matrix::random(64, 64, &mut rng);
    let b = Matrix::random(64, 64, &mut rng);
    let c = rt.run_tile("f32", 64, &a, &b).unwrap();
    let want = a.matmul(&b);
    assert!(
        c.max_abs_diff(&want) < 1e-3,
        "diff {}",
        c.max_abs_diff(&want)
    );
}

#[test]
fn bf16_tile_close_to_f32_reference() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(2);
    let a = Matrix::random(64, 64, &mut rng);
    let b = Matrix::random(64, 64, &mut rng);
    let c = rt.run_tile("bf16", 64, &a, &b).unwrap();
    let want = a.matmul(&b);
    // bf16 multiply: ~2-3 decimal digits.
    assert!(c.rel_frob_diff(&want) < 2e-2, "diff {}", c.rel_frob_diff(&want));
    // ... but clearly not garbage.
    assert!(c.rel_frob_diff(&want) > 0.0);
}

#[test]
fn acc_tile_accumulates() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(3);
    let a = Matrix::random(64, 64, &mut rng);
    let b = Matrix::random(64, 64, &mut rng);
    let c0 = Matrix::random(64, 64, &mut rng);
    let c = rt.run_tile_acc("f32", 64, &a, &b, &c0).unwrap();
    let mut want = a.matmul(&b);
    want.add_block(0, 0, 64, 64, &c0);
    assert!(c.max_abs_diff(&want) < 1e-3);
}

#[test]
fn general_gemm_tiles_pad_and_accumulate() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(4);
    // Not tile-aligned in any dimension; forces padding + k-chunks.
    let a = Matrix::random(100, 150, &mut rng);
    let b = Matrix::random(150, 70, &mut rng);
    let c = rt.run_gemm("f32", &a, &b).unwrap();
    let want = a.matmul(&b);
    assert!(c.max_abs_diff(&want) < 1e-2, "diff {}", c.max_abs_diff(&want));
}

#[test]
fn executable_cache_reused() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(5);
    let a = Matrix::random(64, 64, &mut rng);
    let b = Matrix::random(64, 64, &mut rng);
    rt.run_tile("f32", 64, &a, &b).unwrap();
    let compiles_after_first = rt.compiles;
    for _ in 0..5 {
        rt.run_tile("f32", 64, &a, &b).unwrap();
    }
    assert_eq!(rt.compiles, compiles_after_first, "cache miss on re-run");
    assert!(rt.executions >= 6);
}

#[test]
fn warmup_compiles_menu() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let n = rt.warmup("f32").unwrap();
    assert!(n >= 3);
    assert_eq!(rt.compiles, n);
}

#[test]
fn run_tile_shape_validation() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let a = Matrix::zeros(32, 64);
    let b = Matrix::zeros(64, 64);
    assert!(rt.run_tile("f32", 64, &a, &b).is_err());
    assert!(rt
        .run_gemm("f32", &Matrix::zeros(8, 9), &Matrix::zeros(8, 8))
        .is_err());
}

#[test]
fn e2e_coexecution_verified() {
    // The end-to-end driver: profile the PJRT executables, POAS-plan a
    // real GEMM, co-execute on three worker threads, assemble, verify.
    let Some(dir) = artifact_dir() else { return };
    let coord = PjrtCoordinator::new(&dir, None).unwrap();
    let mut rng = Rng::new(6);
    let (m, n, k) = (192, 128, 160);
    let a = Matrix::random(m, n * 0 + k, &mut rng); // m x k
    let b = Matrix::random(k, n, &mut rng);
    let run = coord.run(&a, &b, true).unwrap();
    // All rows computed by someone.
    let rows: u64 = run.devices.iter().map(|d| d.rows).sum();
    assert_eq!(rows, m as u64);
    // Numerics: mixed precision (bf16 band) bounded error.
    let err = run.verify_rel_err.unwrap();
    assert!(err < 2e-2, "verification error {err}");
    assert!(run.makespan_s > 0.0);
    // The plan used the same POAS machinery (priorities assigned).
    assert_eq!(run.plan.priorities.len(), 3);
}
