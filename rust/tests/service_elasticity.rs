//! Elastic membership on the serving cluster: scale-out joins,
//! graceful drains and the autoscaler policy (PR 8 tentpole).
//!
//! The contract under test, against the crash path of
//! `service_faults.rs`: a *graceful* drain displaces zero in-flight
//! work — the running execution finishes on the leaving shard and only
//! its queue redistributes through front-end admission — while a join
//! inserts a freshly profiled shard whose machine-seconds meter starts
//! at provision time. The companion replay/conservation properties
//! live in `prop_invariants.rs`.

use poas::config::presets;
use poas::service::{AutoscalerPolicy, Cluster, GemmRequest, QosClass};
use poas::workload::GemmSize;

fn heavy() -> GemmSize {
    GemmSize::square(16_000)
}

/// Virtual seconds one heavy request takes on an idle mach2 shard —
/// the service-time unit the elasticity loads are phrased in.
fn unit() -> f64 {
    let mut c = Cluster::builder().machine(&presets::mach2()).seed(7).build();
    c.submit(heavy(), 2);
    c.run_to_completion().makespan
}

// ---------------------------------------------------------------------
// Graceful drain: zero in-flight displacement
// ---------------------------------------------------------------------

#[test]
fn drain_finishes_inflight_on_the_leaving_shard_and_requeues_only_its_queue() {
    // Two identical shards, six heavy requests at t = 0 — routing
    // splits them three and three, each shard dispatching one
    // immediately — then shard 1 drains long before anything can
    // finish. The in-flight execution must complete *on shard 1*; only
    // the queued remainder redistributes.
    let mut c = Cluster::builder().replicas(&presets::mach1(), 2).seed(9).build();
    for _ in 0..6 {
        c.submit(heavy(), 2);
    }
    c.inject_drain(0.01, 1);
    let report = c.run_to_completion();

    // Exactly once each: nothing lost, nothing duplicated.
    assert_eq!(report.served.len(), 6);
    let mut ids: Vec<u64> = report.served.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 6);

    // The drain displaced shard 1's queue — and only its queue. The
    // in-flight dispatch survives: exactly one record finishes on the
    // leaving shard, dispatched before the drain fired.
    let on_drained: Vec<_> = report
        .served
        .iter()
        .filter(|r| r.shard == Some(1))
        .collect();
    assert_eq!(
        on_drained.len(),
        1,
        "exactly the in-flight request finishes on the draining shard"
    );
    assert!(on_drained[0].start < 0.01, "it was dispatched pre-drain");
    assert!(!on_drained[0].mode.is_unserved());
    assert_eq!(report.shards[1].served_by_class.iter().sum::<usize>(), 1);
    assert_eq!(report.requeued, 2, "the two queued requests redistribute");
    assert_eq!(report.shards[1].requeued, 2);
    assert_eq!(report.shards[0].requeued, 0);
    for r in &report.served {
        assert!(!r.mode.is_unserved());
        assert_eq!(r.arrival, 0.0, "requeue keeps the original arrival");
        if r.shard != Some(1) {
            assert_eq!(r.shard, Some(0));
        }
    }

    // Billing: the drained shard retires when its in-flight execution
    // ends, so its span is shorter than the survivor's full session.
    assert!(report.shards[1].provisioned_s < report.shards[0].provisioned_s);
    let sum: f64 = report.shards.iter().map(|s| s.provisioned_s).sum();
    assert!((report.machine_seconds - sum).abs() < 1e-9);
    let util = report.utilization();
    assert!(util > 0.0 && util <= 1.0 + 1e-9, "utilization {util}");
}

#[test]
fn drain_then_restart_revives_the_shard_and_bills_both_spans() {
    // Shard 1 drains at t = 0.01 (its queue redistributes), comes back
    // mid-run, and serves again: the machine-seconds meter folds the
    // first span and reopens at the restart, so the revived shard is
    // never billed for the gap it sat retired.
    let u = unit();
    let mut c = Cluster::builder().replicas(&presets::mach1(), 2).seed(9).build();
    for _ in 0..4 {
        c.submit(heavy(), 2);
    }
    c.inject_drain(0.01, 1);
    let back_at = 6.0 * u;
    c.inject_restart(back_at, 1);
    for i in 0..4 {
        c.submit_request_at(back_at, GemmRequest::new(100 + i, heavy(), 2));
    }
    let report = c.run_to_completion();

    assert_eq!(report.served.len(), 8);
    assert!(
        report
            .served
            .iter()
            .any(|r| r.shard == Some(1) && r.start >= back_at),
        "the revived shard must serve again"
    );
    // The gap is not billed: shard 1's two spans are both shorter than
    // the wall-clock session, and the sum still reconciles.
    assert!(report.shards[1].provisioned_s < report.shards[0].provisioned_s);
    let sum: f64 = report.shards.iter().map(|s| s.provisioned_s).sum();
    assert!((report.machine_seconds - sum).abs() < 1e-9);
}

#[test]
fn draining_an_idle_shard_retires_it_immediately() {
    let mut c = Cluster::builder().replicas(&presets::mach1(), 2).seed(11).build();
    c.inject_drain(0.5, 1);
    c.submit_request_at(1.0, GemmRequest::new(0, heavy(), 2));
    let report = c.run_to_completion();

    assert_eq!(report.served.len(), 1);
    assert_eq!(report.request(0).unwrap().shard, Some(0));
    assert_eq!(report.requeued, 0, "an idle drain displaces nothing");
    // The idle shard's bill stops at the drain instant.
    assert!((report.shards[1].provisioned_s - 0.5).abs() < 1e-9);
}

// ---------------------------------------------------------------------
// Scale-out joins
// ---------------------------------------------------------------------

#[test]
fn joined_shard_serves_and_is_billed_from_provision_time() {
    // One shard takes a burst; a second machine joins mid-backlog and
    // picks up later arrivals (or steals queued work). Its bill starts
    // at the join, not at t = 0.
    let u = unit();
    let mut c = Cluster::builder().machine(&presets::mach2()).seed(13).build();
    for _ in 0..4 {
        c.submit(heavy(), 2);
    }
    let join_at = 0.5 * u;
    c.inject_join(join_at, presets::mach2(), 77);
    for i in 0..4 {
        c.submit_request_at(join_at + 0.1 * u, GemmRequest::new(100 + i, heavy(), 2));
    }
    let report = c.run_to_completion();

    assert_eq!(report.served.len(), 8);
    assert_eq!(report.shards.len(), 2, "the join adds a shard to the report");
    assert!(
        report.shards[1].dispatches > 0,
        "the joined shard must take work"
    );
    for r in &report.served {
        assert!(!r.mode.is_unserved());
        if r.shard == Some(1) {
            assert!(r.start >= join_at, "nothing runs on a shard before it joins");
        }
    }
    // Billed from provision time: shorter span than the founding shard,
    // and the total reconciles.
    assert!(report.shards[1].provisioned_s < report.shards[0].provisioned_s);
    let sum: f64 = report.shards.iter().map(|s| s.provisioned_s).sum();
    assert!((report.machine_seconds - sum).abs() < 1e-9);
}

#[test]
fn join_ends_a_total_outage_like_a_restart() {
    // The only shard crashes with work parked at the front door; a new
    // machine joining must re-admit the parked arrivals the way a
    // restart does.
    let mut c = Cluster::builder().machine(&presets::mach1()).seed(17).build();
    c.inject_crash(0.0, 0);
    c.submit_request_at(0.1, GemmRequest::new(0, heavy(), 2));
    c.inject_join(1.0, presets::mach1(), 99);
    let report = c.run_to_completion();

    assert_eq!(report.served.len(), 1);
    let r = report.request(0).unwrap();
    assert!(!r.mode.is_unserved());
    assert_eq!(r.shard, Some(1), "the parked request runs on the joiner");
    assert!(r.start >= 1.0);
    assert_eq!(r.arrival, 0.1, "parking keeps the original arrival");
}

// ---------------------------------------------------------------------
// Autoscaler: flash crowd
// ---------------------------------------------------------------------

#[test]
fn autoscaler_rides_a_flash_crowd_without_deadline_loss() {
    // Twelve SLO-bound requests arrive every quarter-unit — far beyond
    // one shard's capacity, comfortable for three. Three builds:
    //
    // * `base`: one static shard — admission must start denying SLOs
    //   once the predicted sojourn overflows the budget;
    // * `autoscaled`: the same shard plus a two-entry pool — pressure
    //   (and the deadline-risk signal) pulls capacity in while the
    //   crowd builds;
    // * `static3`: three always-on shards — the overprovisioned
    //   reference.
    //
    // The autoscaled build must match the overprovisioned deadline
    // outcome (no denials, same hit rate within a point) at a smaller
    // machine-seconds bill than three always-on shards.
    let u = unit();
    let deadline = 4.0 * u;
    let submit_crowd = |c: &mut Cluster| {
        for i in 0..12u64 {
            c.submit_request_at(
                0.25 * u * i as f64,
                GemmRequest::new(i, heavy(), 2)
                    .with_class(QosClass::Interactive)
                    .with_deadline(deadline),
            );
        }
    };
    let pool_policy = || {
        let mut p = AutoscalerPolicy::new(vec![presets::mach2(), presets::mach2()]);
        p.eval_interval_s = 0.5 * u;
        p.scale_up_pressure_s = 1.5 * u;
        p.scale_down_pressure_s = 0.25 * u;
        p.scale_down_evals = 2;
        p
    };

    let mut base = Cluster::builder().machine(&presets::mach2()).seed(19).build();
    submit_crowd(&mut base);
    let base = base.run_to_completion();

    let mut autoscaled = Cluster::builder()
        .machine(&presets::mach2())
        .seed(19)
        .autoscaler(pool_policy())
        .build();
    submit_crowd(&mut autoscaled);
    let autoscaled = autoscaled.run_to_completion();

    let mut static3 = Cluster::builder().replicas(&presets::mach2(), 3).seed(19).build();
    submit_crowd(&mut static3);
    let static3 = static3.run_to_completion();

    // The single static shard drowns: deadline admission turns SLOs
    // away. The autoscaled cluster rides the crowd like the
    // overprovisioned one.
    assert!(base.denied > 0, "one shard must deny under the crowd");
    assert_eq!(static3.denied, 0, "three shards absorb it");
    assert!(
        autoscaled.denied < base.denied,
        "scaling out must shed denials: {} vs {}",
        autoscaled.denied,
        base.denied
    );
    assert!(
        autoscaled.shards.len() > 1,
        "the pool must actually provision"
    );
    assert!(
        autoscaled.deadline_hit_rate() >= static3.deadline_hit_rate() - 0.01,
        "autoscaled hit rate {} fell below the overprovisioned {}",
        autoscaled.deadline_hit_rate(),
        static3.deadline_hit_rate()
    );
    // And the bill: pool shards join late (and drain once the crowd
    // passes), so the autoscaled build pays fewer machine-seconds than
    // three always-on shards.
    assert!(
        autoscaled.machine_seconds < static3.machine_seconds,
        "autoscaled bill {} not below static {}",
        autoscaled.machine_seconds,
        static3.machine_seconds
    );
    // Conservation on all three builds.
    for r in [&base, &autoscaled, &static3] {
        assert_eq!(r.served.len(), 12);
        assert_eq!(
            r.denied,
            r.served.iter().filter(|s| s.mode.is_denied()).count()
        );
    }
}

#[test]
fn autoscaler_without_load_never_provisions() {
    // Two light requests on an idle cluster: pressure never crosses the
    // threshold, no denials — the pool must stay untouched and the run
    // must terminate (the evaluation event re-arms only while work
    // remains).
    let mut c = Cluster::builder()
        .machine(&presets::mach2())
        .seed(23)
        .autoscaler(AutoscalerPolicy::new(vec![presets::mach2()]))
        .build();
    c.submit(GemmSize::square(2_000), 1);
    c.submit_request_at(5.0, GemmRequest::new(1, GemmSize::square(2_000), 1));
    let report = c.run_to_completion();
    assert_eq!(report.served.len(), 2);
    assert_eq!(report.shards.len(), 1, "no pool shard may join idle");
}
