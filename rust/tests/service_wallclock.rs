//! Wall-clock driver smoke tests: bounded-channel backpressure, clean
//! shutdown, exactly-once completion accounting under crashes and
//! drains, and decision equivalence with the virtual driver.
//!
//! Wall *timings* are non-deterministic by nature, so these tests
//! assert only on the core's deterministic report and on the driver's
//! conservation counters (`forwarded == completed + dropped`,
//! `lost == duplicated == 0`) — never on elapsed seconds.

use poas::config::presets;
use poas::service::request::ExecMode;
use poas::service::scenario::{digest, Scenario};
use poas::service::{Cluster, QosClass, WallClockDriver, WallClockOptions};
use poas::workload::GemmSize;

fn cluster(shards: usize, seed: u64) -> Cluster {
    Cluster::builder()
        .replicas(&presets::mach2(), shards)
        .seed(seed)
        .build()
}

/// Submit a deterministic mixed burst and return how many requests it
/// placed.
fn submit_burst(c: &mut Cluster, n: usize) -> usize {
    for i in 0..n {
        let size = match i % 3 {
            0 => GemmSize::square(12_000),
            1 => GemmSize::square(16_000),
            _ => GemmSize::new(14_000, 10_000, 12_000),
        };
        let (class, deadline) = match i % 4 {
            0 => (QosClass::Interactive, Some(120.0)),
            1 => (QosClass::Batch, None),
            _ => (QosClass::Standard, None),
        };
        c.submit_qos(size, 1 + (i % 2) as u32, class, deadline);
    }
    n
}

#[test]
fn burst_completes_exactly_once() {
    let mut c = cluster(4, 11);
    let n = submit_burst(&mut c, 32);
    let mut driver = WallClockDriver::new(c);
    let (report, stats) = driver.run_measured();
    assert_eq!(report.served.len(), n);
    assert!(stats.forwarded > 0, "burst must really dispatch");
    assert_eq!(stats.completed, stats.forwarded);
    assert_eq!(stats.dropped, 0);
    assert_eq!(stats.lost, 0, "every forwarded unit needs a terminal event");
    assert_eq!(stats.duplicated, 0);
    // One wall sojourn per *executed* record (denied/rejected requests
    // never reach a worker).
    let executed = report.served.iter().filter(|r| !r.mode.is_unserved()).count();
    assert_eq!(stats.sojourns_s.len(), executed);
    assert!(stats.p99_sojourn_s() >= 0.0);
}

#[test]
fn decisions_match_the_virtual_driver() {
    let build = |seed| {
        let mut c = cluster(3, seed);
        submit_burst(&mut c, 24);
        c
    };
    let virt = build(5).run_to_completion();
    let (wall, stats) = WallClockDriver::new(build(5)).run_measured();
    assert_eq!(stats.lost, 0);
    assert_eq!(virt.served.len(), wall.served.len());
    let key = |r: &poas::service::ServedRequest| (r.id, r.mode, r.shard);
    let mut a: Vec<_> = virt.served.iter().map(key).collect();
    let mut b: Vec<_> = wall.served.iter().map(key).collect();
    a.sort_by_key(|t| t.0);
    b.sort_by_key(|t| t.0);
    assert_eq!(a, b, "admission/routing decisions must match across drivers");
    assert!(a.iter().any(|(_, mode, _)| *mode != ExecMode::Denied));
}

#[test]
fn tight_channel_backpressure_still_drains() {
    let mut c = cluster(2, 3);
    let n = submit_burst(&mut c, 12);
    // Capacity 1 with real (scaled) execution: the core's forwarding
    // loop must block on the full channel and resume, not deadlock or
    // lose units.
    let opts = WallClockOptions {
        time_scale: 1e-3,
        channel_capacity: 1,
    };
    let (report, stats) = WallClockDriver::with_options(c, opts).run_measured();
    assert_eq!(report.served.len(), n);
    assert_eq!(stats.completed, stats.forwarded);
    assert_eq!(stats.lost, 0);
    assert_eq!(stats.duplicated, 0);
}

#[test]
fn crash_and_drain_conserve_every_unit() {
    let mut c = cluster(3, 9);
    let n = submit_burst(&mut c, 40);
    c.inject_crash(0.02, 0);
    c.inject_restart(0.5, 0);
    c.inject_drain(0.3, 1);
    let opts = WallClockOptions {
        time_scale: 1e-4,
        channel_capacity: 1,
    };
    let (report, stats) = WallClockDriver::with_options(c, opts).run_measured();
    // The core conserves requests (every submission gets exactly one
    // record) and the mirror conserves units: a crashed shard's stale
    // dispatches are dropped, never lost, and nothing settles twice.
    assert_eq!(report.served.len(), n);
    assert_eq!(stats.forwarded, stats.completed + stats.dropped);
    assert_eq!(stats.lost, 0);
    assert_eq!(stats.duplicated, 0);
}

#[test]
fn scenario_digest_is_driver_independent() {
    let base = r#"
        name = "driver_equiv"
        seed = 21
        [[shard]]
        preset = "mach2"
        count = 2
        [[arrivals]]
        process = "poisson"
        rate_rps = 4.0
        count = 8
        menu = "12000*2, 10000x14000x8000"
        [[fault]]
        kind = "crash"
        at = 0.4
        shard = 1
        [[fault]]
        kind = "restart"
        at = 2.0
        shard = 1
    "#;
    let virt: Scenario = base.parse().expect("parse virtual");
    let wall: Scenario = format!("driver = \"wallclock\"\n{base}")
        .parse()
        .expect("parse wallclock");
    assert_eq!(
        digest(&virt.run()),
        digest(&wall.run()),
        "the report is the core's deterministic accounting under both drivers"
    );
}
