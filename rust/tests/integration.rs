//! Cross-module integration tests on the simulated testbeds.
//!
//! These drive the full POAS pipeline (profile → optimize → adapt →
//! schedule → execute) on mach1/mach2 and check the relationships the
//! paper's evaluation rests on. No artifacts required (sim only).

use poas::baselines;
use poas::config::presets;
use poas::coordinator::Pipeline;
use poas::metrics::{prediction_error_pct, rmse};
use poas::optimize::problem::BusModel;
use poas::predict::{profile, PerfModel, ProfileOptions};
use poas::schedule::{build_plan, static_sched::rules_from_config, PlanOptions};
use poas::sim::SimMachine;
use poas::workload::{paper_inputs, GemmSize};

#[test]
fn full_pipeline_both_machines() {
    for cfg in [presets::mach1(), presets::mach2()] {
        let mut p = Pipeline::for_simulated_machine(&cfg, 0);
        let r = p.run_sim(GemmSize::square(30_000), 5);
        assert!(r.makespan > 0.0, "{}", cfg.name);
        assert_eq!(r.plan.active_devices(), 3, "{}", cfg.name);
        assert!(r.exec.bus_trace.is_serialized(), "{}", cfg.name);
    }
}

#[test]
fn prediction_error_is_paper_grade() {
    // Paper §5.2: "the prediction error is low (typically under 5%)".
    // Check the compute-time prediction for every device on both
    // machines at i1, tolerating the thermal drift the paper also saw
    // (mach1 outliers up to ~12%).
    for (cfg, tol) in [(presets::mach1(), 15.0), (presets::mach2(), 12.0)] {
        let mut p = Pipeline::for_simulated_machine(&cfg, 0);
        let size = GemmSize::square(30_000);
        let r = p.run_sim(size, 50);
        for (i, asg) in r.plan.assignments.iter().enumerate() {
            if asg.rows == 0 {
                continue;
            }
            let predicted = r.plan.predicted.compute_pred[i] * 50.0;
            let measured = r.exec.timelines[i].compute_s;
            let e = prediction_error_pct(measured, predicted).abs();
            assert!(
                e < tol,
                "{} dev{i}: compute error {e:.1}% (pred {predicted:.2}s meas {measured:.2}s)",
                cfg.name
            );
        }
    }
}

#[test]
fn copy_prediction_accurate() {
    let cfg = presets::mach2();
    let mut p = Pipeline::for_simulated_machine(&cfg, 1);
    let size = GemmSize::square(30_000);
    let r = p.run_sim(size, 50);
    for (i, asg) in r.plan.assignments.iter().enumerate() {
        if asg.rows == 0 || i == 0 {
            continue; // cpu: no copies
        }
        let predicted = r.plan.predicted.copy_pred[i] * 50.0;
        let measured = r.exec.timelines[i].h2d_s + r.exec.timelines[i].d2h_s;
        let e = prediction_error_pct(measured, predicted).abs();
        // Paper Table 4 mach2 memory errors: ~0-1.3%.
        assert!(e < 6.0, "dev{i}: copy error {e:.1}%");
    }
}

#[test]
fn speedup_over_standalone_xpu_in_paper_band() {
    // Table 7: hgemms vs XPU = 1.14-1.28x (mach1), 1.29-1.45x (mach2).
    let expectations = [(presets::mach1(), 1.05, 1.45), (presets::mach2(), 1.15, 1.75)];
    for (cfg, lo, hi) in expectations {
        let mut p = Pipeline::for_simulated_machine(&cfg, 0);
        let size = GemmSize::square(30_000);
        let reps = 20;
        let co = p.run_sim(size, reps).makespan;
        let xpu = baselines::standalone(&mut p.sim, 2, size, reps).makespan;
        let s = xpu / co;
        assert!(
            s > lo && s < hi,
            "{}: speedup vs XPU {s:.2} outside [{lo}, {hi}]",
            cfg.name
        );
    }
}

#[test]
fn speedup_ordering_cpu_gpu_xpu() {
    // Table 7 ordering: CPU speedup >> GPU speedup >> XPU speedup > 1.
    let cfg = presets::mach1();
    let mut p = Pipeline::for_simulated_machine(&cfg, 0);
    let size = GemmSize::square(30_000);
    let reps = 10;
    let co = p.run_sim(size, reps).makespan;
    let t_cpu = baselines::standalone(&mut p.sim, 0, size, reps).makespan;
    let t_gpu = baselines::standalone(&mut p.sim, 1, size, reps).makespan;
    let t_xpu = baselines::standalone(&mut p.sim, 2, size, reps).makespan;
    let (s_cpu, s_gpu, s_xpu) = (t_cpu / co, t_gpu / co, t_xpu / co);
    assert!(s_cpu > 50.0, "cpu speedup {s_cpu}");
    assert!(s_gpu > 3.0 && s_gpu < 15.0, "gpu speedup {s_gpu}");
    assert!(s_xpu > 1.0 && s_xpu < 2.0, "xpu speedup {s_xpu}");
    assert!(s_cpu > s_gpu && s_gpu > s_xpu);
}

#[test]
fn all_paper_inputs_schedulable() {
    let cfg = presets::mach2();
    let mut p = Pipeline::for_simulated_machine(&cfg, 2);
    for inp in paper_inputs() {
        let r = p.run_sim(inp.size, 2);
        assert!(r.makespan > 0.0, "{}", inp.id);
        let shares = r.plan.shares();
        assert!(shares[2] > 0.5, "{}: xpu share {}", inp.id, shares[2]);
        assert!(
            (shares.iter().sum::<f64>() - 1.0).abs() < 1e-9,
            "{}: shares do not sum to 1",
            inp.id
        );
    }
}

#[test]
fn profile_text_roundtrip_through_disk() {
    let cfg = presets::mach1();
    let mut sim = SimMachine::new(&cfg, 0);
    let model = profile(&mut sim, &ProfileOptions::default()).unwrap();
    let path = std::env::temp_dir().join(format!("poas-profile-{}.txt", std::process::id()));
    model.save(&path).unwrap();
    let loaded = PerfModel::load(&path).unwrap();
    assert_eq!(loaded.machine, model.machine);
    for (a, b) in loaded.devices.iter().zip(&model.devices) {
        assert!((a.a - b.a).abs() / b.a < 1e-10);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn exclusive_bus_model_underestimates_shared_reality() {
    // Scheduling with Eq. 4 as printed (exclusive links) on a shared-bus
    // machine must predict an equal-or-lower makespan than the shared
    // formulation — that is the error the paper's modification fixes.
    let cfg = presets::mach1();
    let mut sim = SimMachine::new(&cfg, 3);
    let model = profile(&mut sim, &ProfileOptions::default()).unwrap();
    let rules = rules_from_config(&cfg);
    let size = GemmSize::square(30_000);
    let shared = build_plan(
        &model,
        size,
        &rules,
        &PlanOptions {
            bus: BusModel::SharedPriority,
            ..Default::default()
        },
    )
    .unwrap();
    let exclusive = build_plan(
        &model,
        size,
        &rules,
        &PlanOptions {
            bus: BusModel::Exclusive,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(exclusive.predicted_makespan() <= shared.predicted_makespan() + 1e-9);
}

#[test]
fn rmse_across_inputs_is_low() {
    // Table 5 analogue: RMSE of compute prediction errors across inputs
    // stays in the paper's single-digit band.
    let cfg = presets::mach2();
    let mut p = Pipeline::for_simulated_machine(&cfg, 4);
    let mut errors = Vec::new();
    for inp in paper_inputs().iter().take(3) {
        let r = p.run_sim(inp.size, 10);
        for (i, asg) in r.plan.assignments.iter().enumerate() {
            if asg.rows == 0 {
                continue;
            }
            let predicted = r.plan.predicted.compute_pred[i] * 10.0;
            let measured = r.exec.timelines[i].compute_s;
            errors.push(prediction_error_pct(measured, predicted));
        }
    }
    let r = rmse(&errors);
    assert!(r < 12.0, "RMSE {r:.2}% too high");
}

#[test]
fn energy_pipeline_trades_time_for_joules() {
    use poas::optimize::energy::{DevicePower, EnergyProblem};
    let cfg = presets::mach1();
    let mut sim = SimMachine::new(&cfg, 5);
    let model = profile(&mut sim, &ProfileOptions::default()).unwrap();
    let size = GemmSize::square(30_000);
    let power: Vec<DevicePower> = cfg
        .devices
        .iter()
        .map(|d| DevicePower {
            active_w: d.active_w,
            idle_w: d.idle_w,
        })
        .collect();
    let time_t = poas::optimize::problem::SplitProblem {
        devices: model.model_inputs(),
        size,
        bus: BusModel::SharedPriority,
        row_integral: false,
    }
    .solve()
    .unwrap()
    .t_pred;

    let (sol_loose, e_loose) = EnergyProblem {
        devices: model.model_inputs(),
        power: power.clone(),
        size,
        bus: BusModel::SharedPriority,
        deadline_s: None,
    }
    .solve()
    .unwrap();
    let (sol_tight, e_tight) = EnergyProblem {
        devices: model.model_inputs(),
        power,
        size,
        bus: BusModel::SharedPriority,
        deadline_s: Some(time_t * 1.02),
    }
    .solve()
    .unwrap();
    assert!(e_tight >= e_loose - 1e-6);
    assert!(sol_loose.t_pred >= sol_tight.t_pred - 1e-9);
}

#[test]
fn dynamic_scheduler_tracks_thermal_drift_end_to_end() {
    let cfg = presets::mach1();
    let mut p = Pipeline::for_simulated_machine(&cfg, 6);
    let (results, dynsched) = p.run_sim_dynamic(GemmSize::square(30_000), 30, 5);
    assert_eq!(results.len(), 5);
    assert!(dynsched.replans >= 1, "expected at least one replan");
    // Later rounds should not be slower than the first round by more
    // than noise (the dynamic scheduler adapts).
    let first = results[0].makespan;
    let last = results.last().unwrap().makespan;
    assert!(last < first * 1.1, "first {first} last {last}");
}

#[test]
fn config_files_match_presets() {
    // configs/*.toml are generated from the presets; loading them back
    // must give identical machines (the CLI's --machine <file> path).
    use poas::config::MachineConfig;
    for (file, preset) in [
        ("configs/mach1.toml", presets::mach1()),
        ("configs/mach2.toml", presets::mach2()),
    ] {
        let path = std::path::Path::new(file);
        if !path.exists() {
            return; // repo checkout without generated configs
        }
        let loaded = MachineConfig::from_file(path).unwrap();
        assert_eq!(loaded, preset, "{file} drifted from the preset");
    }
}
